"""Regenerate the committed golden end-to-end fixture under
rust/tests/fixtures/ (checked by rust/tests/golden_e2e.rs).

The fixture is a tiny conv -> maxpool -> dwconv -> flatten -> dense
Bayesian-Bits model whose numerics are *exact by construction*, so the
expected serve outputs are computed here with plain integer arithmetic,
independent of the Rust implementation:

* weight grids use beta = 127.5 (signed 8-bit step = 255/255 = 1.0
  exactly in f32) and integer-valued weights, so quantization is the
  identity;
* activation grids use beta = 255.0 (unsigned 8-bit step = 1.0) and all
  intermediate activations are integers, so quantization is
  ``min(v, 255)``;
* every accumulator stays far below 2^24, so each f32 the engine
  produces is the exact integer computed here.

Any refactor of lowering/kernels/serving that perturbs a single code
path shows up as a bit-exact mismatch, not a tolerance drift.

Run from the repo root:  python3 python/tools/make_golden_fixture.py
"""

import json
import os
import random
import struct

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                   "tests", "fixtures")

MODEL = "golden_conv"
IN_H, IN_W, IN_C = 6, 6, 2
C1 = 4          # conv1 output channels (channel 2 pruned)
C1_KEPT = [0, 1, 3]
K = 3
FC_IN = 2 * 2 * C1
CLASSES = 3
W_BETA = 127.5  # signed 8-bit step = 2*127.5/255 = 1.0
A_BETA = 255.0  # unsigned 8-bit step = 255/255 = 1.0

OPEN, SHUT = 6.0, -6.0
CHAIN_8BIT = [OPEN, OPEN, SHUT, SHUT]  # z4, z8 open -> 8 bits


def conv_out_same(n, stride):
    return -(-n // stride)


def same_pads(n, k, stride):
    out = conv_out_same(n, stride)
    total = max((out - 1) * stride + k - n, 0)
    return total // 2


def act_codes(v):
    """Unsigned 8-bit activation grid at beta=255 on integer inputs."""
    assert v == int(v) and v >= 0, v
    return min(int(v), 255)


def conv2d(x, w, bias, in_h, in_w, in_c, cout, k, stride, groups,
           kept):
    """Integer conv, NHWC x, HWIO w, SAME padding; pruned channels get
    only their bias."""
    out_h, out_w = conv_out_same(in_h, stride), conv_out_same(in_w, stride)
    ph, pw = same_pads(in_h, k, stride), same_pads(in_w, k, stride)
    cg = in_c // groups
    cpg = cout // groups
    y = [[[bias[c] for c in range(cout)] for _ in range(out_w)]
         for _ in range(out_h)]
    for oh in range(out_h):
        for ow in range(out_w):
            for co in range(cout):
                if co not in kept:
                    continue
                g = co // cpg
                acc = 0
                for kh in range(k):
                    for kw in range(k):
                        ih = oh * stride + kh - ph
                        iw = ow * stride + kw - pw
                        if ih < 0 or iw < 0 or ih >= in_h or iw >= in_w:
                            continue
                        for ci in range(cg):
                            acc += (w[kh][kw][ci][co]
                                    * x[ih][iw][g * cg + ci])
                assert abs(acc) < 1 << 24
                y[oh][ow][co] += acc
    return y


def maxpool2(x, h, w, c):
    return [[[max(x[2 * oh][2 * ow][ch], x[2 * oh][2 * ow + 1][ch],
                  x[2 * oh + 1][2 * ow][ch], x[2 * oh + 1][2 * ow + 1][ch])
              for ch in range(c)]
             for ow in range(w // 2)]
            for oh in range(h // 2)]


def relu3(x):
    return [[[max(v, 0) for v in col] for col in row] for row in x]


def forward(flat_x, p):
    """flat_x: 72 ints NHWC. Returns the 3 integer logits."""
    x = [[[flat_x[(h * IN_W + w) * IN_C + c] for c in range(IN_C)]
          for w in range(IN_W)]
         for h in range(IN_H)]
    # conv1: quantize input, 3x3 SAME stride 1, relu
    q = [[[act_codes(v) for v in col] for col in row] for row in x]
    y = conv2d(q, p["conv1.w"], p["conv1.b"], IN_H, IN_W, IN_C, C1, K,
               1, 1, C1_KEPT)
    y = relu3(y)
    # maxpool 6x6 -> 3x3, then dwconv 3x3 SAME stride 2 -> 2x2, relu
    y = maxpool2(y, IN_H, IN_W, C1)
    q = [[[act_codes(v) for v in col] for col in row] for row in y]
    y = conv2d(q, p["dw.w"], p["dw.b"], 3, 3, C1, C1, K, 2, C1,
               list(range(C1)))
    y = relu3(y)
    # flatten NHWC (2x2x4 -> 16), dense to logits
    flat = [y[oh][ow][c]
            for oh in range(2) for ow in range(2) for c in range(C1)]
    q = [act_codes(v) for v in flat]
    logits = []
    for o in range(CLASSES):
        acc = sum(p["fc.w"][i][o] * q[i] for i in range(FC_IN))
        assert abs(acc) < 1 << 24
        logits.append(acc + p["fc.b"][o])
    return logits


def main():
    os.makedirs(OUT, exist_ok=True)
    rng = random.Random(1234)

    def ints(n, lo, hi):
        return [rng.randint(lo, hi) for _ in range(n)]

    # -- parameters (all integer-valued) ------------------------------
    conv1_w_flat = ints(K * K * IN_C * C1, -3, 3)
    conv1_w = [[[[conv1_w_flat[((kh * K + kw) * IN_C + ci) * C1 + co]
                  for co in range(C1)]
                 for ci in range(IN_C)]
                for kw in range(K)]
               for kh in range(K)]
    dw_w_flat = ints(K * K * 1 * C1, -3, 3)
    dw_w = [[[[dw_w_flat[((kh * K + kw) * 1 + ci) * C1 + co]
               for co in range(C1)]
              for ci in range(1)]
             for kw in range(K)]
            for kh in range(K)]
    fc_w_flat = ints(FC_IN * CLASSES, -3, 3)
    fc_w = [[fc_w_flat[i * CLASSES + o] for o in range(CLASSES)]
            for i in range(FC_IN)]
    conv1_b = ints(C1, -2, 2)
    dw_b = ints(C1, -2, 2)
    fc_b = ints(CLASSES, -2, 2)

    model = {
        "conv1.w": conv1_w, "conv1.b": conv1_b,
        "dw.w": dw_w, "dw.b": dw_b,
        "fc.w": fc_w, "fc.b": fc_b,
    }

    # -- flat parameter vector + manifest params table ----------------
    params = []
    params_json = []

    def param(name, shape, group, values):
        size = 1
        for d in shape:
            size *= d
        assert len(values) == size, name
        params_json.append({"name": name, "shape": list(shape),
                            "group": group, "offset": len(params),
                            "size": size})
        params.extend(float(v) for v in values)

    quant_json = []
    slot_off = [0]

    def quantizer(name, kind, signed, channels, macs, ch_phi):
        n_slots = channels + 4
        quant_json.append({
            "name": name, "kind": kind, "signed": signed,
            "channels": channels, "levels": [2, 4, 8, 16, 32],
            "offset": slot_off[0], "n_slots": n_slots,
            "consumer_macs": macs,
        })
        slot_off[0] += n_slots
        param(f"{name}.phi", [n_slots], "g", list(ch_phi) + CHAIN_8BIT)
        param(f"{name}.beta", [1], "s",
              [W_BETA if kind == "w" else A_BETA])

    conv1_macs = 6 * 6 * C1 * IN_C * K * K
    dw_macs = 2 * 2 * C1 * 1 * K * K
    fc_macs = FC_IN * CLASSES

    param("conv1.w", [K, K, IN_C, C1], "w", conv1_w_flat)
    quantizer("conv1.w", "w", True, C1, conv1_macs,
              [OPEN if c in C1_KEPT else SHUT for c in range(C1)])
    quantizer("conv1.in", "a", False, 1, conv1_macs, [SHUT])
    param("conv1.b", [C1], "w", conv1_b)
    param("dw.w", [K, K, 1, C1], "w", dw_w_flat)
    quantizer("dw.w", "w", True, C1, dw_macs, [OPEN] * C1)
    quantizer("dw.in", "a", False, 1, dw_macs, [SHUT])
    param("dw.b", [C1], "w", dw_b)
    param("fc.w", [FC_IN, CLASSES], "w", fc_w_flat)
    quantizer("fc.w", "w", True, CLASSES, fc_macs, [OPEN] * CLASSES)
    quantizer("fc.in", "a", False, 1, fc_macs, [SHUT])
    param("fc.b", [CLASSES], "w", fc_b)

    layers = [
        {"name": "conv1", "kind": "conv", "macs": conv1_macs,
         "cin": IN_C, "cout": C1, "weight_q": "conv1.w",
         "act_q": "conv1.in", "residual_input": False,
         "ksize": K, "stride": 1, "padding": "SAME", "groups": 1,
         "in_h": IN_H, "in_w": IN_W},
        {"name": "dw", "kind": "dwconv", "macs": dw_macs,
         "cin": C1, "cout": C1, "weight_q": "dw.w", "act_q": "dw.in",
         "residual_input": False,
         "ksize": K, "stride": 2, "padding": "SAME", "groups": C1,
         "in_h": 3, "in_w": 3},
        {"name": "fc", "kind": "dense", "macs": fc_macs,
         "cin": FC_IN, "cout": CLASSES, "weight_q": "fc.w",
         "act_q": "fc.in", "residual_input": False},
    ]

    manifest = {
        "name": MODEL, "engine": "bb", "preset": "small", "batch": 2,
        "n_params": len(params), "n_slots": slot_off[0],
        "input_shape": [IN_H, IN_W, IN_C], "num_classes": CLASSES,
        "levels": [2, 4, 8, 16, 32],
        "dataset": {"name": "mnist_like", "input": [IN_H, IN_W, IN_C],
                    "classes": CLASSES, "train": 8, "test": 4},
        "params": params_json, "quantizers": quant_json,
        "layers": layers, "lam_base": [1.0] * slot_off[0],
        "hlo_train": "t.hlo.txt", "hlo_eval": "e.hlo.txt",
        "init_file": "i.bin",
    }
    with open(os.path.join(OUT, f"{MODEL}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # -- v2 checkpoint (coordinator::checkpoint format) ---------------
    def section(b):
        return struct.pack("<Q", len(b)) + b

    zeros = [0.0] * len(params)
    blob = b"".join([
        section(b"BBCKPT2"),
        section(MODEL.encode()),
        section(b"0"),
        section(struct.pack(f"<{len(params)}f", *params)),
        section(struct.pack(f"<{len(zeros)}f", *zeros)),
        section(struct.pack(f"<{len(zeros)}f", *zeros)),
    ])
    with open(os.path.join(OUT, f"{MODEL}.ckpt"), "wb") as f:
        f.write(blob)

    # -- expected serve outputs ---------------------------------------
    inputs, logits = [], []
    for s in range(4):
        x = [(i * 7 + 3 * s + (i * i) % 5) % 13
             for i in range(IN_H * IN_W * IN_C)]
        inputs.append(x)
        logits.append(forward(x, model))
    expected = {
        "model": MODEL,
        "layers": [
            {"name": "conv1", "w_bits": 8, "kept": C1_KEPT},
            {"name": "dw", "w_bits": 8, "kept": list(range(C1))},
            {"name": "fc", "w_bits": 8, "kept": list(range(CLASSES))},
        ],
        "inputs": inputs,
        "logits": logits,
    }
    with open(os.path.join(OUT, f"{MODEL}_expected.json"), "w") as f:
        json.dump(expected, f, indent=1)
    print(f"wrote {OUT}: manifest ({len(params)} params, "
          f"{slot_off[0]} slots), ckpt ({len(blob)} bytes), "
          f"{len(inputs)} golden cases")
    for s, l in enumerate(logits):
        print(f"  case {s}: logits {l}")


if __name__ == "__main__":
    main()
