"""Train/eval step builders — the functions that get AOT-lowered to HLO.

One train-step executable per model covers every experiment mode in the
paper through its runtime inputs (see DESIGN.md §6):

* ``lock_mask`` / ``lock_val`` — per-gate-slot overrides: fixed-width
  baselines (w8a8, w4a4, ...), quantization-only (z2 locked 1),
  pruning-only (z4+ locked), frozen-gate fine-tuning (§4.2), and the
  FP32 reference (everything locked 1).
* ``det_flag`` — deterministic-gate ablation (App. A.3, Table 2):
  replaces the uniform noise with 0.5.
* ``lr_w / lr_g / lr_s`` — per-group Adam rates; post-training mode
  (§4.2.1) is ``lr_w = 0`` with gates-only (``lr_s = 0``) or
  gates+scales variants.
* ``lam`` — per-slot regularizer weights mu * lam_base (App. B.2.1).

Signature (all f32 unless noted):
  train(flat P, m P, v P, x B..., y B i32, seed i32, step, lr_w, lr_g,
        lr_s, lock_mask G, lock_val G, lam G, det_flag)
    -> (flat', m', v', loss_ce, correct, reg, probs G)
  eval(flat P, gates G, x, y) -> (loss_ce, correct)
"""

import jax
import jax.numpy as jnp

from . import layers as L
from . import optim
from .quant import gather_phi, sample_gates, gate_probs, chains


def build_train_step(spec, apply_fn, engine):
    is_dq = engine.kind == "dq"
    mask_w = jnp.asarray(spec.group_mask("w"))
    mask_g = jnp.asarray(spec.group_mask("g"))
    mask_s = jnp.asarray(spec.group_mask("s"))

    def train_step(flat, m, v, x, y, seed, step, lr_w, lr_g, lr_s,
                   lock_mask, lock_val, lam, det_flag):
        key = jax.random.PRNGKey(seed)
        u = jax.random.uniform(key, (spec.n_slots,), minval=1e-6,
                               maxval=1.0 - 1e-6)
        u = det_flag * 0.5 + (1.0 - det_flag) * u

        def loss_fn(flat):
            if is_dq:
                z = jnp.zeros((spec.n_slots,), jnp.float32)
                probs = engine.bits(spec, flat)
                reg = jnp.dot(lam, probs)
            elif spec.n_slots:
                phi = gather_phi(spec, flat)
                z = sample_gates(phi, u, lock_mask, lock_val)
                probs = gate_probs(phi, lock_mask, lock_val)
                reg = jnp.dot(lam, chains(spec, probs))
            else:  # fp32 engine
                z = jnp.zeros((0,), jnp.float32)
                probs = z
                reg = jnp.float32(0.0)
            logits = apply_fn(flat, z, x)
            ce = L.cross_entropy(logits, y)
            return ce + reg, (ce, reg, logits, probs)

        grads, (ce, reg, logits, probs) = jax.grad(
            loss_fn, has_aux=True)(flat)
        lr_vec = lr_w * mask_w + lr_g * mask_g + lr_s * mask_s
        flat_new, m_new, v_new = optim.adam_update(
            flat, m, v, grads, lr_vec, step)
        correct = L.correct_count(logits, y)
        return flat_new, m_new, v_new, ce, correct, reg, probs

    return train_step


def build_eval_step(spec, apply_fn):
    def eval_step(flat, gates, x, y):
        logits = apply_fn(flat, gates, x)
        return L.cross_entropy(logits, y), L.correct_count(logits, y)

    return eval_step


def example_args_train(spec, batch):
    """ShapeDtypeStructs matching train_step, for jax.jit(...).lower()."""
    f32 = jnp.float32
    P, G = spec.n_params, spec.n_slots
    s = jax.ShapeDtypeStruct
    return (
        s((P,), f32), s((P,), f32), s((P,), f32),
        s((batch,) + spec.input_shape, f32),
        s((batch,), jnp.int32),
        s((), jnp.int32), s((), f32),
        s((), f32), s((), f32), s((), f32),
        s((G,), f32), s((G,), f32), s((G,), f32),
        s((), f32),
    )


def example_args_eval(spec, batch):
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((spec.n_params,), f32), s((spec.n_slots,), f32),
        s((batch,) + spec.input_shape, f32),
        s((batch,), jnp.int32),
    )
