"""Perf A/B: export lenet5 train steps with the fused Pallas quantizer
vs the naive pure-jnp reference quantizer (materializes every residual).

Used by the §Perf pass to measure what the L1 kernel's fused structure
buys at the whole-step level: `python -m compile.perf_ab --out DIR`.
"""

import argparse
import os

from .aot import export_model
from .quant import BBEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/ab_artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    export_model("lenet5", BBEngine(use_pallas=True), "_pallas",
                 args.out, "small")
    export_model("lenet5", BBEngine(use_pallas=False), "_jnpref",
                 args.out, "small")
    print("A/B artifacts written to", args.out)


if __name__ == "__main__":
    main()
