"""Model zoo: paper architectures with CPU-feasible width presets.

Each model module exposes ``model_fn(ctx, x, preset) -> logits`` plus a
``PRESETS`` dict. ``build_model`` runs the build pass and returns the
frozen :class:`ModelSpec` together with a jit-able ``apply`` function
``apply(flat_params, gate_slots, x) -> logits``.
"""

import jax.numpy as jnp

from ..core import Context, ModelSpec

from . import lenet5, vgg7, resnet18, mobilenetv2

MODELS = {
    "lenet5": lenet5,
    "vgg7": vgg7,
    "resnet18": resnet18,
    "mobilenetv2": mobilenetv2,
}


def build_model(name, engine, preset="small", seed=0):
    mod = MODELS[name]
    cfg = mod.PRESETS[preset]
    input_shape = tuple(cfg["input"])
    ctx = Context("build", engine, seed=seed)
    x0 = jnp.zeros((1,) + input_shape, jnp.float32)
    mod.model_fn(ctx, x0, cfg)
    spec = ModelSpec(
        name=f"{name}-{preset}",
        params=ctx.params,
        quantizers=ctx.quantizers,
        layers=ctx.layers,
        input_shape=input_shape,
        num_classes=cfg["classes"],
        levels=engine.levels,
        dataset=dict(cfg["dataset"], input=list(input_shape),
                     classes=cfg["classes"]),
    )

    def apply(flat, gates, x):
        actx = Context("apply", engine).bind(spec, flat, gates)
        return mod.model_fn(actx, x, cfg)

    return spec, apply
