"""VGG-7 (App. B.1: 2x(128C3)-MP2-2x(256C3)-MP2-2x(512C3)-MP2-1024FC-Softmax).

Batch norm after every conv is modelled as the folded per-channel affine
(see layers.affine and DESIGN.md §Substitutions).
"""

from .. import layers as L

PRESETS = {
    "small": {
        "input": (16, 16, 3),
        "classes": 10,
        "widths": (16, 32, 64), "fc": 128,
        "dataset": {"name": "cifar_like", "train": 4096, "test": 1024},
    },
    "paper": {
        "input": (32, 32, 3),
        "classes": 10,
        "widths": (128, 256, 512), "fc": 1024,
        "dataset": {"name": "cifar_like", "train": 16384, "test": 4096},
    },
}


def model_fn(ctx, x, cfg):
    first = True
    for stage, w in enumerate(cfg["widths"]):
        for i in range(2):
            name = f"conv{stage + 1}_{i + 1}"
            x = L.conv2d(ctx, name, x, w, 3, in_signed=first)
            first = False
            x = L.relu(L.affine(ctx, name + ".bn", x))
        x = L.max_pool2(x, ctx)
    x = L.flatten(x, ctx)
    x = L.relu(L.dense(ctx, "fc1", x, cfg["fc"]))
    return L.dense(ctx, "fc2", x, cfg["classes"])
