"""LeNet-5 (paper §4.1 / App. B.1: 32C5 - MP2 - 64C5 - MP2 - 512FC - Softmax).

The ``small`` preset shrinks widths and input resolution to keep the CPU
PJRT train step fast; ``paper`` is the architecture verbatim (used for
analytic BOP tables and available for full-scale runs).
"""

from .. import layers as L

PRESETS = {
    "small": {
        "input": (16, 16, 1),
        "classes": 10,
        "c1": 8, "c2": 16, "fc": 64, "k": 5,
        "dataset": {"name": "mnist_like", "train": 4096, "test": 1024},
    },
    "paper": {
        "input": (28, 28, 1),
        "classes": 10,
        "c1": 32, "c2": 64, "fc": 512, "k": 5,
        "dataset": {"name": "mnist_like", "train": 16384, "test": 4096},
    },
}


def model_fn(ctx, x, cfg):
    x = L.conv2d(ctx, "conv1", x, cfg["c1"], cfg["k"], in_signed=True)
    x = L.max_pool2(L.relu(x), ctx)
    x = L.conv2d(ctx, "conv2", x, cfg["c2"], cfg["k"])
    x = L.max_pool2(L.relu(x), ctx)
    x = L.flatten(x, ctx)
    x = L.relu(L.dense(ctx, "fc1", x, cfg["fc"]))
    return L.dense(ctx, "fc2", x, cfg["classes"])
