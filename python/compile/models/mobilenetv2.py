"""MobileNetV2 (Sandler et al. 2018) — inverted residuals, linear bottlenecks.

The projection (bottleneck) output is linear, hence its consumer
quantizers are *signed*; this is exactly what makes MobileNetV2 hard to
quantize (§4.2, [27, 28]) and why it is in the paper's evaluation.
Depthwise convs use ``groups == cin`` (B == 1 in the paper's MAC
formula, App. B.2.2).
"""

from .. import layers as L

PRESETS = {
    "small": {
        "input": (24, 24, 3),
        "classes": 10,
        "stem": 8, "stem_stride": 1,
        # (cout, stride, expansion, repeats)
        "blocks": ((12, 1, 2, 1), (16, 2, 4, 2), (24, 2, 4, 2),
                   (32, 2, 4, 1)),
        "head": 64,
        "dataset": {"name": "imagenet_like", "train": 4096, "test": 1024},
    },
    "paper": {
        "input": (224, 224, 3),
        "classes": 1000,
        "stem": 32, "stem_stride": 2,  # stock stride-2 stem at 224px
        "blocks": ((16, 1, 1, 1), (24, 2, 6, 2), (32, 2, 6, 3),
                   (64, 2, 6, 4), (96, 1, 6, 3), (160, 2, 6, 3),
                   (320, 1, 6, 1)),
        "head": 1280,
        "dataset": {"name": "imagenet_like", "train": 16384, "test": 4096},
    },
}


def inverted_residual(ctx, name, x, cout, stride, expand):
    cin = x.shape[-1]
    mid = cin * expand
    y = x
    if expand != 1:
        y = L.conv2d(ctx, f"{name}.expand", y, mid, 1, in_signed=True)
        y = L.relu(L.affine(ctx, f"{name}.ebn", y))
    y = L.conv2d(ctx, f"{name}.dw", y, mid, 3, stride=stride, groups=mid,
                 in_signed=(expand == 1))
    y = L.relu(L.affine(ctx, f"{name}.dbn", y))
    # Linear bottleneck: no ReLU => the projection output is signed.
    y = L.conv2d(ctx, f"{name}.project", y, cout, 1)
    y = L.affine(ctx, f"{name}.pbn", y)
    if stride == 1 and cin == cout:
        return x + y  # residual add, un-quantized per App. D.1
    return y


def model_fn(ctx, x, cfg):
    x = L.conv2d(ctx, "stem", x, cfg["stem"], 3,
                 stride=cfg["stem_stride"], in_signed=True)
    x = L.relu(L.affine(ctx, "stem.bn", x))
    i = 0
    for cout, stride, expand, repeats in cfg["blocks"]:
        for r in range(repeats):
            i += 1
            x = inverted_residual(ctx, f"b{i}", x, cout,
                                  stride if r == 0 else 1, expand)
    x = L.conv2d(ctx, "head", x, cfg["head"], 1, in_signed=True)
    x = L.relu(L.affine(ctx, "head.bn", x))
    x = L.global_avg_pool(x, ctx)
    return L.dense(ctx, "fc", x, cfg["classes"], in_signed=False)
