"""ResNet18 (post-activation, BN folded — Table 3 row "Bayesian Bits").

Activation quantization follows the paper's *updated* ImageNet setup
(App. D.1): tensors feeding residual connections are not quantized; the
post-add ReLU output is quantized once by the next block's first conv,
whose quantizer also covers the downsample conv when present (B.2.4 —
``extra_in_macs``).

The ``small`` preset scales widths/resolution for the CPU testbed; the
``paper`` preset is the stock ImageNet ResNet18 topology, used for
analytic BOP accounting.
"""

from .. import layers as L

PRESETS = {
    "small": {
        "input": (24, 24, 3),
        "classes": 10,
        "widths": (8, 16, 32, 64), "blocks": (2, 2, 2, 2),
        "stem_kernel": 3, "stem_stride": 1, "stem_pool": False,
        "dataset": {"name": "imagenet_like", "train": 4096, "test": 1024},
    },
    "paper": {
        "input": (224, 224, 3),
        "classes": 1000,
        "widths": (64, 128, 256, 512), "blocks": (2, 2, 2, 2),
        "stem_kernel": 7, "stem_stride": 2, "stem_pool": True,
        "dataset": {"name": "imagenet_like", "train": 16384, "test": 4096},
    },
}


def basic_block(ctx, name, x, cout, stride, first_signed=False):
    cin = x.shape[-1]
    need_ds = stride != 1 or cin != cout
    _, h, w, _ = x.shape
    ds_macs = L.conv_macs(h, w, cin, cout, 1, stride) if need_ds else 0

    # conv1 quantizes the shared block input; the downsample conv reuses it.
    y = L.conv2d(ctx, f"{name}.conv1", x, cout, 3, stride=stride,
                 in_signed=first_signed, extra_in_macs=ds_macs,
                 residual_input=True)
    y = L.relu(L.affine(ctx, f"{name}.bn1", y))
    y = L.conv2d(ctx, f"{name}.conv2", y, cout, 3)
    y = L.affine(ctx, f"{name}.bn2", y)

    if need_ds:
        sc = L.conv2d(ctx, f"{name}.ds", x, cout, 1, stride=stride,
                      quant_in=False, in_q=f"{name}.conv1.in",
                      residual_input=True)
        sc = L.affine(ctx, f"{name}.dsbn", sc)
    else:
        sc = x
    return L.relu(y + sc)


def model_fn(ctx, x, cfg):
    x = L.conv2d(ctx, "stem", x, cfg["widths"][0], cfg["stem_kernel"],
                 stride=cfg["stem_stride"], in_signed=True)
    x = L.relu(L.affine(ctx, "stem.bn", x))
    if cfg["stem_pool"]:
        x = L.max_pool2(x, ctx)
    for stage, (wdt, nblocks) in enumerate(zip(cfg["widths"], cfg["blocks"])):
        for b in range(nblocks):
            stride = 2 if (stage > 0 and b == 0) else 1
            x = basic_block(ctx, f"s{stage + 1}b{b + 1}", x, wdt, stride)
    x = L.global_avg_pool(x, ctx)
    return L.dense(ctx, "fc", x, cfg["classes"])
