"""AOT exporter: lower every executable once, emit HLO text + manifests.

This is the only place Python runs in the whole system — ``make
artifacts`` invokes it and the Rust coordinator is self-contained
afterwards.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs per model m (engine bb):
  m_train.hlo.txt, m_eval.hlo.txt, m_manifest.json, m_init.bin
per DQ baseline model:  m_dq_{train,eval}.hlo.txt, m_dq_manifest.json, ...
plus quantizer_fwd.hlo.txt + goldens.json for Rust-side kernel parity.
"""

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import steps
from .dq import DQEngine
from .kernels.bayesian_bits import bb_quantize
from .models import build_model
from .quant import BBEngine

BATCH = {"lenet5": 64, "vgg7": 64, "resnet18": 32, "mobilenetv2": 32}
BB_MODELS = ("lenet5", "vgg7", "resnet18", "mobilenetv2")
DQ_MODELS = ("lenet5", "vgg7", "resnet18")

TRAIN_ARGS = ["params", "adam_m", "adam_v", "x", "y", "seed", "step",
              "lr_w", "lr_g", "lr_s", "lock_mask", "lock_val", "lam",
              "det_flag"]
TRAIN_OUTS = ["params", "adam_m", "adam_v", "loss", "correct", "reg",
              "probs"]
EVAL_ARGS = ["params", "gates", "x", "y"]
EVAL_OUTS = ["loss", "correct"]


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})`, which the 0.5.1 text parser happily
    # reads back as zeros — silently corrupting e.g. the per-group
    # learning-rate masks (bisected the hard way; see EXPERIMENTS.md).
    return comp.as_hlo_text(print_large_constants=True)


def export_model(name, engine, tag, out_dir, preset, seed=0):
    spec, apply_fn = build_model(name, engine, preset, seed=seed)
    # Distinguish baseline-engine exports (e.g. lenet5_dq) in run results.
    spec.name = f"{name}{tag}-{preset}"
    batch = BATCH[name]

    train = steps.build_train_step(spec, apply_fn, engine)
    ev = steps.build_eval_step(spec, apply_fn)
    train_hlo = to_hlo_text(
        jax.jit(train).lower(*steps.example_args_train(spec, batch)))
    eval_hlo = to_hlo_text(
        jax.jit(ev).lower(*steps.example_args_eval(spec, batch)))

    base = f"{name}{tag}"
    files = {
        "hlo_train": f"{base}_train.hlo.txt",
        "hlo_eval": f"{base}_eval.hlo.txt",
        "init_file": f"{base}_init.bin",
    }
    with open(os.path.join(out_dir, files["hlo_train"]), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, files["hlo_eval"]), "w") as f:
        f.write(eval_hlo)
    spec.init_flat().tofile(os.path.join(out_dir, files["init_file"]))

    manifest = spec.to_json()
    manifest.update(files)
    manifest.update({
        "engine": engine.kind,
        "preset": preset,
        "batch": batch,
        "train_args": TRAIN_ARGS,
        "train_outputs": TRAIN_OUTS,
        "eval_args": EVAL_ARGS,
        "eval_outputs": EVAL_OUTS,
    })
    with open(os.path.join(out_dir, f"{base}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {base}: P={spec.n_params} G={spec.n_slots} "
          f"train={len(train_hlo) // 1024}KiB eval={len(eval_hlo) // 1024}KiB")
    return spec


def export_quantizer_parity(out_dir, shape=(8, 16), n_cases=6):
    """Standalone quantizer forward + golden vectors for Rust parity."""
    levels = (2, 4, 8, 16, 32)

    def qfwd(x, beta, z2, zh):
        return (bb_quantize(x, beta, z2, zh, signed=True, levels=levels),)

    s = jax.ShapeDtypeStruct
    lowered = jax.jit(qfwd).lower(
        s(shape, jnp.float32), s((1,), jnp.float32),
        s((shape[0],), jnp.float32), s((len(levels) - 1,), jnp.float32))
    with open(os.path.join(out_dir, "quantizer_fwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    rng = np.random.default_rng(1234)
    cases = []
    gate_sets = [
        [1, 1, 1, 1], [1, 1, 0, 0], [0, 0, 0, 0], [1, 0, 0, 0],
        [0.5, 0.25, 1, 0], [1, 1, 1, 0],
    ]
    for i in range(n_cases):
        x = rng.normal(0, 1.2, size=shape).astype(np.float32)
        beta = np.array([abs(rng.normal(2.0, 0.3))], dtype=np.float32)
        z2 = (rng.random(shape[0]) > 0.2).astype(np.float32)
        zh = np.array(gate_sets[i % len(gate_sets)], dtype=np.float32)
        out = np.asarray(qfwd(jnp.asarray(x), jnp.asarray(beta),
                              jnp.asarray(z2), jnp.asarray(zh))[0])
        cases.append({
            "x": x.reshape(-1).tolist(),
            "beta": beta.tolist(),
            "z2": z2.tolist(),
            "zh": zh.tolist(),
            "out": out.reshape(-1).tolist(),
        })
    with open(os.path.join(out_dir, "goldens.json"), "w") as f:
        json.dump({"shape": list(shape), "levels": list(levels),
                   "cases": cases}, f)
    print(f"  quantizer_fwd: {n_cases} golden cases")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(BB_MODELS))
    ap.add_argument("--preset", default="small")
    ap.add_argument("--skip-dq", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    models = [m for m in args.models.split(",") if m]
    print("exporting artifacts ->", os.path.abspath(args.out))
    for name in models:
        export_model(name, BBEngine(), "", args.out, args.preset)
    if not args.skip_dq:
        for name in models:
            if name in DQ_MODELS:
                export_model(name, DQEngine(), "_dq", args.out, args.preset)
    export_quantizer_parity(args.out)
    print("done")


if __name__ == "__main__":
    sys.exit(main())
