"""Differentiable Quantization (DQ) baseline engine (Uhlich et al. 2020).

DQ learns a continuous step size ``d`` and range ``beta`` per tensor;
the effective bit width is *inferred* as ``b = log2((beta - alpha)/d + 1)``
and regularized directly (here with the same BOP-proportional weights as
Bayesian Bits so Table 1 / Table 4 rows are apples-to-apples, §4.1).

Hardware-unfriendliness is the paper's point: the learned ``b`` is
fractional, so deployment must round up to the next power of two
("DQ-restricted"), which inflates the BOP count without changing the
accuracy. That rounding is done on the Rust side (``baselines/dq.rs``)
from the inferred-bits vector this engine reports.

Each DQ quantizer occupies exactly one gate slot in the global slot
vector; the slot's "probability" output is the inferred bit width
(clamped to [1, 32]) so the Rust coordinator can reuse the same
reporting plumbing.
"""

import numpy as np
import jax.numpy as jnp

from .core import const_init
from .kernels.ref import BETA_EPS, round_ste, pact_clip

D_INIT_BITS = 8.0  # start as an 8-bit quantizer


class DQEngine:
    kind = "dq"
    levels = (0,)  # one slot per quantizer, no gate chain

    def __init__(self, max_bits=32.0):
        self.max_bits = max_bits

    def _register(self, ctx, qname, kind, signed, consumer_macs, beta0):
        ctx.register_quantizer(qname, kind, signed, 1, self.levels, None,
                               consumer_macs)
        # log step size: beta-alpha spans (2^b - 1) bins at b bits.
        span = beta0 * (2.0 if signed else 1.0)
        d0 = span / (2.0**D_INIT_BITS - 1.0)
        ctx.param(qname + ".logd", (1,), "g", const_init(float(np.log(d0))))
        ctx.param(qname + ".beta", (1,), "s", const_init(beta0))

    def _apply(self, ctx, qname, x, signed):
        logd = ctx.param(qname + ".logd", (1,), "g", None)
        beta = ctx.param(qname + ".beta", (1,), "s", None)
        d = jnp.exp(logd[0])
        beta_grid = jnp.abs(beta[0])
        alpha = -beta_grid if signed else 0.0
        beta_clip = beta_grid * (1.0 - BETA_EPS)
        alpha_clip = alpha * (1.0 - BETA_EPS)
        xc = pact_clip(x, alpha_clip, beta_clip)
        return d * round_ste(xc / d)

    def quant_weight(self, ctx, name, w, consumer_macs, layer):
        if ctx.mode == "build":
            beta0 = float(np.max(np.abs(np.asarray(w)))) or 1.0
            self._register(ctx, name, "w", True, consumer_macs, beta0)
            return w
        return self._apply(ctx, name, w, signed=True)

    def quant_act(self, ctx, name, x, consumer_macs, signed):
        if ctx.mode == "build":
            self._register(ctx, name, "a", signed, consumer_macs,
                           3.0 if signed else 6.0)
            return x
        return self._apply(ctx, name, x, signed=signed)

    def bits(self, spec, flat):
        """Inferred continuous bit widths, one per quantizer slot."""
        out = []
        for q in spec.quantizers:
            pd = spec.param_index[q.name + ".logd"]
            pb = spec.param_index[q.name + ".beta"]
            d = jnp.exp(flat[pd.offset])
            beta = jnp.abs(flat[pb.offset])
            span = beta * (2.0 if q.signed else 1.0)
            b = jnp.log2(span / d + 1.0)
            out.append(jnp.clip(b, 1.0, self.max_bits))
        return jnp.stack(out)
