"""Quantization engines: Bayesian Bits (the paper) and an FP32 no-op.

An *engine* owns the quantizer parameters (gate logits ``phi``, range
scales ``beta``) and applies the quantizer inside layer forwards. The
same model code builds either a Bayesian Bits network, a DQ baseline
network (``dq.py``), or a plain float network, depending on the engine
the context carries.

Weight tensors are quantized per-output-channel for the pruning gate z2
(channel-major reshape), with the residual gates z4..z32 shared across
the tensor (paper §2.1: shared grid for surviving channels).
Activation tensors are quantized per-tensor (channels == 1).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .core import const_init
from .kernels.bayesian_bits import bb_quantize
from .kernels import ref

PHI_INIT = 6.0  # large => all gates initially open (§4: start at full 32-bit)
ACT_BETA_INIT = 6.0
ACT_BETA_INIT_SIGNED = 3.0


class FP32Engine:
    """Identity engine — no quantizers, no extra parameters."""

    kind = "fp32"
    levels = ()

    def quant_weight(self, ctx, name, w, consumer_macs, layer):
        return w

    def quant_act(self, ctx, name, x, consumer_macs, signed):
        return x


class BBEngine:
    """Bayesian Bits: gated residual decomposition on every tensor."""

    kind = "bb"

    def __init__(self, levels=(2, 4, 8, 16, 32), use_pallas=True):
        self.levels = tuple(levels)
        self.use_pallas = use_pallas

    def _register(self, ctx, qname, kind, signed, channels, consumer_macs,
                  layer, beta0):
        n_slots = channels + len(self.levels) - 1
        ctx.register_quantizer(qname, kind, signed, channels, self.levels,
                               layer, consumer_macs)
        ctx.param(qname + ".phi", (n_slots,), "g", const_init(PHI_INIT))
        ctx.param(qname + ".beta", (1,), "s", const_init(beta0))

    def _apply(self, ctx, qname, x2d, signed):
        beta = ctx.param(qname + ".beta", (1,), "s", None)
        z2, zh = ctx.gate_slots(qname)
        return bb_quantize(x2d, beta, z2, zh, signed=signed,
                           levels=self.levels, use_pallas=self.use_pallas)

    def quant_weight(self, ctx, name, w, consumer_macs, layer):
        cout = int(w.shape[-1])
        if ctx.mode == "build":
            beta0 = float(np.max(np.abs(np.asarray(w)))) or 1.0
            self._register(ctx, name, "w", True, cout, consumer_macs, layer,
                           beta0)
            return w
        w2d = jnp.moveaxis(w, -1, 0).reshape(cout, -1)
        wq = self._apply(ctx, name, w2d, signed=True)
        return jnp.moveaxis(wq.reshape((cout,) + w.shape[:-1]), 0, -1)

    def quant_act(self, ctx, name, x, consumer_macs, signed):
        if ctx.mode == "build":
            beta0 = ACT_BETA_INIT_SIGNED if signed else ACT_BETA_INIT
            self._register(ctx, name, "a", signed, 1, consumer_macs, None,
                           beta0)
            return x
        x2d = x.reshape(1, -1)
        xq = self._apply(ctx, name, x2d, signed=signed)
        return xq.reshape(x.shape)


def gate_param_index(spec):
    """int32 map: gate slot -> position of its phi logit in the flat params."""
    idx = np.zeros(spec.n_slots, dtype=np.int32)
    for q in spec.quantizers:
        p = spec.param_index[q.name + ".phi"]
        assert p.size == q.n_slots
        idx[q.offset:q.offset + q.n_slots] = np.arange(
            p.offset, p.offset + p.size, dtype=np.int32)
    return idx


def gather_phi(spec, flat):
    """All gate logits in slot order, via *static slices*.

    Deliberately avoids `flat[phi_index]` (a gather op): the xla_extension
    0.5.1 backend that executes the AOT artifacts miscompiles the
    large-constant-index gather this produces (verified against the
    jitted reference), while static slice + concatenate round-trips
    exactly. Slot order == registration order, so the concatenation is
    contiguous and cheap.
    """
    parts = []
    for q in spec.quantizers:
        p = spec.param_index[q.name + ".phi"]
        parts.append(jax.lax.slice(flat, (p.offset,),
                                   (p.offset + p.size,)))
    return jnp.concatenate(parts)


def sample_gates(phi, u, lock_mask, lock_val):
    """Stochastic hard-concrete gates with per-slot lock overrides.

    lock_mask == 1 forces the gate to lock_val (used for fixed-width
    baselines, quantization-only / pruning-only ablations, and frozen
    gates during fine-tuning); lock_mask == 0 samples from the
    hard-concrete relaxation (Eq. 20).
    """
    z = ref.hard_concrete_sample(phi, u)
    return lock_mask * lock_val + (1.0 - lock_mask) * z


def gate_probs(phi, lock_mask, lock_val):
    """Per-slot inclusion probabilities R_phi(z>0) with lock overrides."""
    p = ref.prob_active(phi)
    return lock_mask * lock_val + (1.0 - lock_mask) * p


def chains(spec, probs):
    """Per-slot chain probabilities Pi_{j<=i} q(z_j = 1) (Eq. 16).

    Channel slots carry q(z2c); the residual slot for bit b carries
    mean_c q(z2c) * prod_{2<j<=b} q(z_j). Dotting with the lam vector
    (mu * lam_base from the manifest) gives the paper's regularizer.
    """
    parts = []
    for q in spec.quantizers:
        q2 = probs[q.offset:q.offset + q.channels]
        qh = probs[q.offset + q.channels:q.offset + q.n_slots]
        parts.append(q2)
        parts.append(jnp.cumprod(qh) * jnp.mean(q2))
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)
