"""Pure-jnp reference oracle for the Bayesian Bits quantizer.

This module is the *correctness signal* for the Pallas kernel in
``bayesian_bits.py``: it implements the paper's residual decomposition
(Eqs. 1-6 of van Baalen et al., NeurIPS 2020) in the most literal,
naive way possible — every quantized residual tensor is materialized —
so that the fused kernel can be checked against it bit-for-bit
(``pytest python/tests/test_kernel.py``).

Conventions (shared with the kernel and with the Rust host mirror in
``rust/src/quant``):

* ``x`` is pre-shaped to 2-D ``(channels, rest)``; the pruning gate
  ``z2`` is a vector over axis 0 (length ``channels``; broadcast a
  scalar for per-tensor activation quantizers).
* ``signed`` quantizers use ``alpha = -beta``; unsigned use
  ``alpha = 0`` (post-ReLU activations).
* ``beta`` is shrunk by ``(1 - 1e-7)`` before use (paper §2.4) so a
  value of exactly ``beta`` cannot round to an invalid grid point.
* Levels are the hardware-friendly doubling chain ``(2, 4, 8, 16, 32)``
  (a prefix may be used, e.g. ``(2, 4, 8)`` for ImageNet configs).
"""

import jax
import jax.numpy as jnp

# Hard-concrete hyperparameters (Louizos et al. 2018, used in App. A.2).
GAMMA = -0.1
ZETA = 1.1
TAU = 2.0 / 3.0
# Test-time pruning threshold t (Eq. 22); 0.34 ~ the point where the
# probability mass of the exact-zero mixture component dominates.
THRESHOLD = 0.34

LEVELS = (2, 4, 8, 16, 32)

BETA_EPS = 1e-7


def round_ste(x):
    """Round-to-nearest with a straight-through gradient (identity bwd)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def pact_clip(x, alpha, beta):
    """PACT clipping, Eq. 17: beta - relu(beta - alpha - relu(x - alpha)).

    Written with ReLUs (rather than ``jnp.clip``) so autodiff yields the
    PACT gradient for the trainable range ``beta`` for free.
    """
    return beta - jax.nn.relu(beta - alpha - jax.nn.relu(x - alpha))


def effective_range(beta, signed):
    """(alpha, beta_grid, beta_clip) for a raw range parameter beta.

    The grid (step sizes) uses ``|beta|``; the clip bound is shrunk by
    ``(1 - 1e-7)`` (paper §2.4) so the maximum clipped value divided by
    the step can never land exactly on a half-integer and round up to an
    invalid grid point.
    """
    beta_grid = jnp.abs(beta)
    beta_clip = beta_grid * (1.0 - BETA_EPS)
    alpha = jnp.where(signed, -beta_grid, 0.0)
    alpha_clip = jnp.where(signed, -beta_clip, 0.0)
    return alpha, beta_grid, beta_clip, alpha_clip


def step_sizes(beta, signed, levels=LEVELS):
    """The step-size chain s_2, s_4, ... (s_b = s_{b/2} / (2^{b/2} + 1)).

    By induction s_b == (beta - alpha) / (2^b - 1), which the tests
    verify explicitly (the paper's Fig. 1 identity
    (2^4 - 1) = (2^2 - 1)(2^2 + 1)).
    """
    alpha, beta_grid, _, _ = effective_range(beta, signed)
    sizes = []
    s = (beta_grid - alpha) / (2.0**2 - 1.0)
    sizes.append(s)
    for b in levels[1:]:
        s = s / (2.0 ** (b // 2) + 1.0)
        sizes.append(s)
    return sizes


def decompose(x, beta, signed, levels=LEVELS, ste=False):
    """Return (x2, [eps_4, eps_8, ...]) — the raw decomposition terms.

    ``ste=True`` wraps every rounding in a straight-through estimator so
    the expression stays differentiable w.r.t. ``x`` (used by the L2
    training graph; the plain version is the test oracle).
    """
    rnd = round_ste if ste else jnp.round
    alpha, beta_grid, beta_clip, alpha_clip = effective_range(beta, signed)
    xc = pact_clip(x, alpha_clip, beta_clip)
    s = (beta_grid - alpha) / (2.0**2 - 1.0)
    x_cur = s * rnd(xc / s)
    terms = [x_cur]
    for b in levels[1:]:
        s = s / (2.0 ** (b // 2) + 1.0)
        eps = s * rnd((xc - x_cur) / s)
        terms.append(eps)
        x_cur = x_cur + eps
    return terms[0], terms[1:]


def gated_sum(x2, residuals, z2, z_higher):
    """Eq. 6: x_q = z2*(x2 + z4*(e4 + z8*(e8 + ...))) with broadcasting.

    ``z2`` broadcasts over axis 0 (per-channel pruning); ``z_higher`` is
    a vector of scalars, one per residual level, shared per tensor.
    """
    inner = jnp.zeros_like(x2)
    for i in range(len(residuals) - 1, -1, -1):
        inner = z_higher[i] * (residuals[i] + inner)
    z2b = jnp.reshape(z2, (-1,) + (1,) * (x2.ndim - 1))
    return z2b * (x2 + inner)


def bb_quantize_ref(x, beta, z2, z_higher, signed, levels=LEVELS, ste=False):
    """Full Bayesian Bits quantizer forward — the oracle for the kernel.

    Args:
      x:        (C, R) float32 tensor (2-D, channel-major).
      beta:     scalar raw range parameter.
      z2:       (C,) pruning gates in [0, 1].
      z_higher: (len(levels)-1,) residual gates in [0, 1].
      signed:   python bool (static).
      levels:   static tuple of power-of-two bit widths, starting at 2.
    """
    x2, residuals = decompose(x, beta, signed, levels=levels, ste=ste)
    return gated_sum(x2, residuals, z2, z_higher)


def quantize_fixed(x, beta, bit, signed):
    """Plain uniform quantizer x_q = s*round(clip(x)/s) at one bit width.

    Used by tests to check that the decomposition with all gates up to
    ``bit`` open (and the rest closed) is *exactly* the fixed-point
    quantizer at that bit width.
    """
    alpha, beta_grid, beta_clip, alpha_clip = effective_range(beta, signed)
    xc = pact_clip(x, alpha_clip, beta_clip)
    s = (beta_grid - alpha) / (2.0**bit - 1.0)
    return s * jnp.round(xc / s)


# --- Hard-concrete gate distribution (App. A.2) -------------------------


def hard_concrete_sample(phi, u):
    """Sample z given logits phi and uniform noise u (Eq. 20)."""
    g = jnp.log(u) - jnp.log1p(-u)
    s = jax.nn.sigmoid((g + phi) / TAU)
    return jnp.clip(s * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def hard_concrete_mean(phi):
    """Deterministic gate value with the noise switched off (u = 0.5)."""
    s = jax.nn.sigmoid(phi / TAU)
    return jnp.clip(s * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def prob_active(phi):
    """R_phi(z > 0) = sigmoid(phi - tau*log(-gamma/zeta)) (Eq. 21)."""
    return jax.nn.sigmoid(phi - TAU * jnp.log(-GAMMA / ZETA))


def test_time_gate(phi, threshold=THRESHOLD):
    """Eq. 22: z = 1[ sigmoid(tau*log(-gamma/zeta) - phi) < t ]."""
    p_zero = jax.nn.sigmoid(TAU * jnp.log(-GAMMA / ZETA) - phi)
    return (p_zero < threshold).astype(jnp.float32)
