"""Layer-1 Pallas kernel: the fused Bayesian Bits quantizer.

The quantizer (clip -> 2-bit base -> gated residual chain, Eqs. 1-6) is
the op the paper adds to *every* weight and activation tensor, so it is
the compute hot-spot of the whole stack. The naive jnp formulation in
``ref.py`` materializes every residual tensor ``eps_b`` in HBM; this
kernel instead keeps one tile of ``x`` resident in VMEM and runs the
whole chain in-register, writing a single output tile.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles axis 0
(channels) so the per-channel pruning gate ``z2`` is loaded once per
block; ``beta`` and the shared residual gates ride along as tiny
replicated blocks. ``interpret=True`` everywhere — the CPU PJRT client
cannot execute Mosaic custom-calls, and interpret mode lowers the kernel
to plain HLO that the Rust runtime can run.

Autodiff: pallas_call is not differentiable, so the public entry point
``bb_quantize`` wraps the kernel in a ``custom_vjp`` with the paper's
straight-through gradients:

* d xq / d x    = z2 * 1[alpha < x < beta]           (STE through rounds)
* d xq / d beta = z2 * (1[x >= beta] - signed*1[x <= alpha]) * sign(beta)
* d xq / d z2_c = sum_r g * (x2 + z4*(e4 + ...))_cr   (exact)
* d xq / d zh_i = sum   g * z2 * prod_{k<i} zh_k * (e_i + inner_{i+1})
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import BETA_EPS, LEVELS


def _chain(x, beta_grid, alpha, levels, rnd):
    """Shared residual-chain body: returns [x2, eps4, eps8, ...].

    The clip bound is ``beta_grid * (1 - eps)`` while step sizes use
    ``beta_grid`` itself, so the top clipped value can never round up to
    an invalid grid point (paper §2.4).
    """
    beta_clip = beta_grid * (1.0 - BETA_EPS)
    alpha_clip = alpha * (1.0 - BETA_EPS)
    xc = beta_clip - jnp.maximum(
        beta_clip - alpha_clip - jnp.maximum(x - alpha_clip, 0.0), 0.0
    )
    s = (beta_grid - alpha) / (2.0**2 - 1.0)
    x_cur = s * rnd(xc / s)
    terms = [x_cur]
    for b in levels[1:]:
        s = s / (2.0 ** (b // 2) + 1.0)
        eps = s * rnd((xc - x_cur) / s)
        terms.append(eps)
        x_cur = x_cur + eps
    return terms


def _bb_kernel(beta_ref, zh_ref, x_ref, z2_ref, o_ref, *, signed, levels):
    """One grid step: quantize a (block_rows, N) tile fully in VMEM."""
    x = x_ref[...]
    beta_grid = jnp.abs(beta_ref[0])
    alpha = -beta_grid if signed else 0.0
    terms = _chain(x, beta_grid, alpha, levels, jnp.round)
    # Gated accumulation, innermost residual first (Eq. 6).
    inner = jnp.zeros_like(x)
    for i in range(len(levels) - 2, -1, -1):
        inner = zh_ref[i] * (terms[i + 1] + inner)
    z2 = z2_ref[...].reshape(-1, 1)
    o_ref[...] = z2 * (terms[0] + inner)


def _bb_pallas(x, beta, z2, zh, *, signed, levels, block_rows):
    m, n = x.shape
    bm = block_rows if block_rows is not None else m
    assert m % bm == 0, f"rows {m} not divisible by block_rows {bm}"
    kernel = functools.partial(_bb_kernel, signed=signed, levels=levels)
    # A bare 2-bit quantizer (levels == (2,)) has no residual gates; pad
    # the zh block to one (unused) slot so the BlockSpec stays non-empty.
    zh_len = max(1, len(levels) - 1)
    if zh.shape[0] == 0:
        zh = jnp.zeros((1,), x.dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),          # beta
            pl.BlockSpec((zh_len,), lambda i: (0,)),     # zh
            pl.BlockSpec((bm, n), lambda i: (i, 0)),     # x tile
            pl.BlockSpec((bm,), lambda i: (i,)),         # z2 slice
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(beta, zh, x, z2)


@functools.lru_cache(maxsize=None)
def make_bb_quantizer(signed, levels=LEVELS, block_rows=None, use_pallas=True):
    """Build the custom_vjp Bayesian Bits quantizer for a static config.

    Returns f(x, beta, z2, zh) -> xq with
      x:    (C, R) f32   beta: (1,) f32   z2: (C,) f32   zh: (L-1,) f32.

    ``use_pallas=False`` swaps the forward for the pure-jnp oracle
    (identical numerics; used for A/B perf comparison at L2).
    """
    levels = tuple(levels)

    def fwd_impl(x, beta, z2, zh):
        if use_pallas:
            return _bb_pallas(
                x, beta, z2, zh, signed=signed, levels=levels, block_rows=block_rows
            )
        return ref.bb_quantize_ref(x, beta, z2, zh, signed, levels=levels)

    @jax.custom_vjp
    def quantize(x, beta, z2, zh):
        return fwd_impl(x, beta, z2, zh)

    def vjp_fwd(x, beta, z2, zh):
        return fwd_impl(x, beta, z2, zh), (x, beta, z2, zh)

    def vjp_bwd(saved, g):
        x, beta, z2, zh = saved
        beta_grid = jnp.abs(beta[0])
        beta_clip = beta_grid * (1.0 - BETA_EPS)
        alpha = -beta_grid if signed else 0.0
        alpha_clip = alpha * (1.0 - BETA_EPS)
        terms = _chain(x, beta_grid, alpha, levels, jnp.round)
        z2b = z2.reshape(-1, 1)

        # Gate gradients (exact): inner_i = zh_i*(e_i + inner_{i+1}).
        inners = [jnp.zeros_like(x)] * len(levels)
        for i in range(len(levels) - 2, -1, -1):
            inners[i] = zh[i] * (terms[i + 1] + inners[i + 1])
        g_z2 = jnp.sum(g * (terms[0] + inners[0]), axis=1)
        g_zh = []
        prefix = z2b  # z2 * prod_{k<i} zh_k, broadcast over the tile
        for i in range(len(levels) - 1):
            g_zh.append(jnp.sum(g * prefix * (terms[i + 1] + inners[i + 1])))
            prefix = prefix * zh[i]
        g_zh = (jnp.stack(g_zh) if g_zh
                else jnp.zeros((0,), x.dtype))

        # STE gradients for x and the PACT range beta.
        in_range = jnp.logical_and(x > alpha_clip, x < beta_clip).astype(x.dtype)
        g_x = g * z2b * in_range
        upper = (x >= beta_clip).astype(x.dtype)
        d_beta = upper
        if signed:
            d_beta = upper - (x <= alpha_clip).astype(x.dtype)
        g_beta = jnp.sum(g * z2b * d_beta) * jnp.sign(beta[0]) * (1.0 - BETA_EPS)
        return g_x, jnp.reshape(g_beta, (1,)), g_z2, g_zh

    quantize.defvjp(vjp_fwd, vjp_bwd)
    return quantize


def bb_quantize(x, beta, z2, zh, *, signed, levels=LEVELS, block_rows=None,
                use_pallas=True):
    """Convenience wrapper over :func:`make_bb_quantizer`."""
    fn = make_bb_quantizer(
        bool(signed), tuple(levels), block_rows, bool(use_pallas)
    )
    return fn(x, beta, z2, zh)
