"""Quantization-aware NN layer library (Layer 2).

Conventions:

* NHWC activations, HWIO weights.
* Each conv/dense quantizes its own *input* activation (output
  quantization in the sense of Table 3: the tensor is quantized once at
  production and consumed quantized). When one tensor feeds several
  convs (ResNet downsample, B.2.4), the first consumer creates the
  quantizer with ``extra_in_macs`` covering the other consumers, and the
  others pass ``quant_in=False`` + ``in_q`` so the BOP table still knows
  which quantizer feeds them.
* Batch norm is modelled as a per-channel affine (``affine``) — the
  paper folds BN into the preceding conv for quantization (§4, [18]);
  training the folded form directly is equivalent for our purposes.
* Biases and the output logits are not quantized (§4).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .core import he_normal, zeros_init, ones_init


def conv_out_hw(h, w, ksize, stride, padding):
    if padding == "SAME":
        return -(-h // stride), -(-w // stride)
    return (h - ksize) // stride + 1, (w - ksize) // stride + 1


def conv_macs(h, w, cin, cout, ksize, stride, padding="SAME", groups=1):
    """MACs(l) = C_o * W * H * (C_i/groups) * W_f * H_f (App. B.2.2)."""
    ho, wo = conv_out_hw(h, w, ksize, stride, padding)
    return ho * wo * cout * (cin // groups) * ksize * ksize


def conv2d(ctx, name, x, cout, ksize, stride=1, padding="SAME",
           use_bias=True, quant_in=True, in_signed=False, extra_in_macs=0,
           groups=1, in_q=None, residual_input=False):
    """Quantized 2-D convolution; returns pre-activation output."""
    _, h, w, cin = x.shape
    macs = conv_macs(h, w, cin, cout, ksize, stride, padding, groups)
    kind = "dwconv" if groups == cin else "conv"
    if quant_in:
        in_q = f"{name}.in"
        x = ctx.engine.quant_act(ctx, in_q, x, macs + extra_in_macs,
                                 in_signed)
    wshape = (ksize, ksize, cin // groups, cout)
    wgt = ctx.param(f"{name}.w", wshape, "w",
                    he_normal(ksize * ksize * cin // groups))
    wq = ctx.engine.quant_weight(ctx, f"{name}.w", wgt, macs, name)
    y = jax.lax.conv_general_dilated(
        x, wq,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if use_bias:
        b = ctx.param(f"{name}.b", (cout,), "w", zeros_init)
        y = y + b
    ctx.record_layer(name, kind, macs, cin, cout, f"{name}.w", in_q,
                     residual_input,
                     spatial={"ksize": int(ksize), "stride": int(stride),
                              "padding": padding, "groups": int(groups),
                              "in_h": int(h), "in_w": int(w)})
    return y


def dense(ctx, name, x, dout, quant_in=True, in_signed=False, in_q=None):
    """Quantized fully-connected layer over (B, D) input."""
    din = x.shape[-1]
    macs = din * dout
    if quant_in:
        in_q = f"{name}.in"
        x = ctx.engine.quant_act(ctx, in_q, x, macs, in_signed)
    wgt = ctx.param(f"{name}.w", (din, dout), "w", he_normal(din))
    wq = ctx.engine.quant_weight(ctx, f"{name}.w", wgt, macs, name)
    b = ctx.param(f"{name}.b", (dout,), "w", zeros_init)
    ctx.record_layer(name, "dense", macs, din, dout, f"{name}.w", in_q)
    return x @ wq + b


def affine(ctx, name, x):
    """Per-channel scale+shift — the folded-BN stand-in (group 'w')."""
    c = x.shape[-1]
    g = ctx.param(f"{name}.gamma", (c,), "w", ones_init)
    b = ctx.param(f"{name}.beta", (c,), "w", zeros_init)
    return x * g + b


def relu(x):
    return jax.nn.relu(x)


def max_pool2(x, ctx=None):
    """2x2 max pooling, stride 2. Pass ``ctx`` so the op is recorded
    into the next layer's manifest ``pre`` list (the integer engine
    replays it between layers)."""
    if ctx is not None:
        ctx.note_op("maxpool2")
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def global_avg_pool(x, ctx=None):
    if ctx is not None:
        ctx.note_op("gap")
    return jnp.mean(x, axis=(1, 2))


def flatten(x, ctx=None):
    if ctx is not None:
        ctx.note_op("flatten")
    return x.reshape(x.shape[0], -1)


def cross_entropy(logits, y):
    """Mean softmax cross-entropy with integer labels.

    Written with an equality-mask one-hot rather than
    ``take_along_axis``: the gather that op lowers to has a
    scatter-transpose gradient which the xla_extension 0.5.1 backend
    executing the AOT artifacts miscompiles to zeros (bisected against
    the jitted reference). The one-hot form differentiates through plain
    elementwise ops.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    classes = logits.shape[-1]
    onehot = (y[:, None].astype(jnp.int32)
              == jnp.arange(classes, dtype=jnp.int32)[None, :])
    picked = jnp.sum(logp * onehot.astype(logp.dtype), axis=-1)
    return -jnp.mean(picked)


def correct_count(logits, y):
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32))
