"""Adam over the flat parameter vector, with per-group learning rates.

Written explicitly (not optax — build-time dependency discipline, and
the (m, v) state must have a fixed flat layout the Rust coordinator can
checkpoint). Groups get separate scalar learning rates via static 0/1
masks baked into the train-step HLO:

    lr_vec = lr_w * mask_w + lr_g * mask_g + lr_s * mask_s

so PTQ (lr_w = 0), gate freezing (lr_g = 0) and the paper's differing
optimizer treatment of weights vs gates vs ranges (App. B.1) are all
runtime choices of the Rust coordinator, not separate artifacts.
"""

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def adam_update(flat, m, v, grad, lr_vec, step):
    """One Adam step; ``step`` is the 1-based iteration count (f32)."""
    m_new = BETA1 * m + (1.0 - BETA1) * grad
    v_new = BETA2 * v + (1.0 - BETA2) * grad * grad
    m_hat = m_new / (1.0 - BETA1**step)
    v_hat = v_new / (1.0 - BETA2**step)
    flat_new = flat - lr_vec * m_hat / (jnp.sqrt(v_hat) + EPS)
    return flat_new, m_new, v_new
