"""Build-time model core: parameter registry, quantizer registry, context.

Models are written once as plain forward functions over a :class:`Context`.
The same code runs in two modes:

* **build** — executed eagerly with a zeros input; every ``ctx.param``
  call registers a parameter (name, shape, group, init value), every
  quantizer call registers gate slots and layer MAC counts. The result
  is a :class:`ModelSpec` that fixes the flat parameter layout and the
  global gate-slot vector shared with the Rust coordinator (via the
  JSON manifest).
* **apply** — traced under ``jax.jit``; parameters come from one flat
  f32 vector (sliced by the registry offsets) and gate values from one
  flat slot vector. This keeps the AOT train/eval executables down to a
  handful of large inputs, which the Rust runtime marshals cheaply.

Parameter groups: ``'w'`` network weights/biases/affine, ``'g'`` gate
logits phi, ``'s'`` quantizer range scales beta. The groups get separate
learning rates in the train step (PTQ freezes ``'w'`` by ``lr_w = 0``).
"""

import numpy as np
import jax.numpy as jnp  # noqa: F401 (apply-mode arrays flow through here)

GROUPS = ("w", "g", "s")


class ParamSpec:
    """One registered parameter tensor in the flat layout."""

    def __init__(self, name, shape, group, offset, init):
        self.name = name
        self.shape = tuple(int(d) for d in shape)
        self.group = group
        self.offset = offset
        self.size = int(np.prod(self.shape)) if self.shape else 1
        self.init = init  # numpy array, build-time only

    def to_json(self):
        return {
            "name": self.name,
            "shape": list(self.shape),
            "group": self.group,
            "offset": self.offset,
            "size": self.size,
        }


class QuantizerSpec:
    """One quantizer: a pruning-gate block plus the residual-gate chain.

    Slot layout inside the global gate vector: ``channels`` slots for the
    per-channel z2 gates (channels == 1 for per-tensor activation
    quantizers) followed by ``len(levels) - 1`` slots for z4, z8, ...
    """

    def __init__(self, name, kind, signed, channels, levels, layer, offset,
                 consumer_macs):
        self.name = name
        self.kind = kind  # 'w' | 'a'
        self.signed = signed
        self.channels = channels
        self.levels = tuple(levels)
        self.layer = layer
        self.offset = offset  # first slot in the global gate vector
        self.consumer_macs = consumer_macs
        self.n_slots = channels + len(levels) - 1

    def to_json(self):
        return {
            "name": self.name,
            "kind": self.kind,
            "signed": self.signed,
            "channels": self.channels,
            "levels": list(self.levels),
            "layer": self.layer,
            "offset": self.offset,
            "consumer_macs": self.consumer_macs,
            "n_slots": self.n_slots,
        }


class LayerSpec:
    """Compute-layer metadata for MAC/BOP accounting (App. B.2).

    ``spatial`` (conv/dwconv only) carries the layer's execution
    geometry for the integer engine's spatial datapath:
    ``{ksize, stride, padding, groups, in_h, in_w}``. Dense layers omit
    it, and manifests written before the schema addition simply lack
    the keys — the Rust loader defaults those layers to the legacy
    flattened lowering.
    """

    def __init__(self, name, kind, macs, cin, cout, weight_q, act_q,
                 residual_input=False, spatial=None, pre_ops=None):
        self.name = name
        self.kind = kind  # 'conv' | 'dwconv' | 'dense'
        self.macs = macs
        self.cin = cin
        self.cout = cout
        self.weight_q = weight_q  # quantizer name
        self.act_q = act_q  # input-activation quantizer name
        self.residual_input = residual_input  # B.2.3: input not prunable
        self.spatial = spatial
        # interstitial ops between the previous layer and this one
        # ("maxpool2" | "gap" | "flatten"), recorded by the layer
        # library so the engine replays them instead of guessing from
        # shapes
        self.pre_ops = list(pre_ops or [])

    def to_json(self):
        d = {
            "name": self.name,
            "kind": self.kind,
            "macs": self.macs,
            "cin": self.cin,
            "cout": self.cout,
            "weight_q": self.weight_q,
            "act_q": self.act_q,
            "residual_input": self.residual_input,
        }
        if self.spatial is not None:
            d.update(self.spatial)
        if self.pre_ops:
            d["pre"] = list(self.pre_ops)
        return d


class ModelSpec:
    """Frozen result of a build pass."""

    def __init__(self, name, params, quantizers, layers, input_shape,
                 num_classes, levels, dataset):
        self.name = name
        self.params = params
        self.quantizers = quantizers
        self.layers = layers
        self.input_shape = tuple(input_shape)
        self.num_classes = num_classes
        self.levels = tuple(levels)
        self.dataset = dataset
        self.n_params = sum(p.size for p in params)
        self.n_slots = sum(q.n_slots for q in quantizers)
        self.param_index = {p.name: p for p in params}
        self.quant_index = {q.name: q for q in quantizers}

    def init_flat(self):
        flat = np.zeros(self.n_params, dtype=np.float32)
        for p in self.params:
            flat[p.offset:p.offset + p.size] = np.asarray(
                p.init, dtype=np.float32).reshape(-1)
        return flat

    def group_mask(self, group):
        mask = np.zeros(self.n_params, dtype=np.float32)
        for p in self.params:
            if p.group == group:
                mask[p.offset:p.offset + p.size] = 1.0
        return mask

    def lam_base(self):
        """Per-slot BOP-proportional regularizer weights lambda'_{jk}/mu.

        App. B.2.1: lambda'_{jk} = b_j * MACs(l_k) / max_l MACs(l), where
        MACs(l_k) is the MAC count *consuming* the quantized tensor
        (B.2.4 sums over both consumers for tensors feeding two convs).
        Per-channel z2 slots share lambda'_{2k} equally so that the slot
        sum equals the paper's per-quantizer term.
        """
        max_macs = max(l.macs for l in self.layers) if self.layers else 1
        lam = np.zeros(self.n_slots, dtype=np.float32)
        for q in self.quantizers:
            scale = q.consumer_macs / max_macs
            # DQ quantizers (levels == (0,)) have a single slot whose
            # regularizer multiplies the *learned* bit width at runtime,
            # so the base weight is just the MAC scale.
            base_bits = q.levels[0] if q.levels[0] > 0 else 1
            for c in range(q.channels):
                lam[q.offset + c] = base_bits * scale / q.channels
            for i, b in enumerate(q.levels[1:]):
                lam[q.offset + q.channels + i] = b * scale
        return lam

    def to_json(self):
        return {
            "name": self.name,
            "n_params": self.n_params,
            "n_slots": self.n_slots,
            "input_shape": list(self.input_shape),
            "num_classes": self.num_classes,
            "levels": list(self.levels),
            "dataset": self.dataset,
            "params": [p.to_json() for p in self.params],
            "quantizers": [q.to_json() for q in self.quantizers],
            "layers": [l.to_json() for l in self.layers],
            "lam_base": [float(v) for v in self.lam_base()],
        }


class Context:
    """Mode-switched execution context threaded through model forwards."""

    def __init__(self, mode, engine, seed=0):
        assert mode in ("build", "apply")
        self.mode = mode
        self.engine = engine  # quant engine (BB, DQ, or FP32)
        self.rng = np.random.default_rng(seed) if mode == "build" else None
        # build-mode registries
        self.params = []
        self.quantizers = []
        self.layers = []
        self._offset = 0
        self._slot_offset = 0
        self._pending_ops = []
        # apply-mode state
        self.flat = None  # flat parameter vector
        self.gates = None  # flat gate-slot vector
        self._index = None  # name -> ParamSpec

    # -- apply-mode wiring -------------------------------------------------
    def bind(self, spec, flat, gates):
        self.flat = flat
        self.gates = gates
        self._index = spec.param_index
        self._qindex = spec.quant_index
        return self

    # -- parameters ---------------------------------------------------------
    def param(self, name, shape, group, init_fn):
        if self.mode == "build":
            init = np.asarray(init_fn(self.rng, shape), dtype=np.float32)
            assert init.shape == tuple(shape), (name, init.shape, shape)
            spec = ParamSpec(name, shape, group, self._offset, init)
            self.params.append(spec)
            self._offset += spec.size
            return jnp.asarray(init)
        spec = self._index[name]
        seg = self.flat[spec.offset:spec.offset + spec.size]
        return seg.reshape(spec.shape)

    # -- quantizers -----------------------------------------------------------
    def register_quantizer(self, name, kind, signed, channels, levels,
                           layer, consumer_macs):
        spec = QuantizerSpec(name, kind, signed, channels, levels, layer,
                             self._slot_offset, consumer_macs)
        self.quantizers.append(spec)
        self._slot_offset += spec.n_slots
        return spec

    def gate_slots(self, qname):
        q = self._qindex[qname]
        seg = self.gates[q.offset:q.offset + q.n_slots]
        return seg[:q.channels], seg[q.channels:]

    # -- layers ---------------------------------------------------------------
    def note_op(self, name):
        """Record an interstitial op (max_pool2 / global_avg_pool /
        flatten); it attaches to the next recorded layer's ``pre``."""
        if self.mode == "build":
            self._pending_ops.append(name)

    def record_layer(self, name, kind, macs, cin, cout, weight_q, act_q,
                     residual_input=False, spatial=None):
        if self.mode == "build":
            pre, self._pending_ops = self._pending_ops, []
            self.layers.append(LayerSpec(
                name, kind, int(macs), int(cin), int(cout), weight_q, act_q,
                residual_input, spatial, pre))


# -- initializers ------------------------------------------------------------


def he_normal(fan_in):
    def init(rng, shape):
        return rng.normal(0.0, np.sqrt(2.0 / fan_in), size=shape)
    return init


def zeros_init(rng, shape):
    return np.zeros(shape)


def ones_init(rng, shape):
    return np.ones(shape)


def const_init(v):
    def init(rng, shape):
        return np.full(shape, v)
    return init
