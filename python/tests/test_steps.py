"""Train/eval step semantics: locks, groups, determinism, learning."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.models import build_model
from compile.quant import BBEngine, gate_param_index, chains
from compile.dq import DQEngine
from compile import steps
from compile.kernels import ref


@pytest.fixture(scope="module")
def setup():
    eng = BBEngine()
    spec, apply_fn = build_model("lenet5", eng, "small")
    train = jax.jit(steps.build_train_step(spec, apply_fn, eng))
    ev = jax.jit(steps.build_eval_step(spec, apply_fn))
    rng = np.random.default_rng(0)
    B = 16
    x = jnp.asarray(rng.normal(size=(B,) + spec.input_shape)
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, B).astype(np.int32))
    return eng, spec, train, ev, x, y


def base_args(spec, x, y, **kw):
    G = spec.n_slots
    d = dict(
        flat=jnp.asarray(spec.init_flat()),
        m=jnp.zeros(spec.n_params), v=jnp.zeros(spec.n_params),
        x=x, y=y, seed=jnp.int32(7), step=jnp.float32(1),
        lr_w=jnp.float32(1e-3), lr_g=jnp.float32(1e-2),
        lr_s=jnp.float32(1e-3),
        lock_mask=jnp.zeros(G), lock_val=jnp.zeros(G),
        lam=jnp.full(G, 1e-3), det_flag=jnp.float32(0),
    )
    d.update(kw)
    return list(d.values())


def test_loss_decreases_over_steps(setup):
    eng, spec, train, ev, x, y = setup
    args = base_args(spec, x, y)
    flat, m, v = args[0], args[1], args[2]
    losses = []
    for i in range(1, 31):
        out = train(flat, m, v, *args[3:5], jnp.int32(i), jnp.float32(i),
                    *args[7:])
        flat, m, v = out[0], out[1], out[2]
        losses.append(float(out[3]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.7


def test_same_seed_same_result(setup):
    eng, spec, train, ev, x, y = setup
    args = base_args(spec, x, y)
    o1 = train(*args)
    o2 = train(*args)
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))


def test_different_seed_different_gates(setup):
    eng, spec, train, ev, x, y = setup
    args = base_args(spec, x, y)
    o1 = train(*args)
    args[5] = jnp.int32(123)
    o2 = train(*args)
    assert not np.array_equal(np.asarray(o1[0]), np.asarray(o2[0]))


def test_lock_freezes_gate_effect(setup):
    """With all gates locked and lr zeroed the phi params must not move."""
    eng, spec, train, ev, x, y = setup
    G = spec.n_slots
    args = base_args(spec, x, y,
                     lock_mask=jnp.ones(G), lock_val=jnp.ones(G),
                     lr_g=jnp.float32(0.0))
    out = train(*args)
    idx = gate_param_index(spec)
    before = spec.init_flat()[idx]
    after = np.asarray(out[0])[idx]
    np.testing.assert_array_equal(before, after)
    # locked probs are reported as the lock value
    np.testing.assert_array_equal(np.asarray(out[6]), np.ones(G))


def test_lr_w_zero_freezes_weights(setup):
    """PTQ mode: weights stay put, gates/scales move."""
    eng, spec, train, ev, x, y = setup
    args = base_args(spec, x, y, lr_w=jnp.float32(0.0))
    out = train(*args)
    after = np.asarray(out[0])
    before = spec.init_flat()
    mask_w = spec.group_mask("w").astype(bool)
    np.testing.assert_array_equal(before[mask_w], after[mask_w])
    assert not np.array_equal(before[~mask_w], after[~mask_w])


def test_det_flag_removes_noise(setup):
    eng, spec, train, ev, x, y = setup
    a1 = base_args(spec, x, y, det_flag=jnp.float32(1.0))
    o1 = train(*a1)
    a2 = base_args(spec, x, y, det_flag=jnp.float32(1.0),
                   seed=jnp.int32(999))
    o2 = train(*a2)
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))


def test_reg_increases_with_lam(setup):
    eng, spec, train, ev, x, y = setup
    o_small = train(*base_args(spec, x, y, lam=jnp.full(spec.n_slots, 1e-4)))
    o_big = train(*base_args(spec, x, y, lam=jnp.full(spec.n_slots, 1e-1)))
    assert float(o_big[5]) > float(o_small[5])


def test_eval_matches_manual_forward(setup):
    eng, spec, train, ev, x, y = setup
    flat = jnp.asarray(spec.init_flat())
    gates = jnp.ones(spec.n_slots)
    loss, correct = ev(flat, gates, x, y)
    assert np.isfinite(float(loss))
    assert 0 <= float(correct) <= x.shape[0]


def test_eval_fullgates_close_to_fp32(setup):
    """All gates open => 32-bit chain => near-lossless quantization."""
    from compile.quant import FP32Engine
    eng, spec, train, ev, x, y = setup
    spec32, apply32 = build_model("lenet5", FP32Engine(), "small")
    ev32 = jax.jit(steps.build_eval_step(spec32, apply32))
    # share the common (non-quantizer) parameters
    init = spec.init_flat().copy()
    # widen every clip range so only rounding (not clipping) differs
    for q in spec.quantizers:
        p = spec.param_index[q.name + ".beta"]
        init[p.offset] = 64.0
    flat32 = np.zeros(spec32.n_params, np.float32)
    for p32 in spec32.params:
        p = spec.param_index[p32.name]
        flat32[p32.offset:p32.offset + p32.size] = \
            init[p.offset:p.offset + p.size]
    l_bb, c_bb = ev(jnp.asarray(init), jnp.ones(spec.n_slots), x, y)
    l_fp, c_fp = ev32(jnp.asarray(flat32), jnp.zeros(0), x, y)
    np.testing.assert_allclose(float(l_bb), float(l_fp), rtol=2e-2)


def test_chains_product_structure():
    """chain slots = q2c then cumprod of higher gates * mean(q2)."""
    eng = BBEngine(levels=(2, 4, 8))
    spec, _ = build_model("lenet5", eng, "small")
    probs = np.random.default_rng(0).uniform(0.1, 1.0, spec.n_slots) \
        .astype(np.float32)
    ch = np.asarray(chains(spec, jnp.asarray(probs)))
    for q in spec.quantizers:
        p2 = probs[q.offset:q.offset + q.channels]
        ph = probs[q.offset + q.channels:q.offset + q.n_slots]
        np.testing.assert_allclose(ch[q.offset:q.offset + q.channels], p2,
                                   rtol=1e-5)
        expect = np.cumprod(ph) * p2.mean()
        np.testing.assert_allclose(
            ch[q.offset + q.channels:q.offset + q.n_slots], expect,
            rtol=1e-4)


def test_dq_train_step_runs_and_bits_shrink():
    eng = DQEngine()
    spec, apply_fn = build_model("lenet5", eng, "small")
    train = jax.jit(steps.build_train_step(spec, apply_fn, eng))
    rng = np.random.default_rng(0)
    B = 16
    x = jnp.asarray(rng.normal(size=(B,) + spec.input_shape)
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, B).astype(np.int32))
    G = spec.n_slots
    flat = jnp.asarray(spec.init_flat())
    m = jnp.zeros(spec.n_params)
    v = jnp.zeros(spec.n_params)
    bits0 = None
    for i in range(1, 40):
        out = train(flat, m, v, x, y, jnp.int32(i), jnp.float32(i),
                    jnp.float32(0), jnp.float32(5e-2), jnp.float32(0),
                    jnp.zeros(G), jnp.zeros(G), jnp.full(G, 0.05),
                    jnp.float32(0))
        flat, m, v = out[0], out[1], out[2]
        if bits0 is None:
            bits0 = np.asarray(out[6]).copy()
    bits = np.asarray(out[6])
    assert bits.mean() < bits0.mean()  # BOP regularizer pushes bits down
