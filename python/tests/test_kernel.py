"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, ranges, gate patterns and signedness;
assert_allclose against ref.bb_quantize_ref on every draw.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bayesian_bits import bb_quantize

LEVELS_CHOICES = [(2,), (2, 4), (2, 4, 8), (2, 4, 8, 16), (2, 4, 8, 16, 32)]


def make_inputs(rows, cols, beta, seed, signed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, beta, size=(rows, cols)).astype(np.float32)
    if not signed:
        x = np.abs(x)
    return jnp.asarray(x)


@settings(max_examples=60, deadline=None)
@given(
    rows=st.integers(1, 12),
    cols=st.integers(1, 24),
    beta=st.floats(0.1, 8.0),
    seed=st.integers(0, 2**31 - 1),
    signed=st.booleans(),
    levels_i=st.integers(0, len(LEVELS_CHOICES) - 1),
    data=st.data(),
)
def test_kernel_matches_ref(rows, cols, beta, seed, signed, levels_i, data):
    levels = LEVELS_CHOICES[levels_i]
    x = make_inputs(rows, cols, beta, seed, signed)
    b = jnp.asarray([beta], jnp.float32)
    z2 = jnp.asarray(
        data.draw(st.lists(st.sampled_from([0.0, 0.3, 1.0]),
                           min_size=rows, max_size=rows)), jnp.float32)
    zh = jnp.asarray(
        data.draw(st.lists(st.floats(0.0, 1.0), min_size=len(levels) - 1,
                           max_size=len(levels) - 1)), jnp.float32)
    out_k = bb_quantize(x, b, z2, zh, signed=signed, levels=levels)
    out_r = ref.bb_quantize_ref(x, b, z2, zh, signed, levels=levels)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("block_rows", [None, 2, 4])
def test_block_tiling_invariant(signed, block_rows):
    """Tiling the grid must not change the numbers."""
    x = make_inputs(8, 16, 2.0, 7, signed)
    b = jnp.asarray([2.0])
    z2 = jnp.ones(8)
    zh = jnp.asarray([1.0, 1.0, 0.5, 0.0])
    full = bb_quantize(x, b, z2, zh, signed=signed, block_rows=None)
    tiled = bb_quantize(x, b, z2, zh, signed=signed, block_rows=block_rows)
    np.testing.assert_allclose(full, tiled, rtol=0, atol=0)


@pytest.mark.parametrize(
    "bit,zh", [(2, [0, 0, 0, 0]), (4, [1, 0, 0, 0]), (8, [1, 1, 0, 0]),
               (16, [1, 1, 1, 0]), (32, [1, 1, 1, 1])])
@pytest.mark.parametrize("signed", [True, False])
def test_gated_chain_equals_fixed_quantizer(bit, zh, signed):
    """Gates open to level b  <=>  plain uniform b-bit quantizer (Fig. 1)."""
    x = make_inputs(16, 32, 1.5, 11, signed)
    b = jnp.asarray([1.5])
    out = bb_quantize(x, b, jnp.ones(16), jnp.asarray(zh, jnp.float32),
                      signed=signed)
    fixed = ref.quantize_fixed(x, b, bit, signed)
    np.testing.assert_allclose(out, fixed, rtol=1e-4, atol=1e-6)


def test_pruned_channels_are_zero():
    x = make_inputs(6, 10, 2.0, 3, True)
    z2 = jnp.asarray([1, 0, 1, 0, 0, 1], jnp.float32)
    out = bb_quantize(x, jnp.asarray([2.0]), z2, jnp.ones(4), signed=True)
    np.testing.assert_array_equal(np.asarray(out)[1], 0.0)
    np.testing.assert_array_equal(np.asarray(out)[3], 0.0)
    np.testing.assert_array_equal(np.asarray(out)[4], 0.0)
    assert np.abs(np.asarray(out)[0]).sum() > 0


def test_use_pallas_false_matches_true():
    x = make_inputs(8, 8, 2.0, 5, True)
    args = (jnp.asarray([2.0]), jnp.ones(8), jnp.asarray([1., 1., 1., 1.]))
    a = bb_quantize(x, *args, signed=True, use_pallas=True)
    b = bb_quantize(x, *args, signed=True, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


class TestGradients:
    def setup_method(self):
        self.x = make_inputs(4, 6, 2.0, 17, True)
        self.beta = jnp.asarray([2.0])
        self.z2 = jnp.ones(4)
        self.zh = jnp.asarray([1.0, 1.0, 0.5, 0.2])

    def loss(self, x, beta, z2, zh):
        return jnp.sum(
            bb_quantize(x, beta, z2, zh, signed=True) ** 2)

    def test_grad_shapes(self):
        g = jax.grad(self.loss, argnums=(0, 1, 2, 3))(
            self.x, self.beta, self.z2, self.zh)
        assert g[0].shape == self.x.shape
        assert g[1].shape == (1,)
        assert g[2].shape == (4,)
        assert g[3].shape == (4,)

    def test_ste_inside_range_is_gated_identity(self):
        """dxq/dx == z2 inside the clip range (STE)."""
        x = jnp.asarray([[0.3]], jnp.float32)
        for z2v in (1.0, 0.5, 0.0):
            g = jax.grad(lambda x: jnp.sum(bb_quantize(
                x, self.beta, jnp.asarray([z2v]), self.zh, signed=True)))(x)
            np.testing.assert_allclose(g[0, 0], z2v, rtol=1e-6)

    def test_ste_outside_range_flows_to_beta(self):
        """Clipped elements route gradient to beta, not x (PACT)."""
        x = jnp.asarray([[5.0]], jnp.float32)  # above beta=2
        gx = jax.grad(lambda x: jnp.sum(bb_quantize(
            x, self.beta, jnp.ones(1), self.zh, signed=True)))(x)
        gb = jax.grad(lambda b: jnp.sum(bb_quantize(
            x, b, jnp.ones(1), self.zh, signed=True)))(self.beta)
        assert float(gx[0, 0]) == 0.0
        assert float(gb[0]) > 0.0

    def test_gate_grad_matches_residual_magnitude(self):
        """dxq/dz4 == z2 * (e4 + z8*(...)): finite-difference check."""
        def f(zh):
            return jnp.sum(bb_quantize(self.x, self.beta, self.z2, zh,
                                       signed=True))
        g = jax.grad(f)(self.zh)
        eps = 1e-3
        for i in range(4):
            zp = self.zh.at[i].add(eps)
            zm = self.zh.at[i].add(-eps)
            fd = (f(zp) - f(zm)) / (2 * eps)
            np.testing.assert_allclose(g[i], fd, rtol=1e-2, atol=1e-3)
