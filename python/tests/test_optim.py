"""Adam vs an independent numpy reference + group-mask semantics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import optim


def numpy_adam(p, m, v, g, lr, t, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    return p - lr * mh / (np.sqrt(vh) + eps), m, v


@given(seed=st.integers(0, 10_000), t=st.integers(1, 100),
       lr=st.floats(1e-5, 1e-1))
@settings(max_examples=40, deadline=None)
def test_adam_matches_numpy(seed, t, lr):
    rng = np.random.default_rng(seed)
    n = 64
    p = rng.normal(size=n).astype(np.float32)
    m = rng.normal(size=n).astype(np.float32) * 0.1
    v = np.abs(rng.normal(size=n)).astype(np.float32) * 0.01
    g = rng.normal(size=n).astype(np.float32)
    got = optim.adam_update(jnp.asarray(p), jnp.asarray(m), jnp.asarray(v),
                            jnp.asarray(g), jnp.float32(lr), jnp.float32(t))
    want = numpy_adam(p.astype(np.float64), m.astype(np.float64),
                      v.astype(np.float64), g.astype(np.float64), lr, t)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), b, rtol=2e-4, atol=1e-6)


def test_zero_lr_is_identity():
    p = jnp.asarray(np.arange(8, dtype=np.float32))
    g = jnp.ones(8)
    p2, m2, v2 = optim.adam_update(p, jnp.zeros(8), jnp.zeros(8), g,
                                   jnp.float32(0.0), jnp.float32(1))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))
    # optimizer state still accumulates
    assert float(jnp.sum(jnp.abs(m2))) > 0


def test_per_element_lr_vector():
    p = jnp.zeros(4)
    g = jnp.ones(4)
    lr_vec = jnp.asarray([0.0, 1e-2, 0.0, 1e-2])
    p2, _, _ = optim.adam_update(p, jnp.zeros(4), jnp.zeros(4), g, lr_vec,
                                 jnp.float32(1))
    out = np.asarray(p2)
    assert out[0] == 0 and out[2] == 0
    assert out[1] < 0 and out[3] < 0
