"""Model-zoo build/apply checks: shapes, registries, MACs, engines."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.models import build_model, MODELS
from compile.quant import BBEngine, FP32Engine
from compile.dq import DQEngine
from compile import layers as L


@pytest.fixture(scope="module")
def lenet():
    eng = BBEngine()
    spec, apply_fn = build_model("lenet5", eng, "small")
    return eng, spec, apply_fn


@pytest.mark.parametrize("name", list(MODELS))
def test_build_and_apply_all_models(name):
    eng = BBEngine()
    spec, apply_fn = build_model(name, eng, "small")
    assert spec.n_params > 0 and spec.n_slots > 0
    flat = jnp.asarray(spec.init_flat())
    gates = jnp.ones(spec.n_slots)
    x = jnp.zeros((2,) + spec.input_shape)
    logits = apply_fn(flat, gates, x)
    assert logits.shape == (2, spec.num_classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_layout_contiguous(lenet):
    _, spec, _ = lenet
    off = 0
    for p in spec.params:
        assert p.offset == off
        off += p.size
    assert off == spec.n_params


def test_gate_slot_layout_contiguous(lenet):
    _, spec, _ = lenet
    off = 0
    for q in spec.quantizers:
        assert q.offset == off
        assert q.n_slots == q.channels + len(q.levels) - 1
        off += q.n_slots
    assert off == spec.n_slots


def test_every_quantizer_has_phi_and_beta(lenet):
    _, spec, _ = lenet
    for q in spec.quantizers:
        phi = spec.param_index[q.name + ".phi"]
        beta = spec.param_index[q.name + ".beta"]
        assert phi.size == q.n_slots and phi.group == "g"
        assert beta.size == 1 and beta.group == "s"


def test_weight_quantizers_per_channel(lenet):
    _, spec, _ = lenet
    w_quants = [q for q in spec.quantizers if q.kind == "w"]
    assert w_quants, "no weight quantizers registered"
    for q in w_quants:
        layer = next(l for l in spec.layers if l.weight_q == q.name)
        assert q.channels == layer.cout
        assert q.signed


def test_act_quantizers_per_tensor(lenet):
    _, spec, _ = lenet
    a_quants = [q for q in spec.quantizers if q.kind == "a"]
    assert a_quants
    for q in a_quants:
        assert q.channels == 1


def test_mac_counts_match_formula(lenet):
    _, spec, _ = lenet
    by_name = {l.name: l for l in spec.layers}
    # conv1: 16x16 SAME stride1, 1->8 channels, 5x5 kernel
    assert by_name["conv1"].macs == 16 * 16 * 8 * 1 * 5 * 5
    assert by_name["conv2"].macs == 8 * 8 * 16 * 8 * 5 * 5
    assert by_name["fc1"].macs == 4 * 4 * 16 * 64
    assert by_name["fc2"].macs == 64 * 10


def test_lam_base_scaling(lenet):
    """lambda'_{jk} = b_j MACs/maxMAC, split equally over channel slots."""
    _, spec, _ = lenet
    lam = spec.lam_base()
    max_macs = max(l.macs for l in spec.layers)
    for q in spec.quantizers:
        scale = q.consumer_macs / max_macs
        np.testing.assert_allclose(
            lam[q.offset:q.offset + q.channels].sum(), 2 * scale, rtol=1e-4)
        for i, b in enumerate(q.levels[1:]):
            np.testing.assert_allclose(
                lam[q.offset + q.channels + i], b * scale, rtol=1e-4)


def test_pruning_gate_zeroes_channel_logits_effect(lenet):
    """Closing all weight z2 gates of fc2 must freeze logits to bias."""
    _, spec, apply_fn = lenet
    flat = jnp.asarray(spec.init_flat())
    gates = np.ones(spec.n_slots, np.float32)
    q = spec.quant_index["fc2.w"]
    gates[q.offset:q.offset + q.channels] = 0.0
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(2,) + spec.input_shape).astype(np.float32))
    logits = apply_fn(flat, jnp.asarray(gates), x)
    bias = np.asarray(flat[spec.param_index["fc2.b"].offset:
                           spec.param_index["fc2.b"].offset + 10])
    np.testing.assert_allclose(np.asarray(logits),
                               np.tile(bias, (2, 1)), atol=1e-5)


def test_fp32_engine_has_no_quantizers():
    spec, apply_fn = build_model("lenet5", FP32Engine(), "small")
    assert spec.n_slots == 0
    assert all(".phi" not in p.name for p in spec.params)


def test_dq_engine_one_slot_per_quantizer():
    eng = DQEngine()
    spec, apply_fn = build_model("lenet5", eng, "small")
    assert all(q.n_slots == 1 for q in spec.quantizers)
    flat = jnp.asarray(spec.init_flat())
    bits = eng.bits(spec, flat)
    # initialized as an 8-bit quantizer
    np.testing.assert_allclose(np.asarray(bits), 8.0, atol=0.1)


def test_resnet_shared_input_quantizer():
    """Downsample convs reuse the block-input quantizer (B.2.4)."""
    spec, _ = build_model("resnet18", BBEngine(), "small")
    ds_layers = [l for l in spec.layers if l.name.endswith(".ds")]
    assert ds_layers
    for l in ds_layers:
        assert l.act_q.endswith(".conv1.in")
        q = spec.quant_index[l.act_q]
        conv1 = next(x for x in spec.layers
                     if x.name == l.name.replace(".ds", ".conv1"))
        # shared quantizer's consumer MACs covers both convs
        assert q.consumer_macs == conv1.macs + l.macs


def test_depthwise_macs():
    spec, _ = build_model("mobilenetv2", BBEngine(), "small")
    dw = [l for l in spec.layers if l.kind == "dwconv"]
    assert dw
    for l in dw:
        assert l.cin == l.cout  # depthwise
        # B == 1 in the paper's MAC formula
        assert l.macs % (l.cout * 9) == 0


def test_cross_entropy_and_correct():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]])
    y = jnp.asarray([0, 1, 1], jnp.int32)
    ce = L.cross_entropy(logits, y)
    assert float(ce) > 0
    assert float(L.correct_count(logits, y)) == 2.0
