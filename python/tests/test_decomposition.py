"""Decomposition identities from §2.1 / Fig. 1 — properties of the grids."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@given(beta=st.floats(0.05, 50.0), signed=st.booleans())
@settings(max_examples=50, deadline=None)
def test_step_size_recursion_equals_closed_form(beta, signed):
    """s_b = s_{b/2}/(2^{b/2}+1)  ==  (beta-alpha)/(2^b-1)  for all b."""
    sizes = ref.step_sizes(jnp.asarray([beta]), signed)
    span = (2.0 if signed else 1.0) * beta
    for s, b in zip(sizes, ref.LEVELS):
        np.testing.assert_allclose(
            float(s[0]), span / (2.0**b - 1.0), rtol=1e-5)


def test_fig1_identity():
    """(2^4 - 1) == (2^2 - 1)(2^2 + 1) and its higher-order versions."""
    for b in (4, 8, 16, 32):
        h = b // 2
        assert (2**b - 1) == (2**h - 1) * (2**h + 1)


@given(seed=st.integers(0, 10_000), beta=st.floats(0.2, 4.0),
       signed=st.booleans())
@settings(max_examples=40, deadline=None)
def test_residuals_bounded_by_half_step(seed, beta, signed):
    """x - x_b lies in [-s_b/2, s_b/2] after every chain stage (§2.1)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, beta, size=(4, 32)).astype(np.float32)
    if not signed:
        x = np.abs(x)
    b = jnp.asarray([beta])
    x2, residuals = ref.decompose(jnp.asarray(x), b, signed)
    sizes = ref.step_sizes(b, signed)
    alpha, beta_grid, beta_clip, alpha_clip = ref.effective_range(b, signed)
    xc = np.asarray(ref.pact_clip(jnp.asarray(x), alpha_clip, beta_clip))
    x_cur = np.asarray(x2)
    for i, eps in enumerate(residuals):
        s = float(sizes[i][0])  # step of the level we just *came from*
        assert np.all(np.abs(xc - x_cur) <= s / 2 + 1e-6)
        x_cur = x_cur + np.asarray(eps)


@given(seed=st.integers(0, 10_000), bit_i=st.integers(0, 4),
       signed=st.booleans())
@settings(max_examples=40, deadline=None)
def test_partial_sums_live_on_their_grid(seed, bit_i, signed):
    """x_2 + eps_4 + ... + eps_b is an integer multiple of s_b."""
    rng = np.random.default_rng(seed)
    beta = 2.0
    x = rng.normal(0, 2, size=(4, 16)).astype(np.float32)
    if not signed:
        x = np.abs(x)
    b = jnp.asarray([beta])
    x2, residuals = ref.decompose(jnp.asarray(x), b, signed)
    sizes = ref.step_sizes(b, signed)
    partial = np.asarray(x2, dtype=np.float64)
    for i in range(bit_i):
        partial = partial + np.asarray(residuals[i], dtype=np.float64)
    s = float(sizes[bit_i][0])
    ratio = partial / s
    np.testing.assert_allclose(ratio, np.round(ratio), atol=2e-2)


def test_quantization_error_shrinks_with_each_gate():
    """Quantization error vs the *clipped* tensor vanishes as gates open
    (the clipping error itself is range-, not bit-width-, limited)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1.5, size=(8, 64)).astype(np.float32))
    beta = jnp.asarray([2.0])
    z2 = jnp.ones(8)
    alpha, bg, bc, ac = ref.effective_range(beta, True)
    xc = ref.pact_clip(x, ac, bc)
    errs = []
    for k in range(5):
        zh = jnp.asarray([1.0] * k + [0.0] * (4 - k))
        xq = ref.bb_quantize_ref(x, beta, z2, zh, True)
        errs.append(float(jnp.mean((xc - xq) ** 2)))
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo < hi * 0.5, errs  # each extra gate at least halves MSE
    assert errs[-1] < 1e-9  # 32-bit chain ~ lossless vs clipped input at f32


def test_unsigned_output_nonnegative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.abs(rng.normal(0, 2, (4, 16))).astype(np.float32))
    xq = ref.bb_quantize_ref(x, jnp.asarray([1.5]), jnp.ones(4),
                             jnp.ones(4), False)
    assert float(jnp.min(xq)) >= 0.0


def test_output_within_range():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 10, (4, 64)).astype(np.float32))
    beta = 1.25
    xq = ref.bb_quantize_ref(x, jnp.asarray([beta]), jnp.ones(4),
                             jnp.ones(4), True)
    assert float(jnp.max(jnp.abs(xq))) <= beta + 1e-6


class TestHardConcrete:
    def test_prob_active_matches_empirical(self):
        rng = np.random.default_rng(0)
        for phi in (-2.0, 0.0, 1.0, 3.0):
            u = jnp.asarray(rng.uniform(1e-6, 1 - 1e-6, 200_000)
                            .astype(np.float32))
            z = ref.hard_concrete_sample(jnp.float32(phi), u)
            emp = float(jnp.mean((z > 0).astype(jnp.float32)))
            theory = float(ref.prob_active(jnp.float32(phi)))
            assert abs(emp - theory) < 5e-3

    def test_samples_hit_exact_zero_and_one(self):
        rng = np.random.default_rng(1)
        u = jnp.asarray(rng.uniform(1e-6, 1 - 1e-6, 10_000)
                        .astype(np.float32))
        z = np.asarray(ref.hard_concrete_sample(jnp.float32(0.0), u))
        assert (z == 0.0).sum() > 0 and (z == 1.0).sum() > 0
        assert np.all((z >= 0) & (z <= 1))

    def test_threshold_consistent_with_p_zero(self):
        """Eq. 22: gate open iff P(z==0) < t."""
        for phi in np.linspace(-4, 4, 41):
            gate = float(ref.test_time_gate(jnp.float32(phi)))
            p_zero = 1.0 - float(ref.prob_active(jnp.float32(phi)))
            assert gate == (1.0 if p_zero < ref.THRESHOLD else 0.0)

    def test_deterministic_gate_is_mean(self):
        z = ref.hard_concrete_sample(jnp.float32(1.3),
                                     jnp.float32(0.5))
        np.testing.assert_allclose(
            float(z), float(ref.hard_concrete_mean(jnp.float32(1.3))),
            rtol=1e-6)
