"""Artifact consistency: manifests vs built specs, HLO text parseability."""

import json
import os

import numpy as np
import pytest

from compile.models import build_model
from compile.quant import BBEngine

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "lenet5_manifest.json")),
    reason="artifacts not built (run `make artifacts`)")


def load_manifest(name):
    with open(os.path.join(ART, f"{name}_manifest.json")) as f:
        return json.load(f)


@pytest.mark.parametrize("name", ["lenet5", "vgg7", "resnet18",
                                  "mobilenetv2"])
def test_manifest_matches_fresh_build(name):
    man = load_manifest(name)
    spec, _ = build_model(name, BBEngine(), man["preset"])
    assert man["n_params"] == spec.n_params
    assert man["n_slots"] == spec.n_slots
    assert [p["name"] for p in man["params"]] == \
        [p.name for p in spec.params]
    assert [q["offset"] for q in man["quantizers"]] == \
        [q.offset for q in spec.quantizers]
    np.testing.assert_allclose(man["lam_base"], spec.lam_base(), rtol=1e-5)


@pytest.mark.parametrize("name", ["lenet5", "vgg7", "resnet18",
                                  "mobilenetv2"])
def test_init_bin_size_and_values(name):
    man = load_manifest(name)
    raw = np.fromfile(os.path.join(ART, man["init_file"]), dtype=np.float32)
    assert raw.size == man["n_params"]
    assert np.all(np.isfinite(raw))
    spec, _ = build_model(name, BBEngine(), man["preset"])
    np.testing.assert_array_equal(raw, spec.init_flat())


def test_hlo_text_is_parseable_header():
    """HLO text must start with an HloModule header (text interchange)."""
    for f in os.listdir(ART):
        if f.endswith(".hlo.txt"):
            with open(os.path.join(ART, f)) as fh:
                head = fh.read(64)
            assert head.startswith("HloModule"), f


def test_goldens_match_ref():
    from compile.kernels import ref
    import jax.numpy as jnp
    with open(os.path.join(ART, "goldens.json")) as f:
        g = json.load(f)
    shape = tuple(g["shape"])
    for case in g["cases"]:
        x = jnp.asarray(np.asarray(case["x"], np.float32).reshape(shape))
        out = ref.bb_quantize_ref(
            x, jnp.asarray(case["beta"]), jnp.asarray(case["z2"]),
            jnp.asarray(case["zh"]), True, levels=tuple(g["levels"]))
        np.testing.assert_allclose(
            np.asarray(out).reshape(-1), case["out"], rtol=1e-5, atol=1e-6)


def test_train_hlo_mentions_no_custom_calls():
    """Interpret-mode Pallas must lower to plain HLO (no Mosaic calls)."""
    for name in ("lenet5", "resnet18"):
        man = load_manifest(name)
        with open(os.path.join(ART, man["hlo_train"])) as f:
            text = f.read()
        assert "mosaic" not in text.lower()


def test_manifest_lists_io_contract():
    man = load_manifest("lenet5")
    assert man["train_args"][:5] == ["params", "adam_m", "adam_v", "x", "y"]
    assert man["train_outputs"][-1] == "probs"
    assert man["eval_args"] == ["params", "gates", "x", "y"]
    assert man["batch"] > 0
