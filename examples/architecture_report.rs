//! Architecture report — train one Bayesian Bits configuration and dump
//! the learned per-layer bit widths and channel sparsity (Figure 6 /
//! Figures 15-18 style), plus analytic paper-scale BOP context.
//!
//!     cargo run --release --example architecture_report -- \
//!         --model vgg7 --mu 0.05 --quick

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use bayesian_bits::bops::{BopCounter, QuantState};
use bayesian_bits::cli::Args;
use bayesian_bits::config::Mode;
use bayesian_bits::coordinator::trainer::Trainer;
use bayesian_bits::experiments::common::ExpOptions;
use bayesian_bits::models::{descriptor, Preset};
use bayesian_bits::report::arch_viz;
use bayesian_bits::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let opt = ExpOptions::from_args(&args)?;
    let model = args.str_flag("model", "vgg7");
    let mu = args.f64_flag("mu", 0.05)?;

    let rt = Arc::new(Runtime::cpu()?);
    let man = Manifest::load(Path::new(&opt.artifacts_dir), &model)?;
    let cfg = opt.config(&model, Mode::BayesianBits, mu, 1);
    let mut trainer = Trainer::new(rt, man.clone(), cfg)?;
    let result = trainer.run()?;

    println!(
        "trained {model} with mu={mu}: acc {:.2}%, rel GBOPs {:.2}%",
        result.accuracy * 100.0, result.rel_bops_pct
    );
    println!("{}", arch_viz::architecture_report(&man, &result.states));
    println!("{}", arch_viz::summary_line(&man, &result.states));

    // What would this learned configuration cost at *paper scale*?
    // Map learned per-layer bits onto the full-size descriptor by layer
    // name (the topologies match 1:1 across presets).
    let paper = descriptor(model.trim_end_matches("_dq"), Preset::Paper)?;
    let counter = BopCounter::new(paper.clone());
    let mut states: BTreeMap<String, QuantState> = BTreeMap::new();
    for l in &paper {
        if let Some(s) = result.states.get(&l.weight_q) {
            states.insert(l.weight_q.clone(), *s);
        }
        if let Some(s) = result.states.get(&l.act_q) {
            states.insert(l.act_q.clone(), *s);
        }
    }
    println!(
        "projected to paper-scale {model}: {:.2}% of FP32 GBOPs \
         ({:.3} GBOPs absolute)",
        counter.relative_bops_pct(&states),
        counter.bops(&states) / 1e9
    );
    Ok(())
}
