//! Quickstart — the end-to-end driver proving all three layers compose.
//!
//! Trains LeNet-5 on the synthetic MNIST-like task with full Bayesian
//! Bits (joint pruning + mixed precision) for a few hundred steps,
//! logging the loss curve and the live expected-BOPs estimate, then
//! thresholds the gates (Eq. 22), fine-tunes, and prints the learned
//! per-layer bit allocation.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --steps N --mu F --model M (default lenet5).

use std::path::Path;
use std::sync::Arc;

use bayesian_bits::cli::Args;
use bayesian_bits::config::Mode;
use bayesian_bits::coordinator::trainer::Trainer;
use bayesian_bits::experiments::common::ExpOptions;
use bayesian_bits::report::arch_viz;
use bayesian_bits::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let opt = ExpOptions::from_args(&args)?;
    let model = args.str_flag("model", "lenet5");
    let mu = args.f64_flag("mu", 0.01)?;
    let steps = args.usize_flag("steps", 300)?;

    println!("== Bayesian Bits quickstart ==");
    println!("model={model} mu={mu} steps={steps} (+{} fine-tune)",
             steps / 4);

    let rt = Arc::new(Runtime::cpu()?);
    let man = Manifest::load(Path::new(&opt.artifacts_dir), &model)?;
    println!(
        "artifact: P={} params, G={} gate slots, {} layers, batch={}",
        man.n_params, man.n_slots, man.layers.len(), man.batch
    );

    let mut cfg = opt.config(&model, Mode::BayesianBits, mu, 1);
    cfg.steps = steps;
    cfg.finetune_steps = steps / 4;
    cfg.eval_every = (steps / 8).max(1);
    let mut trainer = Trainer::new(rt, man.clone(), cfg)?;
    let result = trainer.run()?;

    println!("\nloss curve (phase 1 + 2):");
    let stride = (result.history.steps.len() / 20).max(1);
    for rec in result.history.steps.iter().step_by(stride) {
        println!(
            "  step {:>5}  loss {:>7.4}  batch-acc {:>5.1}%  \
             exp-BOPs {:>6.2}%",
            rec.step, rec.loss, rec.batch_acc * 100.0, rec.exp_bops_pct
        );
    }

    println!(
        "\nfinal: accuracy {:.2}% (pre-FT {:.2}%), relative GBOPs {:.2}% \
         of FP32",
        result.accuracy * 100.0,
        result.pre_ft_accuracy * 100.0,
        result.rel_bops_pct
    );
    println!("{}", arch_viz::architecture_report(&man, &result.states));
    println!("{}", arch_viz::summary_line(&man, &result.states));
    Ok(())
}
