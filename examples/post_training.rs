//! Post-training mixed precision (§4.2.1) on a pretrained model —
//! the low-data/low-compute deployment workflow:
//!
//! 1. pretrain (or load a cached) full-precision-equivalent model;
//! 2. with frozen weights, learn the gates (and optionally the clip
//!    ranges) under a BOP-proportional prior for a handful of steps;
//! 3. compare against the iterative sensitivity-ordered baseline and a
//!    fixed 8/8 configuration.
//!
//!     cargo run --release --example post_training -- --model lenet5 \
//!         --mus 0.001,0.01 --quick

use std::sync::Arc;

use bayesian_bits::cli::Args;
use bayesian_bits::config::{presets, RunConfig};
use bayesian_bits::coordinator::ptq;
use bayesian_bits::experiments::common::ExpOptions;
use bayesian_bits::report::TableBuilder;
use bayesian_bits::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let opt = ExpOptions::from_args(&args)?;
    let model = args.str_flag("model", "lenet5");
    let mus = args.f64_list_flag("mus", &[0.001, 0.01])?;
    let steps = args.usize_flag("steps", 120)?;

    let rt = Arc::new(Runtime::cpu()?);
    let man = Manifest::load(
        std::path::Path::new(&opt.artifacts_dir), &model)?;
    let mut base_cfg = RunConfig {
        model: model.clone(),
        artifacts_dir: opt.artifacts_dir.clone(),
        ..presets::base_config(&model)
    };
    if opt.quick {
        base_cfg.steps = (base_cfg.steps / 5).max(50);
    }
    let ckpt = opt.out_path(&format!("{model}_pretrained.ckpt"));
    println!("pretraining (or loading) base model -> {ckpt:?}");
    let base = ptq::pretrain_or_load(rt.clone(), &man, &base_cfg, &ckpt)?;

    let mut t = TableBuilder::new(
        &format!("Post-training mixed precision — {model}"),
        &["Variant", "mu", "Acc. (%)", "Rel. GBOPs (%)"],
    );
    for mu in &mus {
        for scales in [false, true] {
            let p = ptq::ptq_learn(rt.clone(), &man, &base, *mu, scales,
                                   steps, 1, 3e-2)?;
            t.row(&[
                p.label.clone(),
                format!("{mu}"),
                format!("{:.2}", p.accuracy * 100.0),
                format!("{:.2}", p.rel_bops_pct),
            ]);
        }
    }
    let fixed = ptq::fixed_point(rt.clone(), &man, &base, 8, 8)?;
    t.row(&[
        fixed.label.clone(), "-".into(),
        format!("{:.2}", fixed.accuracy * 100.0),
        format!("{:.2}", fixed.rel_bops_pct),
    ]);
    println!("{}", t.render());

    println!("sensitivity-ordered iterative baseline (cumulative):");
    let sens = ptq::sensitivity_baseline(rt, &man, &base, 4)?;
    for (k, p) in sens.iter().enumerate() {
        println!("  {k:>3} quantizers lowered: acc {:>6.2}%  rel GBOPs \
                  {:>6.2}%", p.accuracy * 100.0, p.rel_bops_pct);
    }
    Ok(())
}
