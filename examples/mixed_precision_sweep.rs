//! Mixed-precision sweep — a Table 1-style accuracy vs relative-GBOPs
//! trade-off curve on one model, sweeping the global regularization
//! strength mu and comparing against fixed-width baselines.
//!
//!     cargo run --release --example mixed_precision_sweep -- \
//!         --model vgg7 --mus 0.01,0.05,0.1 --quick
//!
//! This is the workflow a practitioner uses to pick an operating point
//! (App. B.2.1: "experiment with a range of regularization strengths to
//! generate a Pareto curve").

use bayesian_bits::cli::Args;
use bayesian_bits::config::Mode;
use bayesian_bits::coordinator::sweep::{aggregate, run_sweep, Job};
use bayesian_bits::experiments::common::ExpOptions;
use bayesian_bits::report::plot::{scatter, Series};
use bayesian_bits::report::TableBuilder;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let opt = ExpOptions::from_args(&args)?;
    let model = args.str_flag("model", "lenet5");
    let mus = args.f64_list_flag("mus", &[0.01, 0.05, 0.1])?;

    let mut jobs: Vec<Job> = Vec::new();
    for mu in &mus {
        jobs.extend(opt.jobs_for(&model, Mode::BayesianBits, *mu));
    }
    for (w, a) in [(8u32, 8u32), (4, 4), (2, 2)] {
        jobs.extend(opt.jobs_for(&model,
                                 Mode::Fixed { w_bits: w, a_bits: a },
                                 0.0));
    }
    let results = run_sweep(jobs, opt.jobs)?;
    let aggs = aggregate(&results);

    let mut t = TableBuilder::new(
        &format!("Mixed-precision sweep — {model}"),
        &["Method", "Acc. (%)", "Rel. GBOPs (%)"],
    );
    for a in &aggs {
        let label = if a.mu > 0.0 {
            format!("Bayesian Bits mu={}", a.mu)
        } else {
            a.mode.clone()
        };
        t.row(&[
            label,
            TableBuilder::pm(a.acc_mean * 100.0, a.acc_stderr * 100.0, 2),
            TableBuilder::pm(a.bops_mean, a.bops_stderr, 2),
        ]);
    }
    println!("{}", t.render());

    let series = [
        Series {
            label: "Bayesian Bits".into(),
            marker: 'o',
            points: aggs.iter().filter(|a| a.mode == "bb")
                .map(|a| (a.bops_mean, a.acc_mean * 100.0)).collect(),
        },
        Series {
            label: "fixed wXaY".into(),
            marker: 'x',
            points: aggs.iter().filter(|a| a.mode.starts_with("fixed"))
                .map(|a| (a.bops_mean, a.acc_mean * 100.0)).collect(),
        },
    ];
    println!("{}", scatter(
        &format!("{model}: accuracy vs relative GBOPs"),
        "rel GBOPs (%)", "acc (%)", &series, 60, 18, true));
    Ok(())
}
