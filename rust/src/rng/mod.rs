//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! [`Pcg64`] is the PCG-XSL-RR 128/64 generator — fast, statistically
//! solid, and with a tiny state that makes dataset generation exactly
//! reproducible across runs and threads (each shard derives its own
//! stream from a seed + stream id).

/// PCG-XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream for parallel shards.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Unbiased uniform integer in [0, bound).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    pub fn normal_scaled(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(7, 1);
        let mut b = Pcg64::with_stream(7, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::new(3);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = rng.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&v));
            sum += v as f64;
        }
        assert!((sum / n as f64 - 3.0).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut rng = Pcg64::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut p = rng.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }
}
