//! Table 4 + Figures 7/8/9: the full ResNet18 grid — Bayesian Bits over
//! the mu grid, quantization-only (QO), pruning-only at w4a8 (PO48) and
//! w8a8 (PO8), fixed-width and FP32 baselines, with pre/post fine-tune
//! accuracy columns.

use anyhow::Result;

use super::common::{agg, save_histories, save_results, ExpOptions};
use crate::config::presets::{FIGURE2_MUS, PRUNE_ONLY_MUS};
use crate::config::Mode;
use crate::coordinator::sweep::{run_sweep, Job};
use crate::coordinator::trainer::RunResult;
use crate::report::TableBuilder;

pub fn run(opt: &ExpOptions, show_preft: bool) -> Result<Vec<RunResult>> {
    let model = "resnet18";
    let mut jobs: Vec<Job> = Vec::new();
    jobs.extend(opt.jobs_for(model, Mode::Fp32, 0.0));
    for (w, a) in [(8, 8), (4, 4), (2, 2)] {
        jobs.extend(opt.jobs_for(model,
                                 Mode::Fixed { w_bits: w, a_bits: a },
                                 0.0));
    }
    for mu in FIGURE2_MUS {
        jobs.extend(opt.jobs_for(model, Mode::BayesianBits, *mu));
        jobs.extend(opt.jobs_for(model, Mode::QuantOnly, *mu));
    }
    for mu in PRUNE_ONLY_MUS {
        jobs.extend(opt.jobs_for(
            model, Mode::PruneOnly { w_bits: 4, a_bits: 8 }, *mu));
        jobs.extend(opt.jobs_for(
            model, Mode::PruneOnly { w_bits: 8, a_bits: 8 }, *mu));
    }
    let results = run_sweep(jobs, opt.jobs)?;
    print_table(opt, &results, show_preft)?;
    save_results(&opt.out_path("table4.json"), "table4", &results)?;
    save_histories(&opt.out_path("table4_runs"), &results)?;
    Ok(results)
}

pub fn print_table(opt: &ExpOptions, results: &[RunResult],
                   show_preft: bool) -> Result<()> {
    let mut t = TableBuilder::new(
        "Table 4 — ResNet18 (ImageNet-like): acc vs relative GBOPs",
        &["Method", "# bits W/A", "Top-1 Acc. (%)", "Rel. GBOPs (%)"],
    );
    let aggs = agg(results);
    for a in &aggs {
        let (label, bits) = pretty_mode(&a.mode, a.mu);
        t.row(&[
            label,
            bits,
            TableBuilder::pm(a.acc_mean * 100.0, a.acc_stderr * 100.0, 2),
            TableBuilder::pm(a.bops_mean, a.bops_stderr, 2),
        ]);
    }
    let mut out = t.render();

    if show_preft {
        let mut t2 = TableBuilder::new(
            "Figure 7 — effect of fine-tuning (pre vs post FT accuracy)",
            &["Method", "mu", "Pre-FT Acc. (%)", "Post-FT Acc. (%)"],
        );
        for r in results {
            if r.mode == "bb" || r.mode.starts_with("prune-only")
                || r.mode == "quant-only"
            {
                t2.row(&[
                    r.mode.clone(),
                    format!("{}", r.mu),
                    format!("{:.2}", r.pre_ft_accuracy * 100.0),
                    format!("{:.2}", r.accuracy * 100.0),
                ]);
            }
        }
        out.push_str(&t2.render());
    }
    println!("{out}");
    std::fs::write(opt.out_path("table4.md"), out)?;
    Ok(())
}

pub fn pretty_mode(mode: &str, mu: f64) -> (String, String) {
    if mode == "fp32" {
        return ("Full precision".into(), "32/32".into());
    }
    if let Some(rest) = mode.strip_prefix("fixed:") {
        return (format!("Fixed (LSQ-like) {rest}"),
                rest.replace('w', "").replace('a', "/"));
    }
    if mode == "bb" {
        return (format!("Bayesian Bits mu={mu}"), "Mixed".into());
    }
    if mode == "quant-only" {
        return (format!("Bayesian Bits, QO; mu={mu}"), "Mixed".into());
    }
    if let Some(rest) = mode.strip_prefix("prune-only:") {
        let tag = if rest == "w4a8" { "PO48" } else { "PO8" };
        return (format!("Bayesian Bits, {tag}; mu={mu}"),
                rest.replace('w', "").replace('a', "/"));
    }
    if mode == "dq" {
        return (format!("DQ mu={mu}"), "Mixed".into());
    }
    (mode.to_string(), "?".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pretty_mode_labels() {
        assert_eq!(pretty_mode("fp32", 0.0).0, "Full precision");
        assert_eq!(pretty_mode("fixed:w4a4", 0.0).1, "4/4");
        assert!(pretty_mode("prune-only:w4a8", 0.5).0.contains("PO48"));
        assert!(pretty_mode("quant-only", 0.1).0.contains("QO"));
    }
}
