//! Experiment harnesses — one module per paper table/figure.
//!
//! | module     | regenerates                                          |
//! |------------|------------------------------------------------------|
//! | `table1`   | Table 1: MNIST + CIFAR10 acc vs rel. GBOPs           |
//! | `table2`   | Table 2: deterministic vs stochastic gates           |
//! | `table4`   | Table 4 + Figures 2a/7/8/9: ResNet18 grid + ablations |
//! | `table5`   | Table 5 + Figure 3: post-training mixed precision    |
//! | `figure2`  | Figure 2a/2b Pareto fronts (resnet18 / mobilenetv2)  |
//! | `figure6`  | Figure 6 / 15-18: learned architectures              |
//! | `figure10` | Figures 10-14: gate evolution + training curves      |
//!
//! Every harness prints the paper-shaped table/plot, writes
//! `<out>/<experiment>.json` + `.md`, and returns the rows so benches
//! and tests can drive the same code.

pub mod common;
pub mod figure10;
pub mod figure2;
pub mod figure6;
pub mod table1;
pub mod table2;
pub mod table4;
pub mod table5;
