//! Figures 10-14: gate-probability evolution and training curves.
//!
//! Reads a `metrics.json` produced by any training run (the table
//! harnesses save one per run) and renders: mean gate probability per
//! bit level over steps (Fig. 10/13/14), loss + accuracy curves
//! (Fig. 11), and the BOPs-vs-accuracy co-evolution (Fig. 12).

use std::path::Path;

use anyhow::{Context, Result};

use super::common::ExpOptions;
use crate::coordinator::metrics::History;
use crate::report::plot::{scatter, Series};
use crate::runtime::Manifest;

pub fn run(opt: &ExpOptions, metrics_path: &Path, model: &str,
           curves: bool) -> Result<String> {
    let history = History::load(metrics_path)
        .with_context(|| format!("load metrics {metrics_path:?}"))?;
    let man = Manifest::load(Path::new(&opt.artifacts_dir), model)?;
    let mut out = render_gate_evolution(&man, &history);
    if curves {
        out.push_str(&render_curves(&history));
    }
    println!("{out}");
    std::fs::write(opt.out_path("figure10.md"), &out)?;
    Ok(out)
}

/// Mean inclusion probability per bit level over training steps.
pub fn render_gate_evolution(man: &Manifest, h: &History) -> String {
    if h.gate_snapshots.is_empty() {
        return "figure10: no gate snapshots recorded\n".into();
    }
    let levels: Vec<u32> = man
        .quantizers
        .first()
        .map(|q| q.levels.clone())
        .unwrap_or_default();
    let mut series: Vec<Series> = Vec::new();
    let markers = ['2', '4', '8', 'S', 'T'];
    for (li, level) in levels.iter().enumerate() {
        let mut pts = Vec::new();
        for snap in &h.gate_snapshots {
            let mut sum = 0.0f64;
            let mut n = 0usize;
            for q in &man.quantizers {
                if li == 0 {
                    for c in 0..q.channels {
                        sum += snap.probs[q.offset + c] as f64;
                        n += 1;
                    }
                } else if li - 1 < q.levels.len() - 1 {
                    sum += snap.probs[q.offset + q.channels + li - 1]
                        as f64;
                    n += 1;
                }
            }
            if n > 0 {
                pts.push((snap.step as f64, sum / n as f64));
            }
        }
        series.push(Series {
            label: format!("mean q(z_{level})"),
            marker: markers[li % markers.len()],
            points: pts,
        });
    }
    scatter("Figure 10 — gate probability evolution", "step",
            "mean inclusion prob", &series, 70, 18, false)
}

/// Loss/accuracy and BOPs co-evolution curves (Figures 11-12).
pub fn render_curves(h: &History) -> String {
    let loss: Vec<(f64, f64)> = h
        .steps
        .iter()
        .map(|r| (r.step as f64, r.loss as f64))
        .collect();
    let bops: Vec<(f64, f64)> = h
        .steps
        .iter()
        .map(|r| (r.step as f64, r.exp_bops_pct))
        .collect();
    let acc: Vec<(f64, f64)> = h
        .evals
        .iter()
        .map(|r| (r.step as f64, r.accuracy * 100.0))
        .collect();
    let mut out = scatter(
        "Figure 11 — training loss",
        "step", "CE loss",
        &[Series { label: "loss".into(), marker: 'l', points: loss }],
        70, 14, false,
    );
    out.push_str(&scatter(
        "Figure 12 — expected rel. BOPs (%) during training",
        "step", "exp rel BOPs (%)",
        &[Series { label: "exp BOPs".into(), marker: 'b', points: bops }],
        70, 14, false,
    ));
    if !acc.is_empty() {
        out.push_str(&scatter(
            "Figure 11b — validation accuracy",
            "step", "acc (%)",
            &[Series { label: "val acc".into(), marker: 'a',
                       points: acc }],
            70, 12, false,
        ));
    }
    out
}
