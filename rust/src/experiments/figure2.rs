//! Figure 2: Pareto fronts — (a) ResNet18, (b) MobileNetV2.
//!
//! Plots accuracy vs relative GBOPs (log x) for Bayesian Bits,
//! quantization-only, pruning-only (ResNet18 only), and the fixed-width
//! baselines, as an ASCII scatter plus a sorted point table.

use anyhow::Result;

use super::common::{agg, save_results, ExpOptions};
use crate::config::presets::{FIGURE2_MUS, PRUNE_ONLY_MUS};
use crate::config::Mode;
use crate::coordinator::sweep::{run_sweep, Job};
use crate::coordinator::trainer::RunResult;
use crate::report::plot::{scatter, Series};
use crate::report::TableBuilder;

pub fn run(opt: &ExpOptions, model: &str) -> Result<Vec<RunResult>> {
    let mut jobs: Vec<Job> = Vec::new();
    for (w, a) in [(8, 8), (4, 4), (2, 2)] {
        jobs.extend(opt.jobs_for(model,
                                 Mode::Fixed { w_bits: w, a_bits: a },
                                 0.0));
    }
    for mu in FIGURE2_MUS {
        jobs.extend(opt.jobs_for(model, Mode::BayesianBits, *mu));
        jobs.extend(opt.jobs_for(model, Mode::QuantOnly, *mu));
    }
    if model == "resnet18" {
        for mu in PRUNE_ONLY_MUS {
            jobs.extend(opt.jobs_for(
                model, Mode::PruneOnly { w_bits: 4, a_bits: 8 }, *mu));
        }
    }
    let results = run_sweep(jobs, opt.jobs)?;
    print_figure(opt, model, &results)?;
    save_results(&opt.out_path(&format!("figure2_{model}.json")),
                 "figure2", &results)?;
    Ok(results)
}

pub fn print_figure(opt: &ExpOptions, model: &str,
                    results: &[RunResult]) -> Result<()> {
    let aggs = agg(results);
    let pick = |prefix: &str, marker: char, label: &str| -> Series {
        Series {
            label: label.to_string(),
            marker,
            points: aggs
                .iter()
                .filter(|a| a.mode == prefix
                            || a.mode.starts_with(prefix))
                .map(|a| (a.bops_mean, a.acc_mean * 100.0))
                .collect(),
        }
    };
    let mut series = vec![
        pick("bb", 'o', "Bayesian Bits"),
        pick("quant-only", 'q', "BB quantization only"),
        pick("fixed:", 'x', "fixed wXaY (LSQ-like)"),
    ];
    if model == "resnet18" {
        series.push(pick("prune-only", 'p', "BB pruning only"));
    }
    let fig = scatter(
        &format!("Figure 2 — {model}: accuracy vs relative GBOPs"),
        "rel GBOPs (%)", "top-1 acc (%)", &series, 64, 20, true,
    );

    let mut t = TableBuilder::new(
        &format!("Figure 2 points — {model}"),
        &["Method", "mu", "Acc. (%)", "Rel. GBOPs (%)"],
    );
    let mut sorted = aggs;
    sorted.sort_by(|a, b| a.bops_mean.partial_cmp(&b.bops_mean).unwrap());
    for a in &sorted {
        t.row(&[
            a.mode.clone(),
            format!("{}", a.mu),
            TableBuilder::pm(a.acc_mean * 100.0, a.acc_stderr * 100.0, 2),
            TableBuilder::pm(a.bops_mean, a.bops_stderr, 2),
        ]);
    }
    let out = format!("{fig}{}", t.render());
    println!("{out}");
    std::fs::write(opt.out_path(&format!("figure2_{model}.md")), out)?;
    Ok(())
}
