//! Table 1: MNIST (LeNet-5) and CIFAR10 (VGG-7) — accuracy vs relative
//! GBOPs for FP32, fixed-width baselines, DQ / DQ-restricted, and
//! Bayesian Bits at mu in {0.01, 0.1}.
//!
//! Fixed-width rows stand in for the paper's TWN/LR-Net/RQ/WAGE
//! comparators (their static bit configurations trained with learned
//! ranges on our substrate); DQ rows use the `_dq` artifacts.

use anyhow::Result;

use super::common::{agg, method_rows, save_histories, save_results,
                    ExpOptions};
use crate::baselines;
use crate::bops::BopCounter;
use crate::config::Mode;
use crate::coordinator::sweep::{run_sweep, Job};
use crate::coordinator::trainer::RunResult;
use crate::report::TableBuilder;
use crate::runtime::Manifest;

pub const MODELS: [&str; 2] = ["lenet5", "vgg7"];
pub const FIXED_ROWS: [(u32, u32); 4] = [(8, 8), (4, 4), (2, 8), (2, 32)];

pub fn run(opt: &ExpOptions, skip_baselines: bool)
           -> Result<Vec<RunResult>> {
    let mut jobs: Vec<Job> = Vec::new();
    for model in MODELS {
        jobs.extend(opt.jobs_for(model, Mode::Fp32, 0.0));
        if !skip_baselines {
            for (w, a) in FIXED_ROWS {
                jobs.extend(opt.jobs_for(
                    model, Mode::Fixed { w_bits: w, a_bits: a }, 0.0));
            }
            jobs.extend(opt.jobs_for(&format!("{model}_dq"), Mode::Dq,
                                     0.05));
        }
        for mu in crate::config::presets::TABLE1_MUS {
            jobs.extend(opt.jobs_for(model, Mode::BayesianBits, *mu));
        }
    }
    let results = run_sweep(jobs, opt.jobs)?;
    print_table(opt, &results)?;
    save_results(&opt.out_path("table1.json"), "table1", &results)?;
    save_histories(&opt.out_path("table1_runs"), &results)?;
    Ok(results)
}

pub fn print_table(opt: &ExpOptions, results: &[RunResult]) -> Result<()> {
    let mut out = String::new();
    for model in MODELS {
        let title = format!(
            "Table 1 ({}) — {} — acc (%) vs relative GBOPs (%)",
            if model == "lenet5" { "MNIST-like" } else { "CIFAR-like" },
            model
        );
        let mut t = TableBuilder::new(&title,
                                      &["Method", "# bits W/A", "Acc. (%)",
                                        "Rel. GBOPs (%)"]);
        let of_model = |rs: &[RunResult], mode: &str| -> Vec<RunResult> {
            rs.iter()
                .filter(|r| r.model.starts_with(model)
                            && r.mode == mode
                            && !r.model.contains("_dq"))
                .cloned()
                .collect()
        };
        // FP32 reference
        let fp = of_model(results, "fp32");
        if !fp.is_empty() {
            let a = agg(&fp);
            t.row(&[
                "FP32".into(),
                "32/32".into(),
                format!("{:.2}", a[0].acc_mean * 100.0),
                format!("{:.2}", a[0].bops_mean),
            ]);
        }
        // fixed-width baselines
        for (w, aa) in FIXED_ROWS {
            let label = format!("fixed:w{w}a{aa}");
            let rows = of_model(results, &label);
            if rows.is_empty() {
                continue;
            }
            let a = agg(&rows);
            t.row(&[
                format!("Fixed (LSQ-like) w{w}a{aa}"),
                format!("{w}/{aa}"),
                TableBuilder::pm(a[0].acc_mean * 100.0,
                                 a[0].acc_stderr * 100.0, 2),
                TableBuilder::pm(a[0].bops_mean, a[0].bops_stderr, 2),
            ]);
        }
        // DQ + DQ-restricted
        let dq: Vec<RunResult> = results
            .iter()
            .filter(|r| r.model.starts_with(model)
                        && r.model.contains("_dq"))
            .cloned()
            .collect();
        if !dq.is_empty() {
            let man = Manifest::load(
                std::path::Path::new(&opt.artifacts_dir),
                &format!("{model}_dq"),
            )?;
            let counter = BopCounter::new(man.layers.clone());
            let a = agg(&dq);
            t.row(&[
                "DQ".into(),
                "Mixed".into(),
                TableBuilder::pm(a[0].acc_mean * 100.0,
                                 a[0].acc_stderr * 100.0, 2),
                TableBuilder::pm(a[0].bops_mean, a[0].bops_stderr, 2),
            ]);
            // restricted: recompute BOPs with widths rounded up to pow2
            let restricted: Vec<f64> = dq
                .iter()
                .map(|r| {
                    // final inferred bits = last gate snapshot probs
                    let bits = r
                        .history
                        .gate_snapshots
                        .last()
                        .map(|g| g.probs.clone())
                        .unwrap_or_else(|| vec![8.0; man.n_slots]);
                    baselines::dq_restricted_pct(&counter, &man, &bits)
                })
                .collect();
            let (bm, _) = crate::util::mean_std(&restricted);
            let bse = crate::util::stderr_of_mean(&restricted);
            t.row(&[
                "DQ - restricted".into(),
                "Mixed (pow2)".into(),
                TableBuilder::pm(a[0].acc_mean * 100.0,
                                 a[0].acc_stderr * 100.0, 2),
                TableBuilder::pm(bm, bse, 2),
            ]);
        }
        // Bayesian Bits
        let bb = of_model(results, "bb");
        method_rows(&mut t, "Bayesian Bits", &agg(&bb), 100.0);
        out.push_str(&t.render());
    }
    println!("{out}");
    std::fs::write(opt.out_path("table1.md"), out)?;
    Ok(())
}
