//! Shared experiment plumbing: job construction, result persistence,
//! table row formatting.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::{Mode, RunConfig};
use crate::coordinator::sweep::{aggregate, Aggregated, Job};
use crate::coordinator::trainer::RunResult;
use crate::config::presets;
use crate::report::TableBuilder;
use crate::util::json::{num, obj, s, Json};

/// Common experiment options parsed from CLI flags.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub artifacts_dir: String,
    pub out_dir: String,
    pub seeds: usize,
    pub quick: bool,
    pub jobs: usize,
    pub steps_override: Option<usize>,
}

impl ExpOptions {
    pub fn from_args(args: &crate::cli::Args) -> Result<ExpOptions> {
        // `--threads` is the global worker-count flag (serve workers,
        // sweep parallelism); `--jobs` stays as the sweep-era alias.
        let jobs_alias = args.usize_flag("jobs", 1)?;
        Ok(ExpOptions {
            artifacts_dir: args.str_flag("artifacts", "artifacts"),
            out_dir: args.str_flag("out", "runs"),
            seeds: args.usize_flag("seeds", 1)?,
            quick: args.bool_flag("quick"),
            jobs: args.usize_flag("threads", jobs_alias)?,
            steps_override: args.opt_flag("steps")
                .map(|v| v.parse()).transpose()
                .map_err(|_| anyhow::anyhow!("--steps expects integer"))?,
        })
    }

    /// Build a run config for (model, mode, mu, seed) under these options.
    pub fn config(&self, model: &str, mode: Mode, mu: f64, seed: u64)
                  -> RunConfig {
        let base = model.trim_end_matches("_dq");
        let mut cfg = presets::base_config(base);
        cfg.model = model.to_string();
        cfg.mode = mode;
        cfg.mu = mu;
        cfg.seed = seed;
        cfg.artifacts_dir = self.artifacts_dir.clone();
        cfg.out_dir = self.out_dir.clone();
        if let Some(steps) = self.steps_override {
            cfg.steps = steps;
            cfg.finetune_steps = steps / 4;
        }
        if self.quick {
            let full = cfg.steps as f64;
            cfg.steps = (cfg.steps / 10).max(40);
            cfg.finetune_steps = (cfg.finetune_steps / 10).max(5);
            // Gates must still be able to travel from the +6 phi init to
            // the Eq. 22 threshold within the shrunken budget: scale the
            // gate LR by the shrink factor (capped).
            let boost = (full / cfg.steps as f64).min(10.0);
            cfg.lr_g = (cfg.lr_g * boost).min(0.3);
        }
        cfg
    }

    /// Jobs across seeds.
    pub fn jobs_for(&self, model: &str, mode: Mode, mu: f64) -> Vec<Job> {
        (0..self.seeds)
            .map(|s| Job {
                cfg: self.config(model, mode.clone(), mu, 1 + s as u64),
            })
            .collect()
    }

    pub fn out_path(&self, name: &str) -> PathBuf {
        let dir = Path::new(&self.out_dir);
        let _ = std::fs::create_dir_all(dir);
        dir.join(name)
    }
}

/// Persist raw results + aggregates for one experiment.
pub fn save_results(path: &Path, experiment: &str, results: &[RunResult])
                    -> Result<()> {
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            obj(vec![
                ("model", s(&r.model)),
                ("mode", s(&r.mode)),
                ("mu", num(r.mu)),
                ("seed", num(r.seed as f64)),
                ("accuracy", num(r.accuracy)),
                ("pre_ft_accuracy", num(r.pre_ft_accuracy)),
                ("rel_bops_pct", num(r.rel_bops_pct)),
                ("test_loss", num(r.test_loss)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("experiment", s(experiment)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// Persist per-run history (metrics.json per run) for figure harnesses.
pub fn save_histories(dir: &Path, results: &[RunResult]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    for r in results {
        let name = format!(
            "{}_{}_mu{}_s{}.metrics.json",
            r.model.replace('/', "_"),
            r.mode.replace([':', '/'], "_"),
            r.mu,
            r.seed
        );
        r.history.save(&dir.join(name))?;
    }
    Ok(())
}

/// Standard "Method | #bits | Acc | Rel GBOPs" rows from aggregates.
pub fn method_rows(table: &mut TableBuilder, label_prefix: &str,
                   aggs: &[Aggregated], acc_scale: f64) {
    for a in aggs {
        let label = if a.mu > 0.0 {
            format!("{label_prefix} mu={}", a.mu)
        } else {
            label_prefix.to_string()
        };
        table.row(&[
            label,
            "Mixed".to_string(),
            TableBuilder::pm(a.acc_mean * acc_scale,
                             a.acc_stderr * acc_scale, 2),
            TableBuilder::pm(a.bops_mean, a.bops_stderr, 2),
        ]);
    }
}

/// Aggregate helper re-export for harnesses.
pub fn agg(results: &[RunResult]) -> Vec<Aggregated> {
    aggregate(results)
}
