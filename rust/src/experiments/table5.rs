//! Table 5 + Figure 3: post-training mixed precision (§4.2.1).
//!
//! Pretrains one ResNet18-small base model (cached checkpoint), then for
//! each mu learns gates-only and gates+scales with frozen weights, and
//! compares against the sensitivity-ordered iterative baseline and the
//! fixed 8/8 push-button row, plotting all Pareto fronts.

use std::sync::Arc;

use anyhow::Result;

use super::common::ExpOptions;
use crate::config::presets::{ptq_steps, PTQ_MUS};
use crate::config::RunConfig;
use crate::coordinator::ptq::{self, PtqPoint};
use crate::report::plot::{scatter, Series};
use crate::report::TableBuilder;
use crate::runtime::{Manifest, Runtime};
use crate::util::json::{num, obj, s, Json};
use crate::util::logging;

pub struct Table5Output {
    pub gates_only: Vec<PtqPoint>,
    pub gates_scales: Vec<PtqPoint>,
    pub sensitivity: Vec<PtqPoint>,
    pub fixed8: PtqPoint,
}

pub fn run(opt: &ExpOptions, model: &str, mus: &[f64])
           -> Result<Table5Output> {
    let rt = Arc::new(Runtime::cpu()?);
    let man = Manifest::load(std::path::Path::new(&opt.artifacts_dir),
                             model)?;
    let mut base_cfg = RunConfig {
        model: model.to_string(),
        artifacts_dir: opt.artifacts_dir.clone(),
        out_dir: opt.out_dir.clone(),
        ..crate::config::presets::base_config(model)
    };
    if opt.quick {
        base_cfg.steps = (base_cfg.steps / 5).max(50);
    }
    let ckpt = opt.out_path(&format!("{model}_pretrained.ckpt"));
    let base = ptq::pretrain_or_load(rt.clone(), &man, &base_cfg, &ckpt)?;

    let steps = if opt.quick { ptq_steps() / 3 } else { ptq_steps() };
    let mus = if mus.is_empty() { PTQ_MUS } else { mus };
    let mut gates_only = Vec::new();
    let mut gates_scales = Vec::new();
    for mu in mus {
        logging::info(format!("PTQ mu={mu}: gates-only"));
        gates_only.push(ptq::ptq_learn(rt.clone(), &man, &base, *mu,
                                       false, steps, 1, crate::config::presets::PTQ_LR_G)?);
        logging::info(format!("PTQ mu={mu}: gates+scales"));
        gates_scales.push(ptq::ptq_learn(rt.clone(), &man, &base, *mu,
                                         true, steps, 1, crate::config::presets::PTQ_LR_G)?);
    }
    logging::info("PTQ: sensitivity baseline");
    let sensitivity = ptq::sensitivity_baseline(rt.clone(), &man, &base,
                                                4)?;
    let fixed8 = ptq::fixed_point(rt, &man, &base, 8, 8)?;

    let out = Table5Output { gates_only, gates_scales, sensitivity,
                             fixed8 };
    print_output(opt, model, mus, &out)?;
    Ok(out)
}

fn points_json(pts: &[PtqPoint]) -> Json {
    Json::Arr(
        pts.iter()
            .map(|p| {
                obj(vec![
                    ("label", s(&p.label)),
                    ("mu", num(p.mu)),
                    ("accuracy", num(p.accuracy)),
                    ("rel_bops_pct", num(p.rel_bops_pct)),
                ])
            })
            .collect(),
    )
}

fn print_output(opt: &ExpOptions, model: &str, mus: &[f64],
                out: &Table5Output) -> Result<()> {
    let mut t = TableBuilder::new(
        &format!("Table 5 — post-training mixed precision ({model})"),
        &["Regularization", "Gates-only Acc (%)", "Gates-only GBOPs (%)",
          "Gates+scales Acc (%)", "Gates+scales GBOPs (%)"],
    );
    for (i, mu) in mus.iter().enumerate() {
        t.row(&[
            format!("mu = {mu}"),
            format!("{:.2}", out.gates_only[i].accuracy * 100.0),
            format!("{:.2}", out.gates_only[i].rel_bops_pct),
            format!("{:.2}", out.gates_scales[i].accuracy * 100.0),
            format!("{:.2}", out.gates_scales[i].rel_bops_pct),
        ]);
    }
    let mk = |pts: &[PtqPoint], marker, label: &str| Series {
        label: label.into(),
        marker,
        points: pts.iter().map(|p| (p.rel_bops_pct, p.accuracy * 100.0))
            .collect(),
    };
    let fig = scatter(
        &format!("Figure 3 — post-training Pareto fronts ({model})"),
        "rel GBOPs (%)", "top-1 acc (%)",
        &[
            mk(&ptq::pareto_front(&out.gates_only), 'g', "BB gates only"),
            mk(&ptq::pareto_front(&out.gates_scales), 's',
               "BB gates + scales"),
            mk(&ptq::pareto_front(&out.sensitivity), 'i',
               "iterative sensitivity baseline"),
            mk(std::slice::from_ref(&out.fixed8), '8', "fixed 8/8"),
        ],
        64, 20, true,
    );
    let text = format!("{}{fig}", t.render());
    println!("{text}");
    std::fs::write(opt.out_path("table5.md"), &text)?;
    let doc = obj(vec![
        ("experiment", s("table5")),
        ("gates_only", points_json(&out.gates_only)),
        ("gates_scales", points_json(&out.gates_scales)),
        ("sensitivity", points_json(&out.sensitivity)),
        ("fixed8", points_json(std::slice::from_ref(&out.fixed8))),
    ]);
    std::fs::write(opt.out_path("table5.json"), doc.to_string())?;
    Ok(())
}
