//! Figure 6 / Figures 15-18: learned per-layer bit allocation and
//! sparsity. Trains one configuration (or reuses results passed in) and
//! prints the architecture report.

use std::sync::Arc;

use anyhow::Result;

use super::common::ExpOptions;
use crate::config::Mode;
use crate::coordinator::trainer::{RunResult, Trainer};
use crate::report::arch_viz::{architecture_report, summary_line};
use crate::runtime::{Manifest, Runtime};

pub fn run(opt: &ExpOptions, model: &str, mu: f64) -> Result<RunResult> {
    let rt = Arc::new(Runtime::cpu()?);
    let man = Manifest::load(std::path::Path::new(&opt.artifacts_dir),
                             model)?;
    let cfg = opt.config(model, Mode::BayesianBits, mu, 1);
    let mut trainer = Trainer::new(rt, man.clone(), cfg)?;
    let result = trainer.run()?;
    let text = print_report(&man, &result);
    std::fs::write(opt.out_path(&format!("figure6_{model}.md")), &text)?;
    Ok(result)
}

pub fn print_report(man: &Manifest, result: &RunResult) -> String {
    let mut text = format!(
        "Figure 6 — learned architecture ({}, mu={}, acc {:.2}%, \
         rel GBOPs {:.2}%)\n",
        result.model, result.mu, result.accuracy * 100.0,
        result.rel_bops_pct
    );
    text.push_str(&architecture_report(man, &result.states));
    text.push_str(&summary_line(man, &result.states));
    text.push('\n');
    println!("{text}");
    text
}
