//! Table 2 (App. A.3): deterministic vs stochastic gates ablation.
//!
//! Stochastic rows are standard Bayesian Bits runs; deterministic rows
//! set the `det_flag` executable input (noise pinned to 0.5) with the
//! paper's adjusted gate hyper-parameters (lower gate LR). Reported
//! pre- and post-fine-tuning, matching the paper's observation that
//! deterministic gates train to configurations whose train loss
//! disagrees with validation accuracy.

use anyhow::Result;

use super::common::{save_results, ExpOptions};
use crate::config::Mode;
use crate::coordinator::sweep::{run_sweep, Job};
use crate::coordinator::trainer::RunResult;
use crate::report::TableBuilder;

pub fn run(opt: &ExpOptions) -> Result<Vec<RunResult>> {
    let cases = [("vgg7", 0.01), ("resnet18", 0.03)];
    let mut jobs: Vec<Job> = Vec::new();
    for (model, mu) in cases {
        for det in [false, true] {
            for seed in 0..opt.seeds {
                let mut cfg = opt.config(model, Mode::BayesianBits, mu,
                                         1 + seed as u64);
                cfg.deterministic_gates = det;
                if det {
                    // paper: lower gate LR, init closer to saturation
                    cfg.lr_g /= 10.0;
                }
                jobs.push(Job { cfg });
            }
        }
    }
    let results = run_sweep(jobs, opt.jobs)?;
    print_table(opt, &results)?;
    save_results(&opt.out_path("table2.json"), "table2", &results)?;
    Ok(results)
}

fn print_table(opt: &ExpOptions, results: &[RunResult]) -> Result<()> {
    let mut t = TableBuilder::new(
        "Table 2 — deterministic vs stochastic gates",
        &["Experiment", "Gating type", "Acc. (%)", "Pre-FT Acc. (%)",
          "Rel. GBOPs (%)", "CE Loss"],
    );
    for r in results {
        let gating = if r.deterministic { "Deterministic" }
                     else { "Stochastic" };
        t.row(&[
            format!("{} mu={}", r.model, r.mu),
            gating.into(),
            format!("{:.2}", r.accuracy * 100.0),
            format!("{:.2}", r.pre_ft_accuracy * 100.0),
            format!("{:.2}", r.rel_bops_pct),
            format!("{:.3}", r.history.smoothed_loss(20)),
        ]);
    }
    let out = t.render();
    println!("{out}");
    std::fs::write(opt.out_path("table2.md"), out)?;
    Ok(())
}

