//! `bbits` — the Bayesian Bits launcher (Layer-3 entrypoint).
//!
//! See `bbits --help` (or `cli::usage`) for the command surface. Every
//! paper table/figure has a dedicated subcommand; `train`/`sweep`/`ptq`
//! expose the underlying machinery for custom runs.


use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use bayesian_bits::cli::{self, Args};
use bayesian_bits::config::{presets, Mode};
use bayesian_bits::coordinator::checkpoint;
use bayesian_bits::coordinator::sweep::{run_sweep, Job};
use bayesian_bits::coordinator::trainer::Trainer;
use bayesian_bits::engine::registry::{closed_loop_deadline,
                                      closed_loop_router, ModelRegistry,
                                      Router};
use bayesian_bits::engine::{self, serve};
use bayesian_bits::experiments::{self, common::ExpOptions};
use bayesian_bits::models::{descriptor, Preset};
use bayesian_bits::bops::BopCounter;
use bayesian_bits::quant::grid::{bb_quantize_host, QuantConfig};
use bayesian_bits::report::{arch_viz, TableBuilder};
use bayesian_bits::runtime::{manifest_gen, Manifest, Runtime,
                             TrainState};
use bayesian_bits::util::bench::Bench;
use bayesian_bits::util::json::Json;
use bayesian_bits::util::logging;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        logging::error(format!("{e:#}"));
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if let Some(level) = args.opt_flag("log-level") {
        match logging::level_from_str(level) {
            Some(l) => logging::set_level(l),
            None => bail!("bad --log-level {level:?}"),
        }
    }
    if args.command.is_empty() || args.bool_flag("help") {
        println!("{}", cli::usage());
        return Ok(());
    }
    let opt = ExpOptions::from_args(&args)?;
    match args.command.as_str() {
        "train" => cmd_train(&args, &opt),
        "sweep" => cmd_sweep(&args, &opt),
        "ptq" | "table5" => {
            let model = args.str_flag("model", "resnet18");
            let mus = args.f64_list_flag("mus", &[])?;
            experiments::table5::run(&opt, &model, &mus)?;
            Ok(())
        }
        "table1" => {
            experiments::table1::run(&opt, args.bool_flag(
                "skip-baselines"))?;
            Ok(())
        }
        "table2" => {
            experiments::table2::run(&opt)?;
            Ok(())
        }
        "table4" => {
            experiments::table4::run(&opt, args.bool_flag("show-preft"))?;
            Ok(())
        }
        "figure2" => {
            let model = args.str_flag("model", "resnet18");
            experiments::figure2::run(&opt, &model)?;
            Ok(())
        }
        "figure3" => {
            let model = args.str_flag("model", "resnet18");
            let mus = args.f64_list_flag("mus", &[])?;
            experiments::table5::run(&opt, &model, &mus)?;
            Ok(())
        }
        "figure6" => {
            let model = args.str_flag("model", "vgg7");
            let mu = args.f64_flag("mu", 0.01)?;
            experiments::figure6::run(&opt, &model, mu)?;
            Ok(())
        }
        "figure10" => {
            let model = args.str_flag("model", "resnet18");
            let run_file = args.str_flag(
                "run",
                &format!("{}/table4_runs", opt.out_dir),
            );
            let path = resolve_metrics_path(Path::new(&run_file))?;
            experiments::figure10::run(&opt, &path, &model,
                                       args.bool_flag("curves"))?;
            Ok(())
        }
        "serve" => cmd_serve(&args, &opt),
        "plan" => cmd_plan(&args, &opt),
        "engine-bench" => cmd_engine_bench(&args),
        "parity" => cmd_parity(&opt),
        "bops" => cmd_bops(),
        "report" => cmd_report(&args, &opt),
        other => bail!("unknown command {other:?}\n\n{}", cli::usage()),
    }
}

/// Build an [`engine::EnginePlan`] from the engine-family CLI flags:
/// a lowered checkpoint when `--checkpoint` is given, a synthetic
/// plan otherwise. Shared by `bbits serve` and `bbits plan`.
fn plan_from_args(args: &Args, opt: &ExpOptions)
                  -> Result<engine::EnginePlan> {
    if let Some(path) = args.opt_flag("load") {
        // a saved artifact replaces lowering entirely; the verified
        // load re-validates structure + code grids and runs the
        // static verifier, so a corrupt file is a typed error here
        return engine::load_plan_verified(Path::new(path),
                                          backend_from_args(args)?)
            .with_context(|| format!("--load {path:?}"));
    }
    if let Some(ckpt) = args.opt_flag("checkpoint") {
        let model = args.str_flag("model", "lenet5");
        // the mode the checkpoint was trained in decides which gate
        // slots were learned vs locked (printed by `bbits train`)
        let mode = Mode::parse(&args.str_flag("mode", "bb"))?;
        let man =
            Manifest::load(Path::new(&opt.artifacts_dir), &model)?;
        let (ck_model, state) = checkpoint::load(Path::new(ckpt))?;
        if ck_model != man.name {
            bail!("checkpoint is for {ck_model:?}, manifest is {:?}",
                  man.name);
        }
        engine::lower_with_mode(&man, &state.params, &mode)
    } else if args.str_flag("model", "").starts_with("preset:") {
        // the multi-model SPEC grammar's preset form, usable without
        // a checkpoint: `bbits plan --model preset:resnet18`
        let (man, params) =
            model_source_from_spec(&args.str_flag("model", ""))?;
        engine::lower(&man, &params)
    } else {
        let dims =
            args.usize_list_flag("dims", &[128, 256, 256, 10])?;
        let wbits = args.usize_flag("wbits", 4)? as u32;
        let abits = args.usize_flag("abits", 8)? as u32;
        let prune = args.f64_flag("prune", 0.25)?;
        let seed = args.usize_flag("seed", 1)? as u64;
        logging::info(format!(
            "no --checkpoint given: using a synthetic w{wbits}a{abits} \
             plan over dims {dims:?}"
        ));
        engine::synthetic_plan("synthetic", &dims, wbits, abits, prune,
                               seed)
    }
}

/// The `--backend` flag: force every integer kernel node onto one
/// backend (`scalar` | `simd` | `blocked`); absent means
/// `BBITS_BACKEND`, then per-node auto selection (which never picks
/// `blocked` — the panel form is opt-in). Shared by
/// serve/plan/engine-bench.
fn backend_from_args(args: &Args) -> Result<Option<engine::Backend>> {
    match args.opt_flag("backend") {
        None => Ok(None),
        Some(s) => Ok(Some(engine::Backend::parse(s)?)),
    }
}

/// `bbits plan` — lower a checkpoint (or a synthetic spec) and
/// inspect the result without serving. `--dump-ir` additionally
/// prints the compiled execution graphs (node list + arena map) for
/// the integer path and the f32 reference path; `--backend` forces
/// the kernel backend the dumped integer nodes carry; `--profile`
/// runs a few synthetic batches through the instrumented interpreter
/// and prints the per-node timings plus the (op, backend, bit-width)
/// aggregate.
fn cmd_plan(args: &Args, opt: &ExpOptions) -> Result<()> {
    let plan = Arc::new(plan_from_args(args, opt)?);
    println!("{}", plan.report());
    if let Some(path) = args.opt_flag("save") {
        let n = engine::save_plan(Path::new(path), &plan)?;
        logging::info(format!(
            "plan artifact written to {path:?} ({n} bytes; decode \
             re-verifies checksum, code grids, and plan structure)"
        ));
    }
    let backend = backend_from_args(args)?;
    if args.bool_flag("verify") {
        verify_plans_from_args(args, opt, backend)?;
    }
    if args.bool_flag("dump-ir") {
        let int_prog = engine::graph::Program::compile_with_backend(
            plan.clone(), true, backend);
        println!("{}", int_prog.dump());
        let f32_prog = engine::graph::Program::compile_with_backend(
            plan.clone(), false, backend);
        println!("{}", f32_prog.dump());
    }
    if args.bool_flag("profile") {
        let int_path = !args.bool_flag("no-int");
        let batch = args.usize_flag("batch", 8)?;
        let iters = args.usize_flag("requests", 12)?.max(1);
        let mut eng = engine::Engine::with_backend(plan.clone(),
                                                   backend);
        eng.set_int_enabled(int_path);
        eng.set_intra_threads(args.usize_flag("intra-threads", 1)?);
        eng.enable_profiling();
        let xs: Vec<f32> = (0..batch * plan.input_dim)
            .map(|i| ((i as f32) * 0.37).sin())
            .collect();
        for _ in 0..iters {
            eng.infer_batch(&xs, batch)?;
        }
        println!(
            "node profile — {} path, {iters} batches x {batch}:",
            if int_path { "int" } else { "f32" }
        );
        for (id, k, t) in eng.node_profile(int_path) {
            println!(
                "profile: node #{id:<3} {:<14} {:<7} w{}a{} \
                 calls={} total={}ns max={}ns",
                k.op, k.backend, k.w_bits, k.a_bits, t.calls,
                t.total_ns, t.max_ns
            );
        }
        let rows = eng.kernel_profile(int_path);
        let mut t = TableBuilder::new(
            "kernel profile — by (op, backend, bit width)",
            &["Op", "Backend", "W", "A", "Calls", "Total us", "Max us",
              "Share"],
        );
        let total: u64 = rows.iter().map(|(_, nt)| nt.total_ns).sum();
        for (k, nt) in &rows {
            t.row(&[
                k.op.to_string(),
                k.backend.to_string(),
                format!("{}", k.w_bits),
                format!("{}", k.a_bits),
                format!("{}", nt.calls),
                format!("{:.1}", nt.total_ns as f64 / 1e3),
                format!("{:.1}", nt.max_ns as f64 / 1e3),
                format!("{:.1}%", if total > 0 {
                    100.0 * nt.total_ns as f64 / total as f64
                } else {
                    0.0
                }),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

/// The plans `bbits plan --verify` proves: the base plan alone, or —
/// when the model source is manifest-based (a checkpoint or a
/// `preset:` spec) and `--ladder T1,T2,...` is given — one lowering
/// per gate threshold, exactly the rungs `serve --ladder` would
/// register.
fn plans_for_verify(args: &Args, opt: &ExpOptions)
                    -> Result<Vec<(String, Arc<engine::EnginePlan>)>> {
    let ladder = args.f64_list_flag("ladder", &[])?;
    if ladder.is_empty() {
        return Ok(vec![("plan".to_string(),
                        Arc::new(plan_from_args(args, opt)?))]);
    }
    let (man, params, mode) = if let Some(ckpt) =
        args.opt_flag("checkpoint")
    {
        let model = args.str_flag("model", "lenet5");
        let mode = Mode::parse(&args.str_flag("mode", "bb"))?;
        let man =
            Manifest::load(Path::new(&opt.artifacts_dir), &model)?;
        let (ck_model, state) = checkpoint::load(Path::new(ckpt))?;
        if ck_model != man.name {
            bail!("checkpoint is for {ck_model:?}, manifest is {:?}",
                  man.name);
        }
        (man, state.params, mode)
    } else if args.str_flag("model", "").starts_with("preset:") {
        let (man, params) =
            model_source_from_spec(&args.str_flag("model", ""))?;
        (man, params, Mode::parse(&args.str_flag("mode", "bb"))?)
    } else {
        bail!("--verify --ladder needs a manifest-level model source \
               to lower at several thresholds: pass --checkpoint CKPT \
               or --model preset:NAME");
    };
    ladder
        .iter()
        .map(|&t| {
            let plan =
                engine::lower_with_mode_at(&man, &params, &mode, t)?;
            Ok((format!("rung t={t}"), Arc::new(plan)))
        })
        .collect()
}

/// `bbits plan --verify`: compile every requested plan on both
/// execution paths and run the full static analysis suite
/// (`engine::verify`) — value-range/overflow proofs, arena aliasing,
/// IR well-formedness, backend/panel invariants. Exits non-zero if
/// any plan fails.
fn verify_plans_from_args(args: &Args, opt: &ExpOptions,
                          backend: Option<engine::Backend>)
                          -> Result<()> {
    let plans = plans_for_verify(args, opt)?;
    let mut failures = 0usize;
    for (label, plan) in &plans {
        for int_path in [true, false] {
            let path = if int_path { "int" } else { "f32" };
            let prog = match
                engine::graph::Program::try_compile_with_backend(
                    plan.clone(), int_path, backend)
            {
                Ok(p) => p,
                Err(e) => {
                    failures += 1;
                    println!("verify: {label} [{path}] FAIL at \
                              compile: {e}");
                    continue;
                }
            };
            let errs = engine::verify_all(&prog);
            if errs.is_empty() {
                println!(
                    "verify: {label} [{path}] ok — {} nodes, {} \
                     buffers, arena {} B",
                    prog.nodes().len(),
                    prog.bufs().len(),
                    prog.arena_bytes()
                );
            } else {
                for e in &errs {
                    println!("verify: {label} [{path}] FAIL: {e}");
                }
                failures += errs.len();
            }
        }
    }
    if failures > 0 {
        bail!("static plan verification failed with {failures} \
               error(s)");
    }
    println!(
        "verify: {} plan(s) passed static verification on both \
         execution paths",
        plans.len()
    );
    Ok(())
}

/// The serve worker-pool knobs shared by the single- and multi-model
/// paths of `bbits serve`.
fn serve_config_from_args(args: &Args) -> Result<serve::ServeConfig> {
    let workers = args.usize_flag(
        "threads",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .min(8),
    )?;
    let slo = match args.opt_flag("slo-ms") {
        Some(_) => {
            let ms = args.f64_flag("slo-ms", 0.0)?;
            if ms <= 0.0 {
                bail!("--slo-ms must be > 0, got {ms}");
            }
            Some(std::time::Duration::from_secs_f64(ms / 1e3))
        }
        None => None,
    };
    let cfg = serve::ServeConfig {
        workers,
        queue_cap: args.usize_flag("queue-cap", 256)?,
        max_batch: args.usize_flag("max-batch", 16)?,
        deadline: std::time::Duration::from_secs_f64(
            args.f64_flag("deadline-ms", 2.0)?.max(0.0) / 1e3,
        ),
        force_f32: args.bool_flag("no-int"),
        backend: backend_from_args(args)?,
        intra_threads: args.usize_flag("intra-threads", 1)?,
        slo,
        verify_plans: args.bool_flag("verify-plans"),
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Resolve one multi-model `--model NAME=SPEC` spec into a lowered
/// plan. SPEC grammar:
///   `preset:MODEL`        in-process preset manifest (deterministic
///                         weights, 8-bit chains, all channels kept)
///   `MANIFEST.json:CKPT`  manifest file + trained checkpoint
///   `MANIFEST.json`       manifest file; params from its init file
///                         when present, a deterministic default init
///                         otherwise
fn plan_from_spec(spec: &str) -> Result<engine::EnginePlan> {
    let (man, params) = model_source_from_spec(spec)?;
    engine::lower(&man, &params)
}

/// Resolve a `--model NAME=SPEC` spec into its manifest + parameter
/// vector — the checkpoint-level source a precision ladder lowers at
/// several thresholds (where a plain model lowers it exactly once).
fn model_source_from_spec(spec: &str)
                          -> Result<(Manifest, Vec<f32>)> {
    if let Some(model) = spec.strip_prefix("preset:") {
        return manifest_gen::preset_manifest(model, false, 42);
    }
    let (mpath, ckpt) = match spec.rsplit_once(':') {
        // trailing colon: an empty checkpoint part, not part of the path
        Some((m, "")) => (m, None),
        Some((m, c)) => (m, Some(c)),
        None => (spec, None),
    };
    let text = std::fs::read_to_string(mpath)
        .with_context(|| format!("read manifest {mpath:?}"))?;
    let dir = Path::new(mpath).parent().unwrap_or(Path::new("."));
    let man = Manifest::from_json(&Json::parse(&text)?, dir)
        .with_context(|| format!("parse manifest {mpath:?}"))?;
    let params = match ckpt {
        Some(c) => {
            let (name, state) = checkpoint::load(Path::new(c))?;
            if name != man.name {
                bail!("checkpoint {c:?} is for {name:?}, manifest \
                       {mpath:?} is {:?}", man.name);
            }
            state.params
        }
        // fall back to the deterministic default init only when the
        // init file is genuinely absent — a present-but-corrupt one
        // must error, not silently serve synthetic weights
        None if man.init_file.exists() => man.load_init()?,
        None => {
            logging::info(format!(
                "manifest {mpath:?}: no init file at {:?}, using the \
                 deterministic default init",
                man.init_file
            ));
            manifest_gen::default_init(&man, 42)
        }
    };
    Ok((man, params))
}

/// `bbits serve` — lower a checkpoint (or a synthetic plan) into the
/// integer engine and drive it with a closed-loop batched load.
/// Repeated `--model NAME=SPEC` flags switch to the multi-model
/// registry/router front-end with per-model stats and an optional
/// `--plan-cache-mb` byte budget over the compiled programs.
fn cmd_serve(args: &Args, opt: &ExpOptions) -> Result<()> {
    let specs: Vec<(String, String)> = args
        .repeated_flag("model")
        .iter()
        .filter_map(|v| {
            v.split_once('=')
                .map(|(n, s)| (n.to_string(), s.to_string()))
        })
        .collect();
    if !specs.is_empty() {
        if specs.len() != args.repeated_flag("model").len() {
            bail!("cannot mix `--model NAME=SPEC` (multi-model) with \
                   a plain `--model NAME`");
        }
        return cmd_serve_multi(args, opt, &specs);
    }
    if args.opt_flag("plan-cache-mb").is_some() {
        bail!("--plan-cache-mb only applies to the multi-model form \
               (repeat --model NAME=SPEC); a single-model server keeps \
               its one compiled plan resident");
    }
    let ladder = args.f64_list_flag("ladder", &[])?;
    if !ladder.is_empty() {
        return cmd_serve_ladder_single(args, opt, &ladder);
    }

    let plan = plan_from_args(args, opt)?;
    println!("{}", plan.report());

    let cfg = serve_config_from_args(args)?;
    let clients = args.usize_flag("clients", 8)?;
    let requests = args.usize_flag("requests", 200)?;
    logging::info(format!(
        "serving with {} workers (max batch {}, deadline {:?}, int \
         path {}); {} clients x {} requests",
        cfg.workers, cfg.max_batch, cfg.deadline,
        if cfg.force_f32 { "OFF" } else { "on" }, clients, requests
    ));
    let trace = trace_from_args(args);
    let server = match &trace {
        Some((_, rec)) => serve::Server::start_traced(
            Arc::new(plan), cfg, rec.clone())?,
        None => serve::Server::start(Arc::new(plan), cfg)?,
    };
    if args.bool_flag("prewarm") {
        let id = if server.plan().model.is_empty() {
            "default".to_string()
        } else {
            server.plan().model.clone()
        };
        server.registry().prewarm(&id)?;
    }
    let stats = serve::closed_loop(&server, clients, requests, 7)?;
    println!("{stats}");
    let out = opt.out_path("serve_stats.json");
    std::fs::write(&out, stats.to_json().to_string())?;
    logging::info(format!("serve stats written to {out:?}"));
    server.shutdown();
    write_trace(trace)?;
    Ok(())
}

/// Single-model `bbits serve --ladder T1,T2,...`: lower the same
/// checkpoint at every listed gate threshold into a precision ladder
/// behind a one-entry registry, drive the closed loop through the
/// SLO/pressure rung pick, and report per-rung rows.
fn cmd_serve_ladder_single(args: &Args, opt: &ExpOptions,
                           ladder: &[f64]) -> Result<()> {
    let Some(ckpt) = args.opt_flag("checkpoint") else {
        bail!("--ladder needs a checkpoint to lower at several \
               thresholds: pass --checkpoint CKPT (or use the \
               multi-model form, e.g. --model a=preset:lenet5 \
               --ladder 0.3,0.5,0.9)");
    };
    let model = args.str_flag("model", "lenet5");
    let mode = Mode::parse(&args.str_flag("mode", "bb"))?;
    let man = Manifest::load(Path::new(&opt.artifacts_dir), &model)?;
    let (ck_model, state) = checkpoint::load(Path::new(ckpt))?;
    if ck_model != man.name {
        bail!("checkpoint is for {ck_model:?}, manifest is {:?}",
              man.name);
    }
    let cfg = serve_config_from_args(args)?;
    let clients = args.usize_flag("clients", 8)?;
    let requests = args.usize_flag("requests", 200)?;
    let registry = Arc::new(ModelRegistry::new());
    let trace = trace_from_args(args);
    if let Some((_, rec)) = &trace {
        registry.set_trace(Some(rec.clone()))?;
    }
    registry.register_ladder(&model, &man, &state.params, &mode,
                             ladder, cfg.clone())?;
    if args.bool_flag("prewarm") {
        registry.prewarm(&model)?;
    }
    print_ladder(&registry, &model);
    logging::info(format!(
        "serving the {}-rung ladder with {} workers/rung (max batch \
         {}, slo {:?}); {} clients x {} requests",
        ladder.len(), cfg.workers, cfg.max_batch, cfg.slo, clients,
        requests
    ));
    let router = Router::new(registry.clone());
    let ids = [model.clone()];
    let (_, per_model) =
        closed_loop_router(&router, &ids, clients, requests, 7)?;
    for (id, st) in &per_model {
        println!("[{id}] {st}");
    }
    print_ladder(&registry, &model);
    let out = opt.out_path("serve_stats.json");
    std::fs::write(&out, registry.stats_json().to_string())?;
    logging::info(format!("serve stats written to {out:?}"));
    registry.shutdown();
    write_trace(trace)?;
    Ok(())
}

/// Print one row per ladder rung of `id`: threshold, bit width, proxy
/// score, residency, request count, and measured latency.
fn print_ladder(registry: &ModelRegistry, id: &str) {
    let Some(rungs) = registry.ladder(id) else { return };
    for r in &rungs {
        println!(
            "[{id}/{}] threshold={:.3} w_bits={} score={:.3} \
             resident={} requests={} p50={:.3}ms p90={:.3}ms",
            r.label, r.threshold, r.w_bits, r.score, r.resident,
            r.stats.requests, r.stats.p50_ms, r.stats.p90_ms
        );
    }
}

/// The `--trace-out FILE` flag: an attached span recorder plus the
/// path its Chrome trace-event JSON is written to after shutdown.
fn trace_from_args(args: &Args)
                   -> Option<(String, Arc<engine::TraceRecorder>)> {
    args.opt_flag("trace-out")
        .map(|p| (p.to_string(), engine::TraceRecorder::new()))
}

/// Export a recorder's spans once the serving stack has quiesced
/// (workers joined — no recording is concurrent with this read).
fn write_trace(trace: Option<(String, Arc<engine::TraceRecorder>)>)
               -> Result<()> {
    let Some((path, rec)) = trace else { return Ok(()) };
    let events = rec.events().len();
    let dropped = rec.dropped();
    std::fs::write(&path, rec.chrome_trace().to_string())
        .with_context(|| format!("write trace {path:?}"))?;
    logging::info(format!(
        "chrome trace written to {path:?} ({events} events{})",
        if dropped > 0 {
            format!(", {dropped} dropped by ring wrap")
        } else {
            String::new()
        }
    ));
    Ok(())
}

/// Multi-model serving: register every `NAME=SPEC`, route a
/// closed-loop load across all of them, and report per-model stats
/// plus the plan-cache counters.
fn cmd_serve_multi(args: &Args, opt: &ExpOptions,
                   specs: &[(String, String)]) -> Result<()> {
    let cfg = serve_config_from_args(args)?;
    let registry = match args.opt_flag("plan-cache-mb") {
        Some(_) => {
            let mb = args.f64_flag("plan-cache-mb", 0.0)?;
            if mb < 0.0 {
                bail!("--plan-cache-mb must be >= 0, got {mb}");
            }
            Arc::new(ModelRegistry::with_budget(
                (mb * 1024.0 * 1024.0) as usize,
            ))
        }
        None => Arc::new(ModelRegistry::new()),
    };
    let trace = trace_from_args(args);
    if let Some((_, rec)) = &trace {
        registry.set_trace(Some(rec.clone()))?;
    }
    let ladder = args.f64_list_flag("ladder", &[])?;
    let mut ids = Vec::new();
    for (name, spec) in specs {
        if ladder.is_empty() {
            let plan = plan_from_spec(spec)
                .with_context(|| format!("--model {name}={spec}"))?;
            println!("{}", plan.report());
            registry.register(name, Arc::new(plan), cfg.clone())?;
        } else {
            // every model becomes a ladder: its checkpoint lowered at
            // each listed gate threshold
            let (man, params) = model_source_from_spec(spec)
                .with_context(|| format!("--model {name}={spec}"))?;
            registry.register_ladder(name, &man, &params,
                                     &Mode::BayesianBits, &ladder,
                                     cfg.clone())?;
            print_ladder(&registry, name);
        }
        ids.push(name.clone());
    }
    if args.bool_flag("prewarm") {
        for id in &ids {
            registry.prewarm(id)?;
        }
    }
    let clients = args.usize_flag("clients", 8)?;
    let requests = args.usize_flag("requests", 200)?;
    logging::info(format!(
        "routing across {} models with {} workers/model (max batch {}, \
         plan cache {}); {} clients x {} requests",
        ids.len(), cfg.workers, cfg.max_batch,
        match registry.budget_bytes() {
            Some(b) => format!("{:.2} MiB", b as f64 / (1024.0 * 1024.0)),
            None => "unbounded".into(),
        },
        clients, requests
    ));
    let router = Router::new(registry.clone());
    let (elapsed, per_model) =
        closed_loop_router(&router, &ids, clients, requests, 7)?;
    for (id, st) in &per_model {
        println!("[{id}] {st}");
        if !ladder.is_empty() {
            print_ladder(&registry, id);
        }
    }
    let cache = registry.cache_stats();
    println!(
        "plan cache: {} hits, {} misses ({} recompiles), {} evictions, \
         {} resident bytes over {:.2}s",
        cache.hits, cache.misses, cache.recompiles, cache.evictions,
        registry.resident_bytes(), elapsed
    );
    // registry stats JSON, with the load window's throughput numbers
    // patched over the raw per-model snapshots; the per-node kernel
    // counters, per-rung ladder rows, and ladder version counters
    // only the registry snapshot carries survive the patch
    let mut json = registry.stats_json();
    if let Json::Obj(top) = &mut json {
        let carry: BTreeMap<String, Vec<(String, Json)>> =
            match top.get("models") {
                Some(Json::Obj(snap)) => snap
                    .iter()
                    .filter_map(|(id, m)| match m {
                        Json::Obj(f) => Some((
                            id.clone(),
                            ["kernels", "rungs", "version",
                             "versions_live"]
                                .iter()
                                .filter_map(|k| {
                                    f.get(*k).map(|v| {
                                        (k.to_string(), v.clone())
                                    })
                                })
                                .collect(),
                        )),
                        _ => None,
                    })
                    .collect(),
                _ => BTreeMap::new(),
            };
        let models: BTreeMap<String, Json> = per_model
            .iter()
            .map(|(id, st)| {
                let mut m = st.to_json();
                if let (Json::Obj(f), Some(kv)) = (&mut m, carry.get(id))
                {
                    for (k, v) in kv {
                        f.insert(k.clone(), v.clone());
                    }
                }
                (id.clone(), m)
            })
            .collect();
        top.insert("models".to_string(), Json::Obj(models));
    }
    let out = opt.out_path("serve_stats.json");
    std::fs::write(&out, json.to_string())?;
    logging::info(format!("serve stats written to {out:?}"));
    registry.shutdown();
    write_trace(trace)?;
    Ok(())
}

/// `bbits engine-bench` — packed integer GEMM and spatial conv at
/// every chain width on synthetic layers, sweeping the scalar, SIMD
/// and cache-blocked kernel backends against the f32 fallback (GEMM
/// sweep shared with `benches/bench_engine.rs`). Writes the
/// machine-readable `BENCH_engine.json` (GEMM) and `BENCH_conv.json`
/// (conv) artifacts, each record carrying a `backend` column;
/// `--backend` restricts the sweep to one backend. `--paper-scale`
/// instead runs measured forwards through the full 224x224 ResNet18
/// lowering per backend and writes `BENCH_paper.json`. The serve
/// family also emits `BENCH_lifecycle.json` ([`lifecycle_bench`]):
/// artifact-vs-lowering cold start and warm-tail isolation during a
/// cold compile.
fn cmd_engine_bench(args: &Args) -> Result<()> {
    if args.bool_flag("paper-scale") {
        return paper_scale_bench(args);
    }
    let conv_only = args.bool_flag("conv-only");
    let serve_only = args.bool_flag("serve-only");
    if conv_only && serve_only {
        bail!("--conv-only and --serve-only are mutually exclusive \
               (together they would skip every sweep)");
    }
    let quick = args.bool_flag("quick");
    let rows = args.usize_flag("rows", 1024)?;
    let cols = args.usize_flag("cols", 1024)?;
    let batch = args.usize_flag("batch", 16)?;
    let backend = backend_from_args(args)?;
    let b = if quick { Bench::quick() } else { Bench::default() };
    if !conv_only && !serve_only {
        bayesian_bits::util::bench::header(&format!(
            "integer engine — {rows}x{cols} GEMM, batch {batch}"
        ));
        let gemm = engine::throughput_sweep(rows, cols, &[batch],
                                            &[2, 4, 8, 16], backend,
                                            &b)?;
        for rec in &gemm {
            println!("{}", rec.line());
        }
        let out = Path::new("BENCH_engine.json");
        bayesian_bits::util::bench::save_json(
            out,
            engine::BENCH_ENGINE_TITLE,
            gemm.iter().map(|r| r.to_json()).collect(),
        )?;
        println!("wrote {}", out.display());
    }

    if !serve_only {
        let hw = args.usize_flag("hw", 14)?;
        let cin = args.usize_flag("cin", 32)?;
        let cout = args.usize_flag("cout", 32)?;
        let ksize = args.usize_flag("ksize", 3)?;
        bayesian_bits::util::bench::header(&format!(
            "integer engine — {hw}x{hw}x{cin}->{cout} k{ksize} spatial \
             conv, batch {batch}"
        ));
        let conv = engine::conv_throughput_sweep(hw, cin, cout, ksize,
                                                 &[batch],
                                                 &[2, 4, 8, 16],
                                                 backend, &b)?;
        for rec in &conv {
            println!("{}", rec.line());
        }
        let out = Path::new("BENCH_conv.json");
        bayesian_bits::util::bench::save_json(
            out,
            "spatial conv images/sec per bit-width config, scalar vs \
             simd vs blocked integer backends vs f32 fallback",
            conv.iter().map(|r| r.to_json()).collect(),
        )?;
        println!("wrote {}", out.display());
    }

    if !conv_only {
        serve_bench(quick)?;
        ladder_bench(quick)?;
        lifecycle_bench(quick)?;
    }
    Ok(())
}

/// `bbits engine-bench --paper-scale` — measured (never projected)
/// forwards through the full paper-scale 224x224 ResNet18 lowering,
/// one record per backend config, written to `BENCH_paper.json`.
/// Unlike the synthetic sweeps this times the complete compiled
/// program — im2col, packed/blocked kernels, the requant chain — so
/// the blocked-vs-simd ratio the CI smoke asserts on is an
/// end-to-end number, not a kernel micro-ratio. Every config's
/// logits are also checked bit-identical against the scalar
/// oracle's before its timings count.
fn paper_scale_bench(args: &Args) -> Result<()> {
    let iters = args.usize_flag("requests", 3)?.max(1);
    let intra = args.usize_flag(
        "intra-threads",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .min(4),
    )?;
    let (man, params) = manifest_gen::preset_manifest_at(
        "resnet18", false, 42, Preset::Paper)?;
    let plan = Arc::new(engine::lower(&man, &params)?);
    println!("{}", plan.report());
    bayesian_bits::util::bench::header(&format!(
        "paper-scale resnet18 — measured 224x224 forwards, {iters} \
         per config"
    ));
    let configs: [(&str, engine::Backend, usize); 4] = [
        ("scalar", engine::Backend::Scalar, 1),
        ("simd", engine::Backend::Simd, 1),
        ("blocked", engine::Backend::Blocked, 1),
        ("blocked_intra", engine::Backend::Blocked, intra.max(1)),
    ];
    let xs: Vec<f32> = (0..plan.input_dim)
        .map(|i| ((i as f32) * 0.37).sin())
        .collect();
    let mut records = Vec::new();
    let mut oracle: Option<Vec<f32>> = None;
    for (name, backend, threads) in configs {
        let mut eng =
            engine::Engine::with_backend(plan.clone(), Some(backend));
        eng.set_intra_threads(threads);
        // warmup forward doubles as the bit-exactness check: every
        // backend computes the same exact integer sums, so the
        // dequantized logits must match the scalar oracle's exactly
        let y = eng.infer(&xs)?;
        match &oracle {
            None => oracle = Some(y),
            Some(want) => {
                if *want != y {
                    bail!("paper-scale parity failure: {name} \
                           (intra={threads}) diverged from the scalar \
                           oracle");
                }
            }
        }
        let mut t: Vec<u64> = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            eng.infer(&xs)?;
            t.push(t0.elapsed().as_nanos() as u64);
        }
        t.sort_unstable();
        let median_ns = t[t.len() / 2];
        let ips = 1e9 / median_ns as f64;
        println!(
            "[{name}] intra={threads} median {:.1}ms ({ips:.2} \
             images/sec)",
            median_ns as f64 / 1e6
        );
        // per-node breakdown from one profiled pass after the timed
        // loop, which stays uninstrumented
        eng.enable_profiling();
        eng.infer(&xs)?;
        let nodes = eng.kernel_profile(true);
        records.push(bayesian_bits::util::json::obj(vec![
            ("backend", bayesian_bits::util::json::s(name)),
            ("intra_threads", bayesian_bits::util::json::num(
                threads as f64)),
            ("median_ms", bayesian_bits::util::json::num(
                median_ns as f64 / 1e6)),
            ("images_per_sec", bayesian_bits::util::json::num(ips)),
            ("nodes", engine::trace::kernel_rows_json(&nodes)),
        ]));
    }
    let out = Path::new("BENCH_paper.json");
    bayesian_bits::util::bench::save_json(
        out,
        "measured end-to-end forwards through the paper-scale 224x224 \
         ResNet18 lowering: scalar vs simd vs blocked (single-thread \
         and intra-request sharded) integer backends",
        records,
    )?;
    println!("wrote {}", out.display());
    Ok(())
}

/// Multi-model serve sweep behind `BENCH_serve.json`: a registry of
/// synthetic models routed by a closed-loop load, once with an
/// unbounded plan cache (steady-state per-model p50/p99) and once
/// with a zero byte budget (worst-case eviction/recompile thrash).
/// Each pass emits one record per model plus a `_cache` record with
/// the plan-cache counters.
fn serve_bench(quick: bool) -> Result<()> {
    let model_dims: &[(&str, &[usize])] = &[
        ("mlp_small", &[64, 128, 10]),
        ("mlp_wide", &[96, 192, 16]),
        ("mlp_deep", &[48, 96, 96, 8]),
    ];
    let (clients, per_client) = if quick { (2, 18) } else { (4, 120) };
    let cfg = serve::ServeConfig {
        workers: 2,
        queue_cap: 64,
        max_batch: 8,
        deadline: std::time::Duration::from_millis(1),
        ..serve::ServeConfig::default()
    };
    bayesian_bits::util::bench::header(&format!(
        "multi-model serving — {} models, {clients} clients x \
         {per_client} requests",
        model_dims.len()
    ));
    let mut records = Vec::new();
    for (mode, registry) in [
        ("unbounded", Arc::new(ModelRegistry::new())),
        ("evict", Arc::new(ModelRegistry::with_budget(0))),
    ] {
        for (i, (name, dims)) in model_dims.iter().enumerate() {
            let plan = engine::synthetic_plan(
                name, dims, if i % 2 == 0 { 4 } else { 8 }, 8, 0.1,
                17 + i as u64)?;
            registry.register(name, Arc::new(plan), cfg.clone())?;
        }
        let ids: Vec<String> =
            model_dims.iter().map(|(n, _)| n.to_string()).collect();
        let router = Router::new(registry.clone());
        let (elapsed, per_model) =
            closed_loop_router(&router, &ids, clients, per_client, 7)?;
        for (id, st) in &per_model {
            println!("[{mode}/{id}] {st}");
            records.push(bayesian_bits::util::json::obj(vec![
                ("model", bayesian_bits::util::json::s(id)),
                ("cache_mode", bayesian_bits::util::json::s(mode)),
                ("requests", bayesian_bits::util::json::num(
                    st.requests as f64)),
                ("p50_ms", bayesian_bits::util::json::num(st.p50_ms)),
                ("p99_ms", bayesian_bits::util::json::num(st.p99_ms)),
                ("throughput_rps", bayesian_bits::util::json::num(
                    st.throughput_rps)),
            ]));
        }
        let cache = registry.cache_stats();
        println!(
            "[{mode}] plan cache: {} hits, {} misses ({} recompiles), \
             {} evictions over {elapsed:.2}s",
            cache.hits, cache.misses, cache.recompiles, cache.evictions
        );
        records.push(bayesian_bits::util::json::obj(vec![
            ("model", bayesian_bits::util::json::s("_cache")),
            ("cache_mode", bayesian_bits::util::json::s(mode)),
            ("hits", bayesian_bits::util::json::num(cache.hits as f64)),
            ("misses", bayesian_bits::util::json::num(
                cache.misses as f64)),
            ("recompiles", bayesian_bits::util::json::num(
                cache.recompiles as f64)),
            ("evictions", bayesian_bits::util::json::num(
                cache.evictions as f64)),
        ]));
        registry.shutdown();
    }
    let out = Path::new("BENCH_serve.json");
    bayesian_bits::util::bench::save_json(
        out,
        "multi-model registry/router serving: per-model latency \
         percentiles and plan-cache eviction counters",
        records,
    )?;
    println!("wrote {}", out.display());
    Ok(())
}

/// Median wall-clock of a batch-of-`n` inference over `plan`, sampled
/// `samples` times after one warmup batch — the SLO calibration probe
/// for [`ladder_bench`].
fn median_batch_ns(plan: &Arc<engine::EnginePlan>, n: usize,
                   samples: usize) -> Result<u64> {
    let mut eng = engine::Engine::new(plan.clone());
    let xs = vec![0.25f32; plan.input_dim * n];
    eng.infer_batch(&xs, n)?;
    let mut t: Vec<u64> = (0..samples)
        .map(|_| {
            let t0 = std::time::Instant::now();
            eng.infer_batch(&xs, n).map(|_| t0.elapsed().as_nanos()
                                                as u64)
        })
        .collect::<Result<_>>()?;
    t.sort_unstable();
    Ok(t[t.len() / 2])
}

/// Deadline-pressure sweep behind `BENCH_ladder.json`: the same
/// synthetic checkpoint served once as a static highest-bit plan and
/// once as a w2/w4/w8 precision ladder, hammered by a closed loop of
/// more clients than one batch absorbs. The SLO is calibrated between
/// the measured w2 and w8 batch times scaled by the steady-state wave
/// depth, so the static plan misses under pressure while the ladder
/// can degrade to cheaper rungs and keep fitting the deadline. Each
/// record carries `within_deadline` / `total` plus per-rung request
/// counts; the CI smoke asserts the ladder beats the static config.
fn ladder_bench(quick: bool) -> Result<()> {
    let dims: &[usize] = &[256, 512, 512, 16];
    let (clients, per_client) = if quick { (12, 16) } else { (12, 60) };
    let cfg = serve::ServeConfig {
        workers: 1,
        queue_cap: 64,
        max_batch: 4,
        deadline: std::time::Duration::from_micros(500),
        ..serve::ServeConfig::default()
    };
    let p2 = Arc::new(engine::synthetic_plan("lad", dims, 2, 8, 0.0,
                                             23)?);
    let p4 = Arc::new(engine::synthetic_plan("lad", dims, 4, 8, 0.0,
                                             23)?);
    let p8 = Arc::new(engine::synthetic_plan("lad", dims, 8, 8, 0.0,
                                             23)?);
    // SLO calibration: steady state stacks `clients / max_batch` waves
    // of work ahead of a fresh request, so scale the midpoint of the
    // cheapest/priciest batch times by that wave depth. Static w8 at
    // 3 waves of t8 overshoots the midpoint; ladder w2 fits under it.
    let t2 = median_batch_ns(&p2, cfg.max_batch, 5)?;
    let t8 = median_batch_ns(&p8, cfg.max_batch, 5)?;
    let waves = (clients / cfg.max_batch).max(1) as u64;
    let slo_ns = waves * (t2 + t8) / 2;
    let slo = std::time::Duration::from_nanos(slo_ns);
    bayesian_bits::util::bench::header(&format!(
        "SLO-adaptive ladder — {clients} clients x {per_client}, \
         slo {:.3}ms (w2 {:.3}ms / w8 {:.3}ms per batch)",
        slo_ns as f64 / 1e6, t2 as f64 / 1e6, t8 as f64 / 1e6
    ));
    let configs: Vec<(&str, Vec<(f64, Arc<engine::EnginePlan>)>)> =
        vec![
            ("static_w8", vec![(0.9, p8.clone())]),
            ("ladder_w2_w4_w8",
             vec![(0.2, p2), (0.5, p4), (0.9, p8)]),
        ];
    let mut records = Vec::new();
    for (name, rungs) in configs {
        let n_rungs = rungs.len();
        let mut cfg = cfg.clone();
        cfg.slo = Some(slo);
        let registry = Arc::new(ModelRegistry::new());
        registry.register_ladder_plans("lad", rungs, cfg)?;
        // Warm every rung's latency histogram while idle so the first
        // pressured pick already knows what each rung costs.
        for rung in 0..n_rungs {
            let tickets: Vec<_> = (0..3)
                .map(|_| registry.submit_rung(
                    "lad", rung, vec![0.5f32; dims[0]]))
                .collect::<Result<_>>()?;
            for t in tickets {
                t.wait()?;
            }
        }
        let router = Router::new(registry.clone());
        let rep = closed_loop_deadline(&router, "lad", clients,
                                       per_client, slo, 7)?;
        let pct = |p: f64| -> f64 {
            let i = ((rep.latencies_ns.len() as f64 - 1.0) * p)
                .round() as usize;
            rep.latencies_ns[i] as f64 / 1e6
        };
        let (p50, p99) = (pct(0.50), pct(0.99));
        println!(
            "[{name}] {}/{} within {:.3}ms SLO, p50 {p50:.3}ms p99 \
             {p99:.3}ms over {:.2}s",
            rep.within, rep.total, slo_ns as f64 / 1e6, rep.elapsed_s
        );
        let mut fields = vec![
            ("config", bayesian_bits::util::json::s(name)),
            ("slo_ms", bayesian_bits::util::json::num(
                slo_ns as f64 / 1e6)),
            ("within_deadline", bayesian_bits::util::json::num(
                rep.within as f64)),
            ("total", bayesian_bits::util::json::num(rep.total as f64)),
            ("p50_ms", bayesian_bits::util::json::num(p50)),
            ("p99_ms", bayesian_bits::util::json::num(p99)),
            ("elapsed_s", bayesian_bits::util::json::num(rep.elapsed_s)),
        ];
        let mut rung_rows = Vec::new();
        for info in registry.ladder("lad").unwrap_or_default() {
            println!(
                "  [{name}/{}] requests={} p90={:.3}ms",
                info.label, info.stats.requests, info.stats.p90_ms
            );
            rung_rows.push(bayesian_bits::util::json::obj(vec![
                ("label", bayesian_bits::util::json::s(&info.label)),
                ("threshold", bayesian_bits::util::json::num(
                    info.threshold)),
                ("w_bits", bayesian_bits::util::json::num(
                    info.w_bits as f64)),
                ("score", bayesian_bits::util::json::num(info.score)),
                ("requests", bayesian_bits::util::json::num(
                    info.stats.requests as f64)),
                ("p90_ms", bayesian_bits::util::json::num(
                    info.stats.p90_ms)),
            ]));
        }
        fields.push(("rungs", Json::Arr(rung_rows)));
        records.push(bayesian_bits::util::json::obj(fields));
        registry.shutdown();
    }
    let out = Path::new("BENCH_ladder.json");
    bayesian_bits::util::bench::save_json(
        out,
        "SLO-adaptive precision ladder vs static highest-bit plan: \
         requests served within a calibrated deadline under closed-loop \
         pressure, with per-rung request counts",
        records,
    )?;
    println!("wrote {}", out.display());
    Ok(())
}

/// Model-lifecycle sweep behind `BENCH_lifecycle.json`, all measured:
///
/// 1. **Cold start** — median wall-clock of manifest → lower →
///    compile-both-paths vs artifact decode → compile-both-paths for
///    the same model (plus the artifact byte size). The artifact path
///    skips lowering entirely, which is the `--load` pitch.
/// 2. **Warm tail isolation** — p50/p99 of a warm model's
///    submit→response latency while a *different* model's cold rung
///    compile deliberately holds its latch for `hold_ms` (via the
///    compile hook), against the same loop with no compile running.
///    With per-rung latches the two distributions must agree; the
///    pre-latch design serialized the warm submits behind the
///    registry lock for the whole compile.
fn lifecycle_bench(quick: bool) -> Result<()> {
    let (man, params) = manifest_gen::preset_manifest("lenet5",
                                                      false, 42)?;
    let iters = if quick { 3 } else { 7 };
    bayesian_bits::util::bench::header(&format!(
        "model lifecycle — lenet5 cold start x{iters}, warm tail \
         during a held cold compile"
    ));
    let median = |t: &mut Vec<u64>| -> f64 {
        t.sort_unstable();
        t[t.len() / 2] as f64 / 1e6
    };
    let mut lower_ns = Vec::with_capacity(iters);
    let mut artifact_ns = Vec::with_capacity(iters);
    let mut artifact_bytes = 0usize;
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        let plan = Arc::new(engine::lower(&man, &params)?);
        let _progs = engine::try_compile_pair_with(&plan, None)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        lower_ns.push(t0.elapsed().as_nanos() as u64);
        let bytes = engine::artifact::encode_plan(&plan);
        artifact_bytes = bytes.len();
        let t1 = std::time::Instant::now();
        let decoded =
            Arc::new(engine::artifact::decode_plan(&bytes)?);
        let _progs = engine::try_compile_pair_with(&decoded, None)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        artifact_ns.push(t1.elapsed().as_nanos() as u64);
    }
    let (lower_ms, artifact_ms) =
        (median(&mut lower_ns), median(&mut artifact_ns));
    println!(
        "cold start: lower+compile {lower_ms:.2}ms, artifact \
         decode+compile {artifact_ms:.2}ms ({artifact_bytes} B \
         artifact)"
    );

    // warm tail: model "w" serves a tight submit/wait loop while
    // model "c"'s first compile holds its rung latch for hold_ms
    let cfg = serve::ServeConfig {
        workers: 2,
        queue_cap: 64,
        max_batch: 8,
        deadline: std::time::Duration::from_micros(200),
        ..serve::ServeConfig::default()
    };
    let hold_ms: u64 = if quick { 150 } else { 400 };
    let samples = if quick { 400 } else { 2000 };
    let warm =
        Arc::new(engine::synthetic_plan("w", &[64, 128, 10], 4, 8,
                                        0.0, 5)?);
    let cold =
        Arc::new(engine::synthetic_plan("c", &[96, 192, 12], 8, 8,
                                        0.0, 6)?);
    let registry = Arc::new(ModelRegistry::new());
    registry.register("w", warm.clone(), cfg.clone())?;
    registry.register("c", cold, cfg)?;
    let x = vec![0.25f32; warm.input_dim];
    registry.submit("w", x.clone())?.wait()?; // warm the rung
    let drive = |n: usize, stop: Option<&std::thread::JoinHandle<_>>|
                 -> Result<Vec<u64>> {
        let mut lat = Vec::with_capacity(n);
        while lat.len() < n
            || stop.map(|h| !h.is_finished()).unwrap_or(false)
        {
            let t0 = std::time::Instant::now();
            registry.submit("w", x.clone())?.wait()?;
            lat.push(t0.elapsed().as_nanos() as u64);
        }
        Ok(lat)
    };
    let pct = |lat: &mut Vec<u64>, p: f64| -> f64 {
        lat.sort_unstable();
        lat[((lat.len() as f64 - 1.0) * p).round() as usize] as f64
            / 1e6
    };
    let mut base = drive(samples, None)?;
    let (base_p50, base_p99) = (pct(&mut base, 0.50),
                                pct(&mut base, 0.99));
    registry._set_compile_hook(Some(Arc::new(move |id: &str, _| {
        if id == "c" {
            std::thread::sleep(
                std::time::Duration::from_millis(hold_ms));
        }
        Ok(())
    })));
    let reg = registry.clone();
    let cold_submit = std::thread::spawn(move || {
        reg.submit("c", vec![0.5f32; 96]).and_then(|t| t.wait())
    });
    let mut during = drive(samples, Some(&cold_submit))?;
    cold_submit
        .join()
        .map_err(|_| anyhow::anyhow!("cold submit panicked"))??;
    registry._set_compile_hook(None);
    let (during_p50, during_p99) = (pct(&mut during, 0.50),
                                    pct(&mut during, 0.99));
    let cache = registry.cache_stats();
    registry.shutdown();
    println!(
        "warm tail: idle p50 {base_p50:.3}ms p99 {base_p99:.3}ms; \
         during a {hold_ms}ms cold compile p50 {during_p50:.3}ms p99 \
         {during_p99:.3}ms over {} samples ({} latch waits by warm \
         traffic)",
        during.len(), cache.latch_waits
    );
    let out = Path::new("BENCH_lifecycle.json");
    bayesian_bits::util::bench::save_json(
        out,
        "model lifecycle: artifact-vs-lowering cold start down to \
         compiled programs, and a warm model's latency tail while \
         another model's cold rung compile holds its latch",
        vec![
            bayesian_bits::util::json::obj(vec![
                ("record", bayesian_bits::util::json::s("cold_start")),
                ("lower_compile_ms",
                 bayesian_bits::util::json::num(lower_ms)),
                ("artifact_compile_ms",
                 bayesian_bits::util::json::num(artifact_ms)),
                ("artifact_bytes", bayesian_bits::util::json::num(
                    artifact_bytes as f64)),
            ]),
            bayesian_bits::util::json::obj(vec![
                ("record", bayesian_bits::util::json::s("warm_tail")),
                ("hold_ms", bayesian_bits::util::json::num(
                    hold_ms as f64)),
                ("samples", bayesian_bits::util::json::num(
                    during.len() as f64)),
                ("baseline_p50_ms",
                 bayesian_bits::util::json::num(base_p50)),
                ("baseline_p99_ms",
                 bayesian_bits::util::json::num(base_p99)),
                ("during_cold_p50_ms",
                 bayesian_bits::util::json::num(during_p50)),
                ("during_cold_p99_ms",
                 bayesian_bits::util::json::num(during_p99)),
                ("warm_latch_waits", bayesian_bits::util::json::num(
                    cache.latch_waits as f64)),
            ]),
        ],
    )?;
    println!("wrote {}", out.display());
    Ok(())
}

fn cmd_train(args: &Args, opt: &ExpOptions) -> Result<()> {
    let model = args.str_flag("model", "lenet5");
    let mode = Mode::parse(&args.str_flag("mode", "bb"))?;
    let mu = args.f64_flag("mu", 0.01)?;
    let seed = args.usize_flag("seed", 1)? as u64;
    let mut cfg = opt.config(&model, mode, mu, seed);
    cfg.deterministic_gates = args.bool_flag("det-gates");
    cfg.lr_w = args.f64_flag("lr-w", cfg.lr_w)?;
    cfg.lr_g = args.f64_flag("lr-g", cfg.lr_g)?;
    cfg.lr_s = args.f64_flag("lr-s", cfg.lr_s)?;
    cfg.eval_every = args.usize_flag("eval-every", cfg.steps / 5)?;
    cfg.finetune_steps =
        args.usize_flag("finetune-steps", cfg.finetune_steps)?;
    if args.bool_flag("no-finetune") {
        cfg.finetune_steps = 0;
    }

    let rt = Arc::new(Runtime::cpu()?);
    let man = Manifest::load(Path::new(&cfg.artifacts_dir), &cfg.model)?;
    let mut trainer = Trainer::new(rt, man.clone(), cfg.clone())?;
    let init = TrainState::init(&man)?;
    let (final_state, result) = trainer.run_keeping_state(init)?;
    println!(
        "\nresult: model={} mode={} mu={} acc={:.4} (pre-FT {:.4}) \
         relBOPs={:.2}% loss={:.4}",
        result.model, result.mode, result.mu, result.accuracy,
        result.pre_ft_accuracy, result.rel_bops_pct, result.test_loss
    );
    println!("{}", arch_viz::architecture_report(&man, &result.states));
    println!("{}", arch_viz::summary_line(&man, &result.states));
    let stem = format!(
        "train_{}_{}_mu{}",
        cfg.model,
        cfg.mode.label().replace(':', "_"),
        cfg.mu
    );
    let out = opt.out_path(&format!("{stem}.metrics.json"));
    result.history.save(&out)?;
    logging::info(format!("metrics written to {out:?}"));
    // final trained state, servable via `bbits serve --checkpoint`
    let ckpt = opt.out_path(&format!("{stem}.ckpt"));
    checkpoint::save(&ckpt, &cfg.model, &final_state)?;
    logging::info(format!(
        "checkpoint written to {ckpt:?} (serve it: bbits serve --model \
         {} --checkpoint {} --mode {})",
        cfg.model,
        ckpt.display(),
        cfg.mode.label()
    ));
    Ok(())
}

fn cmd_sweep(args: &Args, opt: &ExpOptions) -> Result<()> {
    let model = args.str_flag("model", "lenet5");
    let mode = Mode::parse(&args.str_flag("mode", "bb"))?;
    let mus = args.f64_list_flag("mus", presets::FIGURE2_MUS)?;
    let mut jobs: Vec<Job> = Vec::new();
    for mu in &mus {
        jobs.extend(opt.jobs_for(&model, mode.clone(), *mu));
    }
    let results = run_sweep(jobs, opt.jobs)?;
    let mut t = TableBuilder::new(
        &format!("Sweep — {model} ({})", mode.label()),
        &["mu", "Acc. (%)", "Rel. GBOPs (%)"],
    );
    for a in experiments::common::agg(&results) {
        t.row(&[
            format!("{}", a.mu),
            TableBuilder::pm(a.acc_mean * 100.0, a.acc_stderr * 100.0, 2),
            TableBuilder::pm(a.bops_mean, a.bops_stderr, 2),
        ]);
    }
    println!("{}", t.render());
    experiments::common::save_results(
        &opt.out_path("sweep.json"), "sweep", &results)?;
    experiments::common::save_histories(
        &opt.out_path("sweep_runs"), &results)?;
    Ok(())
}

/// Check the Rust host quantizer and the PJRT-executed kernel against
/// the golden vectors exported by aot.py — the three-layer parity proof.
fn cmd_parity(opt: &ExpOptions) -> Result<()> {
    let dir = Path::new(&opt.artifacts_dir);
    let text = std::fs::read_to_string(dir.join("goldens.json"))
        .context("read goldens.json (run `make artifacts`)")?;
    let g = Json::parse(&text)?;
    let shape = g.get("shape")?.usize_vec()?;
    let levels: Vec<u32> = g
        .get("levels")?
        .usize_vec()?
        .iter()
        .map(|v| *v as u32)
        .collect();
    let rt = Runtime::cpu()?;
    let exe = rt.load(&dir.join("quantizer_fwd.hlo.txt"))?;
    let cfg = QuantConfig::new(true, &levels);
    let mut max_host = 0.0f32;
    let mut max_dev = 0.0f32;
    for (i, case) in g.get("cases")?.as_arr()?.iter().enumerate() {
        let x = case.get("x")?.f32_vec()?;
        let beta = case.get("beta")?.f32_vec()?;
        let z2 = case.get("z2")?.f32_vec()?;
        let zh = case.get("zh")?.f32_vec()?;
        let want = case.get("out")?.f32_vec()?;
        let host = bb_quantize_host(&x, shape[0], beta[0], &z2, &zh, &cfg);
        let dev = rt.quantizer_fwd(&exe, &x, shape[0], &beta, &z2, &zh)?;
        for ((h, d), w) in host.iter().zip(&dev).zip(&want) {
            max_host = max_host.max((h - w).abs());
            max_dev = max_dev.max((d - w).abs());
        }
        println!("case {i}: host max|err|={max_host:.2e} \
                  device max|err|={max_dev:.2e}");
    }
    if max_host > 1e-5 || max_dev > 1e-6 {
        bail!("parity failure: host {max_host} device {max_dev}");
    }
    println!("parity OK (host oracle + PJRT kernel vs python goldens)");
    Ok(())
}

/// Analytic BOP tables at both presets for all models.
fn cmd_bops() -> Result<()> {
    for preset in [Preset::Small, Preset::Paper] {
        let mut t = TableBuilder::new(
            &format!("Analytic BOP table ({preset:?} preset)"),
            &["Model", "GMACs", "FP32 GBOPs", "w8a8 (%)", "w4a4 (%)",
              "w2a2 (%)"],
        );
        for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
            let layers = descriptor(model, preset)?;
            let c = BopCounter::new(layers);
            let row = |w, a| {
                let states = c.fixed_states(w, a);
                format!("{:.2}", c.relative_bops_pct(&states))
            };
            t.row(&[
                model.to_string(),
                format!("{:.4}", c.total_macs() as f64 / 1e9),
                format!("{:.3}", c.fp32_bops() / 1e9),
                row(8, 8),
                row(4, 4),
                row(2, 2),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_report(args: &Args, opt: &ExpOptions) -> Result<()> {
    let runs = args.str_flag("runs", &opt.out_dir);
    let dir = Path::new(&runs);
    let mut t = TableBuilder::new(
        &format!("Run summary — {runs}"),
        &["File", "Experiment", "Rows"],
    );
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("read dir {dir:?}"))?
    {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            if let Ok(text) = std::fs::read_to_string(&path) {
                if let Ok(v) = Json::parse(&text) {
                    let exp = v
                        .get("experiment")
                        .ok()
                        .and_then(|e| e.as_str().ok().map(String::from))
                        .unwrap_or_else(|| "-".into());
                    let rows = v
                        .get("results")
                        .ok()
                        .and_then(|r| r.as_arr().ok().map(|a| a.len()))
                        .unwrap_or(0);
                    t.row(&[
                        path.file_name().unwrap().to_string_lossy()
                            .to_string(),
                        exp,
                        rows.to_string(),
                    ]);
                }
            }
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn resolve_metrics_path(p: &Path) -> Result<std::path::PathBuf> {
    if p.is_file() {
        return Ok(p.to_path_buf());
    }
    if p.is_dir() {
        // pick the first metrics file
        for entry in std::fs::read_dir(p)? {
            let path = entry?.path();
            if path
                .file_name()
                .map(|n| n.to_string_lossy().ends_with(".metrics.json"))
                .unwrap_or(false)
            {
                return Ok(path);
            }
        }
    }
    bail!("no metrics file found at {p:?} (train something first, e.g. \
           `bbits train --model resnet18`)")
}
