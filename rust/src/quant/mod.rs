//! Host-side mirror of the Bayesian Bits quantizer math.
//!
//! The device executables carry the authoritative implementation
//! (lowered from the Pallas kernel); this module re-implements the same
//! equations in Rust for three purposes:
//! 1. gate management — thresholding phi into test-time 0/1 gates
//!    (Eq. 22), effective-bit-width and sparsity reports;
//! 2. an independent oracle for parity/property tests against the
//!    artifacts (`tests/runtime_parity.rs`);
//! 3. BOP estimation from checkpoints without touching the device.

pub mod gates;
pub mod grid;

pub use gates::{prob_active, test_time_gate, test_time_gate_at, GateView,
                HardConcrete};
pub use grid::{bb_quantize_host, step_sizes, QuantConfig};

/// Hardware-friendly bit-width chain (paper Eq. 4).
pub const LEVELS: [u32; 5] = [2, 4, 8, 16, 32];
