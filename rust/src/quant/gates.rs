//! Hard-concrete gate distribution (App. A.2) and test-time thresholding.
//!
//! Mirrors `python/compile/kernels/ref.py`; the constants must stay in
//! lock-step (checked by the golden-vector parity test).

/// Hard-concrete hyper-parameters (Louizos et al. 2018).
pub const GAMMA: f64 = -0.1;
pub const ZETA: f64 = 1.1;
pub const TAU: f64 = 2.0 / 3.0;
/// Test-time pruning threshold t in Eq. 22.
pub const THRESHOLD: f64 = 0.34;

#[inline]
pub fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// The stretched/clipped hard-concrete distribution for one gate.
#[derive(Debug, Clone, Copy)]
pub struct HardConcrete {
    pub phi: f64,
}

impl HardConcrete {
    pub fn new(phi: f64) -> Self {
        Self { phi }
    }

    /// Sample z given uniform noise u in (0,1) (Eq. 20).
    pub fn sample(&self, u: f64) -> f64 {
        let g = (u / (1.0 - u)).ln();
        let s = sigmoid((g + self.phi) / TAU);
        (s * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
    }

    /// Deterministic value with the noise switched off (u = 0.5).
    pub fn mean_gate(&self) -> f64 {
        let s = sigmoid(self.phi / TAU);
        (s * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
    }

    /// R_phi(z > 0) = sigma(phi - tau * log(-gamma/zeta)) (Eq. 21).
    pub fn prob_active(&self) -> f64 {
        prob_active(self.phi)
    }

    /// Test-time binary gate (Eq. 22).
    pub fn test_gate(&self) -> bool {
        test_time_gate(self.phi)
    }
}

pub fn prob_active(phi: f64) -> f64 {
    sigmoid(phi - TAU * (-GAMMA / ZETA).ln())
}

/// Eq. 22: z = 1[ sigma(tau log(-gamma/zeta) - phi) < t ] at the
/// paper's default threshold [`THRESHOLD`].
pub fn test_time_gate(phi: f64) -> bool {
    test_time_gate_at(phi, THRESHOLD)
}

/// Eq. 22 at an explicit threshold `t`: the precision-ladder
/// primitive. A smaller `t` opens fewer gates (shorter residual
/// chains, more pruned channels => a cheaper plan); a larger `t`
/// opens more. `t = THRESHOLD` reproduces [`test_time_gate`] exactly.
pub fn test_time_gate_at(phi: f64, threshold: f64) -> bool {
    sigmoid(TAU * (-GAMMA / ZETA).ln() - phi) < threshold
}

/// A view over one quantizer's slots in the global gate vector:
/// `channels` pruning gates (z2, per output channel) followed by the
/// shared residual gates (z4, z8, ...).
#[derive(Debug, Clone)]
pub struct GateView {
    pub channels: usize,
    pub levels: Vec<u32>,
}

impl GateView {
    pub fn n_slots(&self) -> usize {
        self.channels + self.levels.len().saturating_sub(1)
    }

    /// Threshold a slice of phi logits into test-time binary gates.
    pub fn threshold(&self, phi: &[f64]) -> Vec<f32> {
        assert_eq!(phi.len(), self.n_slots());
        phi.iter()
            .map(|p| if test_time_gate(*p) { 1.0 } else { 0.0 })
            .collect()
    }

    /// Effective bit width given binary slot values: 0 if all channels
    /// pruned, otherwise the highest level whose gate chain is open.
    pub fn effective_bits(&self, z: &[f32]) -> u32 {
        assert_eq!(z.len(), self.n_slots());
        let any_channel = z[..self.channels].iter().any(|v| *v > 0.5);
        if !any_channel {
            return 0;
        }
        let mut bits = self.levels[0];
        for (i, b) in self.levels.iter().skip(1).enumerate() {
            if z[self.channels + i] > 0.5 {
                bits = *b;
            } else {
                break;
            }
        }
        bits
    }

    /// Fraction of output channels kept (1.0 when no channels pruned).
    pub fn keep_ratio(&self, z: &[f32]) -> f64 {
        if self.channels == 0 {
            return 1.0;
        }
        z[..self.channels].iter().filter(|v| **v > 0.5).count() as f64
            / self.channels as f64
    }

    /// Expected (soft) bit width from inclusion probabilities — the live
    /// BOP estimate used during training (Figure 12-style tracking).
    pub fn expected_bits(&self, probs: &[f32]) -> f64 {
        assert_eq!(probs.len(), self.n_slots());
        let p2 = probs[..self.channels]
            .iter()
            .map(|p| *p as f64)
            .sum::<f64>()
            / self.channels.max(1) as f64;
        let mut bits = self.levels[0] as f64 * p2;
        let mut chain = p2;
        let mut prev = self.levels[0] as f64;
        for (i, b) in self.levels.iter().skip(1).enumerate() {
            chain *= probs[self.channels + i] as f64;
            bits += (*b as f64 - prev) * chain;
            prev = *b as f64;
        }
        bits
    }

    /// Build lock (mask, value) pairs fixing this quantizer at `bits`
    /// (0 => pruned). Channel gates lock to 1 unless pruned.
    pub fn lock_fixed(&self, bits: u32) -> (Vec<f32>, Vec<f32>) {
        let n = self.n_slots();
        let mask = vec![1.0f32; n];
        let mut val = vec![0.0f32; n];
        if bits >= self.levels[0] {
            for v in val[..self.channels].iter_mut() {
                *v = 1.0;
            }
            for (i, b) in self.levels.iter().skip(1).enumerate() {
                if *b <= bits {
                    val[self.channels + i] = 1.0;
                }
            }
        }
        (mask, val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view() -> GateView {
        GateView { channels: 3, levels: vec![2, 4, 8, 16, 32] }
    }

    #[test]
    fn threshold_matches_eq22() {
        // phi = 0: p_zero = sigma(tau log(-g/z)) = sigma(0.2665*...)
        let p_zero = sigmoid(TAU * (-GAMMA / ZETA).ln());
        assert_eq!(test_time_gate(0.0), p_zero < THRESHOLD);
        assert!(test_time_gate(5.0));
        assert!(!test_time_gate(-5.0));
    }

    #[test]
    fn explicit_threshold_matches_default_and_is_monotone() {
        for phi in [-6.0, -1.0, 0.0, 1.0, 6.0] {
            assert_eq!(test_time_gate(phi),
                       test_time_gate_at(phi, THRESHOLD));
        }
        // raising t can only open gates, never close them
        for phi in [-2.0, -0.5, 0.0, 0.5, 2.0] {
            let mut open = false;
            for t in [0.05, 0.2, 0.34, 0.5, 0.9, 0.99] {
                let g = test_time_gate_at(phi, t);
                assert!(g || !open, "gate closed as t rose");
                open = g;
            }
        }
    }

    #[test]
    fn prob_active_monotone() {
        let mut last = 0.0;
        for phi in [-6.0, -2.0, 0.0, 2.0, 6.0] {
            let p = prob_active(phi);
            assert!(p > last);
            last = p;
        }
    }

    #[test]
    fn sample_within_unit_interval_and_hits_endpoints() {
        let hc = HardConcrete::new(0.0);
        let mut zeros = 0;
        let mut ones = 0;
        let mut rng = crate::rng::Pcg64::new(1);
        for _ in 0..5000 {
            let z = hc.sample(rng.next_f64().clamp(1e-9, 1.0 - 1e-9));
            assert!((0.0..=1.0).contains(&z));
            if z == 0.0 {
                zeros += 1;
            }
            if z == 1.0 {
                ones += 1;
            }
        }
        assert!(zeros > 0 && ones > 0);
    }

    #[test]
    fn effective_bits_chain() {
        let v = view();
        // all channels on, z4 on, z8 off => 4 bits regardless of z16/z32
        let z = vec![1., 1., 1., 1., 0., 1., 1.];
        assert_eq!(v.effective_bits(&z), 4);
        // all gates open => 32
        let z = vec![1.; 7];
        assert_eq!(v.effective_bits(&z), 32);
        // all channels pruned => 0 bits
        let z = vec![0., 0., 0., 1., 1., 1., 1.];
        assert_eq!(v.effective_bits(&z), 0);
    }

    #[test]
    fn keep_ratio_counts_channels() {
        let v = view();
        let z = vec![1., 0., 1., 1., 1., 1., 1.];
        assert!((v.keep_ratio(&z) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn expected_bits_extremes() {
        let v = view();
        let all = vec![1.0f32; 7];
        assert!((v.expected_bits(&all) - 32.0).abs() < 1e-9);
        let none = vec![0.0f32; 7];
        assert_eq!(v.expected_bits(&none), 0.0);
        // z2 only: expected 2 bits
        let two = vec![1., 1., 1., 0., 0., 0., 0.];
        assert!((v.expected_bits(&two) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn lock_fixed_patterns() {
        let v = view();
        let (mask, val) = v.lock_fixed(8);
        assert!(mask.iter().all(|m| *m == 1.0));
        assert_eq!(val, vec![1., 1., 1., 1., 1., 0., 0.]);
        let (_, val0) = v.lock_fixed(0);
        assert!(val0.iter().all(|z| *z == 0.0));
        assert_eq!(v.effective_bits(&val), 8);
    }
}
