//! Host re-implementation of the residual-decomposition quantizer
//! (Eqs. 1-6) — the independent oracle for artifact parity tests.
//!
//! Numerics match the kernel: f32 arithmetic, clip bound shrunk by
//! (1 - 1e-7) while grid steps use |beta| itself (paper §2.4).

pub const BETA_EPS: f32 = 1e-7;

/// Static configuration of one quantizer.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub signed: bool,
    pub levels: Vec<u32>,
}

impl QuantConfig {
    pub fn new(signed: bool, levels: &[u32]) -> Self {
        assert!(levels[0] == 2, "chain starts at 2 bits");
        Self { signed, levels: levels.to_vec() }
    }
}

/// Step-size chain s_2, s_4, ... (s_b = s_{b/2} / (2^{b/2} + 1)).
pub fn step_sizes(beta: f32, cfg: &QuantConfig) -> Vec<f32> {
    let beta_grid = beta.abs();
    let alpha = if cfg.signed { -beta_grid } else { 0.0 };
    let mut out = Vec::with_capacity(cfg.levels.len());
    let mut s = (beta_grid - alpha) / 3.0;
    out.push(s);
    for b in &cfg.levels[1..] {
        s /= (2.0f32).powi((b / 2) as i32) + 1.0;
        out.push(s);
    }
    out
}

fn pact_clip(x: f32, alpha_clip: f32, beta_clip: f32) -> f32 {
    beta_clip - (beta_clip - alpha_clip - (x - alpha_clip).max(0.0)).max(0.0)
}

/// Full quantizer forward over a (channels, rest) tensor.
///
/// * `x` — row-major (channels x rest);
/// * `z2` — per-channel pruning gates (len == channels);
/// * `zh` — residual gates (len == levels.len() - 1);
/// returns the quantized tensor (same layout).
pub fn bb_quantize_host(x: &[f32], channels: usize, beta: f32, z2: &[f32],
                        zh: &[f32], cfg: &QuantConfig) -> Vec<f32> {
    assert_eq!(z2.len(), channels);
    assert_eq!(zh.len(), cfg.levels.len() - 1);
    assert_eq!(x.len() % channels.max(1), 0);
    let rest = x.len() / channels.max(1);

    let beta_grid = beta.abs();
    let beta_clip = beta_grid * (1.0 - BETA_EPS);
    let alpha = if cfg.signed { -beta_grid } else { 0.0 };
    let alpha_clip = alpha * (1.0 - BETA_EPS);

    let mut out = vec![0.0f32; x.len()];
    let n_res = cfg.levels.len() - 1;
    let mut terms = vec![0.0f32; n_res + 1];
    for c in 0..channels {
        for r in 0..rest {
            let v = x[c * rest + r];
            let xc = pact_clip(v, alpha_clip, beta_clip);
            // residual chain
            let mut s = (beta_grid - alpha) / 3.0;
            let mut cur = s * round_half_even(xc / s);
            terms[0] = cur;
            for (i, b) in cfg.levels[1..].iter().enumerate() {
                s /= (2.0f32).powi((b / 2) as i32) + 1.0;
                let eps = s * round_half_even((xc - cur) / s);
                terms[i + 1] = eps;
                cur += eps;
            }
            // gated sum, innermost first (Eq. 6)
            let mut inner = 0.0f32;
            for i in (0..n_res).rev() {
                inner = zh[i] * (terms[i + 1] + inner);
            }
            out[c * rest + r] = z2[c] * (terms[0] + inner);
        }
    }
    out
}

/// XLA's `round` op rounds half away from zero... jnp.round rounds half
/// to even (banker's rounding), matching numpy. The decomposition's
/// residual ratios land exactly on .5 boundaries only at clip edges
/// (prevented by BETA_EPS), but we match jnp exactly anyway.
#[inline]
fn round_half_even(v: f32) -> f32 {
    let r = v.round(); // half away from zero
    if (v - v.trunc()).abs() == 0.5 {
        // half-to-even correction
        let t = v.trunc();
        if t as i64 % 2 == 0 {
            t
        } else {
            t + v.signum()
        }
    } else {
        r
    }
}

/// Plain uniform quantizer at one bit width (tests/fixed baselines).
pub fn quantize_fixed_host(x: &[f32], beta: f32, bit: u32,
                           signed: bool) -> Vec<f32> {
    let (s, codes) = quantize_codes_host(x, beta, bit, signed);
    codes.iter().map(|q| s * *q as f32).collect()
}

/// Precomputed fixed-width quantization grid: the per-element form of
/// [`quantize_codes_host`], shareable as a compile-time constant of
/// the engine's execution graph (`engine::graph::Node::Quantize` and
/// the fused requantize+quantize node both carry one). Constructing a
/// `CodeGrid` and calling [`CodeGrid::code`] per element reproduces
/// `quantize_codes_host` bit-exactly — the function is implemented on
/// top of it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodeGrid {
    /// Grid step: dequantization is `step * code`.
    pub step: f32,
    pub bits: u32,
    pub signed: bool,
    alpha_clip: f32,
    beta_clip: f32,
    lo: i64,
    hi: i64,
}

impl CodeGrid {
    pub fn new(beta: f32, bits: u32, signed: bool) -> CodeGrid {
        let beta_grid = beta.abs();
        let beta_clip = beta_grid * (1.0 - BETA_EPS);
        let alpha = if signed { -beta_grid } else { 0.0 };
        let alpha_clip = alpha * (1.0 - BETA_EPS);
        let step =
            (beta_grid - alpha) / ((2.0f64.powi(bits as i32) - 1.0) as f32);
        // At 32 bits the BETA_EPS clip margin is below one f32 ulp of
        // the max ratio, so rounding in `xc / step` can overshoot the
        // nominal grid end by one ulp; clamp to keep the b-bit
        // contract exact.
        let hi = if signed {
            (1i64 << (bits - 1)) - 1
        } else {
            (1i64 << bits) - 1
        };
        let lo = if signed { -hi } else { 0 };
        CodeGrid { step, bits, signed, alpha_clip, beta_clip, lo, hi }
    }

    /// Integer grid code of one value (clip + banker's rounding).
    #[inline]
    pub fn code(&self, v: f32) -> i64 {
        let xc = pact_clip(v, self.alpha_clip, self.beta_clip);
        (round_half_even(xc / self.step) as i64).clamp(self.lo, self.hi)
    }

    /// Smallest code this grid can emit (`code` clamps into
    /// `[code_lo, code_hi]`) — the interval the static plan verifier
    /// (`engine::verify`) propagates through the compiled graph.
    #[inline]
    pub fn code_lo(&self) -> i64 {
        self.lo
    }

    /// Largest code this grid can emit.
    #[inline]
    pub fn code_hi(&self) -> i64 {
        self.hi
    }
}

/// Integer grid codes for the fixed-width quantizer — the lowering
/// contract of the integer engine (`engine::pack`).
///
/// Returns `(step, codes)` such that `quantize_fixed_host` is exactly
/// `step * codes[i] as f32` element-wise (same clip, same banker's
/// rounding). Signed codes land in `[-(2^(b-1) - 1), 2^(b-1) - 1]` and
/// unsigned codes in `[0, 2^b - 1]`, so every width in
/// [`crate::quant::LEVELS`] fits a `b`-bit word.
pub fn quantize_codes_host(x: &[f32], beta: f32, bit: u32,
                           signed: bool) -> (f32, Vec<i64>) {
    let g = CodeGrid::new(beta, bit, signed);
    (g.step, x.iter().map(|v| g.code(*v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropResult};

    fn cfg() -> QuantConfig {
        QuantConfig::new(true, &[2, 4, 8, 16, 32])
    }

    #[test]
    fn step_sizes_closed_form() {
        let sizes = step_sizes(2.0, &cfg());
        for (s, b) in sizes.iter().zip([2u32, 4, 8, 16, 32]) {
            let want = 4.0 / (2.0f64.powi(b as i32) - 1.0);
            assert!(((*s as f64) - want).abs() < want * 1e-5,
                    "b={b} s={s} want={want}");
        }
    }

    #[test]
    fn full_chain_equals_fixed_quantizer() {
        let mut rng = crate::rng::Pcg64::new(3);
        let x: Vec<f32> = (0..64).map(|_| rng.normal() * 1.5).collect();
        for (bits, zh) in [
            (2u32, [0., 0., 0., 0.]),
            (4, [1., 0., 0., 0.]),
            (8, [1., 1., 0., 0.]),
            (32, [1., 1., 1., 1.]),
        ] {
            let got = bb_quantize_host(&x, 4, 2.0, &[1.; 4], &zh, &cfg());
            let want = quantize_fixed_host(&x, 2.0, bits, true);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "bits={bits} {g} vs {w}");
            }
        }
    }

    #[test]
    fn pruned_channel_is_zero() {
        let x = vec![1.0f32; 8];
        let out = bb_quantize_host(&x, 2, 2.0, &[0.0, 1.0],
                                   &[1., 1., 1., 1.], &cfg());
        assert!(out[..4].iter().all(|v| *v == 0.0));
        assert!(out[4..].iter().all(|v| *v != 0.0));
    }

    #[test]
    fn codes_reconstruct_fixed_quantizer_exactly() {
        let mut rng = crate::rng::Pcg64::new(11);
        for bit in crate::quant::LEVELS {
            for signed in [true, false] {
                let x: Vec<f32> = (0..128)
                    .map(|_| {
                        let v = rng.normal() * 2.0;
                        if signed { v } else { v.abs() }
                    })
                    .collect();
                let (s, codes) = quantize_codes_host(&x, 1.7, bit, signed);
                let want = quantize_fixed_host(&x, 1.7, bit, signed);
                let lim = if signed {
                    (1i64 << (bit - 1)) - 1
                } else {
                    (1i64 << bit) - 1
                };
                for (q, w) in codes.iter().zip(&want) {
                    // bit-exact by construction (same ops)
                    assert_eq!(s * *q as f32, *w, "bit={bit}");
                    assert!(*q <= lim && *q >= if signed { -lim } else { 0 },
                            "bit={bit} code {q} exceeds [{}, {lim}]",
                            if signed { -lim } else { 0 });
                }
            }
        }
    }

    #[test]
    fn code_grid_matches_batch_quantizer_per_element() {
        let mut rng = crate::rng::Pcg64::new(29);
        for bit in crate::quant::LEVELS {
            for signed in [true, false] {
                let x: Vec<f32> = (0..64)
                    .map(|_| {
                        let v = rng.normal() * 3.0;
                        if signed { v } else { v.abs() }
                    })
                    .collect();
                let g = CodeGrid::new(2.3, bit, signed);
                let (s, codes) = quantize_codes_host(&x, 2.3, bit, signed);
                assert_eq!(g.step, s, "bit={bit}");
                for (v, q) in x.iter().zip(&codes) {
                    assert_eq!(g.code(*v), *q, "bit={bit} v={v}");
                }
            }
        }
    }

    #[test]
    fn prop_output_on_grid_and_in_range() {
        check("quantizer_grid_membership", 200, |g| {
            let beta = g.f32_in(0.1, 5.0);
            let signed = g.bool();
            let cfg = QuantConfig::new(signed, &[2, 4, 8]);
            let n = g.usize_in(1, 32);
            let x: Vec<f32> = (0..n)
                .map(|_| {
                    let v = g.f32_in(-8.0, 8.0);
                    if signed { v } else { v.abs() }
                })
                .collect();
            let zh_opts: [[f32; 2]; 3] = [[0., 0.], [1., 0.], [1., 1.]];
            let zh = *g.choose(&zh_opts);
            let bits = if zh[0] == 0.0 { 2 } else if zh[1] == 0.0 { 4 }
                       else { 8 };
            let out = bb_quantize_host(&x, 1, beta, &[1.0], &zh, &cfg);
            let s = step_sizes(beta, &cfg)
                [match bits { 2 => 0, 4 => 1, _ => 2 }];
            for v in &out {
                if *v > beta.abs() + 1e-5 {
                    return PropResult::Fail(format!("out of range {v}"));
                }
                let ratio = v / s;
                if (ratio - ratio.round()).abs() > 1e-2 {
                    return PropResult::Fail(format!(
                        "off grid: v={v} s={s} ratio={ratio}"));
                }
            }
            PropResult::Pass
        });
    }

    #[test]
    fn prop_monotone_error_in_gates() {
        check("more_gates_less_error", 100, |g| {
            let beta = g.f32_in(0.5, 4.0);
            let x: Vec<f32> =
                (0..32).map(|_| g.f32_in(-beta, beta)).collect();
            let cfg = QuantConfig::new(true, &[2, 4, 8, 16, 32]);
            let mut last = f64::INFINITY;
            for k in 0..=4usize {
                let mut zh = [0.0f32; 4];
                for z in zh.iter_mut().take(k) {
                    *z = 1.0;
                }
                let out = bb_quantize_host(&x, 1, beta, &[1.0], &zh, &cfg);
                let err: f64 = x
                    .iter()
                    .zip(&out)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if err > last + 1e-9 {
                    return PropResult::Fail(format!(
                        "error grew at k={k}: {err} > {last}"));
                }
                last = err;
            }
            PropResult::Pass
        });
    }
}
