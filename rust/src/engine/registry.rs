//! Multi-model serving: a named registry of lowered plans, a router
//! that fans requests out to per-model worker pools, and a
//! byte-budget LRU over the *compiled* side of each model.
//!
//! ```text
//!   Router::submit(model_id, x)
//!        │  (name -> entry, LRU touch, lazy compile)
//!        v
//!   ModelRegistry ── entry "a" ── Arc<EnginePlan> (always resident)
//!        │               └─ Active: {int Program, f32 Program,
//!        │                           Pool: queue + workers + arenas}
//!        ├─ entry "b" ── … (cold: plan only, no programs, no pool)
//!        └─ CacheStats {hits, misses, recompiles, evictions}
//! ```
//!
//! Registration is cheap: an entry owns only the lowered
//! [`EnginePlan`] (the weights). Both execution
//! [`Program`](super::graph::Program)s (integer
//! path + f32 reference) and the worker pool with its scratch arenas
//! are compiled lazily on the first request and dropped again when the
//! plan-cache byte budget forces an eviction — the next request to an
//! evicted model transparently recompiles (a *recompile* miss). The
//! cost function is the PR-3 arena accounting:
//! `executed_path.arena_bytes() * max_batch * workers`, i.e. the
//! scratch the pool pins at full occupancy (each worker's `ExecState`
//! materializes only the path it runs). The LRU never
//! evicts the entry being activated, so a single model larger than
//! the budget still serves (over budget, with a warning left to the
//! caller via `resident_bytes()`).
//!
//! Per-model [`ServeStats`] live in the entry, not the pool, so
//! counters, gauges, and latency histograms survive eviction/recompile
//! cycles.
//! An eviction drains the victim's queue before the programs drop —
//! every queued ticket is answered — and a submitter that raced the
//! eviction gets its input handed back internally and retried on the
//! recompiled pool.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::serve::{snapshot_cell, snapshot_stats, Pool, ServeConfig,
                   ServeStats, StatsCell, StatsSnapshot,
                   SubmitRejected, Ticket};
use super::trace::{self, TraceRecorder};
use super::EnginePlan;
use crate::rng::Pcg64;
use crate::runtime::Manifest;
use crate::util::json::{num, obj, Json};

/// Plan-cache counters: every submit is a hit (programs resident) or
/// a miss (cold compile); recompiles are the subset of misses whose
/// entry had been compiled before (i.e. evicted in between).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub recompiles: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("hits", num(self.hits as f64)),
            ("misses", num(self.misses as f64)),
            ("recompiles", num(self.recompiles as f64)),
            ("evictions", num(self.evictions as f64)),
        ])
    }
}

/// The compiled (evictable) side of one entry.
struct Active {
    pool: Arc<Pool>,
    cost_bytes: usize,
}

struct Entry {
    plan: Arc<EnginePlan>,
    cfg: ServeConfig,
    /// Survives eviction — stats are per *model*, not per pool.
    stats: Arc<StatsCell>,
    active: Option<Active>,
    /// LRU tick of the last submit.
    last_used: u64,
    /// Whether this entry has ever compiled (recompile accounting).
    compiled_once: bool,
}

#[derive(Default)]
struct Inner {
    entries: BTreeMap<String, Entry>,
    /// Monotonic LRU clock, bumped per submit.
    clock: u64,
    resident_bytes: usize,
    cache: CacheStats,
    closed: bool,
}

/// Named multi-model serving front-end. See the module docs for the
/// architecture; [`Router`] is the cheap clonable submit handle.
pub struct ModelRegistry {
    inner: Mutex<Inner>,
    /// Plan-cache byte budget; `None` = unbounded (never evict).
    budget_bytes: Option<usize>,
    /// Span recorder handed to every pool spawned after `set_trace`;
    /// `None` keeps the serve path on its zero-overhead branch.
    trace: Mutex<Option<Arc<TraceRecorder>>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// Registry with no plan-cache budget: compiled programs stay
    /// resident until shutdown.
    pub fn new() -> ModelRegistry {
        ModelRegistry { inner: Mutex::new(Inner::default()),
                        budget_bytes: None,
                        trace: Mutex::new(None) }
    }

    /// Registry whose compiled programs + arenas are LRU-evicted once
    /// their summed cost exceeds `bytes`. A budget of 0 keeps at most
    /// the single model being served resident.
    pub fn with_budget(bytes: usize) -> ModelRegistry {
        ModelRegistry { inner: Mutex::new(Inner::default()),
                        budget_bytes: Some(bytes),
                        trace: Mutex::new(None) }
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    /// Attach (or detach) a span recorder. Pools spawned afterwards —
    /// lazy compiles and post-eviction recompiles included — record
    /// request spans and per-node kernel slices into it; pools already
    /// running are unaffected, so set this before the first request.
    pub fn set_trace(&self, trace: Option<Arc<TraceRecorder>>) {
        *self.trace.lock().unwrap() = trace;
    }

    /// Register a lowered plan under `id`. Cheap: compilation of the
    /// execution programs is deferred to the first request.
    pub fn register(&self, id: &str, plan: Arc<EnginePlan>,
                    cfg: ServeConfig) -> Result<()> {
        if id.is_empty() {
            bail!("model id must be non-empty");
        }
        cfg.validate()?;
        plan.validate()?;
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            bail!("registry is shut down");
        }
        if g.entries.contains_key(id) {
            bail!("model {id:?} is already registered");
        }
        g.entries.insert(id.to_string(), Entry {
            plan,
            cfg,
            stats: Arc::new(StatsCell::new()),
            active: None,
            last_used: 0,
            compiled_once: false,
        });
        Ok(())
    }

    /// Lower a manifest + parameter vector and register the result —
    /// "loading another model is just compiling another program".
    pub fn register_manifest(&self, id: &str, man: &Manifest,
                             params: &[f32], cfg: ServeConfig)
                             -> Result<()> {
        let plan = super::lower(man, params)?;
        self.register(id, Arc::new(plan), cfg)
    }

    /// Route one request to `id`'s worker pool (compiling the model's
    /// programs first if it is cold), and return the response ticket.
    /// Blocks on that model's queue backpressure, never on another
    /// model's.
    pub fn submit(&self, id: &str, input: Vec<f32>) -> Result<Ticket> {
        // Bounded retry: losing the checkout -> enqueue race to an
        // eviction is rare, but under a tiny budget with adversarial
        // interleaving one request could otherwise ping-pong compiles
        // forever. Each retry re-activates the model, so a handful of
        // attempts is ample in practice.
        const MAX_EVICTION_RETRIES: usize = 16;
        let mut input = input;
        for _ in 0..MAX_EVICTION_RETRIES {
            let pool = self.checkout(id, input.len())?;
            match pool.submit(input) {
                Ok(t) => return Ok(t),
                // the pool was evicted (or is draining) between
                // checkout and enqueue: take the input back and
                // reactivate — requests survive their plan going cold
                Err(SubmitRejected::Closed(back)) => input = back,
                // checkout() already validated the width against the
                // same plan Arc, so this arm is unreachable from here
                // today — kept as a real error (not a panic) for any
                // future direct Pool caller path
                Err(SubmitRejected::BadWidth { got, want }) => {
                    bail!("request has {got} values, model {id:?} \
                           wants {want}");
                }
            }
        }
        bail!("model {id:?}: request lost the eviction race \
               {MAX_EVICTION_RETRIES} times — plan-cache budget is too \
               tight for the offered concurrency");
    }

    /// LRU-touch `id`, lazily compiling + evicting as needed, and
    /// return its live pool.
    fn checkout(&self, id: &str, width: usize) -> Result<Arc<Pool>> {
        // evicted pools collected under the lock, drained after it —
        // a victim's queue join must not stall other models' submits
        let mut victims: Vec<Active> = Vec::new();
        let mut g = self.inner.lock().unwrap();
        // split the guard once so entries / cache / resident_bytes
        // borrow as disjoint fields
        let inner = &mut *g;
        if inner.closed {
            bail!("registry is shut down");
        }
        if !inner.entries.contains_key(id) {
            let known: Vec<&str> =
                inner.entries.keys().map(|k| k.as_str()).collect();
            bail!("unknown model {id:?} (registered: {known:?})");
        }
        inner.clock += 1;
        let now = inner.clock;
        let e = inner.entries.get_mut(id).unwrap();
        if width != e.plan.input_dim {
            bail!("request has {width} values, model {id:?} wants {}",
                  e.plan.input_dim);
        }
        e.last_used = now;
        if let Some(a) = &e.active {
            inner.cache.hits += 1;
            return Ok(a.pool.clone());
        }
        // cold: compile both paths and spawn the pool. Done under the
        // registry lock — submits to other (warm) models queue behind
        // this compile; acceptable at current plan sizes, and it keeps
        // the LRU/byte accounting trivially consistent.
        inner.cache.misses += 1;
        if e.compiled_once {
            inner.cache.recompiles += 1;
        }
        e.compiled_once = true;
        let (plan, cfg, stats) =
            (e.plan.clone(), e.cfg.clone(), e.stats.clone());
        let (int_prog, f32_prog) =
            super::compile_pair_with(&plan, cfg.backend);
        // each worker's ExecState only ever materializes the arenas
        // of the path it executes, so the cache cost charges that
        // path alone (the other program's node list is negligible)
        let exec_arena = if cfg.force_f32 {
            f32_prog.arena_bytes()
        } else {
            int_prog.arena_bytes()
        };
        let cost_bytes = exec_arena * cfg.max_batch * cfg.workers;
        let trace = self.trace.lock().unwrap().clone();
        let pool = Arc::new(
            Pool::start(plan, int_prog, f32_prog, cfg, stats, trace)
                .map_err(|e| anyhow!("{e}"))?,
        );
        inner.resident_bytes += cost_bytes;
        if let Some(budget) = self.budget_bytes {
            while inner.resident_bytes > budget {
                // evict the least-recently-used *other* resident model
                let victim = inner
                    .entries
                    .iter()
                    .filter(|(k, e)| {
                        e.active.is_some() && k.as_str() != id
                    })
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone());
                let Some(victim) = victim else { break };
                let a = inner
                    .entries
                    .get_mut(&victim)
                    .unwrap()
                    .active
                    .take()
                    .unwrap();
                inner.resident_bytes -= a.cost_bytes;
                inner.cache.evictions += 1;
                victims.push(a);
            }
        }
        inner.entries.get_mut(id).unwrap().active =
            Some(Active { pool: pool.clone(), cost_bytes });
        drop(g);
        // drain each victim's queue (every ticket answered) and join
        // its workers with the registry unlocked; the programs +
        // arenas drop with the pool
        for a in victims {
            a.pool.shutdown();
        }
        Ok(pool)
    }

    /// Drop `id`'s compiled programs + pool (draining its queue), as
    /// the budget sweep would. Returns false if unknown or already
    /// cold. The entry itself stays registered.
    pub fn evict(&self, id: &str) -> bool {
        let a = {
            let mut g = self.inner.lock().unwrap();
            let inner = &mut *g;
            let Some(e) = inner.entries.get_mut(id) else {
                return false;
            };
            let Some(a) = e.active.take() else { return false };
            inner.resident_bytes -= a.cost_bytes;
            inner.cache.evictions += 1;
            a
        };
        // drain + join with the registry unlocked, as checkout does
        a.pool.shutdown();
        true
    }

    /// Registered model ids, sorted.
    pub fn model_ids(&self) -> Vec<String> {
        self.inner.lock().unwrap().entries.keys().cloned().collect()
    }

    /// The lowered plan behind `id` (always resident, even when the
    /// compiled programs are evicted).
    pub fn plan(&self, id: &str) -> Option<Arc<EnginePlan>> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .map(|e| e.plan.clone())
    }

    /// Whether `id`'s compiled programs are currently resident.
    pub fn is_resident(&self, id: &str) -> Option<bool> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .map(|e| e.active.is_some())
    }

    /// Summed cost of every resident compiled model.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.inner.lock().unwrap().cache
    }

    /// Per-model stats snapshot; `None` for an unknown id.
    pub fn stats(&self, id: &str) -> Option<ServeStats> {
        Some(snapshot_stats(&self.stats_cell(id)?))
    }

    /// The shared per-model stats cell (test oracle access).
    pub(crate) fn stats_cell(&self, id: &str) -> Option<Arc<StatsCell>> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .map(|e| e.stats.clone())
    }

    /// Aggregate stats across every model: counters and gauges
    /// summed, latency percentiles over the element-wise *merged*
    /// histograms. Histogram merge is exact (bucket counts add), so
    /// unlike the reservoir-resampling scheme this replaced, a
    /// high-traffic model's distribution is weighted by its true
    /// request count.
    pub fn aggregate_stats(&self) -> ServeStats {
        let cells: Vec<Arc<StatsCell>> = {
            let g = self.inner.lock().unwrap();
            g.entries.values().map(|e| e.stats.clone()).collect()
        };
        let mut agg: Option<StatsSnapshot> = None;
        for cell in &cells {
            let s = snapshot_cell(cell);
            match &mut agg {
                Some(a) => a.merge(&s),
                None => agg = Some(s),
            }
        }
        agg.as_ref()
           .map(ServeStats::from_snapshot)
           .unwrap_or_default()
    }

    /// The full stats surface as one JSON document:
    /// `{"models": {id: ServeStats…}, "aggregate": ServeStats,
    ///   "cache": {hits, misses, recompiles, evictions,
    ///             budget_bytes, resident_bytes, resident_models}}`.
    pub fn stats_json(&self) -> Json {
        let ids = self.model_ids();
        let mut models = BTreeMap::new();
        for id in &ids {
            let Some(cell) = self.stats_cell(id) else { continue };
            let mut st = match snapshot_stats(&cell).to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("ServeStats::to_json is an object"),
            };
            // per-(op, backend, bit-width) kernel timers, present once
            // the model has served a profiled batch
            let rows = cell.kernel_rows();
            if !rows.is_empty() {
                st.insert("kernels".to_string(),
                          trace::kernel_rows_json(&rows));
            }
            models.insert(id.clone(), Json::Obj(st));
        }
        let g = self.inner.lock().unwrap();
        let resident: Vec<Json> = g
            .entries
            .iter()
            .filter(|(_, e)| e.active.is_some())
            .map(|(k, _)| Json::Str(k.clone()))
            .collect();
        // start from the canonical counter serialization so a counter
        // added to CacheStats can never go missing here
        let mut cache_map = match g.cache.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("CacheStats::to_json returns an object"),
        };
        cache_map.insert("budget_bytes".to_string(),
                         match self.budget_bytes {
                             Some(b) => num(b as f64),
                             None => Json::Null,
                         });
        cache_map.insert("resident_bytes".to_string(),
                         num(g.resident_bytes as f64));
        cache_map.insert("resident_models".to_string(),
                         Json::Arr(resident));
        let cache = Json::Obj(cache_map);
        drop(g);
        Json::Obj(BTreeMap::from([
            ("models".to_string(), Json::Obj(models)),
            ("aggregate".to_string(), self.aggregate_stats().to_json()),
            ("cache".to_string(), cache),
        ]))
    }

    /// Stop accepting requests and drain + join every resident pool.
    /// Queued requests are still answered; idempotent.
    pub fn shutdown(&self) {
        let actives: Vec<Active> = {
            let mut g = self.inner.lock().unwrap();
            let inner = &mut *g;
            inner.closed = true;
            let mut v = Vec::new();
            for e in inner.entries.values_mut() {
                if let Some(a) = e.active.take() {
                    inner.resident_bytes -= a.cost_bytes;
                    v.push(a);
                }
            }
            v
        };
        for a in actives {
            a.pool.shutdown();
        }
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Cheap clonable submit handle over a shared registry — the routing
/// layer handed to request producers.
#[derive(Clone)]
pub struct Router {
    registry: Arc<ModelRegistry>,
}

impl Router {
    pub fn new(registry: Arc<ModelRegistry>) -> Router {
        Router { registry }
    }

    /// Route one request to `model_id` and return its ticket.
    pub fn submit(&self, model_id: &str, input: Vec<f32>)
                  -> Result<Ticket> {
        self.registry.submit(model_id, input)
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }
}

/// Closed-loop load driver over a router: `clients` threads each
/// submit `per_client` random requests, rotating through `ids`
/// (client `c` starts at offset `c`, so models interleave across
/// clients). Returns the wall-clock window plus per-model stats with
/// throughput filled in — what `bbits serve --model NAME=SPEC` and
/// the `engine-bench` serve sweep report.
pub fn closed_loop_router(router: &Router, ids: &[String],
                          clients: usize, per_client: usize, seed: u64)
                          -> Result<(f64, Vec<(String, ServeStats)>)> {
    if ids.is_empty() {
        bail!("closed_loop_router needs at least one model id");
    }
    let dims: Vec<usize> = ids
        .iter()
        .map(|id| {
            router
                .registry()
                .plan(id)
                .map(|p| p.input_dim)
                .ok_or_else(|| anyhow!("unknown model {id:?}"))
        })
        .collect::<Result<_>>()?;
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let dims = &dims;
                scope.spawn(move || -> Result<()> {
                    let mut rng = Pcg64::with_stream(seed, c as u64);
                    for r in 0..per_client {
                        let m = (c + r) % ids.len();
                        let x: Vec<f32> = (0..dims[m])
                            .map(|_| rng.normal())
                            .collect();
                        router.submit(&ids[m], x)?.wait()?;
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().map_err(|_| anyhow!("load client panicked"))??;
        }
        Ok(())
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    let per_model = ids
        .iter()
        .map(|id| {
            let mut st = router.registry().stats(id).unwrap_or_default();
            st.elapsed_s = elapsed;
            st.throughput_rps = if elapsed > 0.0 {
                st.requests as f64 / elapsed
            } else {
                0.0
            };
            (id.clone(), st)
        })
        .collect();
    Ok((elapsed, per_model))
}
