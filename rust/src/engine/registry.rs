//! Multi-model serving: a named registry of lowered plans, a router
//! that fans requests out to per-model worker pools, and a
//! byte-budget LRU over the *compiled* side of each model. Each entry
//! holds a **precision ladder**: one or more rungs, each the same
//! checkpoint lowered at a different Eq. 22 gate threshold (e.g.
//! `w2`/`w4`/`w8` variants of one posterior), and the per-request
//! rung pick degrades to cheaper bit widths under SLO/queue pressure
//! instead of shedding load.
//!
//! ```text
//!   Router::submit(model_id, x)
//!        │  (name -> entry, rung pick, LRU touch, slot claim)
//!        v
//!   ModelRegistry ── entry "a" ── version 2 (current: all routing)
//!        │               │            ├─ rung t0.20/w2 ── Slot::Warm
//!        │               │            │    {int+f32 Programs,
//!        │               │            │     Pool: queue+workers}
//!        │               │            └─ rung t0.90/w8 ── Slot::Cold
//!        │               └─ version 1 (draining; retired once idle)
//!        ├─ entry "b" ── version 3 ── rung t0.34/w8 ─ Slot::Compiling
//!        │                                              (latch)
//!        └─ CacheStats {hits, misses, recompiles, evictions,
//!                       latch_waits, swaps, drained}
//! ```
//!
//! **Compile latches.** A cold rung's checkpoint→compile→verify→
//! pool-spawn runs *off* the registry mutex: `checkout` takes the
//! lock only to claim the slot (`Cold → Compiling(latch)`) or read it
//! back, racing submits to the same rung park on the rung's own
//! latch, and submits to every other model see only an O(1) critical
//! section — a cold compile never blocks warm traffic. The builder
//! reconciles LRU/byte accounting (and the miss/recompile counters)
//! under the lock only after the compile succeeded; a failed compile
//! rolls the slot back to `Cold` untouched.
//!
//! **Versioned hot-swap.** Re-registering an id pushes a new ladder
//! version: new submits route to it immediately, in-flight requests
//! drain on the old version's rungs, and the superseded version is
//! retired (pools shut down, bytes reclaimed, `cache.drained`) once
//! every rung is idle — retirement ticks on submits, registrations,
//! stats scrapes, and explicit [`ModelRegistry::retire_idle`] calls.
//!
//! **Fast cold start.** A lowered plan can be serialized to a
//! versioned artifact ([`super::artifact`]) and reloaded without the
//! checkpoint→lower step; [`ModelRegistry::prewarm`] then compiles
//! every rung eagerly so the first request is a cache hit.
//!
//! Registration is cheap: a rung owns only the lowered
//! [`EnginePlan`] (the weights). Both execution
//! [`Program`](super::graph::Program)s (integer
//! path + f32 reference) and the worker pool with its scratch arenas
//! are compiled lazily on the first request and dropped again when the
//! plan-cache byte budget forces an eviction — the next request to an
//! evicted rung transparently recompiles (a *recompile* miss). The
//! cost function counts the full resident set of a compiled rung:
//! `(int.arena_bytes() + f32.arena_bytes()) * max_batch * workers`,
//! i.e. the scratch the pool pins at full occupancy across both
//! programs of the pair (each worker holds both paths so the
//! `force_f32` A/B lever and error fallbacks never allocate
//! mid-request). The LRU is rung-granular — a cold rung of a hot
//! model evicts before the hot rung — and never evicts the rung being
//! activated, so a single rung larger than the budget still serves.
//!
//! Per-rung [`ServeStats`] live in the rung, not the pool, so
//! counters, gauges, and latency histograms survive eviction/recompile
//! cycles; the per-rung latency histogram doubles as the measured
//! cost signal the rung pick consumes.
//! An eviction drains the victim's queue before the programs drop —
//! every queued ticket is answered — and a submitter that raced the
//! eviction gets its input handed back internally and retried on the
//! recompiled pool.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::serve::{snapshot_cell, snapshot_stats, Pool, ServeConfig,
                   ServeStats, StatsCell, StatsSnapshot,
                   SubmitRejected, Ticket};
use super::trace::{self, KernelKey, NodeTimer, TraceRecorder};
use super::EnginePlan;
use crate::config::Mode;
use crate::quant::gates;
use crate::rng::Pcg64;
use crate::runtime::Manifest;
use crate::util::json::{num, obj, Json};

/// Plan-cache + lifecycle counters: every submit is a hit (programs
/// resident), a miss (cold compile completed by this submit), or a
/// latch wait (parked on another submit's in-flight compile);
/// recompiles are the subset of misses whose rung had been compiled
/// before (i.e. evicted in between). `swaps` counts re-registrations
/// that installed a new ladder version under an existing name,
/// `drained` counts superseded versions retired after their in-flight
/// work drained. All counters are rung- or version-granular events.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub recompiles: u64,
    pub evictions: u64,
    /// Submits that parked on a per-rung compile latch instead of
    /// running (or being blocked by) the cold compile themselves.
    pub latch_waits: u64,
    /// Hot-swaps: `register*` under an already-registered name.
    pub swaps: u64,
    /// Superseded ladder versions retired once fully idle.
    pub drained: u64,
}

impl CacheStats {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("hits", num(self.hits as f64)),
            ("misses", num(self.misses as f64)),
            ("recompiles", num(self.recompiles as f64)),
            ("evictions", num(self.evictions as f64)),
            ("latch_waits", num(self.latch_waits as f64)),
            ("swaps", num(self.swaps as f64)),
            ("drained", num(self.drained as f64)),
        ])
    }
}

/// Live load signals for one ladder rung, consumed by [`pick_rung`].
#[derive(Debug, Clone, Copy)]
pub struct RungLoad {
    /// Measured p90 request latency in ns; 0 = no samples yet, which
    /// the policy treats optimistically (the first served batch
    /// corrects it).
    pub lat_ns: u64,
    /// Requests submitted to this rung and not yet answered.
    pub backlog: u64,
}

/// Pick the ladder rung for one request. `rungs` ascend in precision
/// (rung 0 is the cheapest, the last is the most accurate — ascending
/// gate threshold). With an SLO, the policy walks down from the most
/// accurate rung and takes the first whose predicted completion —
/// its measured p90 scaled by the batch waves queued ahead of the
/// request — still fits the budget, falling through to the cheapest
/// rung when none does. Without an SLO it sheds precision linearly
/// with queue pressure (total backlog against `queue_cap`). Both arms
/// are monotone: a deeper queue never picks a *more* expensive rung.
pub fn pick_rung(rungs: &[RungLoad], slo: Option<Duration>,
                 queue_cap: usize, max_batch: usize) -> usize {
    let n = rungs.len();
    if n <= 1 {
        return 0;
    }
    let total: u64 = rungs.iter().map(|r| r.backlog).sum();
    match slo {
        Some(slo) => {
            let slo_ns = slo.as_nanos();
            let waves = 1 + total as u128 / max_batch.max(1) as u128;
            for i in (0..n).rev() {
                if rungs[i].lat_ns as u128 * waves <= slo_ns {
                    return i;
                }
            }
            0
        }
        None => {
            let cap = queue_cap.max(1);
            let shed =
                (total.min(cap as u64) as usize * n) / (cap + 1);
            n - 1 - shed.min(n - 1)
        }
    }
}

/// Reporting view of one ladder rung (`ModelRegistry::ladder`).
#[derive(Debug, Clone)]
pub struct RungInfo {
    /// Unique per-model rung label, e.g. `"r0/t0.200/w2"`.
    pub label: String,
    /// Eq. 22 gate threshold this rung was lowered at.
    pub threshold: f64,
    /// Register-time proxy accuracy score in [0, 1].
    pub score: f64,
    /// Largest weight bit width across the rung's layers.
    pub w_bits: u32,
    /// Whether the rung's compiled programs are currently resident.
    pub resident: bool,
    pub stats: ServeStats,
}

/// The compiled (evictable) side of one rung.
struct Active {
    pool: Arc<Pool>,
    cost_bytes: usize,
}

/// One-shot completion latch for a rung's cold compile. The submit
/// that claims a cold slot compiles off the registry lock; racing
/// submits to the *same* rung park here — on the rung's own condvar,
/// never the registry mutex — until the compiler publishes the pool
/// (or the failure). Submits to other models and warm rungs take the
/// registry lock only for the O(1) slot readback.
struct CompileLatch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

enum LatchState {
    Pending,
    Ready(Arc<Pool>),
    Failed(String),
}

impl CompileLatch {
    fn new() -> CompileLatch {
        CompileLatch { state: Mutex::new(LatchState::Pending),
                       cv: Condvar::new() }
    }

    fn ready(&self, pool: Arc<Pool>) {
        *self.state.lock().unwrap() = LatchState::Ready(pool);
        self.cv.notify_all();
    }

    fn fail(&self, err: &str) {
        *self.state.lock().unwrap() =
            LatchState::Failed(err.to_string());
        self.cv.notify_all();
    }

    fn wait(&self) -> std::result::Result<Arc<Pool>, String> {
        let mut g = self.state.lock().unwrap();
        loop {
            match &*g {
                LatchState::Pending => g = self.cv.wait(g).unwrap(),
                LatchState::Ready(p) => return Ok(p.clone()),
                LatchState::Failed(e) => return Err(e.clone()),
            }
        }
    }
}

/// Lifecycle state of one rung's compiled side.
enum Slot {
    /// No compiled programs resident (never compiled, or evicted).
    Cold,
    /// A submit claimed the slot and is compiling off-lock; racing
    /// submits park on the latch.
    Compiling(Arc<CompileLatch>),
    /// Compiled programs + pool resident and serving.
    Warm(Active),
}

/// One rung of a model's precision ladder.
struct Rung {
    label: String,
    threshold: f64,
    score: f64,
    w_bits: u32,
    plan: Arc<EnginePlan>,
    /// Survives eviction — stats are per *rung*, not per pool; the
    /// latency histogram is also the rung's measured cost signal.
    stats: Arc<StatsCell>,
    slot: Slot,
    /// LRU tick of the last submit.
    last_used: u64,
    /// Whether this rung has ever compiled (recompile accounting).
    compiled_once: bool,
}

/// One registered ladder version. Re-registering an id pushes a new
/// version: new submits route to the newest, in-flight work drains on
/// the old rungs, and a superseded version is retired (pools shut
/// down, bytes reclaimed) once every rung is idle.
struct Version {
    version: u64,
    cfg: ServeConfig,
    /// Ascending gate threshold == ascending precision; `rungs.last()`
    /// is the most accurate (the idle default), `rungs[0]` the
    /// cheapest. Single-rung entries behave exactly like the
    /// pre-ladder registry.
    rungs: Vec<Rung>,
}

impl Version {
    /// The most accurate rung — the version's canonical plan.
    fn top(&self) -> &Rung {
        self.rungs.last().expect("ladder has at least one rung")
    }
}

struct Entry {
    /// Oldest → newest; `versions.last()` is current (all routing),
    /// earlier versions only drain. Never empty.
    versions: Vec<Version>,
}

impl Entry {
    fn current(&self) -> &Version {
        self.versions.last().expect("entry has at least one version")
    }

    fn current_mut(&mut self) -> &mut Version {
        self.versions
            .last_mut()
            .expect("entry has at least one version")
    }
}

/// Cheap register-time proxy for a rung's accuracy: the
/// parameter-weighted mean over layers of (bits/8, capped at 1) x
/// kept-channel ratio. Widths ≥ 8 bits count as full fidelity (the
/// paper's 8-bit configurations track FP32 closely), pruned channels
/// scale fidelity down. Not a measured accuracy — a free, monotone
/// ranking signal available before the rung ever runs.
fn proxy_accuracy(plan: &EnginePlan) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for l in &plan.layers {
        let w = (l.in_dim * l.out_dim) as f64;
        let bits = (l.w_bits.min(8) as f64) / 8.0;
        let kept = l.kept.len() as f64 / l.out_dim.max(1) as f64;
        num += w * bits * kept;
        den += w;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

#[derive(Default)]
struct Inner {
    entries: BTreeMap<String, Entry>,
    /// Monotonic LRU clock, bumped per submit.
    clock: u64,
    /// Monotonic ladder-version allocator (global across models).
    next_version: u64,
    resident_bytes: usize,
    cache: CacheStats,
    closed: bool,
}

/// Test seam: called off the registry lock at the top of every cold
/// rung compile with `(model_id, rung)`. Lets tests stall a compile
/// (to race warm traffic against it) or fail it deterministically.
/// Not a stable API.
#[doc(hidden)]
pub type CompileHook =
    Arc<dyn Fn(&str, usize) -> std::result::Result<(), String>
            + Send
            + Sync>;

/// Named multi-model serving front-end. See the module docs for the
/// architecture; [`Router`] is the cheap clonable submit handle.
pub struct ModelRegistry {
    inner: Mutex<Inner>,
    /// Plan-cache byte budget; `None` = unbounded (never evict).
    budget_bytes: Option<usize>,
    /// Span recorder handed to every pool spawned after `set_trace`;
    /// `None` keeps the serve path on its zero-overhead branch.
    trace: Mutex<Option<Arc<TraceRecorder>>>,
    /// Test-only compile delay/failure injection ([`CompileHook`]).
    compile_hook: Mutex<Option<CompileHook>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// Registry with no plan-cache budget: compiled programs stay
    /// resident until shutdown.
    pub fn new() -> ModelRegistry {
        ModelRegistry { inner: Mutex::new(Inner::default()),
                        budget_bytes: None,
                        trace: Mutex::new(None),
                        compile_hook: Mutex::new(None) }
    }

    /// Registry whose compiled programs + arenas are LRU-evicted once
    /// their summed cost exceeds `bytes`. A budget of 0 keeps at most
    /// the single rung being served resident.
    pub fn with_budget(bytes: usize) -> ModelRegistry {
        ModelRegistry { inner: Mutex::new(Inner::default()),
                        budget_bytes: Some(bytes),
                        trace: Mutex::new(None),
                        compile_hook: Mutex::new(None) }
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    /// Attach (or detach) a span recorder. Every pool spawned
    /// afterwards — lazy compiles and post-eviction recompiles
    /// included — records request spans and per-node kernel slices
    /// into it. A pool keeps the recorder it started with, so this
    /// **errors if any pool is already running or compiling**: attach
    /// the recorder before the first request instead of mid-traffic
    /// (evict the model first to force a recompile if you must
    /// re-attach late).
    pub fn set_trace(&self, trace: Option<Arc<TraceRecorder>>)
                     -> Result<()> {
        // held across the write so a cold claim can't slip between
        // the liveness check and the recorder swap
        let g = self.inner.lock().unwrap();
        let live = g.entries.values().any(|e| {
            e.versions.iter().any(|v| {
                v.rungs
                 .iter()
                 .any(|r| !matches!(r.slot, Slot::Cold))
            })
        });
        if live {
            bail!("set_trace: pools are already running — a live pool \
                   keeps the recorder it started with; attach the \
                   recorder before the first request (or evict first)");
        }
        *self.trace.lock().unwrap() = trace;
        drop(g);
        Ok(())
    }

    /// Install (or clear) the test-only cold-compile hook.
    #[doc(hidden)]
    pub fn _set_compile_hook(&self, hook: Option<CompileHook>) {
        *self.compile_hook.lock().unwrap() = hook;
    }

    /// Register a lowered plan under `id` as a single-rung ladder at
    /// the paper's default gate threshold. Cheap: compilation of the
    /// execution programs is deferred to the first request.
    pub fn register(&self, id: &str, plan: Arc<EnginePlan>,
                    cfg: ServeConfig) -> Result<()> {
        self.register_ladder_plans(id,
                                   vec![(gates::THRESHOLD, plan)], cfg)
    }

    /// Register a precision ladder from explicit (threshold, plan)
    /// rungs. Thresholds must be distinct, in (0, 1); rungs are stored
    /// in ascending threshold order (== ascending precision), and
    /// every plan must agree on input/output width — they are the
    /// same model at different fidelities.
    pub fn register_ladder_plans(&self, id: &str,
                                 rungs: Vec<(f64, Arc<EnginePlan>)>,
                                 cfg: ServeConfig) -> Result<()> {
        if id.is_empty() {
            bail!("model id must be non-empty");
        }
        cfg.validate()?;
        if rungs.is_empty() {
            bail!("model {id:?}: a ladder needs at least one rung");
        }
        let mut rungs = rungs;
        rungs.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in rungs.windows(2) {
            if w[0].0 == w[1].0 {
                bail!("model {id:?}: duplicate ladder threshold {}",
                      w[0].0);
            }
        }
        for (t, plan) in &rungs {
            if !(*t > 0.0 && *t < 1.0) {
                bail!("model {id:?}: gate threshold must be in (0, 1), \
                       got {t}");
            }
            plan.validate()?;
            if plan.input_dim != rungs[0].1.input_dim
                || plan.output_dim != rungs[0].1.output_dim
            {
                bail!("model {id:?}: ladder rungs disagree on model \
                       width ({}x{} vs {}x{})",
                      plan.input_dim, plan.output_dim,
                      rungs[0].1.input_dim, rungs[0].1.output_dim);
            }
        }
        if cfg.verify_plans {
            // prove every rung before it can serve: compile both
            // paths transiently and run the static verifier. The
            // compiled pair is discarded — checkout still compiles
            // lazily, so a verified-but-cold model costs no cache
            // budget until first use.
            for (t, plan) in &rungs {
                let (int_prog, f32_prog) =
                    super::try_compile_pair_with(plan, cfg.backend)
                        .map_err(|e| {
                            anyhow!("model {id:?} rung t={t}: plan \
                                     failed static verification at \
                                     compile: {e}")
                        })?;
                for prog in [&int_prog, &f32_prog] {
                    prog.verify().map_err(|e| {
                        anyhow!("model {id:?} rung t={t} ({} path): \
                                 static plan verification failed: {e}",
                                if prog.int_path() { "int" }
                                else { "f32" })
                    })?;
                }
            }
        }
        let rungs: Vec<Rung> = rungs
            .into_iter()
            .enumerate()
            .map(|(i, (threshold, plan))| {
                let w_bits = plan
                    .layers
                    .iter()
                    .map(|l| l.w_bits)
                    .max()
                    .unwrap_or(0);
                Rung {
                    label: format!("r{i}/t{threshold:.3}/w{w_bits}"),
                    threshold,
                    score: proxy_accuracy(&plan),
                    w_bits,
                    plan,
                    stats: Arc::new(StatsCell::new()),
                    slot: Slot::Cold,
                    last_used: 0,
                    compiled_once: false,
                }
            })
            .collect();
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            bail!("registry is shut down");
        }
        let inner = &mut *g;
        inner.next_version += 1;
        let version =
            Version { version: inner.next_version, cfg, rungs };
        match inner.entries.get_mut(id) {
            // hot-swap: the new version becomes current — every new
            // submit routes to it, in-flight requests drain on the old
            // rungs, and the superseded version retires (pools shut
            // down, bytes reclaimed, `cache.drained`) once idle
            Some(e) => {
                e.versions.push(version);
                inner.cache.swaps += 1;
            }
            None => {
                inner.entries.insert(id.to_string(),
                                     Entry { versions: vec![version] });
            }
        }
        let freed = sweep_idle_versions(inner);
        drop(g);
        for a in freed {
            a.pool.shutdown();
        }
        Ok(())
    }

    /// Lower one checkpoint at each of `thresholds` and register the
    /// resulting ladder — one posterior, many bit widths. Thresholds
    /// are deduplicated after sorting; distinct thresholds may still
    /// lower to identical plans when no gate logit sits between them
    /// (each rung keeps its own label and stats either way).
    pub fn register_ladder(&self, id: &str, man: &Manifest,
                           params: &[f32], mode: &Mode,
                           thresholds: &[f64], cfg: ServeConfig)
                           -> Result<()> {
        let mut ts = thresholds.to_vec();
        ts.sort_by(|a, b| a.total_cmp(b));
        ts.dedup();
        let rungs = ts
            .into_iter()
            .map(|t| {
                let plan =
                    super::lower::lower_with_mode_at(man, params, mode,
                                                     t)?;
                Ok((t, Arc::new(plan)))
            })
            .collect::<Result<Vec<_>>>()?;
        self.register_ladder_plans(id, rungs, cfg)
    }

    /// Lower a manifest + parameter vector and register the result —
    /// "loading another model is just compiling another program".
    pub fn register_manifest(&self, id: &str, man: &Manifest,
                             params: &[f32], cfg: ServeConfig)
                             -> Result<()> {
        let plan = super::lower(man, params)?;
        self.register(id, Arc::new(plan), cfg)
    }

    /// Route one request to `id`, picking the ladder rung from the
    /// model's SLO and current queue pressure ([`pick_rung`]), and
    /// return the response ticket. Single-rung models skip the policy.
    /// Blocks on that model's queue backpressure, never on another
    /// model's.
    pub fn submit(&self, id: &str, input: Vec<f32>) -> Result<Ticket> {
        let rung = self.pick_rung_for(id)?;
        self.submit_to(id, rung, input)
    }

    /// Route one request to a specific ladder rung (index in ascending
    /// threshold order, as reported by [`Self::ladder`]) — replay and
    /// bit-exactness tests pin rungs with this.
    pub fn submit_rung(&self, id: &str, rung: usize, input: Vec<f32>)
                       -> Result<Ticket> {
        self.submit_to(id, rung, input)
    }

    /// The live rung pick for `id`: per-rung measured p90 + backlog
    /// gauges against the model's SLO and queue capacity. Always
    /// picks within the *current* ladder version — older versions
    /// only drain.
    fn pick_rung_for(&self, id: &str) -> Result<usize> {
        let (cells, slo, queue_cap, max_batch) = {
            let g = self.inner.lock().unwrap();
            let Some(e) = g.entries.get(id) else {
                let known: Vec<&str> =
                    g.entries.keys().map(|k| k.as_str()).collect();
                bail!("unknown model {id:?} (registered: {known:?})");
            };
            let v = e.current();
            if v.rungs.len() <= 1 {
                return Ok(0);
            }
            (v.rungs.iter().map(|r| r.stats.clone()).collect::<Vec<_>>(),
             v.cfg.slo, v.cfg.queue_cap, v.cfg.max_batch)
        };
        // gauge + histogram reads happen off the registry lock — a
        // stats scrape or busy worker must not stall routing
        let loads: Vec<RungLoad> = cells
            .iter()
            .map(|c| RungLoad { lat_ns: c.measured_p90_ns(),
                                backlog: c.backlog() })
            .collect();
        Ok(pick_rung(&loads, slo, queue_cap, max_batch))
    }

    fn submit_to(&self, id: &str, rung: usize, input: Vec<f32>)
                 -> Result<Ticket> {
        // Bounded retry: losing the checkout -> enqueue race to an
        // eviction is rare, but under a tiny budget with adversarial
        // interleaving one request could otherwise ping-pong compiles
        // forever. Each retry re-activates the rung, so a handful of
        // attempts is ample in practice.
        const MAX_EVICTION_RETRIES: usize = 16;
        let mut input = input;
        for _ in 0..MAX_EVICTION_RETRIES {
            let pool = self.checkout(id, rung, input.len())?;
            match pool.submit(input) {
                Ok(t) => return Ok(t),
                // the pool was evicted (or is draining) between
                // checkout and enqueue: take the input back and
                // reactivate — requests survive their plan going cold
                Err(SubmitRejected::Closed(back)) => input = back,
                // checkout() already validated the width against the
                // same plan Arc, so this arm is unreachable from here
                // today — kept as a real error (not a panic) for any
                // future direct Pool caller path
                Err(SubmitRejected::BadWidth { got, want }) => {
                    bail!("request has {got} values, model {id:?} \
                           wants {want}");
                }
            }
        }
        bail!("model {id:?}: request lost the eviction race \
               {MAX_EVICTION_RETRIES} times — plan-cache budget is too \
               tight for the offered concurrency");
    }

    /// LRU-touch rung `rung` of `id`'s **current** ladder version,
    /// lazily compiling + evicting as needed, and return its live
    /// pool. The registry lock is held only to claim or read back the
    /// rung slot — the checkpoint→compile→verify→pool-spawn work of a
    /// cold rung runs off-lock behind the rung's [`CompileLatch`], so
    /// a cold compile never blocks a warm model's submit.
    fn checkout(&self, id: &str, rung: usize, width: usize)
                -> Result<Arc<Pool>> {
        let (claim, retired) = {
            let mut g = self.inner.lock().unwrap();
            // split the guard once so entries / cache /
            // resident_bytes borrow as disjoint fields
            let inner = &mut *g;
            if inner.closed {
                bail!("registry is shut down");
            }
            // superseded versions whose pools have drained retire on
            // the next registry touch; pools shut down off-lock below
            let retired = sweep_idle_versions(inner);
            let claim = claim_slot(inner, id, rung, width);
            (claim, retired)
        };
        for a in retired {
            a.pool.shutdown();
        }
        match claim? {
            Claim::Hit(pool) => Ok(pool),
            Claim::Wait(latch) => latch.wait().map_err(|e| {
                anyhow!("model {id:?}: the cold compile this submit \
                         parked on failed: {e}")
            }),
            Claim::Build(job) => self.build_rung(id, rung, job),
        }
    }

    /// Run one claimed cold compile off-lock and reconcile the
    /// outcome: on success the pool is installed (miss/recompile
    /// counters and byte accounting settle here, and the LRU sweep
    /// runs), on failure the slot rolls back to Cold with **no**
    /// counter movement — a failed compile is not a miss and must not
    /// make the next success report as a recompile. Either way the
    /// latch is published so parked submits wake.
    fn build_rung(&self, id: &str, rung: usize, job: BuildJob)
                  -> Result<Arc<Pool>> {
        let BuildJob { latch, plan, cfg, stats, version,
                       compiled_once } = job;
        match self.compile_slot(id, rung, plan, &cfg, stats) {
            Err(err) => {
                {
                    let mut g = self.inner.lock().unwrap();
                    if let Some(r) =
                        find_rung(&mut g, id, version, rung)
                    {
                        if matches!(r.slot, Slot::Compiling(_)) {
                            r.slot = Slot::Cold;
                        }
                    }
                }
                latch.fail(&format!("{err:#}"));
                Err(err)
            }
            Ok((pool, cost_bytes)) => {
                let mut victims: Vec<Active> = Vec::new();
                let installed = {
                    let mut g = self.inner.lock().unwrap();
                    let inner = &mut *g;
                    let found = !inner.closed
                        && find_rung_inner(inner, id, version, rung)
                            .is_some();
                    if found {
                        inner.cache.misses += 1;
                        if compiled_once {
                            inner.cache.recompiles += 1;
                        }
                        let r = find_rung_inner(inner, id, version,
                                                rung)
                            .expect("rung found above");
                        r.compiled_once = true;
                        r.slot = Slot::Warm(Active {
                            pool: pool.clone(),
                            cost_bytes,
                        });
                        inner.resident_bytes += cost_bytes;
                        if let Some(budget) = self.budget_bytes {
                            sweep_lru(inner, budget,
                                      (id, version, rung),
                                      &mut victims);
                        }
                    }
                    found
                };
                // drain each victim's queue (every ticket answered)
                // and join its workers with the registry unlocked;
                // the programs + arenas drop with the pool
                for a in victims {
                    a.pool.shutdown();
                }
                if installed {
                    latch.ready(pool.clone());
                    Ok(pool)
                } else {
                    // the registry shut down while we compiled: the
                    // slot is gone — drain the orphan pool and wake
                    // parked submits with the typed failure
                    pool.shutdown();
                    latch.fail("rung was retired during its cold \
                                compile");
                    bail!("model {id:?}: rung was retired during its \
                           cold compile");
                }
            }
        }
    }

    /// The off-lock portion of a cold compile: test hook, compile +
    /// static verification of both program paths, cost computation,
    /// pool spawn. Holds no registry state.
    fn compile_slot(&self, id: &str, rung: usize,
                    plan: Arc<EnginePlan>, cfg: &ServeConfig,
                    stats: Arc<StatsCell>)
                    -> Result<(Arc<Pool>, usize)> {
        if let Some(hook) = self.compile_hook.lock().unwrap().clone() {
            hook(id, rung).map_err(|e| {
                anyhow!("model {id:?} rung {rung}: compile hook \
                         failed: {e}")
            })?;
        }
        let (int_prog, f32_prog) =
            super::try_compile_pair_with(&plan, cfg.backend)
                .map_err(|e| anyhow!("model {id:?}: plan failed \
                                      static verification at \
                                      compile: {e}"))?;
        // full resident set of the pair: every worker's ExecState can
        // materialize either path (force_f32 A/B lever, parity
        // checks), so both arenas are pinned while the rung is warm —
        // charging only the executed path let the byte budget
        // silently overshoot
        let cost_bytes = (int_prog.arena_bytes()
                          + f32_prog.arena_bytes())
            * cfg.max_batch
            * cfg.workers
            // blocked-backend weight panels are compiled once and
            // shared by every worker through the program Arc — charged
            // once, not per worker or per batch slot
            + int_prog.panel_bytes();
        let trace = self.trace.lock().unwrap().clone();
        let pool = Arc::new(
            Pool::start(plan, int_prog, f32_prog, cfg.clone(), stats,
                        trace)
                .map_err(|e| anyhow!("{e}"))?,
        );
        Ok((pool, cost_bytes))
    }

    /// Drop every resident rung of `id` (compiled programs + pool,
    /// draining each queue, across every live ladder version), as the
    /// budget sweep would. Returns false if unknown or already fully
    /// cold; rungs mid-compile are left to their builder. The entry
    /// itself stays registered.
    pub fn evict(&self, id: &str) -> bool {
        let actives: Vec<Active> = {
            let mut g = self.inner.lock().unwrap();
            let inner = &mut *g;
            let Some(e) = inner.entries.get_mut(id) else {
                return false;
            };
            let mut v = Vec::new();
            let mut bytes = 0usize;
            let mut evictions = 0u64;
            for ver in e.versions.iter_mut() {
                for r in ver.rungs.iter_mut() {
                    match std::mem::replace(&mut r.slot, Slot::Cold) {
                        Slot::Warm(a) => {
                            bytes += a.cost_bytes;
                            evictions += 1;
                            v.push(a);
                        }
                        other => r.slot = other,
                    }
                }
            }
            inner.resident_bytes -= bytes;
            inner.cache.evictions += evictions;
            v
        };
        if actives.is_empty() {
            return false;
        }
        // drain + join with the registry unlocked, as checkout does
        for a in actives {
            a.pool.shutdown();
        }
        true
    }

    /// Eagerly compile + spawn every rung of `id`'s current ladder
    /// version — the register-time pre-warm path, so the first submit
    /// is a cache hit instead of a cold compile. Each rung counts as
    /// a normal miss; submits racing the pre-warm park on the same
    /// per-rung latches.
    pub fn prewarm(&self, id: &str) -> Result<()> {
        let widths: Vec<usize> = {
            let g = self.inner.lock().unwrap();
            let Some(e) = g.entries.get(id) else {
                let known: Vec<&str> =
                    g.entries.keys().map(|k| k.as_str()).collect();
                bail!("unknown model {id:?} (registered: {known:?})");
            };
            e.current()
             .rungs
             .iter()
             .map(|r| r.plan.input_dim)
             .collect()
        };
        for (rung, width) in widths.into_iter().enumerate() {
            self.checkout(id, rung, width)?;
        }
        Ok(())
    }

    /// Run one retirement sweep: superseded ladder versions with no
    /// in-flight compile and zero backlog on every rung are removed
    /// and their pools drained. Returns the number of versions
    /// retired. Retirement also runs opportunistically on every
    /// submit, registration, and stats scrape, so calling this is
    /// only needed to bound *when* an idle old version's memory is
    /// reclaimed.
    pub fn retire_idle(&self) -> u64 {
        let (freed, n) = {
            let mut g = self.inner.lock().unwrap();
            let inner = &mut *g;
            let before = inner.cache.drained;
            let freed = sweep_idle_versions(inner);
            (freed, inner.cache.drained - before)
        };
        for a in freed {
            a.pool.shutdown();
        }
        n
    }

    /// Registered model ids, sorted.
    pub fn model_ids(&self) -> Vec<String> {
        self.inner.lock().unwrap().entries.keys().cloned().collect()
    }

    /// The model's canonical lowered plan — the current version's
    /// most accurate rung's (always resident, even when the compiled
    /// programs are evicted).
    pub fn plan(&self, id: &str) -> Option<Arc<EnginePlan>> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .map(|e| e.current().top().plan.clone())
    }

    /// Reporting view of `id`'s current ladder version, ascending
    /// threshold order.
    pub fn ladder(&self, id: &str) -> Option<Vec<RungInfo>> {
        let rungs: Vec<(String, f64, f64, u32, bool, Arc<StatsCell>)> = {
            let g = self.inner.lock().unwrap();
            g.entries.get(id)?.current().rungs
                .iter()
                .map(|r| (r.label.clone(), r.threshold, r.score,
                          r.w_bits,
                          matches!(r.slot, Slot::Warm(_)),
                          r.stats.clone()))
                .collect()
        };
        Some(rungs
            .into_iter()
            .map(|(label, threshold, score, w_bits, resident, cell)| {
                RungInfo { label, threshold, score, w_bits, resident,
                           stats: snapshot_stats(&cell) }
            })
            .collect())
    }

    /// Whether any of `id`'s rungs (any live version) is currently
    /// resident.
    pub fn is_resident(&self, id: &str) -> Option<bool> {
        self.inner.lock().unwrap().entries.get(id).map(|e| {
            e.versions.iter().any(|v| {
                v.rungs
                 .iter()
                 .any(|r| matches!(r.slot, Slot::Warm(_)))
            })
        })
    }

    /// `id`'s current ladder version number and how many versions are
    /// still live (current + superseded-but-draining).
    pub fn versions(&self, id: &str) -> Option<(u64, usize)> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .map(|e| (e.current().version, e.versions.len()))
    }

    /// Summed cost of every resident compiled rung.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.inner.lock().unwrap().cache
    }

    /// Per-model stats snapshot, merged across the ladder's rungs;
    /// `None` for an unknown id.
    pub fn stats(&self, id: &str) -> Option<ServeStats> {
        let cells = self.rung_cells(id)?;
        Some(merged_cells_stats(&cells))
    }

    /// The stats cell of `id`'s current most accurate rung (test
    /// oracle access; single-rung models have exactly one cell).
    pub(crate) fn stats_cell(&self, id: &str) -> Option<Arc<StatsCell>> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .map(|e| e.current().top().stats.clone())
    }

    /// Every stats cell of `id`'s ladder across **all** live versions
    /// (oldest first), so per-model totals keep counting traffic that
    /// is still draining on a superseded version.
    fn rung_cells(&self, id: &str) -> Option<Vec<Arc<StatsCell>>> {
        self.inner.lock().unwrap().entries.get(id).map(|e| {
            e.versions
             .iter()
             .flat_map(|v| v.rungs.iter().map(|r| r.stats.clone()))
             .collect()
        })
    }

    /// Aggregate stats across every model and rung: counters and
    /// gauges summed, latency percentiles over the element-wise
    /// *merged* histograms. Histogram merge is exact (bucket counts
    /// add), so unlike the reservoir-resampling scheme this replaced,
    /// a high-traffic model's distribution is weighted by its true
    /// request count.
    pub fn aggregate_stats(&self) -> ServeStats {
        let cells: Vec<Arc<StatsCell>> = {
            let g = self.inner.lock().unwrap();
            g.entries
                .values()
                .flat_map(|e| {
                    e.versions.iter().flat_map(|v| {
                        v.rungs.iter().map(|r| r.stats.clone())
                    })
                })
                .collect()
        };
        merged_cells_stats(&cells)
    }

    /// The full stats surface as one JSON document:
    /// `{"models": {id: ServeStats… + "rungs": {label: rung row…}
    ///              + "version"/"versions_live"},
    ///   "aggregate": ServeStats,
    ///   "cache": {hits, misses, recompiles, evictions, latch_waits,
    ///             swaps, drained, budget_bytes, resident_bytes,
    ///             resident_models}}`.
    /// Each rung row is the rung's own ServeStats plus its threshold,
    /// proxy score, max weight bits, and residency (current ladder
    /// version; per-model totals also count draining old versions).
    /// A stats scrape doubles as a retirement tick: superseded
    /// versions that have gone idle are reclaimed first.
    pub fn stats_json(&self) -> Json {
        self.retire_idle();
        let ids = self.model_ids();
        let mut models = BTreeMap::new();
        for id in &ids {
            let Some(cells) = self.rung_cells(id) else { continue };
            let Some(infos) = self.ladder(id) else { continue };
            let Some((version, versions_live)) = self.versions(id)
            else {
                continue;
            };
            let mut st = match merged_cells_stats(&cells).to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("ServeStats::to_json is an object"),
            };
            // per-(op, backend, bit-width) kernel timers, present once
            // the model has served a profiled batch (merged over rungs)
            let mut kernels: BTreeMap<KernelKey, NodeTimer> =
                BTreeMap::new();
            for cell in &cells {
                for (k, t) in cell.kernel_rows() {
                    kernels.entry(k).or_default().merge(&t);
                }
            }
            if !kernels.is_empty() {
                let rows = trace::sorted_kernel_rows(&kernels);
                st.insert("kernels".to_string(),
                          trace::kernel_rows_json(&rows));
            }
            let mut rungs = BTreeMap::new();
            for info in infos {
                let mut row = match info.stats.to_json() {
                    Json::Obj(m) => m,
                    _ => unreachable!(),
                };
                row.insert("threshold".to_string(),
                           num(info.threshold));
                row.insert("score".to_string(), num(info.score));
                row.insert("w_bits".to_string(),
                           num(info.w_bits as f64));
                row.insert("resident".to_string(),
                           Json::Bool(info.resident));
                rungs.insert(info.label, Json::Obj(row));
            }
            st.insert("rungs".to_string(), Json::Obj(rungs));
            st.insert("version".to_string(), num(version as f64));
            st.insert("versions_live".to_string(),
                      num(versions_live as f64));
            models.insert(id.clone(), Json::Obj(st));
        }
        let g = self.inner.lock().unwrap();
        let resident: Vec<Json> = g
            .entries
            .iter()
            .filter(|(_, e)| {
                e.versions.iter().any(|v| {
                    v.rungs
                     .iter()
                     .any(|r| matches!(r.slot, Slot::Warm(_)))
                })
            })
            .map(|(k, _)| Json::Str(k.clone()))
            .collect();
        // start from the canonical counter serialization so a counter
        // added to CacheStats can never go missing here
        let mut cache_map = match g.cache.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("CacheStats::to_json returns an object"),
        };
        cache_map.insert("budget_bytes".to_string(),
                         match self.budget_bytes {
                             Some(b) => num(b as f64),
                             None => Json::Null,
                         });
        cache_map.insert("resident_bytes".to_string(),
                         num(g.resident_bytes as f64));
        cache_map.insert("resident_models".to_string(),
                         Json::Arr(resident));
        let cache = Json::Obj(cache_map);
        drop(g);
        Json::Obj(BTreeMap::from([
            ("models".to_string(), Json::Obj(models)),
            ("aggregate".to_string(), self.aggregate_stats().to_json()),
            ("cache".to_string(), cache),
        ]))
    }

    /// Stop accepting requests and drain + join every resident pool
    /// (every live version). Queued requests are still answered;
    /// idempotent. A rung mid-compile is left to its builder, which
    /// observes `closed`, drains its orphan pool, and fails its latch.
    pub fn shutdown(&self) {
        let actives: Vec<Active> = {
            let mut g = self.inner.lock().unwrap();
            let inner = &mut *g;
            inner.closed = true;
            let mut v = Vec::new();
            let mut bytes = 0usize;
            for e in inner.entries.values_mut() {
                for ver in e.versions.iter_mut() {
                    for r in ver.rungs.iter_mut() {
                        match std::mem::replace(&mut r.slot,
                                                Slot::Cold) {
                            Slot::Warm(a) => {
                                bytes += a.cost_bytes;
                                v.push(a);
                            }
                            other => r.slot = other,
                        }
                    }
                }
            }
            inner.resident_bytes -= bytes;
            v
        };
        for a in actives {
            a.pool.shutdown();
        }
    }
}

/// What one locked claim pass decided for a checkout.
enum Claim {
    /// Rung is warm: counted as a hit.
    Hit(Arc<Pool>),
    /// Another submit is compiling this rung: park on its latch.
    Wait(Arc<CompileLatch>),
    /// This submit claimed the cold slot: compile off-lock.
    Build(BuildJob),
}

/// Everything a claimed cold compile needs off-lock, captured under
/// the claim so the builder never re-reads registry state it didn't
/// pin.
struct BuildJob {
    latch: Arc<CompileLatch>,
    plan: Arc<EnginePlan>,
    cfg: ServeConfig,
    stats: Arc<StatsCell>,
    /// Ladder version the slot belongs to — the install step re-finds
    /// the rung by (id, version, rung) so a hot-swap racing the
    /// compile can never install into the wrong ladder.
    version: u64,
    compiled_once: bool,
}

/// The O(1) under-lock portion of checkout: validate, LRU-touch, and
/// read back or claim the rung slot of `id`'s current version.
fn claim_slot(inner: &mut Inner, id: &str, rung: usize, width: usize)
              -> Result<Claim> {
    if !inner.entries.contains_key(id) {
        let known: Vec<&str> =
            inner.entries.keys().map(|k| k.as_str()).collect();
        bail!("unknown model {id:?} (registered: {known:?})");
    }
    inner.clock += 1;
    let now = inner.clock;
    let e = inner.entries.get_mut(id).unwrap();
    let v = e.current_mut();
    if rung >= v.rungs.len() {
        bail!("model {id:?} has {} ladder rungs, rung {rung} \
               requested", v.rungs.len());
    }
    let version = v.version;
    let cfg = v.cfg.clone();
    let r = &mut v.rungs[rung];
    if width != r.plan.input_dim {
        bail!("request has {width} values, model {id:?} wants {}",
              r.plan.input_dim);
    }
    r.last_used = now;
    Ok(match &r.slot {
        Slot::Warm(a) => {
            inner.cache.hits += 1;
            Claim::Hit(a.pool.clone())
        }
        Slot::Compiling(latch) => {
            inner.cache.latch_waits += 1;
            Claim::Wait(latch.clone())
        }
        Slot::Cold => {
            let latch = Arc::new(CompileLatch::new());
            r.slot = Slot::Compiling(latch.clone());
            Claim::Build(BuildJob { latch,
                                    plan: r.plan.clone(),
                                    cfg,
                                    stats: r.stats.clone(),
                                    version,
                                    compiled_once: r.compiled_once })
        }
    })
}

/// Locate a rung by (id, ladder version, rung index); `None` once the
/// version has been retired or the id dropped.
fn find_rung_inner<'a>(inner: &'a mut Inner, id: &str, version: u64,
                       rung: usize) -> Option<&'a mut Rung> {
    inner
        .entries
        .get_mut(id)?
        .versions
        .iter_mut()
        .find(|v| v.version == version)?
        .rungs
        .get_mut(rung)
}

fn find_rung<'a>(g: &'a mut std::sync::MutexGuard<'_, Inner>, id: &str,
                 version: u64, rung: usize) -> Option<&'a mut Rung> {
    find_rung_inner(&mut *g, id, version, rung)
}

/// Evict least-recently-used warm rungs (any model, any version —
/// except the rung just installed, identified by `keep`) until the
/// resident byte total fits `budget`. Victims are handed back for
/// off-lock shutdown.
fn sweep_lru(inner: &mut Inner, budget: usize,
             keep: (&str, u64, usize), victims: &mut Vec<Active>) {
    let (keep_id, keep_version, keep_rung) = keep;
    while inner.resident_bytes > budget {
        let victim = inner
            .entries
            .iter()
            .flat_map(|(k, e)| {
                e.versions.iter().flat_map(move |v| {
                    v.rungs
                     .iter()
                     .enumerate()
                     .map(move |(ri, r)| (k, v.version, ri, r))
                })
            })
            .filter(|(k, vv, ri, r)| {
                matches!(r.slot, Slot::Warm(_))
                    && !(k.as_str() == keep_id
                         && *vv == keep_version
                         && *ri == keep_rung)
            })
            .min_by_key(|(_, _, _, r)| r.last_used)
            .map(|(k, vv, ri, _)| (k.clone(), vv, ri));
        let Some((vk, vv, vr)) = victim else { break };
        let e = inner.entries.get_mut(&vk).expect("victim id exists");
        let ver = e
            .versions
            .iter_mut()
            .find(|v| v.version == vv)
            .expect("victim version exists");
        let a = match std::mem::replace(&mut ver.rungs[vr].slot,
                                        Slot::Cold) {
            Slot::Warm(a) => a,
            _ => unreachable!("victim filter selects warm slots"),
        };
        inner.resident_bytes -= a.cost_bytes;
        inner.cache.evictions += 1;
        victims.push(a);
    }
}

/// Retire superseded ladder versions whose rungs have fully drained:
/// no in-flight compile and zero backlog. Warm pools are handed back
/// for off-lock shutdown; bytes and the `drained` counter settle
/// here. The current (last) version is never retired.
fn sweep_idle_versions(inner: &mut Inner) -> Vec<Active> {
    let mut freed = Vec::new();
    let mut bytes_freed = 0usize;
    let mut drained = 0u64;
    for e in inner.entries.values_mut() {
        let mut i = 0;
        while e.versions.len() > 1 && i < e.versions.len() - 1 {
            let idle = e.versions[i].rungs.iter().all(|r| {
                !matches!(r.slot, Slot::Compiling(_))
                    && r.stats.backlog() == 0
            });
            if !idle {
                i += 1;
                continue;
            }
            let v = e.versions.remove(i);
            for r in v.rungs {
                if let Slot::Warm(a) = r.slot {
                    bytes_freed += a.cost_bytes;
                    freed.push(a);
                }
            }
            drained += 1;
        }
    }
    inner.resident_bytes -= bytes_freed;
    inner.cache.drained += drained;
    freed
}

/// Merge a set of stats cells into one [`ServeStats`].
fn merged_cells_stats(cells: &[Arc<StatsCell>]) -> ServeStats {
    let mut agg: Option<StatsSnapshot> = None;
    for cell in cells {
        let s = snapshot_cell(cell);
        match &mut agg {
            Some(a) => a.merge(&s),
            None => agg = Some(s),
        }
    }
    agg.as_ref()
       .map(ServeStats::from_snapshot)
       .unwrap_or_default()
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Cheap clonable submit handle over a shared registry — the routing
/// layer handed to request producers.
#[derive(Clone)]
pub struct Router {
    registry: Arc<ModelRegistry>,
}

impl Router {
    pub fn new(registry: Arc<ModelRegistry>) -> Router {
        Router { registry }
    }

    /// Route one request to `model_id` (rung picked by SLO/pressure)
    /// and return its ticket.
    pub fn submit(&self, model_id: &str, input: Vec<f32>)
                  -> Result<Ticket> {
        self.registry.submit(model_id, input)
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }
}

/// Closed-loop load driver over a router: `clients` threads each
/// submit `per_client` random requests, rotating through `ids`
/// (client `c` starts at offset `c`, so models interleave across
/// clients). Returns the wall-clock window plus per-model stats with
/// throughput filled in — what `bbits serve --model NAME=SPEC` and
/// the `engine-bench` serve sweep report.
pub fn closed_loop_router(router: &Router, ids: &[String],
                          clients: usize, per_client: usize, seed: u64)
                          -> Result<(f64, Vec<(String, ServeStats)>)> {
    if ids.is_empty() {
        bail!("closed_loop_router needs at least one model id");
    }
    let dims: Vec<usize> = ids
        .iter()
        .map(|id| {
            router
                .registry()
                .plan(id)
                .map(|p| p.input_dim)
                .ok_or_else(|| anyhow!("unknown model {id:?}"))
        })
        .collect::<Result<_>>()?;
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let dims = &dims;
                scope.spawn(move || -> Result<()> {
                    let mut rng = Pcg64::with_stream(seed, c as u64);
                    for r in 0..per_client {
                        let m = (c + r) % ids.len();
                        let x: Vec<f32> = (0..dims[m])
                            .map(|_| rng.normal())
                            .collect();
                        router.submit(&ids[m], x)?.wait()?;
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().map_err(|_| anyhow!("load client panicked"))??;
        }
        Ok(())
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    let per_model = ids
        .iter()
        .map(|id| {
            let mut st = router.registry().stats(id).unwrap_or_default();
            st.elapsed_s = elapsed;
            st.throughput_rps = if elapsed > 0.0 {
                st.requests as f64 / elapsed
            } else {
                0.0
            };
            (id.clone(), st)
        })
        .collect();
    Ok((elapsed, per_model))
}

/// Outcome of a deadline-counting closed loop ([`closed_loop_deadline`]).
pub struct DeadlineReport {
    /// Requests whose submit -> response latency fit the SLO.
    pub within: u64,
    pub total: u64,
    pub elapsed_s: f64,
    /// Every per-request latency (ns), ascending.
    pub latencies_ns: Vec<u64>,
}

/// Closed-loop driver over one model that measures each request
/// against a deadline: `clients` threads each submit `per_client`
/// random requests back-to-back; every response's end-to-end latency
/// is compared to `slo`. This is the `BENCH_ladder.json` harness —
/// the same pressured loop run against a static plan and against a
/// ladder shows how many requests each serves within the deadline.
pub fn closed_loop_deadline(router: &Router, id: &str, clients: usize,
                            per_client: usize, slo: Duration, seed: u64)
                            -> Result<DeadlineReport> {
    let dim = router
        .registry()
        .plan(id)
        .map(|p| p.input_dim)
        .ok_or_else(|| anyhow!("unknown model {id:?}"))?;
    let t0 = Instant::now();
    let mut latencies: Vec<u64> = Vec::new();
    std::thread::scope(|scope| -> Result<()> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<Vec<u64>> {
                    let mut rng = Pcg64::with_stream(seed, c as u64);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let x: Vec<f32> =
                            (0..dim).map(|_| rng.normal()).collect();
                        let t = Instant::now();
                        router.submit(id, x)?.wait()?;
                        lats.push(t.elapsed().as_nanos() as u64);
                    }
                    Ok(lats)
                })
            })
            .collect();
        for h in handles {
            latencies.extend(
                h.join()
                 .map_err(|_| anyhow!("load client panicked"))??);
        }
        Ok(())
    })?;
    latencies.sort_unstable();
    let slo_ns = slo.as_nanos() as u64;
    let within =
        latencies.iter().filter(|l| **l <= slo_ns).count() as u64;
    Ok(DeadlineReport {
        within,
        total: latencies.len() as u64,
        elapsed_s: t0.elapsed().as_secs_f64(),
        latencies_ns: latencies,
    })
}
