//! Integer GEMM kernels over packed weights, plus the f32 reference
//! fallback — the arithmetic core of the inference engine.
//!
//! The integer path computes `y = W x` on raw grid codes with exact
//! integer accumulation and a single requantize multiply at the end:
//!
//! ```text
//! y[r] = (s_w * s_a) * sum_c q_w[r,c] * q_a[c]
//! ```
//!
//! For widths up to 8x8 bits the inner loop accumulates in `i32`
//! (blocked so the partial sum cannot overflow), spilling each block
//! into an `i64` total; 16-bit operands go straight to `i64` because a
//! single product can exceed `i32`. The f32 fallback multiplies the
//! *simulated-quantized* dense rows (`codes * step`), so the two paths
//! agree up to f32 accumulation error — the invariant
//! `tests/engine_parity.rs` pins down.

use super::pack::PackedMatrix;
use crate::quant::grid::quantize_codes_host;

/// i32 accumulation block: with |w| <= 127 and |a| <= 255, a block sum
/// is bounded by 127 * 255 * 4096 < 2^27 — far from i32 overflow.
const I32_BLOCK: usize = 4096;

/// Exact dot product of two code vectors. `low_bit` selects the
/// blocked-i32 fast path (safe when both operands are <= 8 bits).
#[inline]
pub fn dot_codes(w: &[i32], a: &[i32], low_bit: bool) -> i64 {
    debug_assert_eq!(w.len(), a.len());
    if low_bit {
        let mut total = 0i64;
        for (wb, ab) in w.chunks(I32_BLOCK).zip(a.chunks(I32_BLOCK)) {
            let mut acc = 0i32;
            for (x, y) in wb.iter().zip(ab) {
                acc += *x * *y;
            }
            total += acc as i64;
        }
        total
    } else {
        w.iter().zip(a).map(|(x, y)| *x as i64 * *y as i64).sum()
    }
}

/// Whether a (weight bits, activation bits) pair may use the blocked
/// i32 accumulator.
#[inline]
pub fn low_bit_pair(w_bits: u32, a_bits: u32) -> bool {
    w_bits <= 8 && a_bits <= 8
}

/// Packed matrix times a batch of code vectors.
///
/// * `acts` — `n` activation-code vectors, flat `[n, cols]`;
/// * `y` — flat `[n, rows]` accumulator outputs;
/// * `row_scratch` — caller-provided buffer of at least `cols` slots.
///
/// Rows are decoded once and reused across the whole batch, so the
/// unpack cost amortizes with the serving micro-batch size.
pub fn matmul_packed(w: &PackedMatrix, acts: &[i32], n: usize,
                     act_bits: u32, row_scratch: &mut [i32],
                     y: &mut [i64]) {
    let cols = w.cols;
    let rows = w.rows;
    debug_assert_eq!(acts.len(), n * cols);
    debug_assert_eq!(y.len(), n * rows);
    let low = low_bit_pair(w.bits, act_bits);
    for r in 0..rows {
        w.unpack_row_into(r, row_scratch);
        let row = &row_scratch[..cols];
        for s in 0..n {
            y[s * rows + r] =
                dot_codes(row, &acts[s * cols..(s + 1) * cols], low);
        }
    }
}

/// Dense f32 matrix (`rows x cols`, row-major) times a batch of f32
/// vectors — the reference/fallback path.
pub fn matmul_f32(w: &[f32], rows: usize, cols: usize, xs: &[f32],
                  n: usize, y: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(xs.len(), n * cols);
    debug_assert_eq!(y.len(), n * rows);
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        for s in 0..n {
            let x = &xs[s * cols..(s + 1) * cols];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[s * rows + r] = acc;
        }
    }
}

/// Quantize a flat activation tensor to integer codes in `out`;
/// returns the grid step. Numerics are exactly
/// `quant::grid::quantize_codes_host` (one clip + banker's rounding),
/// so the engine's activation grid is the host oracle's grid.
pub fn quantize_acts(x: &[f32], beta: f32, bits: u32, signed: bool,
                     out: &mut Vec<i32>) -> f32 {
    let (step, codes) = quantize_codes_host(x, beta, bits, signed);
    out.clear();
    out.extend(codes.iter().map(|q| *q as i32));
    step
}

/// Dequantize codes back to f32 (`step * code`) — the simulated-quant
/// activation the f32 reference path consumes.
pub fn dequantize(codes: &[i32], step: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend(codes.iter().map(|q| step * *q as f32));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_codes_paths_agree() {
        let mut rng = crate::rng::Pcg64::new(7);
        let n = 2 * I32_BLOCK + 123; // spans multiple blocks
        let w: Vec<i32> =
            (0..n).map(|_| (rng.next_u64() % 255) as i32 - 127).collect();
        let a: Vec<i32> =
            (0..n).map(|_| (rng.next_u64() % 256) as i32).collect();
        let want: i64 =
            w.iter().zip(&a).map(|(x, y)| *x as i64 * *y as i64).sum();
        assert_eq!(dot_codes(&w, &a, true), want);
        assert_eq!(dot_codes(&w, &a, false), want);
    }

    #[test]
    fn matmul_packed_matches_naive() {
        let mut rng = crate::rng::Pcg64::new(9);
        for (bits, a_bits) in [(2u32, 8u32), (4, 4), (8, 8), (16, 16)] {
            let rows = 5;
            let cols = 33;
            let n = 3;
            let hi = (1i64 << (bits - 1)) - 1;
            let codes: Vec<i64> = (0..rows * cols)
                .map(|_| {
                    (rng.next_u64() % (2 * hi + 1) as u64) as i64 - hi
                })
                .collect();
            let w = PackedMatrix::pack(&codes, rows, cols, bits, true)
                .unwrap();
            let amax = (1i64 << a_bits) - 1;
            let acts: Vec<i32> = (0..n * cols)
                .map(|_| (rng.next_u64() % (amax + 1) as u64) as i32)
                .collect();
            let mut scratch = vec![0i32; cols];
            let mut y = vec![0i64; n * rows];
            matmul_packed(&w, &acts, n, a_bits, &mut scratch, &mut y);
            for s in 0..n {
                for r in 0..rows {
                    let want: i64 = (0..cols)
                        .map(|c| {
                            codes[r * cols + c]
                                * acts[s * cols + c] as i64
                        })
                        .sum();
                    assert_eq!(y[s * rows + r], want,
                               "bits={bits} s={s} r={r}");
                }
            }
        }
    }

    #[test]
    fn quantize_dequantize_acts_on_grid() {
        let x = vec![0.0f32, 0.3, 1.4, -0.7, 9.0];
        let mut codes = Vec::new();
        let step = quantize_acts(&x, 2.0, 8, true, &mut codes);
        let mut back = Vec::new();
        dequantize(&codes, step, &mut back);
        for (orig, b) in x.iter().zip(&back) {
            assert!((b - orig.clamp(-2.0, 2.0)).abs() < step * 0.51,
                    "{orig} -> {b}");
        }
    }
}
