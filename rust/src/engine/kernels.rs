//! Integer GEMM and spatial convolution kernels over packed weights,
//! plus the f32 reference fallbacks — the arithmetic core of the
//! inference engine. Every kernel writes into a caller-owned output
//! slice; the IR executor (`engine::graph`) hands in pre-assigned
//! scratch-arena slices, so the hot path never allocates.
//!
//! The integer path computes `y = W x` on raw grid codes with exact
//! integer accumulation and a single requantize multiply at the end:
//!
//! ```text
//! y[r] = (s_w * s_a) * sum_c q_w[r,c] * q_a[c]
//! ```
//!
//! For widths up to 8x8 bits the inner loop accumulates in `i32`
//! (blocked so the partial sum cannot overflow), spilling each block
//! into an `i64` total; 16-bit operands go straight to `i64` because a
//! single product can exceed `i32`.
//!
//! Spatial conv ([`conv2d_codes`]) is im2col-over-codes: for each
//! output pixel an `(k, k, cin/groups)` patch of activation codes is
//! gathered (zero outside the image) and dotted against every kept
//! channel's decoded row via the same [`dot_codes`] accumulators; the
//! caller decodes packed rows once per batch. Depthwise layers take
//! [`dwconv2d_codes`], which reads its single input channel strided
//! and skips the patch buffer entirely.
//!
//! The f32 fallbacks multiply the *simulated-quantized* dense rows
//! (`codes * step`), so int and f32 paths agree up to f32 accumulation
//! error — the invariants `tests/engine_parity.rs` and
//! `tests/conv_parity.rs` pin down.
//!
//! Every integer kernel exists three times: a scalar form whose inner
//! dot is [`dot_codes`] — the untouched bit-exact arithmetic oracle —
//! a `_simd` form whose inner dot runs eight explicit accumulator
//! lanes (`chunks_exact(LANES)` unrolling, with AVX2/NEON inner loops
//! where the host CPU has them), and a `_panels` form for the
//! `blocked` backend that streams compile-time `[MR x KC]` weight
//! panels (`engine::pack::PanelMatrix`), tiles conv output pixels so
//! each im2col gather amortizes across [`NR`] pixels, and optionally
//! shards its work across scoped threads ([`shard_ranges`]). The
//! scalar/SIMD GEMM/conv loop drivers are shared and parameterized by
//! the dot function; the depthwise SIMD kernel restructures its loops
//! (lanes across kept channels) and stays a separate body. Because
//! every form computes the *exact* integer sum and integer addition
//! is associative, results are bit-identical across backends, loop
//! orders, and thread counts; `tests/kernel_backends.rs` runs the
//! differential battery that pins it. Which form a compiled node
//! executes is the [`Backend`] discriminant the pass pipeline assigns
//! (`engine::passes`).

use anyhow::{bail, Result};

use super::pack::{PackedMatrix, PanelMatrix, KC, MR};
use super::SpatialPlan;
use crate::quant::grid::quantize_codes_host;

/// i32 accumulation block length of the low-bit scalar/SIMD paths.
/// Legality is not argued here: `engine::verify` derives the
/// worst-case block sum `max|w| * max|a| * I32_BLOCK` from each
/// node's actual operand code ranges and proves it below `i32::MAX`
/// on every compiled plan.
pub const I32_BLOCK: usize = 4096;

/// Exact dot product of two code vectors. `low_bit` selects the
/// blocked-i32 fast path (safe when both operands are <= 8 bits).
#[inline]
pub fn dot_codes(w: &[i32], a: &[i32], low_bit: bool) -> i64 {
    debug_assert_eq!(w.len(), a.len());
    if low_bit {
        let mut total = 0i64;
        for (wb, ab) in w.chunks(I32_BLOCK).zip(a.chunks(I32_BLOCK)) {
            let mut acc = 0i32;
            for (x, y) in wb.iter().zip(ab) {
                acc += *x * *y;
            }
            total += acc as i64;
        }
        total
    } else {
        w.iter().zip(a).map(|(x, y)| *x as i64 * *y as i64).sum()
    }
}

/// Whether a (weight bits, activation bits) pair may use the blocked
/// i32 accumulator.
#[inline]
pub fn low_bit_pair(w_bits: u32, a_bits: u32) -> bool {
    w_bits <= 8 && a_bits <= 8
}

/// Observability seam around one kernel section: with a timer attached
/// the closure's wall-clock duration accumulates into it; without one
/// this is a direct call the optimizer erases (`None` is statically
/// known at every current call site, so the disabled form costs
/// nothing). The graph interpreter times whole nodes
/// (`Program::execute_instrumented`); this hook is the finer seam for
/// timing *inside* a kernel (decode vs. accumulate vs. requantize)
/// without restructuring call sites.
#[inline(always)]
pub fn timed<R>(timer: Option<&mut super::trace::NodeTimer>,
                f: impl FnOnce() -> R) -> R {
    match timer {
        None => f(),
        Some(t) => {
            let t0 = std::time::Instant::now();
            let r = f();
            t.observe(t0.elapsed().as_nanos() as u64);
            r
        }
    }
}

// -------------------------------------------------------------------
// Kernel backends (SIMD integer hot path)
// -------------------------------------------------------------------

/// Accumulator lane count of the vectorized integer kernels: 8 x i32
/// is exactly one AVX2 register (two NEON q-registers), and the
/// portable fallback unrolls the same eight explicit lanes, so every
/// specialization accumulates the identical exact integer sums.
pub const LANES: usize = 8;

/// Which kernel implementation a compiled node executes. The scalar
/// kernels are the bit-exact parity oracle; the SIMD kernels compute
/// the same exact i64 accumulators with [`LANES`]-lane chunking, so
/// outputs are bit-identical and the choice is purely a throughput
/// lever. Assigned per node by the pass pipeline; forced globally by
/// the `BBITS_BACKEND` env override or the `--backend` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Simd,
    /// Cache-blocked form: compile-time `[MR x KC]` weight panels
    /// (`engine::pack::PanelMatrix`), patch-tiled conv loops, and
    /// optional kept-row sharding across scoped threads. Never picked
    /// by the per-node auto rule — only a forced `--backend blocked` /
    /// `BBITS_BACKEND=blocked` / `ServeConfig.backend` selects it.
    Blocked,
}

impl Backend {
    pub fn label(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Simd => "simd",
            Backend::Blocked => "blocked",
        }
    }

    /// Parse the CLI/env spelling (`scalar` | `simd` | `blocked`).
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "scalar" => Ok(Backend::Scalar),
            "simd" => Ok(Backend::Simd),
            "blocked" => Ok(Backend::Blocked),
            other => bail!(
                "unknown kernel backend {other:?} (expected \"scalar\", \
                 \"simd\", or \"blocked\")"
            ),
        }
    }

    /// The `BBITS_BACKEND` override: force every integer kernel node
    /// onto one backend. Unset falls back to per-node auto selection;
    /// an invalid value warns and is ignored rather than silently
    /// changing which kernels run.
    pub fn from_env() -> Option<Backend> {
        match std::env::var("BBITS_BACKEND") {
            Ok(v) => match Backend::parse(&v) {
                Ok(b) => Some(b),
                Err(_) => {
                    crate::util::logging::warn(format!(
                        "ignoring BBITS_BACKEND={v:?} (expected \
                         \"scalar\", \"simd\", or \"blocked\")"
                    ));
                    None
                }
            },
            Err(_) => None,
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_enabled() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

/// One <= [`I32_BLOCK`] block of the low-bit path on the portable
/// lanes: eight explicit i32 accumulators over `chunks_exact(LANES)`
/// plus a scalar tail. Each lane sums at most `I32_BLOCK / LANES`
/// products bounded by `127 * 255`, so a lane stays far inside i32
/// range (the same bound that protects the scalar block).
// on aarch64 the NEON form always wins, but the portable lanes stay
// compiled (and unit-tested) as the specification of the lane split
#[cfg_attr(target_arch = "aarch64", allow(dead_code))]
fn dot_block_i32_portable(w: &[i32], a: &[i32]) -> i64 {
    let mut lanes = [0i32; LANES];
    let wc = w.chunks_exact(LANES);
    let ac = a.chunks_exact(LANES);
    let (wr, ar) = (wc.remainder(), ac.remainder());
    for (wv, av) in wc.zip(ac) {
        for (l, acc) in lanes.iter_mut().enumerate() {
            *acc += wv[l] * av[l];
        }
    }
    let mut tail = 0i32;
    for (x, y) in wr.iter().zip(ar) {
        tail += *x * *y;
    }
    lanes.iter().map(|v| *v as i64).sum::<i64>() + tail as i64
}

/// AVX2 specialization of [`dot_block_i32_portable`], built on
/// `vpmaddwd`: both operands of the low-bit path fit `i16` (codes and
/// activation codes are <= 8 bits), so sixteen i32 values pack into
/// one register of i16 lanes and a single multiply-add computes two
/// exact MACs per 32-bit lane. `_mm256_packs_epi32` applies the same
/// 128-bit-lane interleave to both operands, so products still pair
/// `w[i] * a[i]`, and the final lane total is the exact integer sum —
/// permutation cannot change it. Each `vpmaddwd` pair sum is bounded
/// by `2 * 127 * 255 < 2^16` and a lane accumulates at most
/// `I32_BLOCK / 16` of them, far inside i32 (the block bound).
///
/// # Safety
/// The caller must have verified AVX2 is available on this CPU, and —
/// as for every [`dot_block_i32`] path — both operands must be low-bit
/// codes (|v| <= 255): wider values would saturate the i16 pack.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_block_i32_avx2(w: &[i32], a: &[i32]) -> i64 {
    use std::arch::x86_64::*;
    // bound by the shorter operand: a caller-side length mismatch
    // degrades to the same truncated sum the scalar kernel computes
    // instead of an out-of-bounds vector load
    let len = w.len().min(a.len());
    let n = len - len % (2 * LANES);
    // SAFETY: the caller guarantees AVX2 (this fn's only contract
    // beyond the slice bounds); every unaligned load reads
    // `i .. i + LANES` with `i + 2 * LANES <= n <= len`, inside both
    // slices, and the store targets a local array of exactly LANES
    // i32s.
    unsafe {
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i < n {
            let w0 =
                _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
            let w1 = _mm256_loadu_si256(
                w.as_ptr().add(i + LANES) as *const __m256i);
            let a0 =
                _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let a1 = _mm256_loadu_si256(
                a.as_ptr().add(i + LANES) as *const __m256i);
            let wp = _mm256_packs_epi32(w0, w1);
            let ap = _mm256_packs_epi32(a0, a1);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wp, ap));
            i += 2 * LANES;
        }
        let mut lanes = [0i32; LANES];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut tail = 0i32;
        for j in n..len {
            tail += w[j] * a[j];
        }
        lanes.iter().map(|v| *v as i64).sum::<i64>() + tail as i64
    }
}

/// NEON specialization (baseline on aarch64, no runtime detection):
/// two 4-lane multiply-accumulate chains — the same eight lanes.
#[cfg(target_arch = "aarch64")]
fn dot_block_i32_neon(w: &[i32], a: &[i32]) -> i64 {
    // SAFETY: NEON is a mandatory aarch64 feature; every load is in
    // bounds because `n` is limited by the shorter operand.
    unsafe {
        use std::arch::aarch64::*;
        let len = w.len().min(a.len());
        let n = len - len % LANES;
        let mut acc0 = vdupq_n_s32(0);
        let mut acc1 = vdupq_n_s32(0);
        let mut i = 0;
        while i < n {
            let w0 = vld1q_s32(w.as_ptr().add(i));
            let w1 = vld1q_s32(w.as_ptr().add(i + 4));
            let a0 = vld1q_s32(a.as_ptr().add(i));
            let a1 = vld1q_s32(a.as_ptr().add(i + 4));
            acc0 = vmlaq_s32(acc0, w0, a0);
            acc1 = vmlaq_s32(acc1, w1, a1);
            i += LANES;
        }
        let mut tail = 0i32;
        for j in n..len {
            tail += w[j] * a[j];
        }
        vaddlvq_s32(acc0) + vaddlvq_s32(acc1) + tail as i64
    }
}

/// Low-bit block dot on the best specialization this CPU has.
/// Exactly one cfg block survives per target. Operands must be
/// low-bit codes (|v| <= 255, the `low_bit_pair` contract every call
/// site already enforces): the AVX2 form packs them into i16 lanes.
#[inline]
fn dot_block_i32(w: &[i32], a: &[i32]) -> i64 {
    debug_assert!(
        w.iter().all(|v| v.abs() <= 255)
            && a.iter().all(|v| v.abs() <= 255),
        "dot_block_i32 operands outside the low-bit code range"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: guarded by the runtime AVX2 detection above.
            unsafe { dot_block_i32_avx2(w, a) }
        } else {
            dot_block_i32_portable(w, a)
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        dot_block_i32_neon(w, a)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        dot_block_i32_portable(w, a)
    }
}

/// Wide-operand path (16-bit operands go straight to i64): four
/// explicit i64 lanes. AVX2/NEON have no 64-bit vector multiply worth
/// the shuffle traffic, so the widening form stays portable — the win
/// is breaking the single-accumulator dependency chain.
fn dot_wide_i64(w: &[i32], a: &[i32]) -> i64 {
    const W: usize = LANES / 2;
    let mut lanes = [0i64; W];
    let wc = w.chunks_exact(W);
    let ac = a.chunks_exact(W);
    let (wr, ar) = (wc.remainder(), ac.remainder());
    for (wv, av) in wc.zip(ac) {
        for (l, acc) in lanes.iter_mut().enumerate() {
            *acc += wv[l] as i64 * av[l] as i64;
        }
    }
    let mut total: i64 = lanes.iter().sum();
    for (x, y) in wr.iter().zip(ar) {
        total += *x as i64 * *y as i64;
    }
    total
}

/// [`dot_codes`] on the SIMD backend — bit-identical result (both
/// forms compute the exact integer sum; integer addition is
/// associative, so lane order cannot change it).
#[inline]
pub fn dot_codes_simd(w: &[i32], a: &[i32], low_bit: bool) -> i64 {
    debug_assert_eq!(w.len(), a.len());
    if low_bit {
        let mut total = 0i64;
        for (wb, ab) in w.chunks(I32_BLOCK).zip(a.chunks(I32_BLOCK)) {
            total += dot_block_i32(wb, ab);
        }
        total
    } else {
        dot_wide_i64(w, a)
    }
}

/// Shared GEMM driver: decode each packed row once, dot it against
/// every sample. The inner `dot` is the only thing that differs
/// between backends — the arithmetic oracle ([`dot_codes`]) and the
/// lane-chunked form ([`dot_codes_simd`]) stay independent.
fn matmul_packed_with(dot: fn(&[i32], &[i32], bool) -> i64,
                      w: &PackedMatrix, acts: &[i32], n: usize,
                      act_bits: u32, row_scratch: &mut [i32],
                      y: &mut [i64]) {
    let cols = w.cols;
    let rows = w.rows;
    debug_assert_eq!(acts.len(), n * cols);
    debug_assert_eq!(y.len(), n * rows);
    let low = low_bit_pair(w.bits, act_bits);
    for r in 0..rows {
        w.unpack_row_into(r, row_scratch);
        let row = &row_scratch[..cols];
        for s in 0..n {
            y[s * rows + r] =
                dot(row, &acts[s * cols..(s + 1) * cols], low);
        }
    }
}

/// [`matmul_packed`] on the SIMD backend: identical decode/loop
/// structure, vectorized inner dot, bit-identical `y`.
pub fn matmul_packed_simd(w: &PackedMatrix, acts: &[i32], n: usize,
                          act_bits: u32, row_scratch: &mut [i32],
                          y: &mut [i64]) {
    matmul_packed_with(dot_codes_simd, w, acts, n, act_bits,
                       row_scratch, y);
}

/// Packed matrix times a batch of code vectors.
///
/// * `acts` — `n` activation-code vectors, flat `[n, cols]`;
/// * `y` — flat `[n, rows]` accumulator outputs;
/// * `row_scratch` — caller-provided buffer of at least `cols` slots.
///
/// Rows are decoded once and reused across the whole batch, so the
/// unpack cost amortizes with the serving micro-batch size.
pub fn matmul_packed(w: &PackedMatrix, acts: &[i32], n: usize,
                     act_bits: u32, row_scratch: &mut [i32],
                     y: &mut [i64]) {
    timed(None, || {
        matmul_packed_with(dot_codes, w, acts, n, act_bits,
                           row_scratch, y)
    });
}

/// Dense f32 matrix (`rows x cols`, row-major) times a batch of f32
/// vectors — the reference/fallback path.
pub fn matmul_f32(w: &[f32], rows: usize, cols: usize, xs: &[f32],
                  n: usize, y: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(xs.len(), n * cols);
    debug_assert_eq!(y.len(), n * rows);
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        for s in 0..n {
            let x = &xs[s * cols..(s + 1) * cols];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[s * rows + r] = acc;
        }
    }
}

/// Gather the `(k, k, cin/groups)` input patch feeding output pixel
/// `(oh, ow)` of group `g` into `out[..patch_len]`, in the weight
/// rows' `(kh, kw, ci)` order (HWIO channel-last, matching the
/// lowering's `[cout, cin/groups * k * k]` rows). Taps outside the
/// image read zero (padding). `x` is one sample's NHWC tensor.
pub fn extract_patch<T: Copy + Default>(x: &[T], sp: &SpatialPlan,
                                        g: usize, oh: usize, ow: usize,
                                        out: &mut [T]) {
    let cg = sp.in_c / sp.groups;
    debug_assert_eq!(x.len(), sp.in_len());
    debug_assert!(out.len() >= sp.k * sp.k * cg);
    let c0 = g * cg;
    let ih0 = (oh * sp.stride) as isize - sp.pad_top as isize;
    let iw0 = (ow * sp.stride) as isize - sp.pad_left as isize;
    let mut o = 0;
    for kh in 0..sp.k {
        let ih = ih0 + kh as isize;
        let row_ok = ih >= 0 && (ih as usize) < sp.in_h;
        for kw in 0..sp.k {
            let iw = iw0 + kw as isize;
            if row_ok && iw >= 0 && (iw as usize) < sp.in_w {
                let base =
                    (ih as usize * sp.in_w + iw as usize) * sp.in_c + c0;
                out[o..o + cg].copy_from_slice(&x[base..base + cg]);
            } else {
                out[o..o + cg].fill(T::default());
            }
            o += cg;
        }
    }
}

/// Shared im2col driver: one patch gather per (pixel, group), then
/// every kept row of that group dotted with `dot` — again the only
/// backend difference.
#[allow(clippy::too_many_arguments)]
fn conv2d_codes_with(dot: fn(&[i32], &[i32], bool) -> i64,
                     w_rows: &[i32], kept: &[u32],
                     cout_per_group: usize, sp: &SpatialPlan,
                     acts: &[i32], n: usize, low: bool,
                     patch: &mut [i32], y: &mut [i64]) {
    let rows = kept.len();
    let plen = sp.patch_len();
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    for s in 0..n {
        let x = &acts[s * in_len..(s + 1) * in_len];
        for oh in 0..sp.out_h {
            for ow in 0..sp.out_w {
                let ybase = (s * opix + oh * sp.out_w + ow) * rows;
                let mut cur_g = usize::MAX;
                for r in 0..rows {
                    let g = kept[r] as usize / cout_per_group;
                    if g != cur_g {
                        extract_patch(x, sp, g, oh, ow, patch);
                        cur_g = g;
                    }
                    y[ybase + r] = dot(
                        &w_rows[r * plen..(r + 1) * plen],
                        &patch[..plen], low);
                }
            }
        }
    }
}

/// Spatial integer convolution over decoded weight codes (im2col over
/// codes).
///
/// * `w_rows` — `[rows, patch_len]` codes, decoded once per batch;
/// * `kept` — dense output channel of each row, ascending (so rows of
///   one group are contiguous and a patch is gathered once per
///   (pixel, group));
/// * `cout_per_group` — dense output channels per group;
/// * `acts` — `n` NHWC activation-code tensors, flat `[n, in_len]`;
/// * `low` — both operands <= 8 bits: blocked-i32 accumulation;
/// * `patch` — caller scratch of at least `patch_len` slots;
/// * `y` — flat `[n, out_pixels, rows]` exact accumulators.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_codes(w_rows: &[i32], kept: &[u32], cout_per_group: usize,
                    sp: &SpatialPlan, acts: &[i32], n: usize, low: bool,
                    patch: &mut [i32], y: &mut [i64]) {
    timed(None, || {
        conv2d_codes_with(dot_codes, w_rows, kept, cout_per_group, sp,
                          acts, n, low, patch, y)
    });
}

/// Depthwise fast path (`groups == in_c`): each kept output channel
/// reads exactly one input channel, so taps are gathered strided from
/// the NHWC tensor without the im2col patch buffer. Same contract as
/// [`conv2d_codes`] otherwise.
pub fn dwconv2d_codes(w_rows: &[i32], kept: &[u32],
                      cout_per_group: usize, sp: &SpatialPlan,
                      acts: &[i32], n: usize, low: bool, y: &mut [i64]) {
    debug_assert_eq!(sp.groups, sp.in_c);
    let rows = kept.len();
    let plen = sp.k * sp.k;
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    // the whole k*k window fits one i32 block at low widths
    let low = low && plen <= I32_BLOCK;
    for s in 0..n {
        let x = &acts[s * in_len..(s + 1) * in_len];
        for oh in 0..sp.out_h {
            let ih0 = (oh * sp.stride) as isize - sp.pad_top as isize;
            for ow in 0..sp.out_w {
                let iw0 =
                    (ow * sp.stride) as isize - sp.pad_left as isize;
                let ybase = (s * opix + oh * sp.out_w + ow) * rows;
                for r in 0..rows {
                    let ci = kept[r] as usize / cout_per_group;
                    let rbase = r * plen;
                    let mut acc32 = 0i32;
                    let mut acc = 0i64;
                    for kh in 0..sp.k {
                        let ih = ih0 + kh as isize;
                        if ih < 0 || ih as usize >= sp.in_h {
                            continue;
                        }
                        let xrow = ih as usize * sp.in_w;
                        for kw in 0..sp.k {
                            let iw = iw0 + kw as isize;
                            if iw < 0 || iw as usize >= sp.in_w {
                                continue;
                            }
                            let wv = w_rows[rbase + kh * sp.k + kw];
                            let av = x
                                [(xrow + iw as usize) * sp.in_c + ci];
                            if low {
                                acc32 += wv * av;
                            } else {
                                acc += wv as i64 * av as i64;
                            }
                        }
                    }
                    y[ybase + r] =
                        if low { acc32 as i64 } else { acc };
                }
            }
        }
    }
}

/// [`conv2d_codes`] on the SIMD backend: the same im2col structure
/// (one patch gather per (pixel, group)), vectorized row dots,
/// bit-identical `y`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_codes_simd(w_rows: &[i32], kept: &[u32],
                         cout_per_group: usize, sp: &SpatialPlan,
                         acts: &[i32], n: usize, low: bool,
                         patch: &mut [i32], y: &mut [i64]) {
    conv2d_codes_with(dot_codes_simd, w_rows, kept, cout_per_group,
                      sp, acts, n, low, patch, y);
}

/// [`dwconv2d_codes`] on the SIMD backend: the strided tap gather is
/// inherently scatter-shaped along the patch, so the lanes run
/// *across kept channels* instead — [`LANES`] rows accumulate
/// together per output pixel, one tap at a time. Same exact per-row
/// sums, bit-identical `y`.
pub fn dwconv2d_codes_simd(w_rows: &[i32], kept: &[u32],
                           cout_per_group: usize, sp: &SpatialPlan,
                           acts: &[i32], n: usize, low: bool,
                           y: &mut [i64]) {
    debug_assert_eq!(sp.groups, sp.in_c);
    let rows = kept.len();
    let plen = sp.k * sp.k;
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    // a row's k*k window fits one i32 lane at low widths (the scalar
    // kernel's condition, trivially met: plen <= I32_BLOCK)
    let low = low && plen <= I32_BLOCK;
    for s in 0..n {
        let x = &acts[s * in_len..(s + 1) * in_len];
        for oh in 0..sp.out_h {
            let ih0 = (oh * sp.stride) as isize - sp.pad_top as isize;
            for ow in 0..sp.out_w {
                let iw0 =
                    (ow * sp.stride) as isize - sp.pad_left as isize;
                let ybase = (s * opix + oh * sp.out_w + ow) * rows;
                let mut r0 = 0;
                while r0 < rows {
                    let ln = LANES.min(rows - r0);
                    // input channel each lane's row reads
                    let mut ci = [0usize; LANES];
                    for (l, c) in ci.iter_mut().enumerate().take(ln) {
                        *c = kept[r0 + l] as usize / cout_per_group;
                    }
                    let mut acc32 = [0i32; LANES];
                    let mut acc64 = [0i64; LANES];
                    for kh in 0..sp.k {
                        let ih = ih0 + kh as isize;
                        if ih < 0 || ih as usize >= sp.in_h {
                            continue;
                        }
                        let xrow = ih as usize * sp.in_w;
                        for kw in 0..sp.k {
                            let iw = iw0 + kw as isize;
                            if iw < 0 || iw as usize >= sp.in_w {
                                continue;
                            }
                            let xbase =
                                (xrow + iw as usize) * sp.in_c;
                            let tap = kh * sp.k + kw;
                            if low {
                                for l in 0..ln {
                                    acc32[l] += w_rows
                                        [(r0 + l) * plen + tap]
                                        * x[xbase + ci[l]];
                                }
                            } else {
                                for l in 0..ln {
                                    acc64[l] += w_rows
                                        [(r0 + l) * plen + tap]
                                        as i64
                                        * x[xbase + ci[l]] as i64;
                                }
                            }
                        }
                    }
                    for l in 0..ln {
                        y[ybase + r0 + l] = if low {
                            acc32[l] as i64
                        } else {
                            acc64[l]
                        };
                    }
                    r0 += ln;
                }
            }
        }
    }
}

/// f32 reference spatial convolution over the simulated-quant dense
/// rows — im2col with the blocked backend's pixel tiling: output
/// pixels go [`NR`] at a time, each patch is gathered once per (tile,
/// group) into `patch` (caller scratch of at least `NR * patch_len`
/// slots), and each weight row is then dotted against all `NR`
/// patches while it is hot. Only the (row, pixel) loop order changes
/// versus the untiled form — every individual dot product accumulates
/// in the same element order, so the f32 results are bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32(w_rows: &[f32], kept: &[u32], cout_per_group: usize,
                  sp: &SpatialPlan, xs: &[f32], n: usize,
                  patch: &mut [f32], y: &mut [f32]) {
    let rows = kept.len();
    let plen = sp.patch_len();
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(xs.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    debug_assert!(patch.len() >= NR * plen);
    for s in 0..n {
        let x = &xs[s * in_len..(s + 1) * in_len];
        let mut p0 = 0;
        while p0 < opix {
            let tl = NR.min(opix - p0);
            let mut cur_g = usize::MAX;
            for r in 0..rows {
                let g = kept[r] as usize / cout_per_group;
                if g != cur_g {
                    for (pi, tb) in
                        patch.chunks_mut(plen).enumerate().take(tl)
                    {
                        let p = p0 + pi;
                        extract_patch(x, sp, g, p / sp.out_w,
                                      p % sp.out_w, tb);
                    }
                    cur_g = g;
                }
                let row = &w_rows[r * plen..(r + 1) * plen];
                for pi in 0..tl {
                    let mut acc = 0.0f32;
                    for (a, b) in row
                        .iter()
                        .zip(&patch[pi * plen..(pi + 1) * plen])
                    {
                        acc += a * b;
                    }
                    y[(s * opix + p0 + pi) * rows + r] = acc;
                }
            }
            p0 += tl;
        }
    }
}

// -------------------------------------------------------------------
// Blocked backend: panel streaming, patch tiles, kept-row sharding
// -------------------------------------------------------------------

/// Output-pixel tile width of the blocked conv kernels: each im2col
/// patch is gathered once per `[KC x NR]` activation block, and one
/// `[MR x KC]` weight panel is then dotted against all `NR` patches
/// while it sits in L1 (8 KiB panel + `NR * KC * 4 = 8 KiB` patch
/// block — half of a typical 32 KiB L1d).
pub const NR: usize = 8;

/// Split `units` work items into at most `threads` contiguous,
/// disjoint `(start, end)` ranges covering `0..units` (never more
/// ranges than units, sizes differing by at most one).
pub fn shard_ranges(units: usize, threads: usize)
                    -> Vec<(usize, usize)> {
    let t = threads.max(1).min(units.max(1));
    let (base, extra) = (units / t, units % t);
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Shared output pointer handed to the scoped-thread shards. Sound
/// because every shard writes a statically disjoint set of `y`
/// indices: [`shard_ranges`] partitions the row blocks (GEMM,
/// depthwise) or output-pixel tiles (conv), and each output element is
/// owned by exactly one block/tile.
struct ShardPtr(*mut i64);
// SAFETY: the pointer targets the caller's output slice, which
// outlives the scoped-thread join; shards never read it and write
// only their own disjoint index set (see the struct doc), so moving
// the wrapper across threads cannot race.
unsafe impl Send for ShardPtr {}
// SAFETY: shared access is write-only to disjoint indices (above);
// no aliasing mutable access exists through `&ShardPtr`.
unsafe impl Sync for ShardPtr {}

/// One GEMM row block of [`matmul_panels`]: accumulate the block's
/// `mr` rows against all `n` samples, panel by panel, then write the
/// rows' outputs. Accumulation per row runs in ascending-k order with
/// a <= [`KC`]-sized i32 block per panel on the low-bit path — a
/// different partial-sum grouping than the scalar oracle's
/// [`I32_BLOCK`] chunks, but every grouping of an exact integer sum is
/// the same sum.
fn matmul_panels_block(pm: &PanelMatrix, acts: &[i32], n: usize,
                       low: bool, b: usize, acc: &mut [i64],
                       y: *mut i64) {
    let (rows, cols) = (pm.rows, pm.cols);
    let (r0, mr) = pm.blocks()[b];
    acc[..mr * n].fill(0);
    for kb in 0..pm.kblocks() {
        let k0 = kb * KC;
        let klen = KC.min(cols.saturating_sub(k0));
        if klen == 0 {
            break;
        }
        let panel = pm.panel(b, kb);
        for s in 0..n {
            let ab = &acts[s * cols + k0..s * cols + k0 + klen];
            for m in 0..mr {
                let wrow = &panel[m * KC..m * KC + klen];
                acc[m * n + s] += if low {
                    dot_block_i32(wrow, ab)
                } else {
                    dot_wide_i64(wrow, ab)
                };
            }
        }
    }
    for m in 0..mr {
        for s in 0..n {
            // SAFETY: this block owns output rows r0..r0+mr (row
            // blocks partition 0..rows), in bounds by the caller's
            // `y.len() == n * rows` check.
            unsafe { *y.add(s * rows + r0 + m) = acc[m * n + s] };
        }
    }
}

/// [`matmul_packed`] on the `blocked` backend: streams compile-time
/// decoded `[MR x KC]` panels (no per-call row decode) and keeps each
/// panel L1-resident while it is dotted against every sample's
/// matching activation block. `threads > 1` shards the panel row
/// blocks across scoped threads — each shard writes a disjoint set of
/// kept rows, and because every backend computes the same *exact*
/// integer sums and integer addition is associative, the result is
/// bit-identical to the scalar oracle for every thread count.
pub fn matmul_panels(pm: &PanelMatrix, acts: &[i32], n: usize,
                     act_bits: u32, threads: usize, y: &mut [i64]) {
    debug_assert_eq!(acts.len(), n * pm.cols);
    debug_assert_eq!(y.len(), n * pm.rows);
    let low = low_bit_pair(pm.bits, act_bits);
    let nb = pm.blocks().len();
    let shards = shard_ranges(nb, threads);
    let yp = ShardPtr(y.as_mut_ptr());
    if shards.len() == 1 {
        let mut acc = vec![0i64; MR * n];
        for b in 0..nb {
            matmul_panels_block(pm, acts, n, low, b, &mut acc, yp.0);
        }
        return;
    }
    std::thread::scope(|scope| {
        for &(b0, b1) in &shards {
            let yp = &yp;
            scope.spawn(move || {
                let mut acc = vec![0i64; MR * n];
                for b in b0..b1 {
                    matmul_panels_block(pm, acts, n, low, b, &mut acc,
                                        yp.0);
                }
            });
        }
    });
}

/// [`conv2d_codes`] on the `blocked` backend: output pixels are tiled
/// [`NR`] at a time, each im2col patch is gathered once per (tile,
/// group) into the tile buffer, and every `[MR x KC]` weight panel of
/// the group is dotted against all `NR` patches while L1-resident —
/// the panel traffic that [`conv2d_codes`] pays once per pixel is
/// paid once per tile. `threads > 1` shards the *pixel tiles* across
/// scoped threads (sharding rows would duplicate every patch gather
/// per shard); each shard owns a disjoint pixel range of `y`. Exact
/// integer sums throughout, so bit-identical to the scalar oracle.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_panels(pm: &PanelMatrix, kept: &[u32],
                     cout_per_group: usize, sp: &SpatialPlan,
                     acts: &[i32], n: usize, act_bits: u32,
                     threads: usize, y: &mut [i64]) {
    let rows = kept.len();
    let plen = sp.patch_len();
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(pm.rows, rows);
    debug_assert_eq!(pm.cols, plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    let low = low_bit_pair(pm.bits, act_bits);
    let tiles = opix.div_ceil(NR);
    let shards = shard_ranges(tiles, threads);
    let yp = ShardPtr(y.as_mut_ptr());
    let run = |t0: usize, t1: usize, yp: &ShardPtr| {
        let mut tile = vec![0i32; NR * plen];
        for s in 0..n {
            let x = &acts[s * in_len..(s + 1) * in_len];
            for t in t0..t1 {
                let p0 = t * NR;
                let tl = NR.min(opix - p0);
                let mut cur_g = usize::MAX;
                for (b, &(r0, mr)) in pm.blocks().iter().enumerate() {
                    if mr == 0 {
                        continue;
                    }
                    let g = kept[r0] as usize / cout_per_group;
                    if g != cur_g {
                        for (pi, tb) in
                            tile.chunks_mut(plen).enumerate().take(tl)
                        {
                            let p = p0 + pi;
                            extract_patch(x, sp, g, p / sp.out_w,
                                          p % sp.out_w, tb);
                        }
                        cur_g = g;
                    }
                    let mut acc = [0i64; MR * NR];
                    for kb in 0..pm.kblocks() {
                        let k0 = kb * KC;
                        let klen = KC.min(plen.saturating_sub(k0));
                        if klen == 0 {
                            break;
                        }
                        let panel = pm.panel(b, kb);
                        for pi in 0..tl {
                            let ab =
                                &tile[pi * plen + k0..pi * plen + k0
                                    + klen];
                            for m in 0..mr {
                                let wrow =
                                    &panel[m * KC..m * KC + klen];
                                acc[m * NR + pi] += if low {
                                    dot_block_i32(wrow, ab)
                                } else {
                                    dot_wide_i64(wrow, ab)
                                };
                            }
                        }
                    }
                    for pi in 0..tl {
                        let ybase = (s * opix + p0 + pi) * rows;
                        for m in 0..mr {
                            // SAFETY: this shard owns pixel range
                            // p0..p0+tl of sample s; rows partition.
                            unsafe {
                                *yp.0.add(ybase + r0 + m) =
                                    acc[m * NR + pi];
                            }
                        }
                    }
                }
            }
        }
    };
    if shards.len() == 1 {
        run(0, tiles, &yp);
        return;
    }
    std::thread::scope(|scope| {
        for &(t0, t1) in &shards {
            let yp = &yp;
            let run = &run;
            scope.spawn(move || run(t0, t1, yp));
        }
    });
}

/// [`dwconv2d_codes`] on the `blocked` backend: filter rows come from
/// the compile-time panels (no per-call decode), each decoded `k*k`
/// row stays hot across every output pixel it produces, and
/// `threads > 1` shards the kept channels across scoped threads (each
/// channel's outputs are disjoint, and depthwise tap gathers are
/// per-channel so sharding duplicates no work). Bit-identical to the
/// scalar oracle: same exact per-row integer sums.
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d_panels(pm: &PanelMatrix, kept: &[u32],
                       cout_per_group: usize, sp: &SpatialPlan,
                       acts: &[i32], n: usize, act_bits: u32,
                       threads: usize, y: &mut [i64]) {
    debug_assert_eq!(sp.groups, sp.in_c);
    let rows = kept.len();
    let plen = sp.k * sp.k;
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(pm.rows, rows);
    debug_assert_eq!(pm.cols, plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    // the whole k*k window fits one i32 block at low widths
    let low = low_bit_pair(pm.bits, act_bits) && plen <= I32_BLOCK;
    let shards = shard_ranges(rows, threads);
    let yp = ShardPtr(y.as_mut_ptr());
    let run = |r_lo: usize, r_hi: usize, yp: &ShardPtr| {
        let mut row = vec![0i32; plen];
        for (b, &(r0, mr)) in pm.blocks().iter().enumerate() {
            for m in 0..mr {
                let r = r0 + m;
                if r < r_lo || r >= r_hi {
                    continue;
                }
                for kb in 0..pm.kblocks() {
                    let k0 = kb * KC;
                    let klen = KC.min(plen.saturating_sub(k0));
                    if klen == 0 {
                        break;
                    }
                    row[k0..k0 + klen].copy_from_slice(
                        &pm.panel(b, kb)[m * KC..m * KC + klen]);
                }
                let ci = kept[r] as usize / cout_per_group;
                for s in 0..n {
                    let x = &acts[s * in_len..(s + 1) * in_len];
                    for oh in 0..sp.out_h {
                        let ih0 = (oh * sp.stride) as isize
                            - sp.pad_top as isize;
                        for ow in 0..sp.out_w {
                            let iw0 = (ow * sp.stride) as isize
                                - sp.pad_left as isize;
                            let mut acc32 = 0i32;
                            let mut acc = 0i64;
                            for kh in 0..sp.k {
                                let ih = ih0 + kh as isize;
                                if ih < 0 || ih as usize >= sp.in_h {
                                    continue;
                                }
                                let xrow = ih as usize * sp.in_w;
                                for kw in 0..sp.k {
                                    let iw = iw0 + kw as isize;
                                    if iw < 0
                                        || iw as usize >= sp.in_w
                                    {
                                        continue;
                                    }
                                    let wv = row[kh * sp.k + kw];
                                    let av = x[(xrow + iw as usize)
                                        * sp.in_c + ci];
                                    if low {
                                        acc32 += wv * av;
                                    } else {
                                        acc += wv as i64 * av as i64;
                                    }
                                }
                            }
                            let yi = (s * opix + oh * sp.out_w + ow)
                                * rows + r;
                            // SAFETY: this shard owns kept rows
                            // r_lo..r_hi; in bounds by the y.len()
                            // check above.
                            unsafe {
                                *yp.0.add(yi) = if low {
                                    acc32 as i64
                                } else {
                                    acc
                                };
                            }
                        }
                    }
                }
            }
        }
    };
    if shards.len() == 1 {
        run(0, rows, &yp);
        return;
    }
    std::thread::scope(|scope| {
        for &(r_lo, r_hi) in &shards {
            let yp = &yp;
            let run = &run;
            scope.spawn(move || run(r_lo, r_hi, yp));
        }
    });
}

/// Quantize a flat activation tensor to integer codes in `out`;
/// returns the grid step. Numerics are exactly
/// `quant::grid::quantize_codes_host` (one clip + banker's rounding),
/// so the engine's activation grid is the host oracle's grid. The IR
/// executor quantizes through a precomputed `CodeGrid` instead — same
/// numerics, no per-batch code `Vec`; this form remains for tests and
/// host-side tools.
pub fn quantize_acts(x: &[f32], beta: f32, bits: u32, signed: bool,
                     out: &mut Vec<i32>) -> f32 {
    let (step, codes) = quantize_codes_host(x, beta, bits, signed);
    out.clear();
    out.extend(codes.iter().map(|q| *q as i32));
    step
}

/// Dequantize codes back to f32 (`step * code`) — the simulated-quant
/// activation the f32 reference path consumes.
pub fn dequantize(codes: &[i32], step: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend(codes.iter().map(|q| step * *q as f32));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_hook_passes_through_and_accumulates() {
        // disabled form: pure pass-through
        assert_eq!(timed(None, || 41 + 1), 42);
        // enabled form: result unchanged, duration observed
        let mut t = super::super::trace::NodeTimer::default();
        let r = timed(Some(&mut t), || (0..100u64).sum::<u64>());
        assert_eq!(r, 4950);
        assert_eq!(t.calls, 1);
        assert!(t.max_ns <= t.total_ns || t.calls == 1);
    }

    #[test]
    fn dot_codes_paths_agree() {
        let mut rng = crate::rng::Pcg64::new(7);
        let n = 2 * I32_BLOCK + 123; // spans multiple blocks
        let w: Vec<i32> =
            (0..n).map(|_| (rng.next_u64() % 255) as i32 - 127).collect();
        let a: Vec<i32> =
            (0..n).map(|_| (rng.next_u64() % 256) as i32).collect();
        let want: i64 =
            w.iter().zip(&a).map(|(x, y)| *x as i64 * *y as i64).sum();
        assert_eq!(dot_codes(&w, &a, true), want);
        assert_eq!(dot_codes(&w, &a, false), want);
    }

    #[test]
    fn dot_codes_simd_bit_exact_vs_scalar_every_length() {
        let mut rng = crate::rng::Pcg64::new(11);
        // every remainder-lane shape up to a few vectors, plus block
        // boundaries of the low-bit path
        let mut sizes: Vec<usize> = (0..=3 * LANES + 1).collect();
        sizes.extend([I32_BLOCK - 1, I32_BLOCK, I32_BLOCK + 1,
                      2 * I32_BLOCK + 17]);
        for n in sizes {
            let w: Vec<i32> = (0..n)
                .map(|_| (rng.next_u64() % 255) as i32 - 127)
                .collect();
            let a: Vec<i32> =
                (0..n).map(|_| (rng.next_u64() % 256) as i32).collect();
            for low in [true, false] {
                assert_eq!(dot_codes_simd(&w, &a, low),
                           dot_codes(&w, &a, low), "n={n} low={low}");
            }
            // wide operands exercise the i64 lanes for real
            let w16: Vec<i32> = (0..n)
                .map(|_| (rng.next_u64() % 65535) as i32 - 32767)
                .collect();
            let a16: Vec<i32> = (0..n)
                .map(|_| (rng.next_u64() % 65536) as i32)
                .collect();
            assert_eq!(dot_codes_simd(&w16, &a16, false),
                       dot_codes(&w16, &a16, false), "wide n={n}");
        }
    }

    #[test]
    fn matmul_packed_simd_bit_exact_vs_scalar() {
        let mut rng = crate::rng::Pcg64::new(13);
        for (bits, a_bits) in [(2u32, 8u32), (4, 4), (8, 8), (16, 16)] {
            for cols in [1usize, 7, LANES, 3 * LANES + 1, 130] {
                let rows = 5;
                let n = 3;
                let hi = (1i64 << (bits - 1)) - 1;
                let codes: Vec<i64> = (0..rows * cols)
                    .map(|_| {
                        (rng.next_u64() % (2 * hi + 1) as u64) as i64
                            - hi
                    })
                    .collect();
                let w = PackedMatrix::pack(&codes, rows, cols, bits,
                                           true)
                    .unwrap();
                let amax = (1i64 << a_bits) - 1;
                let acts: Vec<i32> = (0..n * cols)
                    .map(|_| {
                        (rng.next_u64() % (amax + 1) as u64) as i32
                    })
                    .collect();
                let mut scratch = vec![0i32; cols];
                let mut ys = vec![0i64; n * rows];
                let mut yv = vec![0i64; n * rows];
                matmul_packed(&w, &acts, n, a_bits, &mut scratch,
                              &mut ys);
                matmul_packed_simd(&w, &acts, n, a_bits, &mut scratch,
                                   &mut yv);
                assert_eq!(ys, yv, "bits={bits} cols={cols}");
            }
        }
    }

    #[test]
    fn conv_kernels_simd_bit_exact_vs_scalar() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(17);
        for (groups, stride) in [(1usize, 1usize), (2, 2), (3, 1)] {
            let (in_h, in_w, cg, cout, k) = (5, 4, 3, 2 * groups, 3);
            let in_c = groups * cg;
            let sp = SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                      Padding::Same, groups)
                .unwrap();
            let plen = sp.patch_len();
            let kept: Vec<u32> = (0..cout as u32).collect();
            let w: Vec<i32> = (0..cout * plen)
                .map(|_| (rng.next_u64() % 15) as i32 - 7)
                .collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            for low in [true, false] {
                let mut patch = vec![0i32; plen];
                let mut ys = vec![0i64; n * sp.out_pixels() * cout];
                let mut yv = ys.clone();
                conv2d_codes(&w, &kept, cout / groups, &sp, &x, n, low,
                             &mut patch, &mut ys);
                conv2d_codes_simd(&w, &kept, cout / groups, &sp, &x, n,
                                  low, &mut patch, &mut yv);
                assert_eq!(ys, yv, "g={groups} s={stride} low={low}");
            }
        }
    }

    #[test]
    fn dwconv_simd_bit_exact_vs_scalar_with_pruning() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(19);
        // channel counts straddling the lane width, pruned subsets
        for c in [3usize, LANES, LANES + 3, 2 * LANES + 1] {
            let sp = SpatialPlan::new(5, 5, c, 3, 1, Padding::Same, c)
                .unwrap();
            let plen = sp.patch_len();
            // prune every third channel (at least one survivor)
            let kept: Vec<u32> = (0..c as u32)
                .filter(|ch| ch % 3 != 1 || c < 3)
                .collect();
            let w: Vec<i32> = (0..kept.len() * plen)
                .map(|_| (rng.next_u64() % 7) as i32 - 3)
                .collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            for low in [true, false] {
                let mut ys =
                    vec![0i64; n * sp.out_pixels() * kept.len()];
                let mut yv = ys.clone();
                dwconv2d_codes(&w, &kept, 1, &sp, &x, n, low, &mut ys);
                dwconv2d_codes_simd(&w, &kept, 1, &sp, &x, n, low,
                                    &mut yv);
                assert_eq!(ys, yv, "c={c} low={low}");
            }
        }
    }

    #[test]
    fn every_block_specialization_matches_scalar() {
        // pin each specialization directly, independent of what the
        // runtime dispatcher picks on this host
        let mut rng = crate::rng::Pcg64::new(23);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let w: Vec<i32> = (0..n)
                .map(|_| (rng.next_u64() % 255) as i32 - 127)
                .collect();
            let a: Vec<i32> =
                (0..n).map(|_| (rng.next_u64() % 256) as i32).collect();
            let want = dot_codes(&w, &a, false);
            assert_eq!(dot_block_i32_portable(&w, &a), want, "n={n}");
            assert_eq!(dot_wide_i64(&w, &a), want, "n={n}");
            #[cfg(target_arch = "x86_64")]
            {
                if avx2_enabled() {
                    // SAFETY: AVX2 presence just checked.
                    let got = unsafe { dot_block_i32_avx2(&w, &a) };
                    assert_eq!(got, want, "avx2 n={n}");
                }
            }
        }
    }

    #[test]
    fn backend_parse_and_labels_round_trip() {
        assert_eq!(Backend::parse("scalar").unwrap(), Backend::Scalar);
        assert_eq!(Backend::parse("simd").unwrap(), Backend::Simd);
        assert_eq!(Backend::parse("blocked").unwrap(),
                   Backend::Blocked);
        assert!(Backend::parse("avx512").is_err());
        assert_eq!(Backend::Scalar.label(), "scalar");
        assert_eq!(Backend::Simd.label(), "simd");
        assert_eq!(Backend::Blocked.label(), "blocked");
    }

    #[test]
    fn shard_ranges_partition_without_gaps() {
        for units in [0usize, 1, 2, 7, 8, 9, 63, 100] {
            for threads in [1usize, 2, 3, 4, 8, 200] {
                let shards = shard_ranges(units, threads);
                assert!(!shards.is_empty());
                assert!(shards.len() <= threads.max(1));
                assert!(shards.len() <= units.max(1));
                let mut next = 0;
                for &(a, b) in &shards {
                    assert_eq!(a, next, "u={units} t={threads}");
                    assert!(b >= a);
                    next = b;
                }
                assert_eq!(next, units, "u={units} t={threads}");
                // balanced: sizes differ by at most one
                let sizes: Vec<usize> =
                    shards.iter().map(|&(a, b)| b - a).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(),
                                sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "u={units} t={threads}");
            }
        }
    }

    #[test]
    fn matmul_panels_bit_exact_vs_scalar_every_remainder_shape() {
        let mut rng = crate::rng::Pcg64::new(29);
        for (bits, a_bits) in [(2u32, 8u32), (4, 4), (8, 8), (16, 16)] {
            for rows in [1usize, MR - 1, MR, MR + 1, 3 * MR + 1] {
                for cols in [1usize, 7, KC - 1, KC, KC + 1,
                             2 * KC + 17]
                {
                    let n = 2;
                    let hi = (1i64 << (bits - 1)) - 1;
                    let codes: Vec<i64> = (0..rows * cols)
                        .map(|_| {
                            (rng.next_u64() % (2 * hi + 1) as u64)
                                as i64 - hi
                        })
                        .collect();
                    let w = PackedMatrix::pack(&codes, rows, cols,
                                               bits, true)
                        .unwrap();
                    let pm = PanelMatrix::from_packed(&w);
                    let amax = (1i64 << a_bits.min(8)) - 1;
                    let acts: Vec<i32> = (0..n * cols)
                        .map(|_| {
                            (rng.next_u64() % (amax + 1) as u64) as i32
                        })
                        .collect();
                    let mut scratch = vec![0i32; cols];
                    let mut ys = vec![0i64; n * rows];
                    matmul_packed(&w, &acts, n, a_bits, &mut scratch,
                                  &mut ys);
                    for threads in [1usize, 2, 3, 4] {
                        let mut yb = vec![0i64; n * rows];
                        matmul_panels(&pm, &acts, n, a_bits, threads,
                                      &mut yb);
                        assert_eq!(ys, yb,
                                   "bits={bits} rows={rows} \
                                    cols={cols} threads={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn conv2d_panels_bit_exact_vs_scalar_with_groups_and_threads() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(31);
        for (groups, stride) in [(1usize, 1usize), (2, 2), (3, 1)] {
            let (in_h, in_w, cg, k) = (5, 4, 3, 3);
            let in_c = groups * cg;
            // odd per-group row count so panel blocks break at group
            // boundaries below MR
            let cout = 3 * groups;
            let sp = SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                      Padding::Same, groups)
                .unwrap();
            let plen = sp.patch_len();
            let kept: Vec<u32> = (0..cout as u32).collect();
            let codes: Vec<i64> = (0..cout * plen)
                .map(|_| (rng.next_u64() % 15) as i64 - 7)
                .collect();
            let w = PackedMatrix::pack(&codes, cout, plen, 4, true)
                .unwrap();
            let cpg = cout / groups;
            let pm = PanelMatrix::from_packed_grouped(&w, |r| {
                kept[r] as usize / cpg
            });
            let wd: Vec<i32> = codes.iter().map(|c| *c as i32).collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            let mut patch = vec![0i32; plen];
            let mut ys = vec![0i64; n * sp.out_pixels() * cout];
            conv2d_codes(&wd, &kept, cpg, &sp, &x, n, true, &mut patch,
                         &mut ys);
            for threads in [1usize, 2, 3, 4] {
                let mut yb = vec![0i64; ys.len()];
                conv2d_panels(&pm, &kept, cpg, &sp, &x, n, 4, threads,
                              &mut yb);
                assert_eq!(ys, yb,
                           "g={groups} s={stride} threads={threads}");
            }
        }
    }

    #[test]
    fn dwconv2d_panels_bit_exact_vs_scalar_with_pruning_and_threads() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(37);
        for c in [3usize, MR, MR + 3, 2 * MR + 1] {
            let sp = SpatialPlan::new(5, 5, c, 3, 1, Padding::Same, c)
                .unwrap();
            let plen = sp.patch_len();
            let kept: Vec<u32> = (0..c as u32)
                .filter(|ch| ch % 3 != 1 || c < 3)
                .collect();
            let codes: Vec<i64> = (0..kept.len() * plen)
                .map(|_| (rng.next_u64() % 7) as i64 - 3)
                .collect();
            let w = PackedMatrix::pack(&codes, kept.len(), plen, 4,
                                       true)
                .unwrap();
            let pm = PanelMatrix::from_packed(&w);
            let wd: Vec<i32> = codes.iter().map(|c| *c as i32).collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            for a_bits in [8u32, 16] {
                let low = low_bit_pair(4, a_bits);
                let mut ys =
                    vec![0i64; n * sp.out_pixels() * kept.len()];
                dwconv2d_codes(&wd, &kept, 1, &sp, &x, n, low,
                               &mut ys);
                for threads in [1usize, 2, 3, 4] {
                    let mut yb = vec![0i64; ys.len()];
                    dwconv2d_panels(&pm, &kept, 1, &sp, &x, n, a_bits,
                                    threads, &mut yb);
                    assert_eq!(ys, yb,
                               "c={c} a={a_bits} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn matmul_packed_matches_naive() {
        let mut rng = crate::rng::Pcg64::new(9);
        for (bits, a_bits) in [(2u32, 8u32), (4, 4), (8, 8), (16, 16)] {
            let rows = 5;
            let cols = 33;
            let n = 3;
            let hi = (1i64 << (bits - 1)) - 1;
            let codes: Vec<i64> = (0..rows * cols)
                .map(|_| {
                    (rng.next_u64() % (2 * hi + 1) as u64) as i64 - hi
                })
                .collect();
            let w = PackedMatrix::pack(&codes, rows, cols, bits, true)
                .unwrap();
            let amax = (1i64 << a_bits) - 1;
            let acts: Vec<i32> = (0..n * cols)
                .map(|_| (rng.next_u64() % (amax + 1) as u64) as i32)
                .collect();
            let mut scratch = vec![0i32; cols];
            let mut y = vec![0i64; n * rows];
            matmul_packed(&w, &acts, n, a_bits, &mut scratch, &mut y);
            for s in 0..n {
                for r in 0..rows {
                    let want: i64 = (0..cols)
                        .map(|c| {
                            codes[r * cols + c]
                                * acts[s * cols + c] as i64
                        })
                        .sum();
                    assert_eq!(y[s * rows + r], want,
                               "bits={bits} s={s} r={r}");
                }
            }
        }
    }

    #[test]
    fn extract_patch_handles_padding_and_groups() {
        use crate::models::Padding;
        // 3x3x2 input, k=2, stride 1, SAME (pad bottom/right), 2 groups
        let sp = SpatialPlan::new(3, 3, 2, 2, 1, Padding::Same, 2)
            .unwrap();
        assert_eq!((sp.out_h, sp.out_w), (3, 3));
        assert_eq!((sp.pad_top, sp.pad_left), (0, 0));
        let x: Vec<i32> = (0..18).collect(); // x[(h*3+w)*2+c] = idx
        let mut p = vec![0i32; sp.patch_len()];
        // pixel (0,0), group 0: taps (0,0),(0,1),(1,0),(1,1) channel 0
        extract_patch(&x, &sp, 0, 0, 0, &mut p);
        assert_eq!(p, vec![0, 2, 6, 8]);
        // group 1 reads channel 1
        extract_patch(&x, &sp, 1, 0, 0, &mut p);
        assert_eq!(p, vec![1, 3, 7, 9]);
        // bottom-right pixel: bottom/right taps are zero padding
        extract_patch(&x, &sp, 0, 2, 2, &mut p);
        assert_eq!(p, vec![16, 0, 0, 0]);
    }

    #[test]
    fn conv2d_codes_matches_direct_convolution() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(21);
        for (stride, padding, groups) in
            [(1usize, Padding::Same, 1usize), (2, Padding::Valid, 1),
             (1, Padding::Same, 2), (2, Padding::Same, 2)]
        {
            let (in_h, in_w, in_c, cout, k) = (5, 4, 4, 6, 3);
            let sp = SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                      padding, groups)
                .unwrap();
            let plen = sp.patch_len();
            let kept: Vec<u32> = (0..cout as u32).collect();
            let w: Vec<i32> = (0..cout * plen)
                .map(|_| (rng.next_u64() % 15) as i32 - 7)
                .collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            let mut patch = vec![0i32; plen];
            let mut y = vec![0i64; n * sp.out_pixels() * cout];
            conv2d_codes(&w, &kept, cout / groups, &sp, &x, n, true,
                         &mut patch, &mut y);
            // brute-force direct convolution, independent indexing
            let cg = in_c / groups;
            for s in 0..n {
                let xs = &x[s * sp.in_len()..(s + 1) * sp.in_len()];
                for oh in 0..sp.out_h {
                    for ow in 0..sp.out_w {
                        for (r, ch) in kept.iter().enumerate() {
                            let g = *ch as usize / (cout / groups);
                            let mut want = 0i64;
                            for kh in 0..k {
                                for kw in 0..k {
                                    let ih = (oh * stride + kh) as isize
                                        - sp.pad_top as isize;
                                    let iw = (ow * stride + kw) as isize
                                        - sp.pad_left as isize;
                                    if ih < 0 || iw < 0
                                        || ih as usize >= in_h
                                        || iw as usize >= in_w
                                    {
                                        continue;
                                    }
                                    for ci in 0..cg {
                                        let wv = w[r * plen
                                            + (kh * k + kw) * cg + ci]
                                            as i64;
                                        let av = xs[(ih as usize * in_w
                                            + iw as usize)
                                            * in_c + g * cg + ci]
                                            as i64;
                                        want += wv * av;
                                    }
                                }
                            }
                            let got = y[(s * sp.out_pixels()
                                + oh * sp.out_w + ow)
                                * cout + r];
                            assert_eq!(got, want,
                                       "s={s} oh={oh} ow={ow} r={r} \
                                        stride={stride} g={groups}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dwconv_fast_path_matches_generic_kernel() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(33);
        for stride in [1usize, 2] {
            let (hw, c, k) = (5, 6, 3);
            let sp = SpatialPlan::new(hw, hw, c, k, stride,
                                      Padding::Same, c)
                .unwrap();
            let plen = sp.patch_len();
            assert_eq!(plen, k * k);
            // prune channels 1 and 4
            let kept: Vec<u32> = vec![0, 2, 3, 5];
            let w: Vec<i32> = (0..kept.len() * plen)
                .map(|_| (rng.next_u64() % 7) as i32 - 3)
                .collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            let mut patch = vec![0i32; plen];
            let mut ya = vec![0i64; n * sp.out_pixels() * kept.len()];
            let mut yb = ya.clone();
            conv2d_codes(&w, &kept, 1, &sp, &x, n, true, &mut patch,
                         &mut ya);
            dwconv2d_codes(&w, &kept, 1, &sp, &x, n, true, &mut yb);
            assert_eq!(ya, yb, "stride={stride}");
            // i64 accumulation path agrees too
            let mut yc = vec![0i64; yb.len()];
            dwconv2d_codes(&w, &kept, 1, &sp, &x, n, false, &mut yc);
            assert_eq!(ya, yc);
        }
    }

    #[test]
    fn quantize_dequantize_acts_on_grid() {
        let x = vec![0.0f32, 0.3, 1.4, -0.7, 9.0];
        let mut codes = Vec::new();
        let step = quantize_acts(&x, 2.0, 8, true, &mut codes);
        let mut back = Vec::new();
        dequantize(&codes, step, &mut back);
        for (orig, b) in x.iter().zip(&back) {
            assert!((b - orig.clamp(-2.0, 2.0)).abs() < step * 0.51,
                    "{orig} -> {b}");
        }
    }
}
