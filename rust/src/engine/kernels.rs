//! Integer GEMM and spatial convolution kernels over packed weights,
//! plus the f32 reference fallbacks — the arithmetic core of the
//! inference engine. Every kernel writes into a caller-owned output
//! slice; the IR executor (`engine::graph`) hands in pre-assigned
//! scratch-arena slices, so the hot path never allocates.
//!
//! The integer path computes `y = W x` on raw grid codes with exact
//! integer accumulation and a single requantize multiply at the end:
//!
//! ```text
//! y[r] = (s_w * s_a) * sum_c q_w[r,c] * q_a[c]
//! ```
//!
//! For widths up to 8x8 bits the inner loop accumulates in `i32`
//! (blocked so the partial sum cannot overflow), spilling each block
//! into an `i64` total; 16-bit operands go straight to `i64` because a
//! single product can exceed `i32`.
//!
//! Spatial conv ([`conv2d_codes`]) is im2col-over-codes: for each
//! output pixel an `(k, k, cin/groups)` patch of activation codes is
//! gathered (zero outside the image) and dotted against every kept
//! channel's decoded row via the same [`dot_codes`] accumulators; the
//! caller decodes packed rows once per batch. Depthwise layers take
//! [`dwconv2d_codes`], which reads its single input channel strided
//! and skips the patch buffer entirely.
//!
//! The f32 fallbacks multiply the *simulated-quantized* dense rows
//! (`codes * step`), so int and f32 paths agree up to f32 accumulation
//! error — the invariants `tests/engine_parity.rs` and
//! `tests/conv_parity.rs` pin down.

use super::pack::PackedMatrix;
use super::SpatialPlan;
use crate::quant::grid::quantize_codes_host;

/// i32 accumulation block: with |w| <= 127 and |a| <= 255, a block sum
/// is bounded by 127 * 255 * 4096 < 2^27 — far from i32 overflow.
const I32_BLOCK: usize = 4096;

/// Exact dot product of two code vectors. `low_bit` selects the
/// blocked-i32 fast path (safe when both operands are <= 8 bits).
#[inline]
pub fn dot_codes(w: &[i32], a: &[i32], low_bit: bool) -> i64 {
    debug_assert_eq!(w.len(), a.len());
    if low_bit {
        let mut total = 0i64;
        for (wb, ab) in w.chunks(I32_BLOCK).zip(a.chunks(I32_BLOCK)) {
            let mut acc = 0i32;
            for (x, y) in wb.iter().zip(ab) {
                acc += *x * *y;
            }
            total += acc as i64;
        }
        total
    } else {
        w.iter().zip(a).map(|(x, y)| *x as i64 * *y as i64).sum()
    }
}

/// Whether a (weight bits, activation bits) pair may use the blocked
/// i32 accumulator.
#[inline]
pub fn low_bit_pair(w_bits: u32, a_bits: u32) -> bool {
    w_bits <= 8 && a_bits <= 8
}

/// Packed matrix times a batch of code vectors.
///
/// * `acts` — `n` activation-code vectors, flat `[n, cols]`;
/// * `y` — flat `[n, rows]` accumulator outputs;
/// * `row_scratch` — caller-provided buffer of at least `cols` slots.
///
/// Rows are decoded once and reused across the whole batch, so the
/// unpack cost amortizes with the serving micro-batch size.
pub fn matmul_packed(w: &PackedMatrix, acts: &[i32], n: usize,
                     act_bits: u32, row_scratch: &mut [i32],
                     y: &mut [i64]) {
    let cols = w.cols;
    let rows = w.rows;
    debug_assert_eq!(acts.len(), n * cols);
    debug_assert_eq!(y.len(), n * rows);
    let low = low_bit_pair(w.bits, act_bits);
    for r in 0..rows {
        w.unpack_row_into(r, row_scratch);
        let row = &row_scratch[..cols];
        for s in 0..n {
            y[s * rows + r] =
                dot_codes(row, &acts[s * cols..(s + 1) * cols], low);
        }
    }
}

/// Dense f32 matrix (`rows x cols`, row-major) times a batch of f32
/// vectors — the reference/fallback path.
pub fn matmul_f32(w: &[f32], rows: usize, cols: usize, xs: &[f32],
                  n: usize, y: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(xs.len(), n * cols);
    debug_assert_eq!(y.len(), n * rows);
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        for s in 0..n {
            let x = &xs[s * cols..(s + 1) * cols];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[s * rows + r] = acc;
        }
    }
}

/// Gather the `(k, k, cin/groups)` input patch feeding output pixel
/// `(oh, ow)` of group `g` into `out[..patch_len]`, in the weight
/// rows' `(kh, kw, ci)` order (HWIO channel-last, matching the
/// lowering's `[cout, cin/groups * k * k]` rows). Taps outside the
/// image read zero (padding). `x` is one sample's NHWC tensor.
pub fn extract_patch<T: Copy + Default>(x: &[T], sp: &SpatialPlan,
                                        g: usize, oh: usize, ow: usize,
                                        out: &mut [T]) {
    let cg = sp.in_c / sp.groups;
    debug_assert_eq!(x.len(), sp.in_len());
    debug_assert!(out.len() >= sp.k * sp.k * cg);
    let c0 = g * cg;
    let ih0 = (oh * sp.stride) as isize - sp.pad_top as isize;
    let iw0 = (ow * sp.stride) as isize - sp.pad_left as isize;
    let mut o = 0;
    for kh in 0..sp.k {
        let ih = ih0 + kh as isize;
        let row_ok = ih >= 0 && (ih as usize) < sp.in_h;
        for kw in 0..sp.k {
            let iw = iw0 + kw as isize;
            if row_ok && iw >= 0 && (iw as usize) < sp.in_w {
                let base =
                    (ih as usize * sp.in_w + iw as usize) * sp.in_c + c0;
                out[o..o + cg].copy_from_slice(&x[base..base + cg]);
            } else {
                out[o..o + cg].fill(T::default());
            }
            o += cg;
        }
    }
}

/// Spatial integer convolution over decoded weight codes (im2col over
/// codes).
///
/// * `w_rows` — `[rows, patch_len]` codes, decoded once per batch;
/// * `kept` — dense output channel of each row, ascending (so rows of
///   one group are contiguous and a patch is gathered once per
///   (pixel, group));
/// * `cout_per_group` — dense output channels per group;
/// * `acts` — `n` NHWC activation-code tensors, flat `[n, in_len]`;
/// * `low` — both operands <= 8 bits: blocked-i32 accumulation;
/// * `patch` — caller scratch of at least `patch_len` slots;
/// * `y` — flat `[n, out_pixels, rows]` exact accumulators.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_codes(w_rows: &[i32], kept: &[u32], cout_per_group: usize,
                    sp: &SpatialPlan, acts: &[i32], n: usize, low: bool,
                    patch: &mut [i32], y: &mut [i64]) {
    let rows = kept.len();
    let plen = sp.patch_len();
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    for s in 0..n {
        let x = &acts[s * in_len..(s + 1) * in_len];
        for oh in 0..sp.out_h {
            for ow in 0..sp.out_w {
                let ybase = (s * opix + oh * sp.out_w + ow) * rows;
                let mut cur_g = usize::MAX;
                for r in 0..rows {
                    let g = kept[r] as usize / cout_per_group;
                    if g != cur_g {
                        extract_patch(x, sp, g, oh, ow, patch);
                        cur_g = g;
                    }
                    y[ybase + r] = dot_codes(
                        &w_rows[r * plen..(r + 1) * plen],
                        &patch[..plen], low);
                }
            }
        }
    }
}

/// Depthwise fast path (`groups == in_c`): each kept output channel
/// reads exactly one input channel, so taps are gathered strided from
/// the NHWC tensor without the im2col patch buffer. Same contract as
/// [`conv2d_codes`] otherwise.
pub fn dwconv2d_codes(w_rows: &[i32], kept: &[u32],
                      cout_per_group: usize, sp: &SpatialPlan,
                      acts: &[i32], n: usize, low: bool, y: &mut [i64]) {
    debug_assert_eq!(sp.groups, sp.in_c);
    let rows = kept.len();
    let plen = sp.k * sp.k;
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(acts.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    // the whole k*k window fits one i32 block at low widths
    let low = low && plen <= I32_BLOCK;
    for s in 0..n {
        let x = &acts[s * in_len..(s + 1) * in_len];
        for oh in 0..sp.out_h {
            let ih0 = (oh * sp.stride) as isize - sp.pad_top as isize;
            for ow in 0..sp.out_w {
                let iw0 =
                    (ow * sp.stride) as isize - sp.pad_left as isize;
                let ybase = (s * opix + oh * sp.out_w + ow) * rows;
                for r in 0..rows {
                    let ci = kept[r] as usize / cout_per_group;
                    let rbase = r * plen;
                    let mut acc32 = 0i32;
                    let mut acc = 0i64;
                    for kh in 0..sp.k {
                        let ih = ih0 + kh as isize;
                        if ih < 0 || ih as usize >= sp.in_h {
                            continue;
                        }
                        let xrow = ih as usize * sp.in_w;
                        for kw in 0..sp.k {
                            let iw = iw0 + kw as isize;
                            if iw < 0 || iw as usize >= sp.in_w {
                                continue;
                            }
                            let wv = w_rows[rbase + kh * sp.k + kw];
                            let av = x
                                [(xrow + iw as usize) * sp.in_c + ci];
                            if low {
                                acc32 += wv * av;
                            } else {
                                acc += wv as i64 * av as i64;
                            }
                        }
                    }
                    y[ybase + r] =
                        if low { acc32 as i64 } else { acc };
                }
            }
        }
    }
}

/// f32 reference spatial convolution over the simulated-quant dense
/// rows — same im2col structure as [`conv2d_codes`], scalar f32
/// accumulation.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32(w_rows: &[f32], kept: &[u32], cout_per_group: usize,
                  sp: &SpatialPlan, xs: &[f32], n: usize,
                  patch: &mut [f32], y: &mut [f32]) {
    let rows = kept.len();
    let plen = sp.patch_len();
    let in_len = sp.in_len();
    let opix = sp.out_pixels();
    debug_assert_eq!(w_rows.len(), rows * plen);
    debug_assert_eq!(xs.len(), n * in_len);
    debug_assert_eq!(y.len(), n * opix * rows);
    for s in 0..n {
        let x = &xs[s * in_len..(s + 1) * in_len];
        for oh in 0..sp.out_h {
            for ow in 0..sp.out_w {
                let ybase = (s * opix + oh * sp.out_w + ow) * rows;
                let mut cur_g = usize::MAX;
                for r in 0..rows {
                    let g = kept[r] as usize / cout_per_group;
                    if g != cur_g {
                        extract_patch(x, sp, g, oh, ow, patch);
                        cur_g = g;
                    }
                    let row = &w_rows[r * plen..(r + 1) * plen];
                    let mut acc = 0.0f32;
                    for (a, b) in row.iter().zip(&patch[..plen]) {
                        acc += a * b;
                    }
                    y[ybase + r] = acc;
                }
            }
        }
    }
}

/// Quantize a flat activation tensor to integer codes in `out`;
/// returns the grid step. Numerics are exactly
/// `quant::grid::quantize_codes_host` (one clip + banker's rounding),
/// so the engine's activation grid is the host oracle's grid. The IR
/// executor quantizes through a precomputed `CodeGrid` instead — same
/// numerics, no per-batch code `Vec`; this form remains for tests and
/// host-side tools.
pub fn quantize_acts(x: &[f32], beta: f32, bits: u32, signed: bool,
                     out: &mut Vec<i32>) -> f32 {
    let (step, codes) = quantize_codes_host(x, beta, bits, signed);
    out.clear();
    out.extend(codes.iter().map(|q| *q as i32));
    step
}

/// Dequantize codes back to f32 (`step * code`) — the simulated-quant
/// activation the f32 reference path consumes.
pub fn dequantize(codes: &[i32], step: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend(codes.iter().map(|q| step * *q as f32));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_codes_paths_agree() {
        let mut rng = crate::rng::Pcg64::new(7);
        let n = 2 * I32_BLOCK + 123; // spans multiple blocks
        let w: Vec<i32> =
            (0..n).map(|_| (rng.next_u64() % 255) as i32 - 127).collect();
        let a: Vec<i32> =
            (0..n).map(|_| (rng.next_u64() % 256) as i32).collect();
        let want: i64 =
            w.iter().zip(&a).map(|(x, y)| *x as i64 * *y as i64).sum();
        assert_eq!(dot_codes(&w, &a, true), want);
        assert_eq!(dot_codes(&w, &a, false), want);
    }

    #[test]
    fn matmul_packed_matches_naive() {
        let mut rng = crate::rng::Pcg64::new(9);
        for (bits, a_bits) in [(2u32, 8u32), (4, 4), (8, 8), (16, 16)] {
            let rows = 5;
            let cols = 33;
            let n = 3;
            let hi = (1i64 << (bits - 1)) - 1;
            let codes: Vec<i64> = (0..rows * cols)
                .map(|_| {
                    (rng.next_u64() % (2 * hi + 1) as u64) as i64 - hi
                })
                .collect();
            let w = PackedMatrix::pack(&codes, rows, cols, bits, true)
                .unwrap();
            let amax = (1i64 << a_bits) - 1;
            let acts: Vec<i32> = (0..n * cols)
                .map(|_| (rng.next_u64() % (amax + 1) as u64) as i32)
                .collect();
            let mut scratch = vec![0i32; cols];
            let mut y = vec![0i64; n * rows];
            matmul_packed(&w, &acts, n, a_bits, &mut scratch, &mut y);
            for s in 0..n {
                for r in 0..rows {
                    let want: i64 = (0..cols)
                        .map(|c| {
                            codes[r * cols + c]
                                * acts[s * cols + c] as i64
                        })
                        .sum();
                    assert_eq!(y[s * rows + r], want,
                               "bits={bits} s={s} r={r}");
                }
            }
        }
    }

    #[test]
    fn extract_patch_handles_padding_and_groups() {
        use crate::models::Padding;
        // 3x3x2 input, k=2, stride 1, SAME (pad bottom/right), 2 groups
        let sp = SpatialPlan::new(3, 3, 2, 2, 1, Padding::Same, 2)
            .unwrap();
        assert_eq!((sp.out_h, sp.out_w), (3, 3));
        assert_eq!((sp.pad_top, sp.pad_left), (0, 0));
        let x: Vec<i32> = (0..18).collect(); // x[(h*3+w)*2+c] = idx
        let mut p = vec![0i32; sp.patch_len()];
        // pixel (0,0), group 0: taps (0,0),(0,1),(1,0),(1,1) channel 0
        extract_patch(&x, &sp, 0, 0, 0, &mut p);
        assert_eq!(p, vec![0, 2, 6, 8]);
        // group 1 reads channel 1
        extract_patch(&x, &sp, 1, 0, 0, &mut p);
        assert_eq!(p, vec![1, 3, 7, 9]);
        // bottom-right pixel: bottom/right taps are zero padding
        extract_patch(&x, &sp, 0, 2, 2, &mut p);
        assert_eq!(p, vec![16, 0, 0, 0]);
    }

    #[test]
    fn conv2d_codes_matches_direct_convolution() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(21);
        for (stride, padding, groups) in
            [(1usize, Padding::Same, 1usize), (2, Padding::Valid, 1),
             (1, Padding::Same, 2), (2, Padding::Same, 2)]
        {
            let (in_h, in_w, in_c, cout, k) = (5, 4, 4, 6, 3);
            let sp = SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                      padding, groups)
                .unwrap();
            let plen = sp.patch_len();
            let kept: Vec<u32> = (0..cout as u32).collect();
            let w: Vec<i32> = (0..cout * plen)
                .map(|_| (rng.next_u64() % 15) as i32 - 7)
                .collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            let mut patch = vec![0i32; plen];
            let mut y = vec![0i64; n * sp.out_pixels() * cout];
            conv2d_codes(&w, &kept, cout / groups, &sp, &x, n, true,
                         &mut patch, &mut y);
            // brute-force direct convolution, independent indexing
            let cg = in_c / groups;
            for s in 0..n {
                let xs = &x[s * sp.in_len()..(s + 1) * sp.in_len()];
                for oh in 0..sp.out_h {
                    for ow in 0..sp.out_w {
                        for (r, ch) in kept.iter().enumerate() {
                            let g = *ch as usize / (cout / groups);
                            let mut want = 0i64;
                            for kh in 0..k {
                                for kw in 0..k {
                                    let ih = (oh * stride + kh) as isize
                                        - sp.pad_top as isize;
                                    let iw = (ow * stride + kw) as isize
                                        - sp.pad_left as isize;
                                    if ih < 0 || iw < 0
                                        || ih as usize >= in_h
                                        || iw as usize >= in_w
                                    {
                                        continue;
                                    }
                                    for ci in 0..cg {
                                        let wv = w[r * plen
                                            + (kh * k + kw) * cg + ci]
                                            as i64;
                                        let av = xs[(ih as usize * in_w
                                            + iw as usize)
                                            * in_c + g * cg + ci]
                                            as i64;
                                        want += wv * av;
                                    }
                                }
                            }
                            let got = y[(s * sp.out_pixels()
                                + oh * sp.out_w + ow)
                                * cout + r];
                            assert_eq!(got, want,
                                       "s={s} oh={oh} ow={ow} r={r} \
                                        stride={stride} g={groups}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dwconv_fast_path_matches_generic_kernel() {
        use crate::models::Padding;
        let mut rng = crate::rng::Pcg64::new(33);
        for stride in [1usize, 2] {
            let (hw, c, k) = (5, 6, 3);
            let sp = SpatialPlan::new(hw, hw, c, k, stride,
                                      Padding::Same, c)
                .unwrap();
            let plen = sp.patch_len();
            assert_eq!(plen, k * k);
            // prune channels 1 and 4
            let kept: Vec<u32> = vec![0, 2, 3, 5];
            let w: Vec<i32> = (0..kept.len() * plen)
                .map(|_| (rng.next_u64() % 7) as i32 - 3)
                .collect();
            let n = 2;
            let x: Vec<i32> = (0..n * sp.in_len())
                .map(|_| (rng.next_u64() % 16) as i32)
                .collect();
            let mut patch = vec![0i32; plen];
            let mut ya = vec![0i64; n * sp.out_pixels() * kept.len()];
            let mut yb = ya.clone();
            conv2d_codes(&w, &kept, 1, &sp, &x, n, true, &mut patch,
                         &mut ya);
            dwconv2d_codes(&w, &kept, 1, &sp, &x, n, true, &mut yb);
            assert_eq!(ya, yb, "stride={stride}");
            // i64 accumulation path agrees too
            let mut yc = vec![0i64; yb.len()];
            dwconv2d_codes(&w, &kept, 1, &sp, &x, n, false, &mut yc);
            assert_eq!(ya, yc);
        }
    }

    #[test]
    fn quantize_dequantize_acts_on_grid() {
        let x = vec![0.0f32, 0.3, 1.4, -0.7, 9.0];
        let mut codes = Vec::new();
        let step = quantize_acts(&x, 2.0, 8, true, &mut codes);
        let mut back = Vec::new();
        dequantize(&codes, step, &mut back);
        for (orig, b) in x.iter().zip(&back) {
            assert!((b - orig.clamp(-2.0, 2.0)).abs() < step * 0.51,
                    "{orig} -> {b}");
        }
    }
}
