//! Static plan verification: machine-checked proofs over compiled
//! [`Program`]s.
//!
//! The pass pipeline's output used to be trusted on the strength of
//! prose — the blocked-i32 accumulator bound lived in a `kernels.rs`
//! comment, arena non-aliasing was pinned by one independent test, and
//! a buggy pass would only surface as a wrong answer (or a silent
//! integer overflow) at serve time. This module turns those arguments
//! into analyses that run against every compiled artifact:
//!
//! 1. **Value-range / overflow analysis** — per-buffer integer
//!    intervals are seeded from each producing grid's code range and
//!    propagated through the node list; at every integer kernel the
//!    worst-case accumulator magnitude `max|w| * max|a| * block_len`
//!    is computed from the *actual* operand ranges and the kernel's
//!    accumulation geometry ([`kernels::I32_BLOCK`] chunks on the
//!    scalar/SIMD paths, [`pack::KC`]-deep panels on the blocked
//!    backend, the whole patch for depthwise) and compared against
//!    `i32::MAX` / `i64::MAX`. The bound is *derived*, never assumed:
//!    a 16-bit grid smuggled onto a node the kernel will dispatch down
//!    the low-bit path is rejected here, not at overflow time.
//! 2. **Arena soundness** — liveness is recomputed from the node list
//!    independently of `engine::arena`, and any two simultaneously
//!    live buffers of one dtype whose assigned slots overlap (or fall
//!    outside the arena) are rejected.
//! 3. **IR well-formedness** — def-before-use, single writer per
//!    buffer, dtype/shape agreement on every edge, pass-stable node-id
//!    uniqueness, and no reference to an id the pass pipeline retired.
//! 4. **Backend invariants** — blocked nodes carry panels whose
//!    MR/KC geometry, zero-padded remainders, and per-group row blocks
//!    match the node's layer; SIMD/scalar assignments obey the
//!    lane-width auto rule unless a forced override is recorded.
//! 5. **f32 range / adapter geometry** — the integer intervals extend
//!    through the float edges: requantize-scale products
//!    (`acc_bound * |scale| + max|bias|`), dequantize steps, and
//!    f32-path matmuls are bounded and rejected when the bound is
//!    non-finite or past `f32::MAX` (the kernel would materialize
//!    `inf`); `AdaptSpatial`/`AdaptFeatures` nodes are checked
//!    against the plan manifest (the layer's pre-op tuple and its
//!    spatial input geometry), catching transposed adapters whose
//!    flat length is right but whose NHWC interpretation is not.
//!
//! [`verify`] returns the first [`VerifyError`]; [`verify_all`]
//! collects every finding. Neither ever panics — a corrupt program
//! produces errors, not index faults (every access is guarded), which
//! is what lets the mutation battery in `tests/verify.rs` feed this
//! module deliberately broken programs.
//!
//! Debug builds run [`verify`] automatically at the end of
//! `Program::compile`; release builds opt in via `bbits plan --verify`
//! or `ServeConfig::verify_plans` (the registry then proves every
//! ladder rung at register time). Verification is compile-time only —
//! the interpreter hot loop never pays for it.

use std::fmt;

use super::graph::{BufId, DType, Node, Program};
use super::kernels::{self, Backend};
use super::pack::{code_range, KC, MR};
use super::{ActSpec, PlanLayer, PreOp};

/// One statically-proven defect in a compiled [`Program`]. Each
/// variant is a distinct failure class; `tests/verify.rs` pins the
/// mapping from hand-made corruption to variant.
#[derive(Debug, Clone, PartialEq)]
pub enum VerifyError {
    /// Parallel program arrays disagree (nodes / node_ids /
    /// node_layer / panels lengths) or a node names a layer outside
    /// the plan — nothing else can be trusted, so this reports alone.
    Malformed { detail: String },
    /// A node references a buffer id outside the buffer table.
    BadBuffer { node: usize, buf: BufId },
    /// A reachable buffer was never assigned an arena slot.
    UnassignedBuffer { node: usize, buf: BufId },
    /// A node reads a buffer no earlier node (or the input) defined —
    /// the typed form of the `engine::arena` use-before-def assert.
    UseBeforeDef { node: usize, buf: BufId },
    /// Two nodes write the same buffer (every buffer has exactly one
    /// producer in a well-formed program).
    MultipleWriters { buf: BufId, first: usize, second: usize },
    /// A `Pre` placeholder survived compilation (the materialization
    /// pass must expand every one).
    TransientNode { node: usize },
    /// Two nodes carry the same pass-stable id.
    DuplicateNodeId { id: usize, first: usize, second: usize },
    /// A node id at or past the id allocator's high-water mark.
    UnknownNodeId { node: usize, id: usize, bound: usize },
    /// A node carries an id the pass pipeline retired (absorbed by
    /// fusion or dropped by elision) — stale attribution at best, a
    /// resurrected node at worst.
    RetiredNodeId { node: usize, id: usize },
    /// An edge's buffer dtype disagrees with what the node computes.
    EdgeDType { node: usize, buf: BufId, want: DType, got: DType },
    /// An edge's buffer length disagrees with the node's static shape.
    EdgeShape { node: usize, buf: BufId, want: usize, got: usize },
    /// Program input/output spec disagrees with the plan.
    BadIo { detail: String },
    /// Two simultaneously-live buffers share arena bytes.
    ArenaAlias {
        a: BufId,
        b: BufId,
        dtype: DType,
        /// Element ranges `[offset, offset + len)` of the two slots.
        a_slot: (usize, usize),
        b_slot: (usize, usize),
    },
    /// A buffer's slot runs past the end of its dtype arena.
    ArenaOutOfBounds { buf: BufId, dtype: DType, end: usize, arena: usize },
    /// Worst-case accumulator magnitude exceeds the accumulator type:
    /// `max_w * max_a * block_len > limit` — the machine-checked form
    /// of the bound `kernels.rs` used to state in prose.
    AccumulatorOverflow {
        node: usize,
        op: &'static str,
        path: AccPath,
        max_w: i64,
        max_a: i64,
        block_len: usize,
        bound: i128,
        limit: i128,
    },
    /// A low-bit-path operand can exceed the i16 range the AVX2
    /// `vpmaddwd` form packs into (`_mm256_packs_epi32` saturates).
    PackSaturation { node: usize, max_code: i64, limit: i64 },
    /// The i16-pair multiply-add `w0*a0 + w1*a1` can exceed i32.
    PairSumOverflow { node: usize, max_w: i64, max_a: i64 },
    /// An integer kernel whose activation source has no propagated
    /// code range (its producer is not a quantizing node).
    MissingRange { node: usize, buf: BufId },
    /// A blocked kernel node whose layer has no compiled panels.
    MissingPanels { node: usize, layer: usize },
    /// Panel storage inconsistent with the node's layer (dims, block
    /// partition, depth-block count, padding, data size).
    PanelGeometry { layer: usize, detail: String },
    /// A conv panel row block spans two filter groups.
    PanelGroupStraddle { layer: usize, block: usize },
    /// A backend assignment the auto rule could not have produced and
    /// no forced override explains.
    BackendRule {
        node: usize,
        backend: Backend,
        lane_dim: usize,
        lanes: usize,
    },
    /// A statically-bounded f32 edge can exceed `f32::MAX` (or the
    /// bound itself is non-finite): a requantize-scale product,
    /// dequantize step, bias add, or f32-path matmul whose worst case
    /// materializes `inf` and poisons everything downstream.
    F32RangeOverflow { node: usize, op: &'static str, bound: f64 },
    /// An adapter node's geometry disagrees with the plan manifest:
    /// `AdaptSpatial` from/to vs the layer's pre-op tuple and spatial
    /// input dims, or `AdaptFeatures` width vs the layer's input
    /// width.
    AdapterGeometry { node: usize, detail: String },
}

/// Which accumulator a kernel's dispatch rule selects for a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccPath {
    /// Blocked i32 partial sums spilled into an i64 total.
    BlockedI32,
    /// Straight-to-i64 wide path.
    WideI64,
}

impl fmt::Display for AccPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AccPath::BlockedI32 => write!(f, "blocked-i32"),
            AccPath::WideI64 => write!(f, "wide-i64"),
        }
    }
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::Malformed { detail } => {
                write!(f, "malformed program: {detail}")
            }
            VerifyError::BadBuffer { node, buf } => write!(
                f,
                "node {node} references buffer {buf} outside the \
                 buffer table"
            ),
            VerifyError::UnassignedBuffer { node, buf } => write!(
                f,
                "buffer {buf} (touched by node {node}) is live but \
                 has no arena slot"
            ),
            VerifyError::UseBeforeDef { node, buf } => write!(
                f,
                "node {node} reads buffer {buf} before any node \
                 defines it"
            ),
            VerifyError::MultipleWriters { buf, first, second } => {
                write!(
                    f,
                    "buffer {buf} is written by node {first} and \
                     again by node {second}"
                )
            }
            VerifyError::TransientNode { node } => write!(
                f,
                "node {node} is a transient Pre placeholder the \
                 materialization pass must expand"
            ),
            VerifyError::DuplicateNodeId { id, first, second } => {
                write!(
                    f,
                    "pass-stable id {id} is carried by node {first} \
                     and node {second}"
                )
            }
            VerifyError::UnknownNodeId { node, id, bound } => write!(
                f,
                "node {node} carries id {id}, past the allocator \
                 high-water mark {bound}"
            ),
            VerifyError::RetiredNodeId { node, id } => write!(
                f,
                "node {node} carries id {id}, which the pass \
                 pipeline retired"
            ),
            VerifyError::EdgeDType { node, buf, want, got } => write!(
                f,
                "node {node}: buffer {buf} is {}, node needs {}",
                got.label(),
                want.label()
            ),
            VerifyError::EdgeShape { node, buf, want, got } => write!(
                f,
                "node {node}: buffer {buf} holds {got} elements, \
                 node needs {want}"
            ),
            VerifyError::BadIo { detail } => {
                write!(f, "program io: {detail}")
            }
            VerifyError::ArenaAlias { a, b, dtype, a_slot, b_slot } => {
                write!(
                    f,
                    "simultaneously-live {} buffers {a} [{}..{}) and \
                     {b} [{}..{}) share arena space",
                    dtype.label(),
                    a_slot.0,
                    a_slot.1,
                    b_slot.0,
                    b_slot.1
                )
            }
            VerifyError::ArenaOutOfBounds { buf, dtype, end, arena } => {
                write!(
                    f,
                    "buffer {buf} ends at {} element {end} of an \
                     arena holding {arena}",
                    dtype.label()
                )
            }
            VerifyError::AccumulatorOverflow {
                node,
                op,
                path,
                max_w,
                max_a,
                block_len,
                bound,
                limit,
            } => write!(
                f,
                "node {node} ({op}): {path} accumulator can reach \
                 |w|*|a|*block = {max_w}*{max_a}*{block_len} = \
                 {bound} > {limit}"
            ),
            VerifyError::PackSaturation { node, max_code, limit } => {
                write!(
                    f,
                    "node {node}: low-bit operand can reach \
                     {max_code}, past the i16 pack limit {limit} \
                     (vpmaddwd would saturate)"
                )
            }
            VerifyError::PairSumOverflow { node, max_w, max_a } => {
                write!(
                    f,
                    "node {node}: i16-pair sum 2*{max_w}*{max_a} \
                     exceeds i32"
                )
            }
            VerifyError::MissingRange { node, buf } => write!(
                f,
                "node {node}: integer kernel reads buffer {buf} \
                 with no propagated code range"
            ),
            VerifyError::MissingPanels { node, layer } => write!(
                f,
                "node {node}: blocked backend on layer {layer} with \
                 no compiled weight panels"
            ),
            VerifyError::PanelGeometry { layer, detail } => {
                write!(f, "layer {layer} panels: {detail}")
            }
            VerifyError::PanelGroupStraddle { layer, block } => write!(
                f,
                "layer {layer} panel row block {block} spans two \
                 filter groups"
            ),
            VerifyError::BackendRule {
                node,
                backend,
                lane_dim,
                lanes,
            } => write!(
                f,
                "node {node}: backend {} with lane dimension \
                 {lane_dim} violates the auto rule (simd at >= \
                 {lanes} lanes, blocked only when forced) and no \
                 forced override is recorded",
                backend.label()
            ),
            VerifyError::F32RangeOverflow { node, op, bound } => write!(
                f,
                "node {node} ({op}): f32 edge can reach magnitude \
                 {bound:e}, past f32::MAX — the kernel would \
                 materialize inf"
            ),
            VerifyError::AdapterGeometry { node, detail } => {
                write!(f, "node {node}: adapter geometry: {detail}")
            }
        }
    }
}

impl std::error::Error for VerifyError {}

/// Verify a compiled program; `Ok(())` or the first defect found.
pub fn verify(prog: &Program) -> Result<(), VerifyError> {
    match verify_all(prog).into_iter().next() {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

/// Verify a compiled program and collect every defect. Never panics:
/// all indexing is guarded, so deliberately corrupted programs (the
/// mutation battery) report errors instead of faulting.
pub fn verify_all(prog: &Program) -> Vec<VerifyError> {
    let mut errs = Vec::new();
    if let Err(e) = check_structure(prog) {
        // parallel arrays disagree: per-node analyses would index
        // out of step, so report the structural defect alone
        return vec![e];
    }
    check_node_ids(prog, &mut errs);
    check_buffers_and_edges(prog, &mut errs);
    check_io(prog, &mut errs);
    let live = check_dataflow(prog, &mut errs);
    check_arena(prog, &live, &mut errs);
    check_backends(prog, &mut errs);
    check_overflow(prog, &mut errs);
    check_adapters(prog, &mut errs);
    errs
}

// ------------------------------------------------------------------
// Structure / ids
// ------------------------------------------------------------------

fn check_structure(prog: &Program) -> Result<(), VerifyError> {
    let n = prog.nodes.len();
    if prog.node_ids.len() != n || prog.node_layer.len() != n {
        return Err(VerifyError::Malformed {
            detail: format!(
                "parallel arrays disagree: {n} nodes, {} ids, {} \
                 layer indices",
                prog.node_ids.len(),
                prog.node_layer.len()
            ),
        });
    }
    if prog.panels.len() != prog.plan.layers.len() {
        return Err(VerifyError::Malformed {
            detail: format!(
                "panel table has {} entries for {} layers",
                prog.panels.len(),
                prog.plan.layers.len()
            ),
        });
    }
    for (i, node) in prog.nodes.iter().enumerate() {
        if let Some(li) = node.layer() {
            if li >= prog.plan.layers.len() {
                return Err(VerifyError::Malformed {
                    detail: format!(
                        "node {i} ({}) names layer {li} of {}",
                        node.op_name(),
                        prog.plan.layers.len()
                    ),
                });
            }
        }
    }
    Ok(())
}

fn check_node_ids(prog: &Program, errs: &mut Vec<VerifyError>) {
    let mut first_at = std::collections::BTreeMap::new();
    for (i, &id) in prog.node_ids.iter().enumerate() {
        if id >= prog.id_bound {
            errs.push(VerifyError::UnknownNodeId {
                node: i,
                id,
                bound: prog.id_bound,
            });
            continue;
        }
        if prog.retired_ids.contains(&id) {
            errs.push(VerifyError::RetiredNodeId { node: i, id });
        }
        match first_at.get(&id) {
            None => {
                first_at.insert(id, i);
            }
            Some(&first) => errs.push(VerifyError::DuplicateNodeId {
                id,
                first,
                second: i,
            }),
        }
    }
}

// ------------------------------------------------------------------
// Edges: buffer ids, dtypes, shapes
// ------------------------------------------------------------------

/// `(dtype, len)` the node requires of one buffer; `len == None`
/// accepts any length (the flat width adapter's input).
type EdgeSpec = (DType, Option<usize>);

/// Expected `(src, dst)` edge specs of a node, from the plan's static
/// shapes. `None` when the node's layer geometry is itself broken
/// (reported separately).
fn edge_specs(prog: &Program, node: &Node)
              -> Option<(Option<EdgeSpec>, EdgeSpec)> {
    let layer = |li: usize| prog.plan.layers.get(li);
    Some(match node {
        Node::Pre { .. } => return None,
        Node::MaxPool2 { h, w, c, .. } => (
            Some((DType::F32, Some(h * w * c))),
            (DType::F32, Some((h / 2) * (w / 2) * c)),
        ),
        Node::GlobalAvgPool { h, w, c, .. } => {
            (Some((DType::F32, Some(h * w * c))), (DType::F32, Some(*c)))
        }
        Node::AdaptSpatial { from, to, .. } => (
            Some((DType::F32, Some(from.0 * from.1 * from.2))),
            (DType::F32, Some(to.0 * to.1 * to.2)),
        ),
        Node::AdaptFeatures { want, .. } => {
            // the flat adapter pools/replicates from any width
            (Some((DType::F32, None)), (DType::F32, Some(*want)))
        }
        Node::Quantize { src, .. } => {
            let len = prog.bufs.get(*src).map(|b| b.len);
            (Some((DType::F32, len)), (DType::I32, len))
        }
        Node::Dequantize { src, .. } => {
            let len = prog.bufs.get(*src).map(|b| b.len);
            (Some((DType::I32, len)), (DType::F32, len))
        }
        Node::Gemm { layer: li, int, .. }
        | Node::Conv2d { layer: li, int, .. } => {
            let l = layer(*li)?;
            let opix = l
                .spatial
                .as_ref()
                .map(|sp| sp.out_pixels())
                .unwrap_or(1);
            let (sdt, ddt) =
                if *int { (DType::I32, DType::I64) }
                else { (DType::F32, DType::F32) };
            (
                Some((sdt, Some(l.input_len()))),
                (ddt, Some(opix * l.kept.len())),
            )
        }
        Node::DwConv2d { layer: li, .. } => {
            let l = layer(*li)?;
            let opix = l
                .spatial
                .as_ref()
                .map(|sp| sp.out_pixels())
                .unwrap_or(1);
            (
                Some((DType::I32, Some(l.input_len()))),
                (DType::I64, Some(opix * l.kept.len())),
            )
        }
        Node::Requant { layer: li, .. } => {
            let l = layer(*li)?;
            let opix = l
                .spatial
                .as_ref()
                .map(|sp| sp.out_pixels())
                .unwrap_or(1);
            (
                Some((DType::I64, Some(opix * l.kept.len()))),
                (DType::F32, Some(l.output_len())),
            )
        }
        Node::Epilogue { layer: li, .. } => {
            let l = layer(*li)?;
            let opix = l
                .spatial
                .as_ref()
                .map(|sp| sp.out_pixels())
                .unwrap_or(1);
            (
                Some((DType::F32, Some(opix * l.kept.len()))),
                (DType::F32, Some(l.output_len())),
            )
        }
        Node::EpilogueQuantize { layer: li, .. } => {
            let l = layer(*li)?;
            let opix = l
                .spatial
                .as_ref()
                .map(|sp| sp.out_pixels())
                .unwrap_or(1);
            (
                Some((DType::F32, Some(opix * l.kept.len()))),
                (DType::I32, Some(l.output_len())),
            )
        }
        Node::RequantQuantize { layer: li, .. } => {
            let l = layer(*li)?;
            let opix = l
                .spatial
                .as_ref()
                .map(|sp| sp.out_pixels())
                .unwrap_or(1);
            (
                Some((DType::I64, Some(opix * l.kept.len()))),
                (DType::I32, Some(l.output_len())),
            )
        }
        Node::BiasFill { layer: li, .. } => {
            let l = layer(*li)?;
            (None, (DType::F32, Some(l.output_len())))
        }
    })
}

fn check_edge(prog: &Program, node: usize, buf: BufId, spec: EdgeSpec,
              errs: &mut Vec<VerifyError>) {
    let Some(b) = prog.bufs.get(buf) else {
        errs.push(VerifyError::BadBuffer { node, buf });
        return;
    };
    let (want_dt, want_len) = spec;
    if b.dtype != want_dt {
        errs.push(VerifyError::EdgeDType {
            node,
            buf,
            want: want_dt,
            got: b.dtype,
        });
    }
    if let Some(want) = want_len {
        if b.len != want {
            errs.push(VerifyError::EdgeShape {
                node,
                buf,
                want,
                got: b.len,
            });
        }
    }
}

fn check_buffers_and_edges(prog: &Program, errs: &mut Vec<VerifyError>) {
    for (i, node) in prog.nodes.iter().enumerate() {
        if matches!(node, Node::Pre { .. }) {
            errs.push(VerifyError::TransientNode { node: i });
            continue;
        }
        match edge_specs(prog, node) {
            None => {
                // Pre handled above; a None from a bad layer index was
                // already reported by check_structure
            }
            Some((src_spec, dst_spec)) => {
                match (node.reads(), src_spec) {
                    (Some(src), Some(spec)) => {
                        check_edge(prog, i, src, spec, errs)
                    }
                    (Some(src), None) if prog.bufs.get(src).is_none() => {
                        errs.push(VerifyError::BadBuffer {
                            node: i,
                            buf: src,
                        });
                    }
                    _ => {}
                }
                check_edge(prog, i, node.writes(), dst_spec, errs);
            }
        }
    }
}

fn check_io(prog: &Program, errs: &mut Vec<VerifyError>) {
    match prog.bufs.get(prog.input) {
        None => errs.push(VerifyError::BadIo {
            detail: format!("input buffer {} out of range", prog.input),
        }),
        Some(b) => {
            if b.dtype != DType::F32 || b.len != prog.plan.input_dim {
                errs.push(VerifyError::BadIo {
                    detail: format!(
                        "input buffer is {} x{}, plan wants f32 x{}",
                        b.dtype.label(),
                        b.len,
                        prog.plan.input_dim
                    ),
                });
            }
        }
    }
    match prog.bufs.get(prog.output) {
        None => errs.push(VerifyError::BadIo {
            detail: format!("output buffer {} out of range", prog.output),
        }),
        Some(b) => {
            if b.dtype != DType::F32 || b.len != prog.plan.output_dim {
                errs.push(VerifyError::BadIo {
                    detail: format!(
                        "output buffer is {} x{}, plan wants f32 x{}",
                        b.dtype.label(),
                        b.len,
                        prog.plan.output_dim
                    ),
                });
            }
        }
    }
}

// ------------------------------------------------------------------
// Dataflow: def-before-use, single writer, live intervals
// ------------------------------------------------------------------

/// Per-buffer live interval in event time (input defined at 0, node
/// `i` runs at `i + 1`, the caller reads the output at `len + 1`) —
/// recomputed here from the node list, deliberately independent of
/// the `engine::arena` implementation it cross-checks.
struct Liveness {
    def: Vec<usize>,
    last: Vec<usize>,
}

const UNDEF: usize = usize::MAX;

fn check_dataflow(prog: &Program, errs: &mut Vec<VerifyError>)
                  -> Liveness {
    let nb = prog.bufs.len();
    let mut def = vec![UNDEF; nb];
    let mut last = vec![0usize; nb];
    let mut writer = vec![UNDEF; nb];
    if prog.input < nb {
        def[prog.input] = 0;
    }
    for (i, node) in prog.nodes.iter().enumerate() {
        let t = i + 1;
        if let Some(r) = node.reads() {
            if r >= nb {
                // reported as BadBuffer by the edge pass
            } else if def[r] == UNDEF {
                errs.push(VerifyError::UseBeforeDef { node: i, buf: r });
            } else {
                last[r] = last[r].max(t);
            }
        }
        let w = node.writes();
        if w >= nb {
            continue;
        }
        if writer[w] != UNDEF {
            errs.push(VerifyError::MultipleWriters {
                buf: w,
                first: writer[w],
                second: i,
            });
        }
        writer[w] = i;
        if def[w] == UNDEF {
            def[w] = t;
        }
        last[w] = last[w].max(t);
    }
    if prog.output < nb && def[prog.output] != UNDEF {
        last[prog.output] = prog.nodes.len() + 1;
    }
    Liveness { def, last }
}

// ------------------------------------------------------------------
// Arena soundness
// ------------------------------------------------------------------

fn arena_len(prog: &Program, dt: DType) -> usize {
    match dt {
        DType::F32 => prog.f32_len,
        DType::I32 => prog.i32_len,
        DType::I64 => prog.i64_len,
    }
}

fn check_arena(prog: &Program, live: &Liveness,
               errs: &mut Vec<VerifyError>) {
    let nb = prog.bufs.len();
    // reachable = has a live interval (the input counts even if no
    // node reads it; orphaned buffers keep offset None and are free)
    let reachable: Vec<BufId> = (0..nb)
        .filter(|&b| live.def.get(b).is_some_and(|d| *d != UNDEF))
        .collect();
    for &b in &reachable {
        let spec = &prog.bufs[b];
        let Some(off) = spec.offset else {
            // find a node touching it for the report
            let node = prog
                .nodes
                .iter()
                .position(|n| {
                    n.writes() == b || n.reads() == Some(b)
                })
                .unwrap_or(0);
            errs.push(VerifyError::UnassignedBuffer { node, buf: b });
            continue;
        };
        let end = off + spec.len;
        let arena = arena_len(prog, spec.dtype);
        if end > arena {
            errs.push(VerifyError::ArenaOutOfBounds {
                buf: b,
                dtype: spec.dtype,
                end,
                arena,
            });
        }
    }
    // pairwise: same dtype, overlapping live intervals, overlapping
    // slots. Quadratic in buffer count, which is tens per program.
    for (ai, &a) in reachable.iter().enumerate() {
        let (Some(ao), sa) = (prog.bufs[a].offset, &prog.bufs[a]) else {
            continue;
        };
        for &b in &reachable[ai + 1..] {
            let (Some(bo), sb) = (prog.bufs[b].offset, &prog.bufs[b])
            else {
                continue;
            };
            if sa.dtype != sb.dtype {
                continue;
            }
            let lives_overlap = live.def[a] <= live.last[b]
                && live.def[b] <= live.last[a];
            let slots_overlap = ao < bo + sb.len && bo < ao + sa.len;
            if lives_overlap && slots_overlap && sa.len > 0 && sb.len > 0
            {
                errs.push(VerifyError::ArenaAlias {
                    a,
                    b,
                    dtype: sa.dtype,
                    a_slot: (ao, ao + sa.len),
                    b_slot: (bo, bo + sb.len),
                });
            }
        }
    }
}

// ------------------------------------------------------------------
// Backend invariants
// ------------------------------------------------------------------

/// Lane dimension the auto rule inspects for an integer kernel node —
/// mirrors `passes::assign_backends`.
fn lane_dim(prog: &Program, node: &Node) -> Option<usize> {
    match node {
        Node::Gemm { layer, int: true, .. }
        | Node::Conv2d { layer, int: true, .. } => {
            prog.plan.layers.get(*layer).map(|l| l.in_dim)
        }
        Node::DwConv2d { layer, .. } => {
            prog.plan.layers.get(*layer).map(|l| l.kept.len())
        }
        _ => None,
    }
}

fn check_backends(prog: &Program, errs: &mut Vec<VerifyError>) {
    for (i, node) in prog.nodes.iter().enumerate() {
        let Some(backend) = node.backend() else { continue };
        let Some(lane) = lane_dim(prog, node) else {
            // f32-form kernel: must stay scalar
            if backend != Backend::Scalar {
                errs.push(VerifyError::BackendRule {
                    node: i,
                    backend,
                    lane_dim: 0,
                    lanes: kernels::LANES,
                });
            }
            continue;
        };
        if backend == Backend::Blocked {
            check_panels(prog, i, node, errs);
        }
        if prog.forced_backend.is_some() {
            continue;
        }
        // unforced: the auto rule picks SIMD at lane_dim >= LANES,
        // scalar below, and never blocked
        let auto_ok = match backend {
            Backend::Simd => lane >= kernels::LANES,
            Backend::Scalar => lane < kernels::LANES,
            Backend::Blocked => false,
        };
        if !auto_ok {
            errs.push(VerifyError::BackendRule {
                node: i,
                backend,
                lane_dim: lane,
                lanes: kernels::LANES,
            });
        }
    }
}

fn check_panels(prog: &Program, i: usize, node: &Node,
                errs: &mut Vec<VerifyError>) {
    let Some(li) = node.layer() else { return };
    let Some(l) = prog.plan.layers.get(li) else { return };
    let Some(Some(pm)) = prog.panels.get(li) else {
        errs.push(VerifyError::MissingPanels { node: i, layer: li });
        return;
    };
    let mut geom = |detail: String| {
        errs.push(VerifyError::PanelGeometry { layer: li, detail });
    };
    let Some(packed) = l.packed.as_ref() else {
        geom("blocked node on a layer without packed rows".into());
        return;
    };
    if pm.bits != packed.bits || pm.signed != packed.signed {
        geom(format!(
            "panel codes are {}-bit signed={}, packed rows are {}-bit \
             signed={}",
            pm.bits, pm.signed, packed.bits, packed.signed
        ));
    }
    // reduction length the kernel dots a panel row against
    let red = match node {
        Node::DwConv2d { .. } => {
            l.spatial.as_ref().map(|sp| sp.k * sp.k).unwrap_or(l.in_dim)
        }
        _ => l.in_dim,
    };
    if pm.rows != l.kept.len() || pm.cols != red {
        geom(format!(
            "panel is {}x{}, node needs {}x{red}",
            pm.rows,
            pm.cols,
            l.kept.len()
        ));
        return; // block/padding checks below assume the dims
    }
    let want_kb = if pm.cols == 0 { 1 } else { pm.cols.div_ceil(KC) };
    if pm.kblocks() != want_kb {
        geom(format!(
            "{} depth blocks for {} cols (want {want_kb})",
            pm.kblocks(),
            pm.cols
        ));
        return;
    }
    // row blocks partition 0..rows in ascending <= MR chunks
    let blocks = pm.blocks();
    let mut next = 0usize;
    for &(r0, mr) in blocks {
        if r0 != next || mr > MR || (mr == 0 && pm.rows != 0) {
            geom(format!(
                "row blocks do not partition 0..{} (block at {r0} of \
                 {mr} rows, expected start {next})",
                pm.rows
            ));
            return;
        }
        next += mr;
    }
    if next != pm.rows {
        geom(format!(
            "row blocks cover {next} of {} rows",
            pm.rows
        ));
        return;
    }
    if pm.panel_bytes() != blocks.len() * pm.kblocks() * MR * KC * 4 {
        geom(format!(
            "panel storage is {} bytes for {} blocks x {} depth blocks",
            pm.panel_bytes(),
            blocks.len(),
            pm.kblocks()
        ));
        return;
    }
    // conv row blocks must not straddle filter groups (one panel is
    // dotted against exactly one group's patch block)
    if let (Node::Conv2d { .. }, Some(sp)) = (node, l.spatial.as_ref()) {
        if sp.groups > 0 && l.out_dim % sp.groups == 0 {
            let cpg = (l.out_dim / sp.groups).max(1);
            for (bi, &(r0, mr)) in blocks.iter().enumerate() {
                let gs: Vec<usize> = (r0..r0 + mr)
                    .filter_map(|r| l.kept.get(r))
                    .map(|&k| k as usize / cpg)
                    .collect();
                if gs.windows(2).any(|w| w[0] != w[1]) {
                    errs.push(VerifyError::PanelGroupStraddle {
                        layer: li,
                        block: bi,
                    });
                }
            }
        }
    }
    // zero-padded remainders: rows past a block's true count and
    // codes past the true row length must be zero (a zero code is
    // the only content that cannot change an exact integer sum)
    for (b, &(_, mr)) in blocks.iter().enumerate() {
        for kb in 0..pm.kblocks() {
            let k0 = kb * KC;
            let klen = KC.min(pm.cols.saturating_sub(k0));
            let panel = pm.panel(b, kb);
            let pad_bad = (0..MR).any(|m| {
                let row = &panel[m * KC..(m + 1) * KC];
                if m >= mr {
                    row.iter().any(|&v| v != 0)
                } else {
                    row[klen..].iter().any(|&v| v != 0)
                }
            });
            if pad_bad {
                errs.push(VerifyError::PanelGeometry {
                    layer: li,
                    detail: format!(
                        "block {b} depth block {kb}: remainder not \
                         zero-padded"
                    ),
                });
                return;
            }
        }
    }
}

// ------------------------------------------------------------------
// Value-range / overflow analysis
// ------------------------------------------------------------------

/// Magnitude bound of an inclusive code interval.
fn interval_mag(lo: i64, hi: i64) -> i64 {
    lo.abs().max(hi.abs())
}

/// The i32 partial-sum block length a kernel node accumulates before
/// spilling to i64, from its backend's actual accumulation geometry.
fn block_len(node: &Node, red: usize) -> usize {
    match node {
        // depthwise accumulates the whole patch in one i32 when low
        // (the kernel refuses the low path past I32_BLOCK)
        Node::DwConv2d { .. } => red,
        _ => match node.backend() {
            Some(Backend::Blocked) => red.min(KC),
            _ => red.min(kernels::I32_BLOCK),
        },
    }
}

fn check_overflow(prog: &Program, errs: &mut Vec<VerifyError>) {
    let nb = prog.bufs.len();
    // per-buffer code interval, seeded by quantizing producers
    let mut range: Vec<Option<(i64, i64)>> = vec![None; nb];
    // per-buffer f32 magnitude bound and i64-accumulator magnitude
    // bound (as f64, so a corrupt scale can only saturate to inf,
    // never wrap) — the float continuation of `range`
    let mut fmag: Vec<Option<f64>> = vec![None; nb];
    let mut accmag: Vec<Option<f64>> = vec![None; nb];
    for (i, node) in prog.nodes.iter().enumerate() {
        // propagate the producing grid's range to the written buffer
        match node {
            Node::Quantize { grid, .. }
            | Node::EpilogueQuantize { grid, .. }
            | Node::RequantQuantize { grid, .. } => {
                if let Some(r) = range.get_mut(node.writes()) {
                    *r = Some((grid.code_lo(), grid.code_hi()));
                }
            }
            _ => {}
        }
        propagate_f32(prog, i, node, &range, &mut fmag,
                      &mut accmag, errs);
        let (int_kernel, op) = match node {
            Node::Gemm { int: true, .. } => (true, node.op_name()),
            Node::Conv2d { int: true, .. } => (true, node.op_name()),
            Node::DwConv2d { .. } => (true, node.op_name()),
            _ => (false, ""),
        };
        if !int_kernel {
            continue;
        }
        let Some(li) = node.layer() else { continue };
        let Some(l) = prog.plan.layers.get(li) else { continue };
        let Some(packed) = l.packed.as_ref() else {
            errs.push(VerifyError::Malformed {
                detail: format!(
                    "node {i} ({op}) runs the integer path on layer \
                     {li} without packed rows"
                ),
            });
            continue;
        };
        // weight range from the packed width's code range
        let (wlo, whi) = code_range(packed.bits, packed.signed);
        let max_w = interval_mag(wlo, whi);
        // activation range from the *propagated* producer interval —
        // the declared ActSpec width only selects the dispatch path
        let Some(src) = node.reads() else { continue };
        let Some(Some((alo, ahi))) = range.get(src) else {
            errs.push(VerifyError::MissingRange { node: i, buf: src });
            continue;
        };
        let max_a = interval_mag(*alo, *ahi);
        // the dispatch decision mirrors the kernels: declared widths
        // pick the path, the derived ranges must prove it safe
        let a_bits = match l.act {
            ActSpec::Int { bits, .. } => bits,
            ActSpec::F32 => {
                errs.push(VerifyError::Malformed {
                    detail: format!(
                        "node {i} ({op}) on layer {li} has no integer \
                         activation grid"
                    ),
                });
                continue;
            }
        };
        let red = match node {
            Node::DwConv2d { .. } => l
                .spatial
                .as_ref()
                .map(|sp| sp.k * sp.k)
                .unwrap_or(l.in_dim),
            _ => l.in_dim,
        };
        // the i64 total a downstream requantize will scale: w*a over
        // the full reduction, independent of the partial-sum path
        if let Some(r) = accmag.get_mut(node.writes()) {
            *r = Some(max_w as f64 * max_a as f64 * red as f64);
        }
        let mut low = kernels::low_bit_pair(packed.bits, a_bits);
        if matches!(node, Node::DwConv2d { .. }) {
            low = low && red <= kernels::I32_BLOCK;
        }
        if low {
            let blk = block_len(node, red);
            let bound =
                max_w as i128 * max_a as i128 * blk as i128;
            if bound > i32::MAX as i128 {
                errs.push(VerifyError::AccumulatorOverflow {
                    node: i,
                    op,
                    path: AccPath::BlockedI32,
                    max_w,
                    max_a,
                    block_len: blk,
                    bound,
                    limit: i32::MAX as i128,
                });
                continue;
            }
            // the GEMM/conv low path can reach the AVX2 vpmaddwd
            // form: operands are packed to i16 (saturating) and each
            // pair sum w0*a0 + w1*a1 must fit one i32 lane step
            if !matches!(node, Node::DwConv2d { .. }) {
                let lim = i16::MAX as i64;
                if max_w > lim || max_a > lim {
                    errs.push(VerifyError::PackSaturation {
                        node: i,
                        max_code: max_w.max(max_a),
                        limit: lim,
                    });
                    continue;
                }
                if 2 * max_w as i128 * max_a as i128
                    > i32::MAX as i128
                {
                    errs.push(VerifyError::PairSumOverflow {
                        node: i,
                        max_w,
                        max_a,
                    });
                }
            }
        } else {
            // wide path: the whole reduction accumulates in i64
            let bound =
                max_w as i128 * max_a as i128 * red as i128;
            if bound > i64::MAX as i128 {
                errs.push(VerifyError::AccumulatorOverflow {
                    node: i,
                    op,
                    path: AccPath::WideI64,
                    max_w,
                    max_a,
                    block_len: red,
                    bound,
                    limit: i64::MAX as i128,
                });
            }
        }
    }
}

/// Largest-magnitude bias entry of a layer (`0` when bias-less);
/// NaN-propagating so a poisoned bias fails the finiteness check
/// instead of vanishing under IEEE `max`.
fn bias_mag(l: &PlanLayer) -> f64 {
    let mut m = 0.0f64;
    if let Some(b) = &l.bias {
        for &v in b {
            let a = (v as f64).abs();
            if a.is_nan() {
                return f64::NAN;
            }
            m = m.max(a);
        }
    }
    m
}

/// Largest-magnitude entry of a layer's simulated-quant f32 rows,
/// NaN-propagating like [`bias_mag`].
fn rows_mag(rows: &[f32]) -> f64 {
    let mut m = 0.0f64;
    for &v in rows {
        let a = (v as f64).abs();
        if a.is_nan() {
            return f64::NAN;
        }
        m = m.max(a);
    }
    m
}

/// Extend the integer code intervals through the program's f32 edges.
/// A per-buffer worst-case magnitude is pushed through dequantize
/// steps, f32-path kernels, requantize-scale products, and epilogue
/// bias adds; any edge whose bound is non-finite or past `f32::MAX`
/// is rejected (the interpreter would materialize `inf`). Pool and
/// adapter nodes never increase magnitude, so they pass their source
/// bound through; the program input itself is unbounded (`None`),
/// which leaves edges unchecked until the first quantize pins a
/// range — the analysis only ever *under*-reports, never cries wolf.
fn propagate_f32(
    prog: &Program,
    i: usize,
    node: &Node,
    range: &[Option<(i64, i64)>],
    fmag: &mut [Option<f64>],
    accmag: &mut [Option<f64>],
    errs: &mut Vec<VerifyError>,
) {
    let layer = |li: usize| prog.plan.layers.get(li);
    let check = |errs: &mut Vec<VerifyError>, bound: f64,
                 op: &'static str| {
        if !bound.is_finite() || bound > f32::MAX as f64 {
            errs.push(VerifyError::F32RangeOverflow {
                node: i,
                op,
                bound,
            });
        }
        bound
    };
    match node {
        Node::Dequantize { src, dst, step } => {
            let Some((lo, hi)) = range.get(*src).copied().flatten()
            else {
                return;
            };
            let b = check(
                errs,
                (*step as f64).abs() * interval_mag(lo, hi) as f64,
                node.op_name(),
            );
            if let Some(slot) = fmag.get_mut(*dst) {
                *slot = Some(b);
            }
        }
        Node::MaxPool2 { src, dst, .. }
        | Node::GlobalAvgPool { src, dst, .. }
        | Node::AdaptSpatial { src, dst, .. }
        | Node::AdaptFeatures { src, dst, .. } => {
            let m = fmag.get(*src).copied().flatten();
            if let Some(slot) = fmag.get_mut(*dst) {
                *slot = m;
            }
        }
        Node::Gemm { layer: li, src, dst, int: false, .. }
        | Node::Conv2d { layer: li, src, dst, int: false, .. } => {
            let Some(l) = layer(*li) else { return };
            let Some(m) = fmag.get(*src).copied().flatten() else {
                return;
            };
            let b = check(
                errs,
                m * rows_mag(&l.f32_rows) * l.in_dim as f64,
                node.op_name(),
            );
            if let Some(slot) = fmag.get_mut(*dst) {
                *slot = Some(b);
            }
        }
        Node::Requant { layer: li, src, dst, scale, .. } => {
            let Some(l) = layer(*li) else { return };
            let Some(a) = accmag.get(*src).copied().flatten() else {
                return;
            };
            let b = check(
                errs,
                a * scale.abs() + bias_mag(l),
                node.op_name(),
            );
            if let Some(slot) = fmag.get_mut(*dst) {
                *slot = Some(b);
            }
        }
        Node::RequantQuantize { layer: li, src, scale, .. } => {
            let Some(l) = layer(*li) else { return };
            let Some(a) = accmag.get(*src).copied().flatten() else {
                return;
            };
            // dst carries codes (its range is seeded from the grid);
            // the bound guards the f32 intermediate inside the fusion
            check(errs, a * scale.abs() + bias_mag(l), node.op_name());
        }
        Node::Epilogue { layer: li, src, dst, .. } => {
            let Some(l) = layer(*li) else { return };
            let Some(m) = fmag.get(*src).copied().flatten() else {
                return;
            };
            let b = check(errs, m + bias_mag(l), node.op_name());
            if let Some(slot) = fmag.get_mut(*dst) {
                *slot = Some(b);
            }
        }
        Node::EpilogueQuantize { layer: li, src, .. } => {
            let Some(l) = layer(*li) else { return };
            let Some(m) = fmag.get(*src).copied().flatten() else {
                return;
            };
            check(errs, m + bias_mag(l), node.op_name());
        }
        Node::BiasFill { layer: li, dst, .. } => {
            let Some(l) = layer(*li) else { return };
            let b = check(errs, bias_mag(l), node.op_name());
            if let Some(slot) = fmag.get_mut(*dst) {
                *slot = Some(b);
            }
        }
        _ => {}
    }
}

// ------------------------------------------------------------------
// Adapter geometry vs the plan manifest
// ------------------------------------------------------------------

/// Check `AdaptSpatial`/`AdaptFeatures` nodes against the plan
/// manifest. An adapter is only ever materialized from its owning
/// layer's pre-op, so its tuple must match the manifest's, and when
/// the layer is spatial the adapter must feed exactly the spatial
/// input geometry — a transposed tuple has the right flat length but
/// a silently wrong NHWC interpretation, which no downstream shape
/// check can see.
fn check_adapters(prog: &Program, errs: &mut Vec<VerifyError>) {
    for (i, node) in prog.nodes.iter().enumerate() {
        let Some(&li) = prog.node_layer.get(i) else { continue };
        let Some(l) = prog.plan.layers.get(li) else { continue };
        match node {
            Node::AdaptSpatial { from, to, .. } => {
                match &l.pre {
                    PreOp::AdaptSpatial { from: mf, to: mt } => {
                        if from != mf || to != mt {
                            errs.push(VerifyError::AdapterGeometry {
                                node: i,
                                detail: format!(
                                    "AdaptSpatial {from:?}->{to:?} \
                                     disagrees with layer {li}'s \
                                     manifest pre-op {mf:?}->{mt:?}"
                                ),
                            });
                        }
                    }
                    other => {
                        errs.push(VerifyError::AdapterGeometry {
                            node: i,
                            detail: format!(
                                "AdaptSpatial node on layer {li}, \
                                 whose manifest pre-op is {other:?}"
                            ),
                        });
                    }
                }
                if let Some(sp) = &l.spatial {
                    let want = (sp.in_h, sp.in_w, sp.in_c);
                    if *to != want {
                        errs.push(VerifyError::AdapterGeometry {
                            node: i,
                            detail: format!(
                                "AdaptSpatial feeds layer {li} as \
                                 {to:?} but its spatial plan reads \
                                 {want:?}"
                            ),
                        });
                    }
                }
            }
            Node::AdaptFeatures { want, .. } => {
                let need = l.input_len();
                if *want != need {
                    errs.push(VerifyError::AdapterGeometry {
                        node: i,
                        detail: format!(
                            "AdaptFeatures width {want} disagrees \
                             with layer {li}'s manifest input width \
                             {need}"
                        ),
                    });
                }
            }
            _ => {}
        }
    }
}

