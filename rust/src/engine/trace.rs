//! Observability primitives for the serving stack: a lock-free span
//! recorder with Chrome trace-event export, HDR-style log-linear
//! histograms for latency/queue-depth percentiles, and per-node kernel
//! timers keyed by (op, backend, bit-width).
//!
//! Everything here is dependency-free and cheap to *not* use: when no
//! [`TraceRecorder`] is attached the serve path takes one branch per
//! batch and the interpreter hot loop is byte-identical to the
//! uninstrumented build (`Program::execute` is untouched; the profiled
//! variant is a separate method).
//!
//! # Ring-buffer layout
//!
//! The recorder is a fixed power-of-two array of slots. A writer claims
//! a slot with one `fetch_add(1, Relaxed)` on the cursor and masks the
//! index — no CAS loop, no lock, writers never wait on each other. Slot
//! fields are plain relaxed atomics; the `seq` field (claim index + 1,
//! so 0 means "never written") is stored last with `Release`. Readers
//! only run after the pool has quiesced (export happens post-shutdown),
//! so a torn slot on wrap is at worst one bogus event in a diagnostic
//! artifact, never UB — the whole recorder is safe Rust. When the
//! buffer wraps, the oldest events are overwritten; [`TraceRecorder::
//! dropped`] reports how many.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};

// -------------------------------------------------------------------
// Span taxonomy
// -------------------------------------------------------------------

/// Typed span phases recorded along a request's path through the pool,
/// plus per-node kernel slices from the instrumented interpreter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Submitter-side: backpressure wait + queue push (args: req, depth).
    Enqueue = 0,
    /// Per request: queue push until its batch was closed (args: req).
    QueueWait = 1,
    /// Per batch: first pop until the deadline window closed (args: batch).
    BatchForm = 2,
    /// Per batch: the `run_batch` call (args: batch).
    Infer = 3,
    /// Per request: response channel send after inference (args: req).
    Respond = 4,
    /// Per IR node execution inside `Infer` (args: node/op/backend/bits).
    Node = 5,
}

impl SpanKind {
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BatchForm => "batch_form",
            SpanKind::Infer => "infer",
            SpanKind::Respond => "respond",
            SpanKind::Node => "node",
        }
    }

    fn from_u64(v: u64) -> SpanKind {
        match v {
            0 => SpanKind::Enqueue,
            1 => SpanKind::QueueWait,
            2 => SpanKind::BatchForm,
            3 => SpanKind::Infer,
            4 => SpanKind::Respond,
            _ => SpanKind::Node,
        }
    }
}

// -------------------------------------------------------------------
// Recorder
// -------------------------------------------------------------------

/// Static attribution for one IR node, registered once per program so
/// node spans can carry (op, backend, bit-width) without any per-event
/// allocation: the event stores only a table index.
#[derive(Debug, Clone)]
pub struct NodeMeta {
    pub op: &'static str,
    pub backend: &'static str,
    pub w_bits: u32,
    pub a_bits: u32,
    /// Pass-stable node id (survives elision/fusion rewrites).
    pub node_id: usize,
    pub model: String,
}

#[derive(Default)]
struct Slot {
    /// Claim index + 1; 0 = never written. Stored last (Release).
    seq: AtomicU64,
    kind: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    tid: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

/// One decoded event, in recorder-epoch nanoseconds.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub kind: SpanKind,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub tid: u64,
    /// Request id (request spans) or node-meta table index (node spans).
    pub a: u64,
    /// Batch size / queue depth, span-kind dependent.
    pub b: u64,
}

/// Default ring capacity: 64K events (~3.5 MB of slots).
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// Lock-free bounded span recorder. Clone the `Arc` freely; recording
/// is `&self` and never blocks.
pub struct TraceRecorder {
    epoch: Instant,
    cursor: AtomicU64,
    mask: usize,
    slots: Vec<Slot>,
    node_meta: Mutex<Vec<NodeMeta>>,
    request_ids: AtomicU64,
}

impl TraceRecorder {
    pub fn new() -> Arc<TraceRecorder> {
        TraceRecorder::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    /// `capacity` is rounded up to the next power of two (min 64).
    pub fn with_capacity(capacity: usize) -> Arc<TraceRecorder> {
        let cap = capacity.max(64).next_power_of_two();
        let slots = (0..cap).map(|_| Slot::default()).collect();
        Arc::new(TraceRecorder {
            epoch: Instant::now(),
            cursor: AtomicU64::new(0),
            mask: cap - 1,
            slots,
            node_meta: Mutex::new(Vec::new()),
            request_ids: AtomicU64::new(0),
        })
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Nanoseconds from the recorder epoch to `t` (0 if `t` precedes it).
    pub fn since(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Allocate a fresh request id (monotonic, starts at 1).
    pub fn next_request_id(&self) -> u64 {
        self.request_ids.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record one span. Lock-free: one fetch_add plus six relaxed
    /// stores; on wrap the oldest slot is silently overwritten.
    pub fn record(&self, kind: SpanKind, start_ns: u64, dur_ns: u64,
                  tid: u64, a: u64, b: u64) {
        let claim = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[claim as usize & self.mask];
        slot.kind.store(kind as u64, Ordering::Relaxed);
        slot.start_ns.store(start_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.tid.store(tid, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(claim + 1, Ordering::Release);
    }

    /// Register a program's node attribution table; returns the base
    /// offset to add to a node index when recording [`SpanKind::Node`].
    pub fn register_nodes(&self, metas: Vec<NodeMeta>) -> u64 {
        let mut table = self.node_meta.lock().unwrap();
        let base = table.len() as u64;
        table.extend(metas);
        base
    }

    /// Events recorded so far but overwritten by ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.cursor
            .load(Ordering::Relaxed)
            .saturating_sub(self.capacity() as u64)
    }

    /// Snapshot of every populated slot, sorted by start time. Meant
    /// to run after the recorded activity has quiesced.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for slot in &self.slots {
            if slot.seq.load(Ordering::Acquire) == 0 {
                continue;
            }
            out.push(TraceEvent {
                kind: SpanKind::from_u64(slot.kind.load(Ordering::Relaxed)),
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
                tid: slot.tid.load(Ordering::Relaxed),
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            });
        }
        out.sort_by_key(|e| (e.start_ns, e.tid));
        out
    }

    /// Serialize as a Chrome trace-event JSON array (`ph: "X"` complete
    /// events, microsecond timestamps) loadable by chrome://tracing and
    /// Perfetto. Node spans carry (op, backend, w_bits, a_bits, model);
    /// request spans carry the request id.
    pub fn chrome_trace(&self) -> Json {
        let metas = self.node_meta.lock().unwrap();
        let events = self.events();
        let mut arr = Vec::with_capacity(events.len());
        for e in &events {
            let (name, cat, args) = match e.kind {
                SpanKind::Node => match metas.get(e.a as usize) {
                    Some(m) => (m.op, "kernel", obj(vec![
                        ("node", num(m.node_id as f64)),
                        ("op", s(m.op)),
                        ("backend", s(m.backend)),
                        ("w_bits", num(m.w_bits as f64)),
                        ("a_bits", num(m.a_bits as f64)),
                        ("model", s(&m.model)),
                        ("batch", num(e.b as f64)),
                    ])),
                    // meta table raced a wrapped slot: keep the event,
                    // degrade the attribution
                    None => ("node", "kernel",
                             obj(vec![("node", num(e.a as f64))])),
                },
                SpanKind::Enqueue => (e.kind.label(), "serve", obj(vec![
                    ("req", num(e.a as f64)),
                    ("depth", num(e.b as f64)),
                ])),
                SpanKind::QueueWait | SpanKind::Respond => {
                    (e.kind.label(), "serve",
                     obj(vec![("req", num(e.a as f64))]))
                }
                SpanKind::BatchForm | SpanKind::Infer => {
                    (e.kind.label(), "serve",
                     obj(vec![("batch", num(e.b as f64))]))
                }
            };
            arr.push(obj(vec![
                ("name", s(name)),
                ("cat", s(cat)),
                ("ph", s("X")),
                ("ts", num(e.start_ns as f64 / 1e3)),
                ("dur", num(e.dur_ns as f64 / 1e3)),
                ("pid", num(1.0)),
                ("tid", num(e.tid as f64)),
                ("args", args),
            ]));
        }
        Json::Arr(arr)
    }
}

// -------------------------------------------------------------------
// Log-linear histogram
// -------------------------------------------------------------------

/// Sub-bucket resolution: 2^6 = 64 linear sub-buckets per octave, so
/// bucket width / bucket low ≤ 1/64 and the midpoint representative is
/// within 1/128 ≈ 0.78% of any value in the bucket — the documented
/// "< 1% relative error" bound. Values below 64 are exact.
const SUB_BITS: u32 = 6;
const SUB: usize = 1 << SUB_BITS;

/// HDR-style log-linear histogram over `u64` values (we record
/// nanoseconds and queue depths). Counts are exact; values are bucketed
/// with ≤ ~0.78% relative error. Merging is elementwise bucket addition
/// — exact, associative, and commutative — so per-worker and per-model
/// histograms aggregate without resampling (unlike the old reservoir
/// merge, which truncated to the slowest model's sample rate).
///
/// Buckets grow lazily with the largest value seen (max 3776 for the
/// full u64 range, ~30 KB), so cloning a snapshot is O(octaves seen),
/// not O(sample count) like the reservoir it replaces.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let e = 63 - v.leading_zeros();
    let sub = ((v >> (e - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    ((e - SUB_BITS) as usize + 1) * SUB + sub
}

/// Midpoint of the bucket's value range (exact for index < 64).
fn bucket_midpoint(index: usize) -> u64 {
    if index < SUB {
        return index as u64;
    }
    let e = (index / SUB + SUB_BITS as usize - 1) as u32;
    let sub = (index % SUB) as u64;
    let low = (1u64 << e) + (sub << (e - SUB_BITS));
    low + (1u64 << (e - SUB_BITS)) / 2
}

impl Histogram {
    pub fn record(&mut self, v: u64) {
        let idx = bucket_index(v);
        if idx >= self.buckets.len() {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Elementwise bucket addition: exact and associative.
    pub fn merge(&mut self, other: &Histogram) {
        if other.buckets.len() > self.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (dst, src) in self.buckets.iter_mut().zip(&other.buckets) {
            *dst += src;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank percentile (`q` in [0, 1]) as the bucket midpoint
    /// of the bucket holding that rank, clamped to the observed max.
    /// Within ~0.78% of the exact nearest-rank value; 0 when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank =
            ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_midpoint(idx).min(self.max);
            }
        }
        self.max
    }
}

// -------------------------------------------------------------------
// Kernel profiling
// -------------------------------------------------------------------

/// Aggregation key for kernel timings: which op, on which backend, at
/// which weight/activation bit width. `Ord` so profiles live in
/// deterministic `BTreeMap`s.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct KernelKey {
    pub op: &'static str,
    pub backend: &'static str,
    pub w_bits: u32,
    pub a_bits: u32,
}

/// Monotonic per-node (or per-key, after aggregation) timing counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NodeTimer {
    pub calls: u64,
    pub total_ns: u64,
    pub max_ns: u64,
}

impl NodeTimer {
    #[inline]
    pub fn observe(&mut self, ns: u64) {
        self.calls += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &NodeTimer) {
        self.calls += other.calls;
        self.total_ns = self.total_ns.saturating_add(other.total_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Serialize aggregated kernel rows (sorted by descending total time)
/// as the JSON array used by `stats_json` and the bench artifacts'
/// per-node breakdown column.
pub fn kernel_rows_json(rows: &[(KernelKey, NodeTimer)]) -> Json {
    let total: u64 = rows.iter().map(|(_, t)| t.total_ns).sum();
    Json::Arr(
        rows.iter()
            .map(|(k, t)| {
                let share = if total > 0 {
                    t.total_ns as f64 / total as f64
                } else {
                    0.0
                };
                obj(vec![
                    ("op", s(k.op)),
                    ("backend", s(k.backend)),
                    ("w_bits", num(k.w_bits as f64)),
                    ("a_bits", num(k.a_bits as f64)),
                    ("calls", num(t.calls as f64)),
                    ("total_ns", num(t.total_ns as f64)),
                    ("max_ns", num(t.max_ns as f64)),
                    ("share", num(share)),
                ])
            })
            .collect(),
    )
}

/// Sort a kernel profile map's rows by descending total time (ties by
/// key for determinism).
pub fn sorted_kernel_rows(
    map: &std::collections::BTreeMap<KernelKey, NodeTimer>,
) -> Vec<(KernelKey, NodeTimer)> {
    let mut rows: Vec<(KernelKey, NodeTimer)> =
        map.iter().map(|(k, t)| (*k, *t)).collect();
    rows.sort_by(|a, b| b.1.total_ns.cmp(&a.1.total_ns)
        .then(a.0.cmp(&b.0)));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_contiguous_at_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(63), 63);
        assert_eq!(bucket_index(64), 64);
        assert_eq!(bucket_index(127), 127);
        assert_eq!(bucket_index(128), 128);
        let mut prev = 0usize;
        for v in [1u64, 63, 64, 65, 127, 128, 1000, 1 << 20, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx >= prev, "{v}");
            prev = idx;
        }
        // full-range index stays small: lazy buckets are bounded
        assert!(bucket_index(u64::MAX) < 3776);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::default();
        for v in 0..64u64 {
            h.record(v);
        }
        for (q, want) in [(0.5, 31), (1.0, 63)] {
            assert_eq!(h.percentile(q), want);
        }
        assert_eq!(h.count(), 64);
    }

    #[test]
    fn midpoint_stays_within_bound() {
        for v in [64u64, 100, 1_000, 123_456, 9_999_999, 1 << 40] {
            let rep = bucket_midpoint(bucket_index(v));
            let err = (rep as f64 - v as f64).abs() / v as f64;
            assert!(err <= 1.0 / 128.0 + 1e-12, "{v}: rep {rep}");
        }
    }

    #[test]
    fn recorder_assigns_monotonic_request_ids() {
        let rec = TraceRecorder::with_capacity(64);
        assert_eq!(rec.next_request_id(), 1);
        assert_eq!(rec.next_request_id(), 2);
    }

    #[test]
    fn recorder_wraps_and_reports_drops() {
        let rec = TraceRecorder::with_capacity(64);
        for i in 0..100u64 {
            rec.record(SpanKind::Infer, i, 1, 0, 0, 4);
        }
        let events = rec.events();
        assert_eq!(events.len(), 64);
        assert_eq!(rec.dropped(), 36);
        // survivors are the newest claims
        assert!(events.iter().all(|e| e.start_ns >= 36));
    }

    #[test]
    fn chrome_trace_roundtrips_through_parser() {
        let rec = TraceRecorder::with_capacity(64);
        let base = rec.register_nodes(vec![NodeMeta {
            op: "gemm.simd",
            backend: "simd",
            w_bits: 4,
            a_bits: 8,
            node_id: 7,
            model: "m".into(),
        }]);
        rec.record(SpanKind::Enqueue, 10, 5, 0, 1, 2);
        rec.record(SpanKind::Node, 20, 3, 1, base, 8);
        let j = rec.chrome_trace();
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(),
                   "enqueue");
        assert_eq!(arr[1].get("name").unwrap().as_str().unwrap(),
                   "gemm.simd");
        let args = arr[1].get("args").unwrap();
        assert_eq!(args.get("w_bits").unwrap().as_usize().unwrap(), 4);
        assert_eq!(args.get("node").unwrap().as_usize().unwrap(), 7);
        assert_eq!(args.get("backend").unwrap().as_str().unwrap(),
                   "simd");
    }
}
