//! Typed execution-graph IR: the compiled form of an [`EnginePlan`].
//!
//! A [`Program`] is a flat, topologically ordered list of [`Node`]s
//! over virtual buffers ([`BufSpec`]) whose arena slots were assigned
//! ahead of time by the pass pipeline (`engine::passes` — graph build,
//! pruned-channel elision, pre-op materialization, quantize/requant
//! fusion, then liveness + arena assignment in `engine::arena`).
//! Executing a program is a single interpreter loop: each node reads
//! and writes pre-assigned slices of three typed scratch arenas (f32
//! activations, i32 activation codes, i64 accumulators) sized once per
//! batch — no per-request `Vec` allocation and no shape re-derivation
//! on the hot path.
//!
//! Both execution paths run the same IR: `Program::compile(plan,
//! true)` emits integer kernels (`Quantize` -> `Gemm`/`Conv2d`/
//! `DwConv2d` -> `Requant`) where a layer has packed weights and an
//! integer activation grid, while `compile(plan, false)` emits the
//! simulated-quant reference (`Quantize` -> `Dequantize` -> f32 kernel
//! -> `Epilogue`) — so int/f32 parity is structural, not two hand-kept
//! code paths. Buffer offsets are recorded in per-sample element
//! units; a batch of `n` samples addresses `offset * n ..
//! (offset + len) * n`, so one liveness solution serves every batch
//! size.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::kernels::Backend;
use super::pack::PanelMatrix;
use super::trace::{KernelKey, NodeMeta, NodeTimer, SpanKind,
                   TraceRecorder};
use super::{adapt_features_into, adapt_spatial_into, kernels,
            EnginePlan};
use crate::quant::grid::CodeGrid;

/// Element type of a virtual buffer — selects its backing arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I64,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::I64 => "i64",
        }
    }
}

/// Virtual buffer id (index into [`Program::bufs`]).
pub type BufId = usize;

/// One virtual buffer: per-sample element count plus the arena slot
/// the assignment pass picked. A batch of `n` samples occupies
/// `offset * n .. (offset + len) * n` of the `dtype` arena. `offset`
/// is `None` for buffers the passes orphaned (e.g. the intermediate
/// f32 activations a fused requantize+quantize eliminated).
#[derive(Debug, Clone)]
pub struct BufSpec {
    pub dtype: DType,
    /// Elements per sample.
    pub len: usize,
    /// Per-sample element offset into the dtype's arena.
    pub offset: Option<usize>,
}

/// One resolved inter-layer transform inside a [`Node::Pre`]
/// placeholder — the unit the pre-op materialization pass expands
/// into concrete nodes.
#[derive(Debug, Clone, PartialEq)]
pub enum PreStep {
    MaxPool2 { h: usize, w: usize, c: usize },
    GlobalAvgPool { h: usize, w: usize, c: usize },
    AdaptSpatial { from: (usize, usize, usize), to: (usize, usize, usize) },
    AdaptFeatures { want: usize },
}

impl PreStep {
    /// Per-sample output width of this step.
    pub fn out_len(&self) -> usize {
        match self {
            PreStep::MaxPool2 { h, w, c } => (h / 2) * (w / 2) * c,
            PreStep::GlobalAvgPool { c, .. } => *c,
            PreStep::AdaptSpatial { to, .. } => to.0 * to.1 * to.2,
            PreStep::AdaptFeatures { want } => *want,
        }
    }
}

/// One executable operation over arena buffers. Kernel nodes index
/// the plan's layer table for weights/bias/geometry; everything else
/// the interpreter needs (grids, requantize scales, shapes) is folded
/// into the node at compile time.
#[derive(Debug, Clone)]
pub enum Node {
    /// Transient macro-node emitted by the graph-build pass and fully
    /// expanded by the pre-op materialization pass; never survives
    /// `Program::compile`.
    Pre { layer: usize, src: BufId, dst: BufId, steps: Vec<PreStep> },
    /// 2x2/stride-2 max pool over an NHWC map (floor semantics: an odd
    /// trailing row/column is dropped, matching the train graph).
    MaxPool2 { src: BufId, dst: BufId, h: usize, w: usize, c: usize },
    /// Per-channel mean over all pixels (classifier heads).
    GlobalAvgPool { src: BufId, dst: BufId, h: usize, w: usize, c: usize },
    /// Per-axis pool/replicate bridge between NHWC maps (ResNet
    /// downsample branches).
    AdaptSpatial {
        src: BufId,
        dst: BufId,
        from: (usize, usize, usize),
        to: (usize, usize, usize),
    },
    /// Legacy flat pool/replicate width adapter (pre-spatial manifests
    /// and residual width drift only).
    AdaptFeatures { src: BufId, dst: BufId, want: usize },
    /// f32 activations -> integer grid codes.
    Quantize { src: BufId, dst: BufId, grid: CodeGrid },
    /// Codes -> f32 (`step * code`) — the simulated-quant activations
    /// the reference path consumes.
    Dequantize { src: BufId, dst: BufId, step: f32 },
    /// Dense GEMM over the layer's kept rows. `int` selects packed
    /// integer codes (i64 accumulators) vs simulated-quant f32 rows;
    /// `backend` is the pass-assigned kernel implementation (always
    /// [`Backend::Scalar`] on the f32 path — only the integer kernels
    /// have SIMD forms).
    Gemm { layer: usize, src: BufId, dst: BufId, int: bool,
           backend: Backend },
    /// Spatial im2col convolution over kept rows (same `int` and
    /// `backend` split).
    Conv2d { layer: usize, src: BufId, dst: BufId, int: bool,
             backend: Backend },
    /// Depthwise integer fast path (`groups == in_c`); the f32
    /// reference runs depthwise layers through [`Node::Conv2d`].
    DwConv2d { layer: usize, src: BufId, dst: BufId, backend: Backend },
    /// i64 accumulators -> dense f32 channels: bias broadcast,
    /// kept-row scatter through the folded `s_w * s_a` requantize
    /// scale, optional ReLU. Pruned channel positions carry bias only.
    Requant { layer: usize, src: BufId, dst: BufId, scale: f64, relu: bool },
    /// f32 accumulators -> dense f32 channels (bias + scatter + ReLU,
    /// no scale) — the reference-path epilogue.
    Epilogue { layer: usize, src: BufId, dst: BufId, relu: bool },
    /// Fused [`Node::Epilogue`] + the next integer layer's
    /// [`Node::Quantize`] on mixed f32/int chains: f32 accumulators go
    /// straight to the integer consumer's activation codes without
    /// materializing the dense f32 buffer between them.
    EpilogueQuantize {
        layer: usize,
        src: BufId,
        dst: BufId,
        relu: bool,
        grid: CodeGrid,
    },
    /// Fused [`Node::Requant`] + the next integer layer's
    /// [`Node::Quantize`]: accumulators go straight to the consumer's
    /// activation codes without materializing the f32 buffer between
    /// two adjacent integer layers.
    RequantQuantize {
        layer: usize,
        src: BufId,
        dst: BufId,
        scale: f64,
        relu: bool,
        grid: CodeGrid,
    },
    /// Fully-pruned layer (pruned-channel elision): the output is its
    /// (ReLU'd) bias broadcast over every pixel; no kernel runs.
    BiasFill { layer: usize, dst: BufId, relu: bool },
}

impl Node {
    /// The buffer this node reads, if any.
    pub fn reads(&self) -> Option<BufId> {
        match self {
            Node::Pre { src, .. }
            | Node::MaxPool2 { src, .. }
            | Node::GlobalAvgPool { src, .. }
            | Node::AdaptSpatial { src, .. }
            | Node::AdaptFeatures { src, .. }
            | Node::Quantize { src, .. }
            | Node::Dequantize { src, .. }
            | Node::Gemm { src, .. }
            | Node::Conv2d { src, .. }
            | Node::DwConv2d { src, .. }
            | Node::Requant { src, .. }
            | Node::Epilogue { src, .. }
            | Node::EpilogueQuantize { src, .. }
            | Node::RequantQuantize { src, .. } => Some(*src),
            Node::BiasFill { .. } => None,
        }
    }

    /// The buffer this node writes.
    pub fn writes(&self) -> BufId {
        match self {
            Node::Pre { dst, .. }
            | Node::MaxPool2 { dst, .. }
            | Node::GlobalAvgPool { dst, .. }
            | Node::AdaptSpatial { dst, .. }
            | Node::AdaptFeatures { dst, .. }
            | Node::Quantize { dst, .. }
            | Node::Dequantize { dst, .. }
            | Node::Gemm { dst, .. }
            | Node::Conv2d { dst, .. }
            | Node::DwConv2d { dst, .. }
            | Node::Requant { dst, .. }
            | Node::Epilogue { dst, .. }
            | Node::EpilogueQuantize { dst, .. }
            | Node::RequantQuantize { dst, .. }
            | Node::BiasFill { dst, .. } => *dst,
        }
    }

    /// Layer index for kernel/epilogue nodes.
    pub fn layer(&self) -> Option<usize> {
        match self {
            Node::Pre { layer, .. }
            | Node::Gemm { layer, .. }
            | Node::Conv2d { layer, .. }
            | Node::DwConv2d { layer, .. }
            | Node::Requant { layer, .. }
            | Node::Epilogue { layer, .. }
            | Node::EpilogueQuantize { layer, .. }
            | Node::RequantQuantize { layer, .. }
            | Node::BiasFill { layer, .. } => Some(*layer),
            _ => None,
        }
    }

    /// Display name; integer kernel nodes carry their backend as a
    /// suffix (`gemm.simd`), which is what `bbits plan --dump-ir`
    /// prints and the CI backend smoke greps for.
    pub fn op_name(&self) -> &'static str {
        match self {
            Node::Pre { .. } => "pre",
            Node::MaxPool2 { .. } => "maxpool2",
            Node::GlobalAvgPool { .. } => "gap",
            Node::AdaptSpatial { .. } => "adapt_spatial",
            Node::AdaptFeatures { .. } => "adapt_features",
            Node::Quantize { .. } => "quantize",
            Node::Dequantize { .. } => "dequantize",
            Node::Gemm { int: false, .. } => "gemm.f32",
            Node::Gemm { backend: Backend::Simd, .. } => "gemm.simd",
            Node::Gemm { backend: Backend::Blocked, .. } => {
                "gemm.blocked"
            }
            Node::Gemm { .. } => "gemm",
            Node::Conv2d { int: false, .. } => "conv2d.f32",
            Node::Conv2d { backend: Backend::Simd, .. } => {
                "conv2d.simd"
            }
            Node::Conv2d { backend: Backend::Blocked, .. } => {
                "conv2d.blocked"
            }
            Node::Conv2d { .. } => "conv2d",
            Node::DwConv2d { backend: Backend::Simd, .. } => {
                "dwconv2d.simd"
            }
            Node::DwConv2d { backend: Backend::Blocked, .. } => {
                "dwconv2d.blocked"
            }
            Node::DwConv2d { .. } => "dwconv2d",
            Node::Requant { .. } => "requant",
            Node::Epilogue { .. } => "epilogue",
            Node::EpilogueQuantize { .. } => "epilogue_quantize",
            Node::RequantQuantize { .. } => "requant_quantize",
            Node::BiasFill { .. } => "bias_fill",
        }
    }

    /// The pass-assigned kernel backend, for kernel nodes.
    pub fn backend(&self) -> Option<Backend> {
        match self {
            Node::Gemm { backend, .. }
            | Node::Conv2d { backend, .. }
            | Node::DwConv2d { backend, .. } => Some(*backend),
            _ => None,
        }
    }
}

/// Per-engine mutable execution state: the three typed arenas plus
/// the weight-side scratch the kernels need (decoded rows, im2col
/// patches). Reused across batches — buffers only ever grow.
#[derive(Default)]
pub struct ExecState {
    f32a: Vec<f32>,
    i32a: Vec<i32>,
    i64a: Vec<i64>,
    /// Packed-row decode scratch for dense GEMMs (one row).
    row: Vec<i32>,
    /// Whole-layer decoded weight codes for spatial kernels.
    wrows: Vec<i32>,
    /// im2col patch scratch (integer / f32 path).
    patch: Vec<i32>,
    patchf: Vec<f32>,
    /// Dense per-channel staging for the fused requantize+quantize.
    dense: Vec<f32>,
    /// Intra-request shard count for blocked kernel nodes (0 and 1
    /// both mean single-threaded; set via [`ExecState::set_intra_threads`]).
    intra: usize,
}

impl ExecState {
    /// Number of scoped threads blocked kernel nodes shard one
    /// request across. Scalar/SIMD nodes ignore this; blocked nodes
    /// split kept rows / output tiles into disjoint output slices,
    /// which is bit-exact by integer-sum associativity.
    pub fn set_intra_threads(&mut self, n: usize) {
        self.intra = n;
    }
}

/// A compiled, arena-assigned execution graph for one plan and one
/// path (integer or f32 reference). Shares the plan through the `Arc`;
/// all mutable state lives in the caller's [`ExecState`].
#[derive(Debug, Clone)]
pub struct Program {
    pub(crate) plan: Arc<EnginePlan>,
    pub(crate) int_path: bool,
    pub(crate) nodes: Vec<Node>,
    /// Owning layer index per node (dump labeling).
    pub(crate) node_layer: Vec<usize>,
    /// Pass-stable id per node: assigned at graph build and preserved
    /// through elision/materialization/fusion rewrites, so profiler
    /// attribution survives the pass pipeline (a fused node keeps the
    /// id of the requantize it absorbed).
    pub(crate) node_ids: Vec<usize>,
    /// High-water mark of the pass pipeline's id allocator: every
    /// legal node id is `< id_bound`.
    pub(crate) id_bound: usize,
    /// Ids the pipeline allocated but retired before the final node
    /// list (absorbed by fusion, dropped by elision) — recorded at
    /// compile time so `engine::verify` can reject any later
    /// reference to them.
    pub(crate) retired_ids: Vec<usize>,
    /// The resolved backend override this program was compiled under
    /// (`--backend` / `BBITS_BACKEND` / `ServeConfig.backend`), if
    /// any — what licenses non-auto backend choices to the verifier.
    pub(crate) forced_backend: Option<Backend>,
    pub(crate) bufs: Vec<BufSpec>,
    /// Compile-time weight panels for [`Backend::Blocked`] kernel
    /// nodes, keyed by layer index (`None` for layers without one).
    /// Shared via `Arc` so cloning a program never re-packs.
    pub(crate) panels: Vec<Option<Arc<PanelMatrix>>>,
    pub(crate) input: BufId,
    pub(crate) output: BufId,
    /// Arena footprints in per-sample elements.
    pub(crate) f32_len: usize,
    pub(crate) i32_len: usize,
    pub(crate) i64_len: usize,
    /// Max simultaneously-live per-sample bytes (the fragmentation-free
    /// lower bound on `arena_bytes`).
    pub(crate) peak_live: usize,
}

impl Program {
    /// Compile a plan through the ordered pass pipeline (graph build
    /// -> pruned-channel elision -> pre-op materialization ->
    /// quantize/requant fusion -> backend assignment -> liveness +
    /// arena assignment). Kernel backends resolve from the
    /// `BBITS_BACKEND` env override, falling back to per-node auto
    /// selection.
    pub fn compile(plan: Arc<EnginePlan>, int_path: bool) -> Program {
        Self::compile_with_backend(plan, int_path, None)
    }

    /// [`Self::compile`] with every integer kernel node forced onto
    /// one [`Backend`] (`None` keeps the env-then-auto resolution) —
    /// the lever behind `--backend` and the differential test battery.
    pub fn compile_with_backend(plan: Arc<EnginePlan>, int_path: bool,
                                forced: Option<Backend>) -> Program {
        Self::try_compile_with_backend(plan, int_path, forced)
            .unwrap_or_else(|e| {
                panic!("plan failed static verification at compile: {e}")
            })
    }

    /// Fallible compile: the pass pipeline plus (in debug builds) the
    /// automatic `engine::verify` run, surfacing any
    /// [`super::verify::VerifyError`] instead of panicking — what
    /// `bbits plan --verify` and `ServeConfig.verify_plans` call.
    pub fn try_compile_with_backend(
        plan: Arc<EnginePlan>, int_path: bool, forced: Option<Backend>,
    ) -> Result<Program, super::verify::VerifyError> {
        super::passes::compile(plan, int_path, forced)
    }

    /// Run the full static analysis suite on this compiled program
    /// (see `engine::verify`); `Ok(())` or the first defect.
    pub fn verify(&self) -> Result<(), super::verify::VerifyError> {
        super::verify::verify(self)
    }

    /// High-water mark of the pass pipeline's node-id allocator.
    pub fn id_bound(&self) -> usize {
        self.id_bound
    }

    /// Ids the pass pipeline allocated and then retired (fusion /
    /// elision) — never legal in [`Self::node_ids`].
    pub fn retired_node_ids(&self) -> &[usize] {
        &self.retired_ids
    }

    /// Mutable node access for the verifier's mutation battery
    /// (`tests/verify.rs` hand-corrupts compiled programs). Not part
    /// of the serving API.
    #[doc(hidden)]
    pub fn nodes_mut(&mut self) -> &mut [Node] {
        &mut self.nodes
    }

    /// See [`Self::nodes_mut`].
    #[doc(hidden)]
    pub fn bufs_mut(&mut self) -> &mut [BufSpec] {
        &mut self.bufs
    }

    /// See [`Self::nodes_mut`].
    #[doc(hidden)]
    pub fn node_ids_mut(&mut self) -> &mut [usize] {
        &mut self.node_ids
    }

    /// See [`Self::nodes_mut`].
    #[doc(hidden)]
    pub fn panels_mut(&mut self) -> &mut Vec<Option<Arc<PanelMatrix>>> {
        &mut self.panels
    }

    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    pub fn int_path(&self) -> bool {
        self.int_path
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Pass-stable node ids, parallel to [`Self::nodes`].
    pub fn node_ids(&self) -> &[usize] {
        &self.node_ids
    }

    /// Profiler aggregation key for node `i`: (op, backend, weight and
    /// activation bit width of the owning layer). Non-kernel nodes
    /// report backend `"-"`; the f32 reference path reports the
    /// simulated bit widths its grids encode.
    pub fn kernel_key(&self, i: usize) -> KernelKey {
        let layer = &self.plan.layers[self.node_layer[i]];
        KernelKey {
            op: self.nodes[i].op_name(),
            backend: self.nodes[i]
                .backend()
                .map(|b| b.label())
                .unwrap_or("-"),
            w_bits: layer.w_bits,
            a_bits: layer.act.bits(),
        }
    }

    /// Attribution table for [`TraceRecorder::register_nodes`]: one
    /// entry per node, in execution order.
    pub fn node_metas(&self) -> Vec<NodeMeta> {
        (0..self.nodes.len())
            .map(|i| {
                let k = self.kernel_key(i);
                NodeMeta {
                    op: k.op,
                    backend: k.backend,
                    w_bits: k.w_bits,
                    a_bits: k.a_bits,
                    node_id: self.node_ids[i],
                    model: self.plan.model.clone(),
                }
            })
            .collect()
    }

    pub fn bufs(&self) -> &[BufSpec] {
        &self.bufs
    }

    pub fn input(&self) -> BufId {
        self.input
    }

    pub fn output(&self) -> BufId {
        self.output
    }

    /// Total per-sample scratch-arena footprint in bytes (all three
    /// typed arenas, after liveness packing).
    pub fn arena_bytes(&self) -> usize {
        self.f32_len * 4 + self.i32_len * 4 + self.i64_len * 8
    }

    /// Max simultaneously-live per-sample bytes across the program —
    /// the packing-independent peak the arena cannot go below.
    pub fn peak_live_bytes(&self) -> usize {
        self.peak_live
    }

    /// Number of fused boundary nodes: requantize+quantize (adjacent
    /// integer layers) plus epilogue+quantize (f32 layer feeding an
    /// integer consumer on a mixed chain) — every place the pass
    /// pipeline eliminated an intermediate dense f32 buffer.
    pub fn fused_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::RequantQuantize { .. }
                                    | Node::EpilogueQuantize { .. }))
            .count()
    }

    /// Number of fused epilogue+quantize nodes only (the mixed
    /// f32/int chain subset of [`Self::fused_count`]).
    pub fn fused_epilogue_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::EpilogueQuantize { .. }))
            .count()
    }

    /// Total bytes of compile-time weight panels held for blocked
    /// kernel nodes (zero unless the blocked backend was forced).
    pub fn panel_bytes(&self) -> usize {
        self.panels
            .iter()
            .flatten()
            .map(|p| p.panel_bytes())
            .sum()
    }

    /// Element range of buffer `b` for an `n`-sample batch.
    #[inline]
    fn range(&self, b: BufId, n: usize) -> (usize, usize) {
        let s = &self.bufs[b];
        let o = s.offset.expect("executing an unassigned buffer") * n;
        (o, o + s.len * n)
    }

    /// Run the program over a flat `[n, input_dim]` batch. The result
    /// lands in the output buffer — read it with [`Self::output_slice`].
    pub fn execute(&self, xs: &[f32], n: usize, st: &mut ExecState)
                   -> Result<()> {
        self.stage_input(xs, n, st)?;
        for node in &self.nodes {
            self.exec_node(node, n, st);
        }
        Ok(())
    }

    /// [`Self::execute`] with every node execution timed into
    /// `timers[i]` (one slot per node) and, when a recorder is given,
    /// recorded as a [`SpanKind::Node`] span at `base + i` in the
    /// recorder's attribution table. Kept separate from `execute` so
    /// the uninstrumented hot loop carries zero profiling branches.
    pub fn execute_instrumented(
        &self, xs: &[f32], n: usize, st: &mut ExecState,
        timers: &mut [NodeTimer],
        trace: Option<(&TraceRecorder, u64, u64)>,
    ) -> Result<()> {
        debug_assert_eq!(timers.len(), self.nodes.len());
        self.stage_input(xs, n, st)?;
        for (i, node) in self.nodes.iter().enumerate() {
            let t0 = Instant::now();
            self.exec_node(node, n, st);
            let dur = t0.elapsed().as_nanos() as u64;
            timers[i].observe(dur);
            if let Some((rec, base, tid)) = trace {
                rec.record(SpanKind::Node, rec.since(t0), dur, tid,
                           base + i as u64, n as u64);
            }
        }
        Ok(())
    }

    /// Shared batch setup: arena sizing + input staging.
    fn stage_input(&self, xs: &[f32], n: usize, st: &mut ExecState)
                   -> Result<()> {
        if xs.len() != n * self.plan.input_dim {
            bail!("batch of {} inputs must be {} x {} values, got {}",
                  n, n, self.plan.input_dim, xs.len());
        }
        st.f32a.resize(self.f32_len * n, 0.0);
        st.i32a.resize(self.i32_len * n, 0);
        st.i64a.resize(self.i64_len * n, 0);
        let (i0, i1) = self.range(self.input, n);
        st.f32a[i0..i1].copy_from_slice(xs);
        Ok(())
    }

    /// The output logits of the last [`Self::execute`] call: flat
    /// `[n, output_dim]`, borrowed straight from the arena.
    pub fn output_slice<'a>(&self, st: &'a ExecState, n: usize)
                            -> &'a [f32] {
        let (o0, o1) = self.range(self.output, n);
        &st.f32a[o0..o1]
    }

    /// Disjoint (src, dst) slice pair inside one f32 arena — the
    /// liveness pass guarantees a node's operands never alias.
    fn f32_pair<'a>(bufs: &[BufSpec], arena: &'a mut [f32], src: BufId,
                    dst: BufId, n: usize) -> (&'a [f32], &'a mut [f32]) {
        let (s, d) = (&bufs[src], &bufs[dst]);
        let s0 = s.offset.expect("unassigned src buffer") * n;
        let s1 = s0 + s.len * n;
        let d0 = d.offset.expect("unassigned dst buffer") * n;
        let d1 = d0 + d.len * n;
        debug_assert!(s1 <= d0 || d1 <= s0,
                      "aliasing arena slices {s0}..{s1} vs {d0}..{d1}");
        if s1 <= d0 {
            let (lo, hi) = arena.split_at_mut(d0);
            (&lo[s0..s1], &mut hi[..d1 - d0])
        } else {
            let (lo, hi) = arena.split_at_mut(s0);
            (&hi[..s1 - s0], &mut lo[d0..d1])
        }
    }

    fn exec_node(&self, node: &Node, n: usize, st: &mut ExecState) {
        let layers = &self.plan.layers;
        match node {
            Node::Pre { .. } => {
                unreachable!("Pre placeholder survived compile")
            }
            Node::MaxPool2 { src, dst, h, w, c } => {
                let (h, w, c) = (*h, *w, *c);
                let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                            *src, *dst, n);
                let (ho, wo) = (h / 2, w / 2);
                let (il, ol) = (h * w * c, ho * wo * c);
                for s in 0..n {
                    let xs = &x[s * il..(s + 1) * il];
                    let out = &mut y[s * ol..(s + 1) * ol];
                    let mut idx = 0;
                    for oh in 0..ho {
                        for ow in 0..wo {
                            let i00 = (2 * oh * w + 2 * ow) * c;
                            let i10 = i00 + w * c;
                            for ch in 0..c {
                                out[idx] = xs[i00 + ch]
                                    .max(xs[i00 + c + ch])
                                    .max(xs[i10 + ch])
                                    .max(xs[i10 + c + ch]);
                                idx += 1;
                            }
                        }
                    }
                }
            }
            Node::GlobalAvgPool { src, dst, h, w, c } => {
                let (h, w, c) = (*h, *w, *c);
                let pixels = h * w;
                let il = pixels * c;
                let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                            *src, *dst, n);
                for s in 0..n {
                    let xs = &x[s * il..(s + 1) * il];
                    let out = &mut y[s * c..(s + 1) * c];
                    for (ch, o) in out.iter_mut().enumerate() {
                        let mut sum = 0.0f32;
                        for p in 0..pixels {
                            sum += xs[p * c + ch];
                        }
                        *o = sum / pixels as f32;
                    }
                }
            }
            Node::AdaptSpatial { src, dst, from, to } => {
                let il = from.0 * from.1 * from.2;
                let ol = to.0 * to.1 * to.2;
                let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                            *src, *dst, n);
                for s in 0..n {
                    adapt_spatial_into(&x[s * il..(s + 1) * il], *from,
                                       *to, &mut y[s * ol..(s + 1) * ol]);
                }
            }
            Node::AdaptFeatures { src, dst, want } => {
                let il = self.bufs[*src].len;
                let ol = *want;
                let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                            *src, *dst, n);
                for s in 0..n {
                    adapt_features_into(&x[s * il..(s + 1) * il],
                                        &mut y[s * ol..(s + 1) * ol]);
                }
            }
            Node::Quantize { src, dst, grid } => {
                let (s0, s1) = self.range(*src, n);
                let (d0, d1) = self.range(*dst, n);
                let x = &st.f32a[s0..s1];
                let q = &mut st.i32a[d0..d1];
                for (o, v) in q.iter_mut().zip(x) {
                    *o = grid.code(*v) as i32;
                }
            }
            Node::Dequantize { src, dst, step } => {
                let (s0, s1) = self.range(*src, n);
                let (d0, d1) = self.range(*dst, n);
                let q = &st.i32a[s0..s1];
                let x = &mut st.f32a[d0..d1];
                let step = *step;
                for (o, v) in x.iter_mut().zip(q) {
                    *o = step * *v as f32;
                }
            }
            Node::Gemm { layer, src, dst, int, backend } => {
                let l = &layers[*layer];
                let cols = l.in_dim;
                if *int {
                    let (s0, s1) = self.range(*src, n);
                    let (d0, d1) = self.range(*dst, n);
                    if let Backend::Blocked = backend {
                        let pm = self.panels[*layer]
                            .as_ref()
                            .expect("blocked GEMM without panels");
                        kernels::matmul_panels(
                            pm, &st.i32a[s0..s1], n, l.act.bits(),
                            st.intra.max(1), &mut st.i64a[d0..d1]);
                    } else {
                        let packed = l
                            .packed
                            .as_ref()
                            .expect("integer GEMM without packed rows");
                        st.row.resize(cols, 0);
                        let mm = match backend {
                            Backend::Simd => kernels::matmul_packed_simd,
                            _ => kernels::matmul_packed,
                        };
                        mm(packed, &st.i32a[s0..s1], n, l.act.bits(),
                           &mut st.row, &mut st.i64a[d0..d1]);
                    }
                } else {
                    let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                                *src, *dst, n);
                    kernels::matmul_f32(&l.f32_rows, l.kept.len(), cols,
                                        x, n, y);
                }
            }
            Node::Conv2d { layer, src, dst, int, backend } => {
                let l = &layers[*layer];
                let sp = l.spatial.as_ref().expect("conv without spatial");
                let rows = l.kept.len();
                let plen = sp.patch_len();
                let cpg = l.out_dim / sp.groups;
                if *int {
                    let (s0, s1) = self.range(*src, n);
                    let (d0, d1) = self.range(*dst, n);
                    if let Backend::Blocked = backend {
                        let pm = self.panels[*layer]
                            .as_ref()
                            .expect("blocked conv without panels");
                        kernels::conv2d_panels(
                            pm, &l.kept, cpg, sp, &st.i32a[s0..s1], n,
                            l.act.bits(), st.intra.max(1),
                            &mut st.i64a[d0..d1]);
                    } else {
                        let packed = l
                            .packed
                            .as_ref()
                            .expect("integer conv without packed rows");
                        st.wrows.resize(rows * plen, 0);
                        for r in 0..rows {
                            packed.unpack_row_into(
                                r,
                                &mut st.wrows[r * plen..(r + 1) * plen]);
                        }
                        st.patch.resize(plen, 0);
                        let low = kernels::low_bit_pair(packed.bits,
                                                        l.act.bits());
                        let conv = match backend {
                            Backend::Simd => kernels::conv2d_codes_simd,
                            _ => kernels::conv2d_codes,
                        };
                        conv(&st.wrows, &l.kept, cpg, sp,
                             &st.i32a[s0..s1], n, low, &mut st.patch,
                             &mut st.i64a[d0..d1]);
                    }
                } else {
                    st.patchf.resize(kernels::NR * plen, 0.0);
                    let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                                *src, *dst, n);
                    kernels::conv2d_f32(&l.f32_rows, &l.kept, cpg, sp, x,
                                        n, &mut st.patchf, y);
                }
            }
            Node::DwConv2d { layer, src, dst, backend } => {
                let l = &layers[*layer];
                let sp = l.spatial.as_ref().expect("dwconv without spatial");
                let rows = l.kept.len();
                let plen = sp.patch_len();
                let cpg = l.out_dim / sp.groups;
                let (s0, s1) = self.range(*src, n);
                let (d0, d1) = self.range(*dst, n);
                if let Backend::Blocked = backend {
                    let pm = self.panels[*layer]
                        .as_ref()
                        .expect("blocked dwconv without panels");
                    kernels::dwconv2d_panels(
                        pm, &l.kept, cpg, sp, &st.i32a[s0..s1], n,
                        l.act.bits(), st.intra.max(1),
                        &mut st.i64a[d0..d1]);
                } else {
                    let packed = l
                        .packed
                        .as_ref()
                        .expect("integer dwconv without packed rows");
                    st.wrows.resize(rows * plen, 0);
                    for r in 0..rows {
                        packed.unpack_row_into(
                            r, &mut st.wrows[r * plen..(r + 1) * plen]);
                    }
                    let low = kernels::low_bit_pair(packed.bits,
                                                    l.act.bits());
                    let dw = match backend {
                        Backend::Simd => kernels::dwconv2d_codes_simd,
                        _ => kernels::dwconv2d_codes,
                    };
                    dw(&st.wrows, &l.kept, cpg, sp, &st.i32a[s0..s1],
                       n, low, &mut st.i64a[d0..d1]);
                }
            }
            Node::Requant { layer, src, dst, scale, relu } => {
                let l = &layers[*layer];
                let rows = l.kept.len();
                let out_dim = l.out_dim;
                let opix = l
                    .spatial
                    .as_ref()
                    .map(|sp| sp.out_pixels())
                    .unwrap_or(1);
                let out_len = opix * out_dim;
                let (s0, s1) = self.range(*src, n);
                let (d0, d1) = self.range(*dst, n);
                let acc = &st.i64a[s0..s1];
                let out = &mut st.f32a[d0..d1];
                fill_bias(out, l.bias.as_deref(), out_dim, n * opix);
                let scale = *scale;
                for s in 0..n {
                    for p in 0..opix {
                        let ybase = (s * opix + p) * rows;
                        let obase = s * out_len + p * out_dim;
                        for (k, ch) in l.kept.iter().enumerate() {
                            out[obase + *ch as usize] +=
                                (acc[ybase + k] as f64 * scale) as f32;
                        }
                    }
                }
                if *relu {
                    relu_slice(out);
                }
            }
            Node::Epilogue { layer, src, dst, relu } => {
                let l = &layers[*layer];
                let rows = l.kept.len();
                let out_dim = l.out_dim;
                let opix = l
                    .spatial
                    .as_ref()
                    .map(|sp| sp.out_pixels())
                    .unwrap_or(1);
                let out_len = opix * out_dim;
                let (x, y) = Self::f32_pair(&self.bufs, &mut st.f32a,
                                            *src, *dst, n);
                fill_bias(y, l.bias.as_deref(), out_dim, n * opix);
                for s in 0..n {
                    for p in 0..opix {
                        let ybase = (s * opix + p) * rows;
                        let obase = s * out_len + p * out_dim;
                        for (k, ch) in l.kept.iter().enumerate() {
                            y[obase + *ch as usize] += x[ybase + k];
                        }
                    }
                }
                if *relu {
                    relu_slice(y);
                }
            }
            Node::EpilogueQuantize { layer, src, dst, relu, grid } => {
                let l = &layers[*layer];
                let rows = l.kept.len();
                let out_dim = l.out_dim;
                let opix = l
                    .spatial
                    .as_ref()
                    .map(|sp| sp.out_pixels())
                    .unwrap_or(1);
                st.dense.resize(out_dim, 0.0);
                let (s0, s1) = self.range(*src, n);
                let (d0, d1) = self.range(*dst, n);
                let x = &st.f32a[s0..s1];
                let out = &mut st.i32a[d0..d1];
                for s in 0..n {
                    for p in 0..opix {
                        let ybase = (s * opix + p) * rows;
                        let obase = (s * opix + p) * out_dim;
                        match &l.bias {
                            Some(b) => st.dense.copy_from_slice(b),
                            None => st.dense.fill(0.0),
                        }
                        for (k, ch) in l.kept.iter().enumerate() {
                            st.dense[*ch as usize] += x[ybase + k];
                        }
                        for (ch, o) in
                            out[obase..obase + out_dim].iter_mut()
                                                       .enumerate()
                        {
                            let mut v = st.dense[ch];
                            if *relu && v < 0.0 {
                                v = 0.0;
                            }
                            *o = grid.code(v) as i32;
                        }
                    }
                }
            }
            Node::RequantQuantize { layer, src, dst, scale, relu, grid } => {
                let l = &layers[*layer];
                let rows = l.kept.len();
                let out_dim = l.out_dim;
                let opix = l
                    .spatial
                    .as_ref()
                    .map(|sp| sp.out_pixels())
                    .unwrap_or(1);
                st.dense.resize(out_dim, 0.0);
                let (s0, s1) = self.range(*src, n);
                let (d0, d1) = self.range(*dst, n);
                let acc = &st.i64a[s0..s1];
                let out = &mut st.i32a[d0..d1];
                let scale = *scale;
                for s in 0..n {
                    for p in 0..opix {
                        let ybase = (s * opix + p) * rows;
                        let obase = (s * opix + p) * out_dim;
                        match &l.bias {
                            Some(b) => st.dense.copy_from_slice(b),
                            None => st.dense.fill(0.0),
                        }
                        for (k, ch) in l.kept.iter().enumerate() {
                            st.dense[*ch as usize] +=
                                (acc[ybase + k] as f64 * scale) as f32;
                        }
                        for (ch, o) in
                            out[obase..obase + out_dim].iter_mut()
                                                       .enumerate()
                        {
                            let mut v = st.dense[ch];
                            if *relu && v < 0.0 {
                                v = 0.0;
                            }
                            *o = grid.code(v) as i32;
                        }
                    }
                }
            }
            Node::BiasFill { layer, dst, relu } => {
                let l = &layers[*layer];
                let opix = l
                    .spatial
                    .as_ref()
                    .map(|sp| sp.out_pixels())
                    .unwrap_or(1);
                let (d0, d1) = self.range(*dst, n);
                let out = &mut st.f32a[d0..d1];
                fill_bias(out, l.bias.as_deref(), l.out_dim, n * opix);
                if *relu {
                    relu_slice(out);
                }
            }
        }
    }

    /// Human-readable node list + arena map (`bbits plan --dump-ir`).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "execution graph — {} ({} path): {} nodes, {} fused",
            self.plan.model,
            if self.int_path { "int" } else { "f32" },
            self.nodes.len(),
            self.fused_count(),
        );
        let _ = writeln!(
            s,
            "arena (per sample): f32[{}] i32[{}] i64[{}] = {} B \
             (peak live {} B)",
            self.f32_len, self.i32_len, self.i64_len,
            self.arena_bytes(), self.peak_live,
        );
        let buf = |b: BufId| -> String {
            let sp = &self.bufs[b];
            match sp.offset {
                Some(o) => format!("@{b} {}[{}..{}]", sp.dtype.label(),
                                   o, o + sp.len),
                None => format!("@{b} {}[-]", sp.dtype.label()),
            }
        };
        for (i, node) in self.nodes.iter().enumerate() {
            let layer = self
                .node_layer
                .get(i)
                .map(|l| self.plan.layers[*l].name.as_str())
                .unwrap_or("-");
            let src = node
                .reads()
                .map(&buf)
                .unwrap_or_else(|| "-".into());
            let _ = writeln!(
                s,
                "{i:>3}. #{:<4} {:<18} {:<14} {src} -> {}",
                self.node_ids[i], node.op_name(), layer,
                buf(node.writes()),
            );
        }
        let _ = writeln!(
            s,
            "input {} | output {}",
            buf(self.input), buf(self.output),
        );
        s
    }
}

/// Broadcast the dense per-channel bias (or zeros) over `reps`
/// pixel-rows of `out` — exactly the pre-kernel fill the interpreter's
/// epilogues start from.
fn fill_bias(out: &mut [f32], bias: Option<&[f32]>, out_dim: usize,
             reps: usize) {
    debug_assert_eq!(out.len(), reps * out_dim);
    match bias {
        Some(b) => {
            for r in 0..reps {
                out[r * out_dim..(r + 1) * out_dim].copy_from_slice(b);
            }
        }
        None => out.fill(0.0),
    }
}

#[inline]
fn relu_slice(out: &mut [f32]) {
    for v in out.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}
