//! Batched request serving over the integer engine: the worker-pool
//! core behind the multi-model front-end.
//!
//! Architecture: a bounded request queue (Mutex + two Condvars for
//! backpressure) feeding `workers` threads, each owning its own
//! [`Engine`] over a *shared* pair of compiled [`Program`]s (int +
//! f32) and the shared read-only plan. A worker drains up to
//! `max_batch` requests, then holds the partial batch open for at
//! most `deadline` waiting for stragglers — the classic
//! micro-batching latency/throughput trade — and runs the whole batch
//! through one `Engine::run_batch` call so packed weight rows are
//! decoded once per batch. The hot path allocates nothing per
//! request: the worker's flat staging buffer is reused across
//! batches, the logits are borrowed straight out of the engine's
//! scratch arena, and each response recycles its own request's input
//! `Vec` as the output buffer. Per-request latency (submit ->
//! response) feeds the percentile stats behind `bbits serve`.
//!
//! The pool itself ([`Pool`]) is crate-internal: the public surfaces
//! are the multi-model [`super::registry::ModelRegistry`] /
//! [`super::registry::Router`] pair, and [`Server`] — the single-model
//! wrapper over a one-entry registry that `closed_loop`, the golden
//! tests, and `bbits serve` without `--model NAME=SPEC` flags use.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::graph::Program;
use super::kernels::Backend;
use super::registry::ModelRegistry;
use super::trace::{self, Histogram, KernelKey, NodeTimer, SpanKind,
                   TraceRecorder};
use super::{Engine, EnginePlan};
use crate::util::json::{num, obj, Json};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (each with its own engine instance).
    pub workers: usize,
    /// Bounded queue capacity; submitters block when full.
    pub queue_cap: usize,
    /// Micro-batch size cap.
    pub max_batch: usize,
    /// How long a partial batch waits for stragglers.
    pub deadline: Duration,
    /// Run the f32 fallback instead of the integer path (A/B lever).
    pub force_f32: bool,
    /// Force every integer kernel node onto one backend when this
    /// model's programs compile (and recompile after eviction);
    /// `None` resolves `BBITS_BACKEND`, then per-node auto selection.
    pub backend: Option<Backend>,
    /// Scoped threads a blocked kernel node shards one request across
    /// (`--intra-threads`; 1 = off). The pool caps the effective value
    /// at `available_parallelism / workers` so worker threads times
    /// intra threads can never oversubscribe the machine. Ignored by
    /// the scalar/SIMD backends.
    pub intra_threads: usize,
    /// Per-request latency target (SLO). With a precision ladder
    /// registered, the rung pick chooses the most accurate rung whose
    /// predicted completion still fits this budget; `None` falls back
    /// to pure queue-pressure shedding. Ignored by single-rung models.
    pub slo: Option<Duration>,
    /// Run the static plan verifier (`engine::verify`) over every
    /// rung's compiled program pair at register time, rejecting the
    /// model with a typed error instead of serving an unsound plan
    /// (`bbits serve --verify-plans`). Debug builds always verify at
    /// compile; this opts release builds in. Register-time only —
    /// no per-request cost.
    pub verify_plans: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .min(8),
            queue_cap: 256,
            max_batch: 16,
            deadline: Duration::from_millis(2),
            force_f32: false,
            backend: None,
            intra_threads: 1,
            slo: None,
            verify_plans: false,
        }
    }
}

/// A structurally invalid [`ServeConfig`], rejected at construction —
/// a zero worker count or queue capacity would wedge every submitter,
/// a zero batch cap would spin a worker forever, and a zero deadline
/// degenerates the micro-batch window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeConfigError {
    ZeroWorkers,
    ZeroQueueCap,
    ZeroMaxBatch,
    ZeroDeadline,
    ZeroIntraThreads,
    ZeroSlo,
}

impl fmt::Display for ServeConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeConfigError::ZeroWorkers => {
                write!(f, "serve config needs workers >= 1 (a pool \
                           with no workers never answers)")
            }
            ServeConfigError::ZeroQueueCap => {
                write!(f, "serve config needs queue_cap >= 1 (a zero \
                           capacity queue blocks every submit)")
            }
            ServeConfigError::ZeroMaxBatch => {
                write!(f, "serve config needs max_batch >= 1 (a worker \
                           cannot run an empty batch)")
            }
            ServeConfigError::ZeroDeadline => {
                write!(f, "serve config needs a non-zero deadline (use \
                           e.g. 1us to effectively disable the \
                           micro-batch window)")
            }
            ServeConfigError::ZeroIntraThreads => {
                write!(f, "serve config needs intra_threads >= 1 (use \
                           1 to disable intra-request sharding)")
            }
            ServeConfigError::ZeroSlo => {
                write!(f, "serve config SLO must be non-zero (omit it \
                           to disable deadline-aware rung selection)")
            }
        }
    }
}

impl std::error::Error for ServeConfigError {}

impl ServeConfig {
    /// Structural validation, run by every construction path
    /// (registry `register`, `Server::start`, pool spawn).
    pub fn validate(&self)
                    -> std::result::Result<(), ServeConfigError> {
        if self.workers == 0 {
            return Err(ServeConfigError::ZeroWorkers);
        }
        if self.queue_cap == 0 {
            return Err(ServeConfigError::ZeroQueueCap);
        }
        if self.max_batch == 0 {
            return Err(ServeConfigError::ZeroMaxBatch);
        }
        if self.deadline.is_zero() {
            return Err(ServeConfigError::ZeroDeadline);
        }
        if self.intra_threads == 0 {
            return Err(ServeConfigError::ZeroIntraThreads);
        }
        if matches!(self.slo, Some(d) if d.is_zero()) {
            return Err(ServeConfigError::ZeroSlo);
        }
        Ok(())
    }
}

struct Request {
    input: Vec<f32>,
    /// Trace request id (0 when no recorder is attached).
    id: u64,
    submitted: Instant,
    tx: mpsc::Sender<std::result::Result<Vec<f32>, String>>,
}

#[derive(Default)]
struct QueueState {
    q: VecDeque<Request>,
    closed: bool,
}

/// Latency sample cap: ~2 MiB of u64s. Beyond it, reservoir sampling
/// keeps a uniform sample of the full history at O(1) memory — this
/// server is meant to run indefinitely.
const LATENCY_SAMPLE_CAP: usize = 1 << 18;

/// Map a uniform 64-bit draw `x` onto `0..n` with a widening multiply
/// (`(x * n) >> 64`). Unlike `x % n`, the map's bucket sizes differ by
/// at most one part in 2^64 / n for any `n`, and it uses the
/// high-entropy top bits of an LCG state instead of the weak low bits.
#[inline]
pub fn bounded_draw(x: u64, n: u64) -> u64 {
    (((x as u128) * (n as u128)) >> 64) as u64
}

/// Per-model counters, latency/queue-depth histograms, and kernel
/// profile. The latency *reservoir* is retained purely as the test
/// oracle for the histogram's documented 1% relative-error bound —
/// every reported percentile comes from the histogram.
#[derive(Default)]
pub(crate) struct StatsInner {
    latencies_ns: Vec<u64>,
    /// Total latencies observed (>= latencies_ns.len()).
    seen: u64,
    /// Cheap LCG state for reservoir replacement.
    lcg: u64,
    requests: u64,
    batches: u64,
    errors: u64,
    /// Primary latency metric: log-linear histogram, O(octaves) to
    /// clone and exactly mergeable across workers/models.
    hist: Histogram,
    /// Queue depth observed at each batch formation.
    qdepth: Histogram,
    /// Per-(op, backend, bit-width) kernel timings, flushed once per
    /// batch by profiling workers (tracing-enabled pools only).
    kernels: BTreeMap<KernelKey, NodeTimer>,
}

impl StatsInner {
    fn record_latency(&mut self, ns: u64) {
        self.record_latency_capped(ns, LATENCY_SAMPLE_CAP);
    }

    /// Reservoir insert with an explicit cap (unit-testable).
    fn record_latency_capped(&mut self, ns: u64, cap: usize) {
        self.hist.record(ns);
        self.seen += 1;
        if self.latencies_ns.len() < cap {
            self.latencies_ns.push(ns);
            return;
        }
        // classic reservoir: keep with probability cap/seen. The
        // replacement slot comes from a widening-multiply bounded
        // draw — `(lcg >> 11) % seen` had both modulo bias and the
        // LCG's weak low bits in play.
        self.lcg = self
            .lcg
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let j = bounded_draw(self.lcg, self.seen);
        if (j as usize) < cap {
            self.latencies_ns[j as usize] = ns;
        }
    }
}

/// One model's stats cell: the locked counters/histograms plus the
/// lock-free gauges submitters and workers bump on the hot path.
/// Owned by the registry entry (an `Arc`), so the numbers survive
/// plan eviction and pool restarts.
pub(crate) struct StatsCell {
    pub(crate) inner: Mutex<StatsInner>,
    /// Requests submitted but not yet answered.
    inflight: AtomicU64,
    /// Queue length after the most recent push/pop.
    queue_depth: AtomicU64,
    started: Instant,
}

impl StatsCell {
    pub(crate) fn new() -> StatsCell {
        StatsCell {
            inner: Mutex::new(StatsInner::default()),
            inflight: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Aggregated kernel rows, sorted by descending total time.
    pub(crate) fn kernel_rows(&self) -> Vec<(KernelKey, NodeTimer)> {
        trace::sorted_kernel_rows(&self.inner.lock().unwrap().kernels)
    }

    /// Live backlog for the rung pick: requests submitted and not yet
    /// answered (queued + mid-inference). Lock-free.
    pub(crate) fn backlog(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Measured p90 request latency in ns (0 until the first response)
    /// — the per-rung cost signal the pick policy consumes.
    pub(crate) fn measured_p90_ns(&self) -> u64 {
        self.inner.lock().unwrap().hist.percentile(0.90)
    }
}

/// Mergeable raw snapshot of one stats cell. Taking it holds the lock
/// only for O(histogram octaves) clones — never the O(reservoir cap)
/// copy the old snapshot path did, so submitters can't stall behind a
/// stats scrape.
#[derive(Clone)]
pub(crate) struct StatsSnapshot {
    pub(crate) hist: Histogram,
    pub(crate) qdepth: Histogram,
    pub(crate) requests: u64,
    pub(crate) batches: u64,
    pub(crate) errors: u64,
    pub(crate) inflight: u64,
    pub(crate) queue_depth: u64,
    pub(crate) uptime: Duration,
}

impl StatsSnapshot {
    /// Cross-model aggregation: histograms merge exactly (elementwise
    /// bucket add), counters and gauges sum, uptime takes the oldest.
    pub(crate) fn merge(&mut self, other: &StatsSnapshot) {
        self.hist.merge(&other.hist);
        self.qdepth.merge(&other.qdepth);
        self.requests += other.requests;
        self.batches += other.batches;
        self.errors += other.errors;
        self.inflight += other.inflight;
        self.queue_depth += other.queue_depth;
        self.uptime = self.uptime.max(other.uptime);
    }
}

pub(crate) fn snapshot_cell(cell: &StatsCell) -> StatsSnapshot {
    let (hist, qdepth, requests, batches, errors) = {
        let inner = cell.inner.lock().unwrap();
        (inner.hist.clone(), inner.qdepth.clone(), inner.requests,
         inner.batches, inner.errors)
    };
    StatsSnapshot {
        hist,
        qdepth,
        requests,
        batches,
        errors,
        inflight: cell.inflight.load(Ordering::Relaxed),
        queue_depth: cell.queue_depth.load(Ordering::Relaxed),
        uptime: cell.started.elapsed(),
    }
}

/// Snapshot a stats cell into a [`ServeStats`].
pub(crate) fn snapshot_stats(cell: &StatsCell) -> ServeStats {
    ServeStats::from_snapshot(&snapshot_cell(cell))
}

/// Test oracle: the exact (sorted) latency reservoir of a cell. Only
/// the histogram-error tests read this.
pub(crate) fn latency_oracle(cell: &StatsCell) -> Vec<u64> {
    let mut v = cell.inner.lock().unwrap().latencies_ns.clone();
    v.sort_unstable();
    v
}

struct Shared {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cfg: ServeConfig,
    stats: Arc<StatsCell>,
    /// Span recorder; `None` keeps the serve path on the untraced
    /// fast path (one branch per batch).
    trace: Option<Arc<TraceRecorder>>,
}

/// Handle for one in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<std::result::Result<Vec<f32>, String>>,
}

impl Ticket {
    /// Block until the response (logits) arrives.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(y)) => Ok(y),
            Ok(Err(e)) => Err(anyhow!("inference failed: {e}")),
            Err(_) => Err(anyhow!("server dropped the request")),
        }
    }
}

/// Aggregate serving statistics. Percentiles come from the log-linear
/// latency histogram (documented ≤ 1% relative error, exactly
/// mergeable across models); gauges read the lock-free cell atomics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    /// errors / requests (0 when idle).
    pub error_rate: f64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// Queue length after the most recent push/pop (gauge).
    pub queue_depth: u64,
    /// p90 of the queue depth seen at batch formation.
    pub queue_depth_p90: f64,
    /// Requests submitted but not yet answered (gauge).
    pub inflight: u64,
    /// Milliseconds since the model's stats cell was created.
    pub uptime_ms: f64,
    /// Wall-clock seconds of the measured window (filled by the load
    /// driver; 0 when only queue stats were sampled).
    pub elapsed_s: f64,
    pub throughput_rps: f64,
}

impl ServeStats {
    /// Derive the reported figures from a raw (possibly merged)
    /// snapshot.
    pub(crate) fn from_snapshot(s: &StatsSnapshot) -> ServeStats {
        let ms = |ns: u64| ns as f64 / 1e6;
        ServeStats {
            requests: s.requests,
            batches: s.batches,
            errors: s.errors,
            error_rate: if s.requests == 0 {
                0.0
            } else {
                s.errors as f64 / s.requests as f64
            },
            mean_batch: if s.batches == 0 {
                0.0
            } else {
                s.requests as f64 / s.batches as f64
            },
            p50_ms: ms(s.hist.percentile(0.50)),
            p90_ms: ms(s.hist.percentile(0.90)),
            p99_ms: ms(s.hist.percentile(0.99)),
            max_ms: ms(s.hist.max()),
            queue_depth: s.queue_depth,
            queue_depth_p90: s.qdepth.percentile(0.90) as f64,
            inflight: s.inflight,
            uptime_ms: s.uptime.as_secs_f64() * 1e3,
            elapsed_s: 0.0,
            throughput_rps: 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("requests", num(self.requests as f64)),
            ("batches", num(self.batches as f64)),
            ("errors", num(self.errors as f64)),
            ("error_rate", num(self.error_rate)),
            ("mean_batch", num(self.mean_batch)),
            ("p50_ms", num(self.p50_ms)),
            ("p90_ms", num(self.p90_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("max_ms", num(self.max_ms)),
            ("queue_depth", num(self.queue_depth as f64)),
            ("queue_depth_p90", num(self.queue_depth_p90)),
            ("inflight", num(self.inflight as f64)),
            ("uptime_ms", num(self.uptime_ms)),
            ("elapsed_s", num(self.elapsed_s)),
            ("throughput_rps", num(self.throughput_rps)),
        ])
    }
}

impl fmt::Display for ServeStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} requests in {} batches (mean batch {:.2}, {} errors, \
             {:.2}% error rate) \
             | latency p50={:.3}ms p90={:.3}ms p99={:.3}ms max={:.3}ms \
             | queue depth {} (p90 {:.0}) inflight {} \
             | {:.1} req/s over {:.2}s (up {:.1}s)",
            self.requests, self.batches, self.mean_batch, self.errors,
            self.error_rate * 100.0,
            self.p50_ms, self.p90_ms, self.p99_ms, self.max_ms,
            self.queue_depth, self.queue_depth_p90, self.inflight,
            self.throughput_rps, self.elapsed_s,
            self.uptime_ms / 1e3,
        )
    }
}

/// Value at quantile `q` of an ascending-sorted sample (nearest rank).
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    sorted[idx]
}

/// Why [`Pool::submit`] did not enqueue. `Closed` hands the input
/// buffer back so the registry can retry on a recompiled pool after
/// an eviction race — a request must survive its plan going cold.
pub(crate) enum SubmitRejected {
    /// Pool is shut down (registry shutdown or plan eviction).
    Closed(Vec<f32>),
    /// Request width does not match the model input.
    BadWidth { got: usize, want: usize },
}

/// One model's worker pool: the bounded queue plus `workers` threads
/// over a shared compiled program pair. Crate-internal — pools are
/// owned (and recycled on eviction) by the registry.
pub(crate) struct Pool {
    shared: Arc<Shared>,
    plan: Arc<EnginePlan>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    /// Spawn the worker pool over pre-compiled programs; accepts
    /// requests immediately. Stats land in the caller's shared cell so
    /// they outlive this pool.
    pub(crate) fn start(plan: Arc<EnginePlan>, int_prog: Arc<Program>,
                        f32_prog: Arc<Program>, cfg: ServeConfig,
                        stats: Arc<StatsCell>,
                        trace: Option<Arc<TraceRecorder>>)
                        -> std::result::Result<Pool, ServeConfigError> {
        cfg.validate()?;
        // cap intra-request sharding so workers x intra threads never
        // oversubscribes the machine, whatever was requested
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let intra =
            cfg.intra_threads.min((cores / cfg.workers).max(1));
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cfg,
            stats,
            trace,
        });
        let workers = (0..shared.cfg.workers)
            .map(|wi| {
                let shared = shared.clone();
                let plan = plan.clone();
                let ip = int_prog.clone();
                let fp = f32_prog.clone();
                // worker trace tids start at 1; tid 0 is submitters
                std::thread::spawn(move || worker_loop(shared, plan,
                                                       ip, fp, intra,
                                                       wi as u64 + 1))
            })
            .collect();
        Ok(Pool { shared, plan, workers: Mutex::new(workers) })
    }

    /// Enqueue one request, blocking while the queue is at capacity
    /// (backpressure), and return a [`Ticket`] for the response.
    pub(crate) fn submit(&self, input: Vec<f32>)
                         -> std::result::Result<Ticket, SubmitRejected> {
        if input.len() != self.plan.input_dim {
            return Err(SubmitRejected::BadWidth {
                got: input.len(),
                want: self.plan.input_dim,
            });
        }
        let (tx, rx) = mpsc::channel();
        let t_submit = Instant::now();
        let mut st = self.shared.state.lock().unwrap();
        while st.q.len() >= self.shared.cfg.queue_cap && !st.closed {
            st = self.shared.not_full.wait(st).unwrap();
        }
        if st.closed {
            // keep the gauge honest on the reject path too
            let depth = st.q.len() as u64;
            drop(st);
            self.shared.stats.queue_depth.store(depth, Ordering::Relaxed);
            return Err(SubmitRejected::Closed(input));
        }
        // request ids are only allocated (and spans only recorded)
        // when a recorder is attached — the untraced submit path costs
        // one None check plus two relaxed atomic stores
        let id = match &self.shared.trace {
            Some(rec) => rec.next_request_id(),
            None => 0,
        };
        st.q.push_back(Request { input, id, submitted: Instant::now(),
                                 tx });
        let depth = st.q.len() as u64;
        drop(st);
        self.shared.stats.queue_depth.store(depth, Ordering::Relaxed);
        self.shared.stats.inflight.fetch_add(1, Ordering::Relaxed);
        if let Some(rec) = &self.shared.trace {
            rec.record(SpanKind::Enqueue, rec.since(t_submit),
                       t_submit.elapsed().as_nanos() as u64, 0, id,
                       depth);
        }
        self.shared.not_empty.notify_one();
        Ok(Ticket { rx })
    }

    /// Stop accepting requests, let the workers drain every queued
    /// request (each pending ticket gets its answer), and join them.
    /// Idempotent — eviction, registry shutdown, and `Drop` all funnel
    /// here.
    pub(crate) fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        let mut ws = self.workers.lock().unwrap();
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: Arc<Shared>, plan: Arc<EnginePlan>,
               int_prog: Arc<Program>, f32_prog: Arc<Program>,
               intra: usize, tid: u64) {
    let mut engine = Engine::from_compiled(plan.clone(), int_prog,
                                           f32_prog);
    engine.set_int_enabled(!shared.cfg.force_f32);
    engine.set_intra_threads(intra);
    if let Some(rec) = &shared.trace {
        // traced pools also profile: per-node spans into the ring,
        // per-kernel aggregates flushed into the stats cell per batch
        engine.enable_profiling();
        engine.attach_trace(rec.clone(), tid);
    }
    let dim = plan.input_dim;
    let od = plan.output_dim;
    // per-worker flat batch staging, reused across batches
    let mut flat: Vec<f32> = Vec::new();
    loop {
        let (batch, t_first, depth_seen) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if !st.q.is_empty() {
                    break;
                }
                if st.closed {
                    return;
                }
                st = shared.not_empty.wait(st).unwrap();
            }
            let t_first = Instant::now();
            let depth_seen = st.q.len() as u64;
            let mut batch = Vec::with_capacity(shared.cfg.max_batch);
            while batch.len() < shared.cfg.max_batch {
                match st.q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            // publish the post-drain depth before the straggler window
            // and the inference itself: with every worker mid-batch
            // nothing else would refresh the gauge, and the rung pick
            // reads it as the pressure signal
            shared.stats.queue_depth
                  .store(st.q.len() as u64, Ordering::Relaxed);
            // micro-batch window: hold a partial batch open briefly
            if batch.len() < shared.cfg.max_batch
                && !shared.cfg.deadline.is_zero()
            {
                let until = Instant::now() + shared.cfg.deadline;
                while batch.len() < shared.cfg.max_batch && !st.closed {
                    let now = Instant::now();
                    if now >= until {
                        break;
                    }
                    let (guard, timeout) = shared
                        .not_empty
                        .wait_timeout(st, until - now)
                        .unwrap();
                    st = guard;
                    while batch.len() < shared.cfg.max_batch {
                        match st.q.pop_front() {
                            Some(r) => batch.push(r),
                            None => break,
                        }
                    }
                    if timeout.timed_out() {
                        break;
                    }
                }
            }
            shared.stats.queue_depth
                  .store(st.q.len() as u64, Ordering::Relaxed);
            (batch, t_first, depth_seen)
        };
        shared.not_full.notify_all();

        let n = batch.len();
        if let Some(rec) = &shared.trace {
            // the batch just closed: per-request queue_wait spans plus
            // one batch_form span covering the straggler window
            let closed = Instant::now();
            for r in &batch {
                rec.record(
                    SpanKind::QueueWait, rec.since(r.submitted),
                    closed.duration_since(r.submitted).as_nanos() as u64,
                    tid, r.id, 0);
            }
            rec.record(SpanKind::BatchForm, rec.since(t_first),
                       closed.duration_since(t_first).as_nanos() as u64,
                       tid, 0, n as u64);
        }
        flat.clear();
        flat.reserve(n * dim);
        for r in &batch {
            flat.extend_from_slice(&r.input);
        }
        // `run_batch` borrows the logits straight out of the engine's
        // arena — no per-batch output allocation…
        let t_infer = Instant::now();
        let result = engine.run_batch(&flat, n);
        let done = Instant::now();
        if let Some(rec) = &shared.trace {
            rec.record(SpanKind::Infer, rec.since(t_infer),
                       done.duration_since(t_infer).as_nanos() as u64,
                       tid, 0, n as u64);
        }
        let mut stats = shared.stats.inner.lock().unwrap();
        stats.batches += 1;
        stats.requests += n as u64;
        stats.qdepth.record(depth_seen);
        // profiling workers drain their per-node timers under the
        // per-batch stats lock they already hold (no-op otherwise)
        engine.flush_profile_into(&mut stats.kernels);
        match result {
            Ok(out) => {
                let trace = shared.trace.as_deref();
                for (i, r) in batch.into_iter().enumerate() {
                    let Request { mut input, id, submitted, tx } = r;
                    let lat =
                        done.duration_since(submitted).as_nanos() as u64;
                    stats.record_latency(lat);
                    // …and each response recycles its own request's
                    // input allocation as the output buffer handed
                    // back through the ticket channel. A dropped
                    // Ticket just makes this send fail — the worker
                    // moves on, nothing wedges.
                    input.clear();
                    input.extend_from_slice(&out[i * od..(i + 1) * od]);
                    let _ = tx.send(Ok(input));
                    if let Some(rec) = trace {
                        rec.record(
                            SpanKind::Respond, rec.since(done),
                            done.elapsed().as_nanos() as u64, tid, id,
                            0);
                    }
                }
            }
            Err(e) => {
                stats.errors += n as u64;
                let msg = format!("{e:#}");
                for r in batch {
                    let _ = r.tx.send(Err(msg.clone()));
                }
            }
        }
        drop(stats);
        shared.stats.inflight
              .fetch_sub(n as u64, Ordering::Relaxed);
    }
}

/// The single-model batched inference server: a thin wrapper over a
/// one-entry [`ModelRegistry`] with no plan-cache budget, preserving
/// the original `start/submit/stats/shutdown` surface for the CLI,
/// the golden tests, and embedders that host exactly one model.
pub struct Server {
    registry: Arc<ModelRegistry>,
    id: String,
    plan: Arc<EnginePlan>,
}

impl Server {
    /// Register the plan under its model name; the worker pool spawns
    /// lazily on the first request.
    pub fn start(plan: Arc<EnginePlan>, cfg: ServeConfig)
                 -> Result<Server> {
        Server::start_inner(plan, cfg, None)
    }

    /// [`Self::start`] with a span recorder attached: the serve path
    /// records `enqueue → queue_wait → batch_form → infer → respond`
    /// spans and per-node kernel slices into `trace` (the
    /// `--trace-out` surface).
    pub fn start_traced(plan: Arc<EnginePlan>, cfg: ServeConfig,
                        trace: Arc<TraceRecorder>) -> Result<Server> {
        Server::start_inner(plan, cfg, Some(trace))
    }

    fn start_inner(plan: Arc<EnginePlan>, cfg: ServeConfig,
                   trace: Option<Arc<TraceRecorder>>) -> Result<Server> {
        let registry = Arc::new(ModelRegistry::new());
        registry
            .set_trace(trace)
            .expect("fresh registry has no running pools");
        let id = if plan.model.is_empty() {
            "default".to_string()
        } else {
            plan.model.clone()
        };
        registry.register(&id, plan.clone(), cfg)?;
        Ok(Server { registry, id, plan })
    }

    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The backing one-entry registry (shared stats JSON, tests).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Enqueue one request, blocking while the queue is at capacity
    /// (backpressure), and return a [`Ticket`] for the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<Ticket> {
        self.registry.submit(&self.id, input)
    }

    /// Snapshot of the latency/batch statistics so far.
    pub fn stats(&self) -> ServeStats {
        self.registry.stats(&self.id).unwrap_or_default()
    }

    /// Stop accepting requests, drain the queue (every queued request
    /// still gets its response), join the workers, and return the
    /// final stats.
    pub fn shutdown(self) -> ServeStats {
        self.registry.shutdown();
        self.registry.stats(&self.id).unwrap_or_default()
    }
}

/// Closed-loop load driver: `clients` threads each submit
/// `per_client` random requests back-to-back and wait for every
/// response. Returns the server stats with throughput over the
/// measured wall-clock window — what `bbits serve` reports. A thin
/// single-model view of [`super::registry::closed_loop_router`],
/// since the server is a one-entry registry.
pub fn closed_loop(server: &Server, clients: usize, per_client: usize,
                   seed: u64) -> Result<ServeStats> {
    let router =
        super::registry::Router::new(server.registry.clone());
    let ids = [server.id.clone()];
    let (elapsed, mut per_model) = super::registry::closed_loop_router(
        &router, &ids, clients, per_client, seed)?;
    let mut stats =
        per_model.pop().map(|(_, st)| st).unwrap_or_default();
    // the counters are cumulative over the server's lifetime, but the
    // throughput figure covers exactly this driver's window — a server
    // with prior traffic must not inflate it
    stats.throughput_rps = if elapsed > 0.0 {
        (clients * per_client) as f64 / elapsed
    } else {
        0.0
    };
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::synthetic_plan;

    fn tiny_plan() -> Arc<EnginePlan> {
        Arc::new(synthetic_plan("t", &[8, 16, 4], 4, 8, 0.2, 9).unwrap())
    }

    #[test]
    fn serves_and_matches_direct_inference() {
        let plan = tiny_plan();
        let server = Server::start(
            plan.clone(),
            ServeConfig {
                workers: 2,
                queue_cap: 32,
                max_batch: 4,
                deadline: Duration::from_millis(1),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let mut eng = Engine::new(plan.clone());
        let mut tickets = Vec::new();
        let mut want = Vec::new();
        for i in 0..10 {
            let x: Vec<f32> =
                (0..8).map(|j| ((i * 8 + j) as f32).sin()).collect();
            want.push(eng.infer(&x).unwrap());
            tickets.push(server.submit(x).unwrap());
        }
        for (t, w) in tickets.into_iter().zip(&want) {
            assert_eq!(&t.wait().unwrap(), w);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 10);
        assert!(stats.batches >= 1 && stats.batches <= 10);
        assert_eq!(stats.errors, 0);
        assert!(stats.p99_ms >= stats.p50_ms);
    }

    #[test]
    fn rejects_bad_request_width_and_bad_config() {
        let server =
            Server::start(tiny_plan(), ServeConfig::default()).unwrap();
        assert!(server.submit(vec![0.0; 3]).is_err());
        let plan = tiny_plan();
        let stats = server.shutdown();
        assert_eq!(stats.requests, 0);
        // a fresh server with zero workers is rejected outright
        let bad =
            ServeConfig { workers: 0, ..ServeConfig::default() };
        assert!(Server::start(plan, bad).is_err());
    }

    // Per-field ServeConfig validation (typed ServeConfigError) is
    // pinned in tests/serve.rs (config_zero_fields_are_typed_errors_
    // not_hangs) alongside the other lifecycle edges.

    // Sized for the Miri CI lane (see ci.yml): a [2,3,2] plan and one
    // worker keep the interpreter run to seconds while still crossing
    // every queue/condvar/join edge of the shutdown path twice.
    #[test]
    fn pool_shutdown_drains_joins_and_stays_idempotent() {
        let plan = Arc::new(
            synthetic_plan("m", &[2, 3, 2], 4, 4, 0.0, 5).unwrap());
        let server = Server::start(
            plan.clone(),
            ServeConfig {
                workers: 1,
                queue_cap: 4,
                max_batch: 2,
                deadline: Duration::from_micros(1),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let mut eng = Engine::new(plan);
        let tickets: Vec<(Ticket, Vec<f32>)> = (0..2)
            .map(|i| {
                let x = vec![0.25 * (i as f32 + 1.0), -0.5];
                let want = eng.infer(&x).unwrap();
                (server.submit(x).unwrap(), want)
            })
            .collect();
        // shutdown drains: both queued tickets still get answers
        let registry = server.registry().clone();
        let stats = server.shutdown();
        for (t, want) in tickets {
            assert_eq!(t.wait().unwrap(), want);
        }
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.errors, 0);
        // idempotent: a second shutdown (and later Drop) is a no-op,
        // and post-shutdown submits are rejected, not queued forever
        registry.shutdown();
        assert!(registry.submit("m", vec![0.0, 0.0]).is_err());
    }

    #[test]
    fn closed_loop_counts_every_request() {
        let server = Server::start(
            tiny_plan(),
            ServeConfig {
                workers: 3,
                max_batch: 8,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let stats = closed_loop(&server, 4, 25, 7).unwrap();
        assert_eq!(stats.requests, 100);
        assert_eq!(stats.errors, 0);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.mean_batch >= 1.0);
        server.shutdown();
    }

    #[test]
    fn queue_depth_gauge_stays_fresh_while_workers_busy() {
        // one worker, batch of one: once the worker is mid-inference,
        // only the submit-side stores and the worker's post-drain
        // store keep the gauge honest. The plan is big enough (~1.1M
        // weights) that one inference dwarfs four enqueues — the
        // worker cannot possibly drain the backlog before the read.
        let plan = Arc::new(
            synthetic_plan("big", &[32, 1024, 1024, 8], 4, 8, 0.0, 11)
                .unwrap());
        let server = Server::start(
            plan,
            ServeConfig {
                workers: 1,
                queue_cap: 16,
                max_batch: 1,
                deadline: Duration::from_micros(1),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                server.submit(vec![i as f32 * 0.1; 32]).unwrap()
            })
            .collect();
        // workers busy (first inference running at most), three
        // requests still queued: the gauge must reflect that now, not
        // after the next batch forms
        assert!(server.stats().queue_depth >= 1,
                "gauge stale while workers busy");
        for t in tickets {
            t.wait().unwrap();
        }
        // fully drained: the last batch formation published depth 0
        let fin = server.shutdown();
        assert_eq!(fin.queue_depth, 0);
        assert_eq!(fin.requests, 4);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[42], 0.5), 42);
    }

    #[test]
    fn percentile_extreme_quantiles_and_degenerate_samples() {
        // empty sample: every quantile is 0, including the extremes
        assert_eq!(percentile(&[], 0.0), 0);
        assert_eq!(percentile(&[], 1.0), 0);
        // single element: every quantile is that element
        assert_eq!(percentile(&[7], 0.0), 7);
        assert_eq!(percentile(&[7], 1.0), 7);
        // q = 0 clamps to the first rank, q = 1 to the last
        let v = [10u64, 20, 30];
        assert_eq!(percentile(&v, 0.0), 10);
        assert_eq!(percentile(&v, 1.0), 30);
        // nearest-rank stays within bounds just inside the extremes
        assert_eq!(percentile(&v, 1e-9), 10);
        assert_eq!(percentile(&v, 1.0 - 1e-9), 30);
    }

    #[test]
    fn idle_server_stats_snapshot_is_all_zero() {
        let server =
            Server::start(tiny_plan(), ServeConfig::default()).unwrap();
        // snapshot before any request: counters and latency quantiles
        // must all read zero, not garbage from an empty reservoir
        let st = server.stats();
        assert_eq!((st.requests, st.batches, st.errors), (0, 0, 0));
        assert_eq!(st.mean_batch, 0.0);
        assert_eq!((st.p50_ms, st.p90_ms, st.p99_ms, st.max_ms),
                   (0.0, 0.0, 0.0, 0.0));
        assert_eq!((st.elapsed_s, st.throughput_rps), (0.0, 0.0));
        // gauges: nothing queued or in flight, error rate zero — but
        // the uptime clock runs from registration
        assert_eq!((st.queue_depth, st.inflight), (0, 0));
        assert_eq!((st.error_rate, st.queue_depth_p90), (0.0, 0.0));
        assert!(st.uptime_ms >= 0.0);
        // shutting down an idle server yields the same zero stats
        let fin = server.shutdown();
        assert_eq!((fin.requests, fin.batches, fin.errors), (0, 0, 0));
        assert_eq!(fin.max_ms, 0.0);
    }

    #[test]
    fn reservoir_keeps_cap_and_replaces_with_late_samples() {
        let mut s = StatsInner::default();
        let cap = 64usize;
        // fill phase: first `cap` samples are marker 0
        for _ in 0..cap {
            s.record_latency_capped(0, cap);
        }
        assert_eq!(s.latencies_ns.len(), cap);
        // replacement phase: 200x the cap, all marker 1. A uniform
        // reservoir should end up ~ (200/201) marker-1; a broken
        // replacement draw (e.g. always out of range) would keep the
        // initial zeros forever.
        for _ in 0..cap * 200 {
            s.record_latency_capped(1, cap);
        }
        assert_eq!(s.latencies_ns.len(), cap);
        assert_eq!(s.seen, (cap * 201) as u64);
        let ones = s.latencies_ns.iter().filter(|v| **v == 1).count();
        assert!(ones >= cap * 8 / 10,
                "reservoir barely replaced: {ones}/{cap} late samples");
    }

    // bounded_draw range/uniformity is pinned in tests/serve.rs
    // (bounded_draw_replaces_modulo_without_bias_artifacts).

    #[test]
    fn histogram_percentiles_match_reservoir_oracle() {
        // the acceptance bound: every reported percentile (histogram)
        // agrees with the exact reservoir oracle within 1% relative
        // error (+1µs absolute slack for sub-bucket rounding)
        let server = Server::start(
            tiny_plan(),
            ServeConfig { workers: 2, ..ServeConfig::default() },
        )
        .unwrap();
        closed_loop(&server, 4, 50, 3).unwrap();
        let st = server.stats();
        let cell = server.registry().stats_cell("t").unwrap();
        let oracle = latency_oracle(&cell);
        assert_eq!(oracle.len(), 200, "reservoir under cap is exact");
        for (q, got_ms) in [(0.50, st.p50_ms), (0.90, st.p90_ms),
                            (0.99, st.p99_ms)] {
            let want_ms = percentile(&oracle, q) as f64 / 1e6;
            let tol = want_ms * 0.01 + 1e-3;
            assert!((got_ms - want_ms).abs() <= tol,
                    "q{q}: hist {got_ms}ms vs oracle {want_ms}ms");
        }
        // max is tracked exactly, not bucketed
        assert_eq!(st.max_ms,
                   *oracle.last().unwrap() as f64 / 1e6);
        // post-traffic gauges: drained and sane
        assert_eq!(st.inflight, 0);
        assert_eq!(st.requests, 200);
        assert_eq!(st.error_rate, 0.0);
        assert!(st.uptime_ms > 0.0);
        assert!(st.queue_depth_p90 >= 0.0);
        server.shutdown();
    }

    #[test]
    fn traced_server_records_all_request_phases() {
        let rec = TraceRecorder::with_capacity(1 << 12);
        let server = Server::start_traced(
            tiny_plan(),
            ServeConfig { workers: 2, ..ServeConfig::default() },
            rec.clone(),
        )
        .unwrap();
        closed_loop(&server, 2, 20, 5).unwrap();
        server.shutdown();
        let events = rec.events();
        for kind in [SpanKind::Enqueue, SpanKind::QueueWait,
                     SpanKind::BatchForm, SpanKind::Infer,
                     SpanKind::Respond, SpanKind::Node] {
            let n = events.iter().filter(|e| e.kind == kind).count();
            assert!(n > 0, "missing {} spans", kind.label());
        }
        // every request got an id and an enqueue span
        let enq: Vec<u64> = events
            .iter()
            .filter(|e| e.kind == SpanKind::Enqueue)
            .map(|e| e.a)
            .collect();
        assert_eq!(enq.len(), 40);
        assert!(enq.iter().all(|id| (1..=40).contains(id)));
    }
}
