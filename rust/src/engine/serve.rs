//! Batched request serving over the integer engine.
//!
//! Architecture: a bounded request queue (Mutex + two Condvars for
//! backpressure) feeding `workers` threads, each owning its own
//! [`Engine`] instance over the shared read-only plan. A worker drains
//! up to `max_batch` requests, then holds the partial batch open for
//! at most `deadline` waiting for stragglers — the classic
//! micro-batching latency/throughput trade — and runs the whole batch
//! through one `Engine::run_batch` call so packed weight rows are
//! decoded once per batch. The hot path allocates nothing per
//! request: the worker's flat staging buffer is reused across
//! batches, the logits are borrowed straight out of the engine's
//! scratch arena, and each response recycles its own request's input
//! `Vec` as the output buffer. Per-request latency (submit ->
//! response) feeds the percentile stats behind `bbits serve`.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::{Engine, EnginePlan};
use crate::rng::Pcg64;
use crate::util::json::{num, obj, Json};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (each with its own engine instance).
    pub workers: usize,
    /// Bounded queue capacity; submitters block when full.
    pub queue_cap: usize,
    /// Micro-batch size cap.
    pub max_batch: usize,
    /// How long a partial batch waits for stragglers.
    pub deadline: Duration,
    /// Run the f32 fallback instead of the integer path (A/B lever).
    pub force_f32: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .min(8),
            queue_cap: 256,
            max_batch: 16,
            deadline: Duration::from_millis(2),
            force_f32: false,
        }
    }
}

struct Request {
    input: Vec<f32>,
    submitted: Instant,
    tx: mpsc::Sender<std::result::Result<Vec<f32>, String>>,
}

#[derive(Default)]
struct QueueState {
    q: VecDeque<Request>,
    closed: bool,
}

/// Latency sample cap: ~2 MiB of u64s. Beyond it, reservoir sampling
/// keeps a uniform sample of the full history at O(1) memory — this
/// server is meant to run indefinitely.
const LATENCY_SAMPLE_CAP: usize = 1 << 18;

#[derive(Default)]
struct StatsInner {
    latencies_ns: Vec<u64>,
    /// Total latencies observed (>= latencies_ns.len()).
    seen: u64,
    /// Cheap LCG state for reservoir replacement.
    lcg: u64,
    requests: u64,
    batches: u64,
    errors: u64,
}

impl StatsInner {
    fn record_latency(&mut self, ns: u64) {
        self.seen += 1;
        if self.latencies_ns.len() < LATENCY_SAMPLE_CAP {
            self.latencies_ns.push(ns);
            return;
        }
        // classic reservoir: keep with probability cap/seen
        self.lcg = self
            .lcg
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let j = (self.lcg >> 11) % self.seen;
        if (j as usize) < LATENCY_SAMPLE_CAP {
            self.latencies_ns[j as usize] = ns;
        }
    }
}

struct Shared {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cfg: ServeConfig,
    stats: Mutex<StatsInner>,
}

/// Handle for one in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<std::result::Result<Vec<f32>, String>>,
}

impl Ticket {
    /// Block until the response (logits) arrives.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(y)) => Ok(y),
            Ok(Err(e)) => Err(anyhow!("inference failed: {e}")),
            Err(_) => Err(anyhow!("server dropped the request")),
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// Wall-clock seconds of the measured window (filled by the load
    /// driver; 0 when only queue stats were sampled).
    pub elapsed_s: f64,
    pub throughput_rps: f64,
}

impl ServeStats {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("requests", num(self.requests as f64)),
            ("batches", num(self.batches as f64)),
            ("errors", num(self.errors as f64)),
            ("mean_batch", num(self.mean_batch)),
            ("p50_ms", num(self.p50_ms)),
            ("p90_ms", num(self.p90_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("max_ms", num(self.max_ms)),
            ("elapsed_s", num(self.elapsed_s)),
            ("throughput_rps", num(self.throughput_rps)),
        ])
    }
}

impl fmt::Display for ServeStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} requests in {} batches (mean batch {:.2}, {} errors) \
             | latency p50={:.3}ms p90={:.3}ms p99={:.3}ms max={:.3}ms \
             | {:.1} req/s over {:.2}s",
            self.requests, self.batches, self.mean_batch, self.errors,
            self.p50_ms, self.p90_ms, self.p99_ms, self.max_ms,
            self.throughput_rps, self.elapsed_s
        )
    }
}

/// Value at quantile `q` of an ascending-sorted sample (nearest rank).
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    sorted[idx]
}

/// The batched inference server.
pub struct Server {
    shared: Arc<Shared>,
    plan: Arc<EnginePlan>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spawn the worker pool; the server accepts requests immediately.
    pub fn start(plan: Arc<EnginePlan>, cfg: ServeConfig)
                 -> Result<Server> {
        if cfg.workers == 0 || cfg.max_batch == 0 || cfg.queue_cap == 0 {
            bail!("serve config needs workers, max_batch and queue_cap \
                   >= 1, got {cfg:?}");
        }
        plan.validate()?;
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cfg,
            stats: Mutex::new(StatsInner::default()),
        });
        let workers = (0..shared.cfg.workers)
            .map(|_| {
                let shared = shared.clone();
                let plan = plan.clone();
                std::thread::spawn(move || worker_loop(shared, plan))
            })
            .collect();
        Ok(Server { shared, plan, workers })
    }

    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// Enqueue one request, blocking while the queue is at capacity
    /// (backpressure), and return a [`Ticket`] for the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<Ticket> {
        if input.len() != self.plan.input_dim {
            bail!("request has {} values, model {:?} wants {}",
                  input.len(), self.plan.model, self.plan.input_dim);
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { input, submitted: Instant::now(), tx };
        let mut st = self.shared.state.lock().unwrap();
        while st.q.len() >= self.shared.cfg.queue_cap && !st.closed {
            st = self.shared.not_full.wait(st).unwrap();
        }
        if st.closed {
            bail!("server is shut down");
        }
        st.q.push_back(req);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(Ticket { rx })
    }

    /// Snapshot of the latency/batch statistics so far. The (possibly
    /// reservoir-sampled) latency buffer is copied out under the lock
    /// and sorted outside it, so workers never stall on a snapshot.
    pub fn stats(&self) -> ServeStats {
        let (mut lat, requests, batches, errors) = {
            let inner = self.shared.stats.lock().unwrap();
            (inner.latencies_ns.clone(), inner.requests, inner.batches,
             inner.errors)
        };
        lat.sort_unstable();
        let ms = |ns: u64| ns as f64 / 1e6;
        ServeStats {
            requests,
            batches,
            errors,
            mean_batch: if batches == 0 {
                0.0
            } else {
                requests as f64 / batches as f64
            },
            p50_ms: ms(percentile(&lat, 0.50)),
            p90_ms: ms(percentile(&lat, 0.90)),
            p99_ms: ms(percentile(&lat, 0.99)),
            max_ms: ms(lat.last().copied().unwrap_or(0)),
            elapsed_s: 0.0,
            throughput_rps: 0.0,
        }
    }

    /// Stop accepting requests, drain the queue, join the workers, and
    /// return the final stats.
    pub fn shutdown(mut self) -> ServeStats {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.workers.is_empty() {
            return; // already shut down
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, plan: Arc<EnginePlan>) {
    let mut engine = Engine::new(plan.clone());
    engine.set_int_enabled(!shared.cfg.force_f32);
    let dim = plan.input_dim;
    let od = plan.output_dim;
    // per-worker flat batch staging, reused across batches
    let mut flat: Vec<f32> = Vec::new();
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if !st.q.is_empty() {
                    break;
                }
                if st.closed {
                    return;
                }
                st = shared.not_empty.wait(st).unwrap();
            }
            let mut batch = Vec::with_capacity(shared.cfg.max_batch);
            while batch.len() < shared.cfg.max_batch {
                match st.q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            // micro-batch window: hold a partial batch open briefly
            if batch.len() < shared.cfg.max_batch
                && !shared.cfg.deadline.is_zero()
            {
                let until = Instant::now() + shared.cfg.deadline;
                while batch.len() < shared.cfg.max_batch && !st.closed {
                    let now = Instant::now();
                    if now >= until {
                        break;
                    }
                    let (guard, timeout) = shared
                        .not_empty
                        .wait_timeout(st, until - now)
                        .unwrap();
                    st = guard;
                    while batch.len() < shared.cfg.max_batch {
                        match st.q.pop_front() {
                            Some(r) => batch.push(r),
                            None => break,
                        }
                    }
                    if timeout.timed_out() {
                        break;
                    }
                }
            }
            batch
        };
        shared.not_full.notify_all();

        let n = batch.len();
        flat.clear();
        flat.reserve(n * dim);
        for r in &batch {
            flat.extend_from_slice(&r.input);
        }
        // `run_batch` borrows the logits straight out of the engine's
        // arena — no per-batch output allocation…
        let result = engine.run_batch(&flat, n);
        let done = Instant::now();
        let mut stats = shared.stats.lock().unwrap();
        stats.batches += 1;
        stats.requests += n as u64;
        match result {
            Ok(out) => {
                for (i, r) in batch.into_iter().enumerate() {
                    let Request { mut input, submitted, tx } = r;
                    let lat =
                        done.duration_since(submitted).as_nanos() as u64;
                    stats.record_latency(lat);
                    // …and each response recycles its own request's
                    // input allocation as the output buffer handed
                    // back through the ticket channel.
                    input.clear();
                    input.extend_from_slice(&out[i * od..(i + 1) * od]);
                    let _ = tx.send(Ok(input));
                }
            }
            Err(e) => {
                stats.errors += n as u64;
                let msg = format!("{e:#}");
                for r in batch {
                    let _ = r.tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Closed-loop load driver: `clients` threads each submit
/// `per_client` random requests back-to-back and wait for every
/// response. Returns the server stats with throughput over the
/// measured wall-clock window — what `bbits serve` reports.
pub fn closed_loop(server: &Server, clients: usize, per_client: usize,
                   seed: u64) -> Result<ServeStats> {
    let dim = server.plan().input_dim;
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<()> {
                    let mut rng = Pcg64::with_stream(seed, c as u64);
                    for _ in 0..per_client {
                        let x: Vec<f32> =
                            (0..dim).map(|_| rng.normal()).collect();
                        server.submit(x)?.wait()?;
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().map_err(|_| anyhow!("load client panicked"))??;
        }
        Ok(())
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mut stats = server.stats();
    stats.elapsed_s = elapsed;
    stats.throughput_rps = if elapsed > 0.0 {
        (clients * per_client) as f64 / elapsed
    } else {
        0.0
    };
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::synthetic_plan;

    fn tiny_plan() -> Arc<EnginePlan> {
        Arc::new(synthetic_plan("t", &[8, 16, 4], 4, 8, 0.2, 9).unwrap())
    }

    #[test]
    fn serves_and_matches_direct_inference() {
        let plan = tiny_plan();
        let server = Server::start(
            plan.clone(),
            ServeConfig {
                workers: 2,
                queue_cap: 32,
                max_batch: 4,
                deadline: Duration::from_millis(1),
                force_f32: false,
            },
        )
        .unwrap();
        let mut eng = Engine::new(plan.clone());
        let mut tickets = Vec::new();
        let mut want = Vec::new();
        for i in 0..10 {
            let x: Vec<f32> =
                (0..8).map(|j| ((i * 8 + j) as f32).sin()).collect();
            want.push(eng.infer(&x).unwrap());
            tickets.push(server.submit(x).unwrap());
        }
        for (t, w) in tickets.into_iter().zip(&want) {
            assert_eq!(&t.wait().unwrap(), w);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 10);
        assert!(stats.batches >= 1 && stats.batches <= 10);
        assert_eq!(stats.errors, 0);
        assert!(stats.p99_ms >= stats.p50_ms);
    }

    #[test]
    fn rejects_bad_request_width_and_bad_config() {
        let server =
            Server::start(tiny_plan(), ServeConfig::default()).unwrap();
        assert!(server.submit(vec![0.0; 3]).is_err());
        let plan = tiny_plan();
        let stats = server.shutdown();
        assert_eq!(stats.requests, 0);
        // a fresh server with zero workers is rejected outright
        let bad =
            ServeConfig { workers: 0, ..ServeConfig::default() };
        assert!(Server::start(plan, bad).is_err());
    }

    #[test]
    fn closed_loop_counts_every_request() {
        let server = Server::start(
            tiny_plan(),
            ServeConfig {
                workers: 3,
                max_batch: 8,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let stats = closed_loop(&server, 4, 25, 7).unwrap();
        assert_eq!(stats.requests, 100);
        assert_eq!(stats.errors, 0);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.mean_batch >= 1.0);
        server.shutdown();
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[42], 0.5), 42);
    }

    #[test]
    fn percentile_extreme_quantiles_and_degenerate_samples() {
        // empty sample: every quantile is 0, including the extremes
        assert_eq!(percentile(&[], 0.0), 0);
        assert_eq!(percentile(&[], 1.0), 0);
        // single element: every quantile is that element
        assert_eq!(percentile(&[7], 0.0), 7);
        assert_eq!(percentile(&[7], 1.0), 7);
        // q = 0 clamps to the first rank, q = 1 to the last
        let v = [10u64, 20, 30];
        assert_eq!(percentile(&v, 0.0), 10);
        assert_eq!(percentile(&v, 1.0), 30);
        // nearest-rank stays within bounds just inside the extremes
        assert_eq!(percentile(&v, 1e-9), 10);
        assert_eq!(percentile(&v, 1.0 - 1e-9), 30);
    }

    #[test]
    fn idle_server_stats_snapshot_is_all_zero() {
        let server =
            Server::start(tiny_plan(), ServeConfig::default()).unwrap();
        // snapshot before any request: counters and latency quantiles
        // must all read zero, not garbage from an empty reservoir
        let st = server.stats();
        assert_eq!((st.requests, st.batches, st.errors), (0, 0, 0));
        assert_eq!(st.mean_batch, 0.0);
        assert_eq!((st.p50_ms, st.p90_ms, st.p99_ms, st.max_ms),
                   (0.0, 0.0, 0.0, 0.0));
        assert_eq!((st.elapsed_s, st.throughput_rps), (0.0, 0.0));
        // shutting down an idle server yields the same zero stats
        let fin = server.shutdown();
        assert_eq!((fin.requests, fin.batches, fin.errors), (0, 0, 0));
        assert_eq!(fin.max_ms, 0.0);
    }
}
