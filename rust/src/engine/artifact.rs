//! Serialized plan artifacts: a versioned binary encode/decode for a
//! lowered [`EnginePlan`] — packed code grids included — so a cold
//! start is a file read instead of checkpoint→lower. The CLI surface
//! is `bbits plan --save FILE` / `--load FILE`; the registry side is
//! `register` + [`super::registry::ModelRegistry::prewarm`].
//!
//! ## Format (version 1)
//!
//! ```text
//!   magic    8 bytes  "BBITPLAN"
//!   version  u32 LE   1
//!   body              model name, dims, layer table (below)
//!   checksum u64 LE   FNV-1a over every preceding byte
//! ```
//!
//! All integers are little-endian; lengths are u64, counts/tags u32 or
//! u8; f32 values are raw IEEE-754 bit patterns. Each layer serializes
//! every [`PlanLayer`] field, with [`PackedMatrix`] stored as its raw
//! packed words (bits/signed/rows/cols + `u64` word array). Panel
//! matrices for the blocked backend are **not** stored — they are a
//! compile-time derivation and are rebuilt by `Program` compilation.
//!
//! ## Trust model
//!
//! A decoded artifact is *data*, never trusted: the checksum catches
//! torn writes, [`PackedMatrix::from_raw`] re-validates every code
//! field and padding bit, `EnginePlan::validate` re-checks structure,
//! and [`load_plan_verified`] additionally compiles both program
//! paths and runs the full static verifier (`engine::verify`) on
//! them — in release builds too, where compile alone does not verify.
//! A corrupt artifact is therefore always a typed [`anyhow::Error`]
//! (or [`VerifyError`]-carrying) failure, never UB or garbage codes.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::pack::PackedMatrix;
use super::{ActSpec, Backend, EnginePlan, PlanLayer, PreOp,
            SpatialPlan};

/// File magic: 8 bytes at offset 0.
pub const MAGIC: &[u8; 8] = b"BBITPLAN";

/// Current artifact format version.
pub const FORMAT_VERSION: u32 = 1;

/// FNV-1a 64-bit over `bytes` — dependency-free integrity check; this
/// guards against corruption (torn writes, truncation, bit rot), not
/// against an adversary.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Byte-appending encoder for the artifact body.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.f32(*x);
        }
    }

    fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.u32(*x);
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for x in v {
            self.u64(*x);
        }
    }
}

/// Bounds-checked cursor over the artifact body; every read is a
/// typed truncation error instead of a panic.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Upper bound on any one decoded length field — rejects absurd
/// lengths from corrupt bytes before they turn into huge allocations.
const MAX_LEN: u64 = 1 << 32;

/// Pre-allocation cap for decoded arrays: a corrupt length field must
/// fail on a bounds-checked read, not on a giant up-front allocation.
const PREALLOC_CAP: usize = 1 << 16;

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.pos < n {
            bail!("plan artifact truncated: need {n} bytes at offset \
                   {}, have {}", self.pos, self.b.len() - self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn len(&mut self, what: &str) -> Result<usize> {
        let n = self.u64()?;
        if n > MAX_LEN {
            bail!("plan artifact: implausible {what} length {n}");
        }
        Ok(n as usize)
    }

    fn usize(&mut self, what: &str) -> Result<usize> {
        self.len(what)
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.len(what)?;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| anyhow!("plan artifact: {what} is not UTF-8"))
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n.min(PREALLOC_CAP));
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn u32s(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n.min(PREALLOC_CAP));
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn u64s(&mut self, what: &str) -> Result<Vec<u64>> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n.min(PREALLOC_CAP));
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }
}

fn enc_spatial(e: &mut Enc, sp: &SpatialPlan) {
    for v in [sp.in_h, sp.in_w, sp.in_c, sp.k, sp.stride, sp.groups,
              sp.pad_top, sp.pad_left, sp.out_h, sp.out_w]
    {
        e.u64(v as u64);
    }
}

fn dec_spatial(d: &mut Dec) -> Result<SpatialPlan> {
    Ok(SpatialPlan { in_h: d.usize("in_h")?,
                     in_w: d.usize("in_w")?,
                     in_c: d.usize("in_c")?,
                     k: d.usize("k")?,
                     stride: d.usize("stride")?,
                     groups: d.usize("groups")?,
                     pad_top: d.usize("pad_top")?,
                     pad_left: d.usize("pad_left")?,
                     out_h: d.usize("out_h")?,
                     out_w: d.usize("out_w")? })
}

fn enc_pre(e: &mut Enc, pre: &PreOp) {
    match pre {
        PreOp::Direct => e.u8(0),
        PreOp::MaxPool2 { h, w, c } => {
            e.u8(1);
            e.u64(*h as u64);
            e.u64(*w as u64);
            e.u64(*c as u64);
        }
        PreOp::GlobalAvgPool { h, w, c } => {
            e.u8(2);
            e.u64(*h as u64);
            e.u64(*w as u64);
            e.u64(*c as u64);
        }
        PreOp::AdaptSpatial { from, to } => {
            e.u8(3);
            for v in [from.0, from.1, from.2, to.0, to.1, to.2] {
                e.u64(v as u64);
            }
        }
    }
}

fn dec_pre(d: &mut Dec) -> Result<PreOp> {
    Ok(match d.u8()? {
        0 => PreOp::Direct,
        1 => PreOp::MaxPool2 { h: d.usize("pool h")?,
                               w: d.usize("pool w")?,
                               c: d.usize("pool c")? },
        2 => PreOp::GlobalAvgPool { h: d.usize("gap h")?,
                                    w: d.usize("gap w")?,
                                    c: d.usize("gap c")? },
        3 => PreOp::AdaptSpatial {
            from: (d.usize("adapt from h")?, d.usize("adapt from w")?,
                   d.usize("adapt from c")?),
            to: (d.usize("adapt to h")?, d.usize("adapt to w")?,
                 d.usize("adapt to c")?),
        },
        t => bail!("plan artifact: unknown pre-op tag {t}"),
    })
}

/// Encode a plan to the versioned artifact byte format (magic +
/// format version + body + checksum).
pub fn encode_plan(plan: &EnginePlan) -> Vec<u8> {
    let mut e = Enc::new();
    e.buf.extend_from_slice(MAGIC);
    e.u32(FORMAT_VERSION);
    e.str(&plan.model);
    e.u64(plan.input_dim as u64);
    e.u64(plan.output_dim as u64);
    e.u64(plan.layers.len() as u64);
    for l in &plan.layers {
        e.str(&l.name);
        e.u64(l.in_dim as u64);
        e.u64(l.out_dim as u64);
        e.u32(l.w_bits);
        e.u32s(&l.kept);
        match &l.packed {
            None => e.u8(0),
            Some(p) => {
                e.u8(1);
                e.u32(p.bits);
                e.u8(p.signed as u8);
                e.u64(p.rows as u64);
                e.u64(p.cols as u64);
                e.u64s(p.raw_words());
            }
        }
        e.f32(l.w_scale);
        e.f32s(&l.f32_rows);
        match l.act {
            ActSpec::F32 => e.u8(0),
            ActSpec::Int { bits, beta, signed } => {
                e.u8(1);
                e.u32(bits);
                e.f32(beta);
                e.u8(signed as u8);
            }
        }
        match &l.bias {
            None => e.u8(0),
            Some(b) => {
                e.u8(1);
                e.f32s(b);
            }
        }
        e.u8(l.relu as u8);
        match &l.spatial {
            None => e.u8(0),
            Some(sp) => {
                e.u8(1);
                enc_spatial(&mut e, sp);
            }
        }
        enc_pre(&mut e, &l.pre);
    }
    let sum = fnv1a(&e.buf);
    e.u64(sum);
    e.buf
}

fn dec_bool(d: &mut Dec, what: &str) -> Result<bool> {
    match d.u8()? {
        0 => Ok(false),
        1 => Ok(true),
        t => bail!("plan artifact: bad {what} flag {t}"),
    }
}

/// Decode an artifact back into a plan. Checks magic, format version,
/// and checksum before touching the body; re-validates packed code
/// grids and plan structure after. Every failure is a typed error.
pub fn decode_plan(bytes: &[u8]) -> Result<EnginePlan> {
    if bytes.len() < MAGIC.len() + 4 + 8 {
        bail!("plan artifact truncated: {} bytes is smaller than the \
               fixed header + checksum", bytes.len());
    }
    if &bytes[..MAGIC.len()] != MAGIC {
        bail!("not a plan artifact: bad magic (expected {:?})",
              std::str::from_utf8(MAGIC).unwrap());
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let actual = fnv1a(body);
    if stored != actual {
        bail!("plan artifact checksum mismatch: stored \
               {stored:#018x}, computed {actual:#018x} — the file is \
               corrupt or was truncated/extended");
    }
    let mut d = Dec { b: body, pos: MAGIC.len() };
    let version = d.u32()?;
    if version != FORMAT_VERSION {
        bail!("plan artifact format version {version} is not \
               supported (this build reads version {FORMAT_VERSION})");
    }
    let model = d.str("model name")?;
    let input_dim = d.usize("input_dim")?;
    let output_dim = d.usize("output_dim")?;
    let nlayers = d.len("layer count")?;
    let mut layers = Vec::with_capacity(nlayers.min(PREALLOC_CAP));
    for li in 0..nlayers {
        let name = d.str("layer name")?;
        let in_dim = d.usize("in_dim")?;
        let out_dim = d.usize("out_dim")?;
        let w_bits = d.u32()?;
        let kept = d.u32s("kept channels")?;
        let packed = if dec_bool(&mut d, "packed-present")? {
            let bits = d.u32()?;
            let signed = dec_bool(&mut d, "packed-signed")?;
            let rows = d.usize("packed rows")?;
            let cols = d.usize("packed cols")?;
            let words = d.u64s("packed words")?;
            Some(PackedMatrix::from_raw(bits, signed, rows, cols,
                                        words)
                .with_context(|| {
                    format!("plan artifact: layer {li} packed matrix")
                })?)
        } else {
            None
        };
        let w_scale = d.f32()?;
        let f32_rows = d.f32s("f32 rows")?;
        let act = match d.u8()? {
            0 => ActSpec::F32,
            1 => ActSpec::Int { bits: d.u32()?,
                                beta: d.f32()?,
                                signed: dec_bool(&mut d,
                                                 "act-signed")? },
            t => bail!("plan artifact: unknown act tag {t}"),
        };
        let bias = if dec_bool(&mut d, "bias-present")? {
            Some(d.f32s("bias")?)
        } else {
            None
        };
        let relu = dec_bool(&mut d, "relu")?;
        let spatial = if dec_bool(&mut d, "spatial-present")? {
            Some(dec_spatial(&mut d)?)
        } else {
            None
        };
        let pre = dec_pre(&mut d)?;
        layers.push(PlanLayer { name, in_dim, out_dim, w_bits, kept,
                                packed, w_scale, f32_rows, act, bias,
                                relu, spatial, pre });
    }
    if d.pos != body.len() {
        bail!("plan artifact: {} trailing bytes after the layer table",
              body.len() - d.pos);
    }
    let plan = EnginePlan { model, input_dim, output_dim, layers };
    plan.validate()
        .context("plan artifact decoded but fails plan validation")?;
    Ok(plan)
}

/// Write `plan` to `path` as a versioned artifact; returns the byte
/// count written.
pub fn save_plan(path: &Path, plan: &EnginePlan) -> Result<usize> {
    let bytes = encode_plan(plan);
    std::fs::write(path, &bytes)
        .with_context(|| format!("write plan artifact {path:?}"))?;
    Ok(bytes.len())
}

/// Read + decode an artifact. Structure and packed grids are
/// validated; for the full static-verifier proof use
/// [`load_plan_verified`].
pub fn load_plan(path: &Path) -> Result<EnginePlan> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read plan artifact {path:?}"))?;
    decode_plan(&bytes)
        .with_context(|| format!("decode plan artifact {path:?}"))
}

/// [`load_plan`] plus the machine-checked proof: compile both program
/// paths (optionally forcing `backend`) and run `engine::verify` on
/// each — explicitly, so release builds get the same guarantee as
/// debug builds. The compiled pair is discarded; serving recompiles
/// lazily as usual. This is what the registry pre-warm path and
/// `bbits plan --load` go through.
pub fn load_plan_verified(path: &Path, backend: Option<Backend>)
                          -> Result<EnginePlan> {
    let plan = load_plan(path)?;
    let arc = Arc::new(plan);
    let (int_prog, f32_prog) =
        super::try_compile_pair_with(&arc, backend).map_err(|e| {
            anyhow!("plan artifact {path:?}: decoded plan failed \
                     static verification at compile: {e}")
        })?;
    for prog in [&int_prog, &f32_prog] {
        prog.verify().map_err(|e| {
            anyhow!("plan artifact {path:?} ({} path): static plan \
                     verification failed: {e}",
                    if prog.int_path() { "int" } else { "f32" })
        })?;
    }
    // the compiled programs hold plan Arcs; drop them so the plan can
    // be handed back by value (clone fallback is unreachable today)
    drop((int_prog, f32_prog));
    Ok(Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone()))
}
