//! Static buffer liveness + scratch-arena assignment — the final
//! compile pass.
//!
//! Every virtual buffer of a program gets a live interval over the
//! node list (defined by its single writer, killed after its last
//! reader; the input is live from before node 0, the output survives
//! the whole program so callers can read it afterwards). Buffers of
//! one dtype whose intervals are disjoint share arena space: a
//! first-fit scan over the currently-live allocations produces the
//! classic ping-pong pattern for a layer chain (activations bounce
//! between two slots) while long-lived buffers stay put. Offsets are
//! in per-sample element units — a batch of `n` scales every slice by
//! `n`, so one solution is valid for every batch size.
//!
//! `tests/ir.rs` re-derives liveness independently and asserts that no
//! two live buffers ever alias.

use super::graph::{BufId, BufSpec, DType, Node};
use super::verify::VerifyError;

/// Arena footprints produced by [`assign`] (per-sample element units;
/// `peak_live_bytes` is the fragmentation-free lower bound).
pub(crate) struct ArenaLayout {
    pub f32_len: usize,
    pub i32_len: usize,
    pub i64_len: usize,
    pub peak_live_bytes: usize,
}

fn dt_index(dt: DType) -> usize {
    match dt {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::I64 => 2,
    }
}

/// Assign an arena offset to every reachable buffer. Orphaned buffers
/// (never written nor read — e.g. eliminated by fusion) keep
/// `offset = None` and cost nothing. A node reading a buffer no
/// earlier node defined is a pass-pipeline bug; it comes back as a
/// typed [`VerifyError::UseBeforeDef`] so release builds get the same
/// diagnosis debug builds used to get from an assert.
pub(crate) fn assign(bufs: &mut [BufSpec], nodes: &[Node], input: BufId,
                     output: BufId) -> Result<ArenaLayout, VerifyError> {
    let nb = bufs.len();
    // def/last in event time: the input is defined at 0, node i runs
    // at i + 1. A node's src dies no earlier than its dst is born, so
    // operands of one node never share a slot.
    let mut def = vec![usize::MAX; nb];
    let mut last = vec![0usize; nb];
    def[input] = 0;
    for (i, node) in nodes.iter().enumerate() {
        let t = i + 1;
        let w = node.writes();
        if def[w] == usize::MAX {
            def[w] = t;
        }
        if last[w] < t {
            last[w] = t;
        }
        if let Some(r) = node.reads() {
            if def[r] == usize::MAX {
                return Err(VerifyError::UseBeforeDef { node: i, buf: r });
            }
            if last[r] < t {
                last[r] = t;
            }
        }
    }
    // the caller reads the output after the last node
    if def[output] != usize::MAX {
        last[output] = nodes.len() + 1;
    }

    let mut order: Vec<BufId> =
        (0..nb).filter(|b| def[*b] != usize::MAX).collect();
    order.sort_by_key(|b| def[*b]);

    let mut lens = [0usize; 3];
    // live allocations per dtype: (offset, len, last)
    let mut active: [Vec<(usize, usize, usize)>; 3] =
        [Vec::new(), Vec::new(), Vec::new()];
    for &b in &order {
        let k = dt_index(bufs[b].dtype);
        // expire allocations dead before this buffer is born
        active[k].retain(|(_, _, l)| *l >= def[b]);
        active[k].sort_unstable_by_key(|(o, _, _)| *o);
        let need = bufs[b].len;
        let mut off = 0usize;
        for (o, l, _) in &active[k] {
            if off + need <= *o {
                break; // fits in the hole before this allocation
            }
            off = off.max(o + l);
        }
        bufs[b].offset = Some(off);
        lens[k] = lens[k].max(off + need);
        active[k].push((off, need, last[b]));
    }

    // fragmentation-free peak: max over program points of live bytes
    let mut peak = 0usize;
    for t in 0..=nodes.len() + 1 {
        let mut cur = 0usize;
        for b in 0..nb {
            if def[b] != usize::MAX && def[b] <= t && last[b] >= t {
                cur += bufs[b].len * bufs[b].dtype.bytes();
            }
        }
        peak = peak.max(cur);
    }

    Ok(ArenaLayout {
        f32_len: lens[0],
        i32_len: lens[1],
        i64_len: lens[2],
        peak_live_bytes: peak,
    })
}
