//! Bit-packed integer weight storage for the power-of-two chain.
//!
//! Codes are stored little-endian inside 64-bit words, `64 / bits`
//! codes per word. Every supported width (2/4/8/16/32) divides 64, so
//! a code never straddles a word boundary; rows are padded up to a
//! whole word so one row is always an aligned `&[u64]` slice — the
//! unit the GEMM kernels decode and the unit pruned-channel elision
//! removes. Signed codes are two's complement within their field and
//! sign-extended on decode.

use anyhow::{bail, Result};

/// Widths the packer accepts — `quant::LEVELS`, the paper's chain.
pub const PACK_BITS: [u32; 5] = [2, 4, 8, 16, 32];

/// Inclusive code range for a width: the symmetric signed grid
/// `[-(2^(b-1) - 1), 2^(b-1) - 1]` or the unsigned `[0, 2^b - 1]`
/// (matching `quant::grid::quantize_codes_host`).
pub fn code_range(bits: u32, signed: bool) -> (i64, i64) {
    if signed {
        let hi = (1i64 << (bits - 1)) - 1;
        (-hi, hi)
    } else {
        (0, (1i64 << bits) - 1)
    }
}

/// A dense `rows x cols` matrix of bit-packed integer codes.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub bits: u32,
    pub signed: bool,
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl PackedMatrix {
    /// Pack row-major codes; rejects out-of-range codes and widths
    /// outside the chain.
    pub fn pack(codes: &[i64], rows: usize, cols: usize, bits: u32,
                signed: bool) -> Result<PackedMatrix> {
        if !PACK_BITS.contains(&bits) {
            bail!("unsupported pack width {bits} (chain: {PACK_BITS:?})");
        }
        if codes.len() != rows * cols {
            bail!("code count {} != {rows}x{cols}", codes.len());
        }
        let (lo, hi) = code_range(bits, signed);
        let per = (64 / bits) as usize;
        let words_per_row = cols.div_ceil(per);
        let mask = field_mask(bits);
        let mut data = vec![0u64; words_per_row * rows];
        for r in 0..rows {
            for c in 0..cols {
                let q = codes[r * cols + c];
                if q < lo || q > hi {
                    bail!(
                        "code {q} at ({r},{c}) outside {}-bit {} range \
                         [{lo}, {hi}]",
                        bits,
                        if signed { "signed" } else { "unsigned" }
                    );
                }
                let word = r * words_per_row + c / per;
                let shift = (c % per) as u32 * bits;
                data[word] |= ((q as u64) & mask) << shift;
            }
        }
        Ok(PackedMatrix { bits, signed, rows, cols, words_per_row, data })
    }

    /// Decode row `r` into `out[..cols]` for the GEMM kernels. `i32`
    /// holds every signed chain width; unsigned fields are limited to
    /// 16 bits here (the integer GEMM path never packs wider).
    pub fn unpack_row_into(&self, r: usize, out: &mut [i32]) {
        debug_assert!(self.signed || self.bits <= 16,
                      "unsigned {}-bit codes overflow i32", self.bits);
        assert!(out.len() >= self.cols);
        let per = (64 / self.bits) as usize;
        let mask = field_mask(self.bits);
        let ext = 64 - self.bits;
        let words =
            &self.data[r * self.words_per_row..(r + 1) * self.words_per_row];
        for c in 0..self.cols {
            let raw = (words[c / per] >> ((c % per) as u32 * self.bits))
                & mask;
            out[c] = if self.signed {
                (((raw << ext) as i64) >> ext) as i32
            } else {
                raw as i32
            };
        }
    }

    /// Decode the full matrix back to row-major codes (tests, report).
    pub fn unpack(&self) -> Vec<i64> {
        let per = (64 / self.bits) as usize;
        let mask = field_mask(self.bits);
        let ext = 64 - self.bits;
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            let words = &self.data
                [r * self.words_per_row..(r + 1) * self.words_per_row];
            for c in 0..self.cols {
                let raw = (words[c / per]
                    >> ((c % per) as u32 * self.bits))
                    & mask;
                out.push(if self.signed {
                    ((raw << ext) as i64) >> ext
                } else {
                    raw as i64
                });
            }
        }
        out
    }

    /// Bytes of packed storage (the dense f32 equivalent is
    /// `rows * cols * 4`).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * 8
    }

    /// The raw packed words, row-major with `cols.div_ceil(64/bits)`
    /// words per row — the plan-artifact serialization unit
    /// ([`crate::engine::artifact`]).
    pub fn raw_words(&self) -> &[u64] {
        &self.data
    }

    /// Rebuild a matrix from raw packed words (the artifact decode
    /// path). Validates width/geometry and that every padding bit and
    /// code field is in range, so a corrupt artifact surfaces as a
    /// typed error here rather than as garbage codes downstream.
    pub fn from_raw(bits: u32, signed: bool, rows: usize, cols: usize,
                    data: Vec<u64>) -> Result<PackedMatrix> {
        if !PACK_BITS.contains(&bits) {
            bail!("unsupported pack width {bits} (chain: {PACK_BITS:?})");
        }
        let per = (64 / bits) as usize;
        let words_per_row = cols.div_ceil(per);
        if data.len() != words_per_row * rows {
            bail!("packed data has {} words, {rows}x{cols} at {bits} \
                   bits needs {}", data.len(), words_per_row * rows);
        }
        let m = PackedMatrix { bits, signed, rows, cols, words_per_row,
                               data };
        let (lo, hi) = code_range(bits, signed);
        let mask = field_mask(bits);
        let ext = 64 - bits;
        for r in 0..rows {
            let words = &m.data
                [r * words_per_row..(r + 1) * words_per_row];
            for c in 0..cols {
                let raw = (words[c / per]
                    >> ((c % per) as u32 * bits))
                    & mask;
                let q = if signed {
                    ((raw << ext) as i64) >> ext
                } else {
                    raw as i64
                };
                if q < lo || q > hi {
                    bail!("packed code {q} at ({r},{c}) outside \
                           {bits}-bit range [{lo}, {hi}]");
                }
            }
            // padding fields past `cols` must be zero — a nonzero pad
            // means torn or misaligned artifact bytes
            for c in cols..words_per_row * per {
                let raw = (words[c / per]
                    >> ((c % per) as u32 * bits))
                    & mask;
                if raw != 0 {
                    bail!("nonzero padding field at row {r} col {c} \
                           in packed data");
                }
            }
        }
        Ok(m)
    }
}

fn field_mask(bits: u32) -> u64 {
    if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Panel height of the blocked weight layout: how many kept rows one
/// panel carries. Matches the kernel lane width so a panel's rows fill
/// the blocked micro-kernel's accumulator block exactly.
pub const MR: usize = 8;

/// Panel depth of the blocked weight layout. One `[MR x KC]` i32 panel
/// is `8 * 256 * 4 = 8 KiB` — a quarter of a typical 32 KiB L1d — so a
/// panel plus the activation tile it is dotted against stay resident
/// while the micro-kernel streams them.
pub const KC: usize = 256;

/// Compile-time repack of a [`PackedMatrix`] for the `blocked` kernel
/// backend: rows are decoded once (no per-call `unpack_row_into`) and
/// laid out panel-major — row blocks of up to [`MR`] rows, each split
/// into depth blocks of [`KC`] codes, stored as contiguous `[MR x KC]`
/// i32 panels. Short blocks are zero-padded to the full panel shape,
/// which is harmless because a zero code contributes nothing to any
/// exact integer dot product.
///
/// Row blocks never straddle a caller-declared group boundary (see
/// [`PanelMatrix::from_packed_grouped`]), so a conv panel's rows all
/// consume the same im2col patch.
#[derive(Debug, Clone)]
pub struct PanelMatrix {
    pub bits: u32,
    pub signed: bool,
    /// Kept (dense) row count of the source matrix.
    pub rows: usize,
    /// Shared row length (K).
    pub cols: usize,
    /// `(first_row, rows_in_block <= MR)` per row block, ascending and
    /// partitioning `0..rows`.
    blocks: Vec<(usize, usize)>,
    /// Depth blocks per row: `ceil(cols / KC)` (min 1).
    kblocks: usize,
    data: Vec<i32>,
}

impl PanelMatrix {
    /// Repack with no group boundaries (GEMM / depthwise layers).
    pub fn from_packed(w: &PackedMatrix) -> PanelMatrix {
        Self::from_packed_grouped(w, |_| 0)
    }

    /// Repack, starting a fresh row block whenever `group_of(row)`
    /// changes (conv layers: the group whose patch the row consumes).
    pub fn from_packed_grouped(w: &PackedMatrix,
                               group_of: impl Fn(usize) -> usize)
                               -> PanelMatrix {
        let (rows, cols) = (w.rows, w.cols);
        let kblocks = cols.div_ceil(KC).max(1);
        let mut blocks: Vec<(usize, usize)> = Vec::new();
        let mut r = 0;
        while r < rows {
            let g = group_of(r);
            let mut mr = 1;
            while mr < MR && r + mr < rows && group_of(r + mr) == g {
                mr += 1;
            }
            blocks.push((r, mr));
            r += mr;
        }
        if blocks.is_empty() {
            blocks.push((0, 0));
        }
        let mut data = vec![0i32; blocks.len() * kblocks * MR * KC];
        let mut row = vec![0i32; cols];
        for (b, &(r0, mr)) in blocks.iter().enumerate() {
            for m in 0..mr {
                w.unpack_row_into(r0 + m, &mut row);
                for kb in 0..kblocks {
                    let k0 = kb * KC;
                    let klen = KC.min(cols.saturating_sub(k0));
                    let dst = ((b * kblocks + kb) * MR + m) * KC;
                    data[dst..dst + klen]
                        .copy_from_slice(&row[k0..k0 + klen]);
                }
            }
        }
        PanelMatrix {
            bits: w.bits,
            signed: w.signed,
            rows,
            cols,
            blocks,
            kblocks,
            data,
        }
    }

    /// The `(first_row, rows_in_block)` row blocks, ascending.
    pub fn blocks(&self) -> &[(usize, usize)] {
        &self.blocks
    }

    /// Depth blocks per row (`ceil(cols / KC)`, min 1).
    pub fn kblocks(&self) -> usize {
        self.kblocks
    }

    /// One contiguous `[MR x KC]` panel: row `m` of row block `b`
    /// occupies `[m * KC .. m * KC + KC]`, zero-padded past the true
    /// row count / row length.
    #[inline]
    pub fn panel(&self, b: usize, kb: usize) -> &[i32] {
        let base = (b * self.kblocks + kb) * MR * KC;
        &self.data[base..base + MR * KC]
    }

    /// Resident bytes of the decoded panel storage (the price of
    /// skipping per-call row decode on the blocked backend).
    pub fn panel_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths_signed_and_unsigned() {
        let mut rng = crate::rng::Pcg64::new(5);
        for bits in PACK_BITS {
            for signed in [true, false] {
                let (lo, hi) = code_range(bits, signed);
                let rows = 3;
                let cols = 17; // forces row padding for every width
                let codes: Vec<i64> = (0..rows * cols)
                    .map(|_| {
                        lo + (rng.next_u64()
                            % ((hi - lo + 1) as u64)) as i64
                    })
                    .collect();
                let p = PackedMatrix::pack(&codes, rows, cols, bits,
                                           signed)
                    .unwrap();
                assert_eq!(p.unpack(), codes, "bits={bits}");
            }
        }
    }

    #[test]
    fn storage_shrinks_with_width() {
        let codes = vec![0i64; 8 * 64];
        let b2 = PackedMatrix::pack(&codes, 8, 64, 2, true).unwrap();
        let b16 = PackedMatrix::pack(&codes, 8, 64, 16, true).unwrap();
        assert_eq!(b2.packed_bytes(), 8 * 64 / 4);
        assert_eq!(b16.packed_bytes(), 8 * 64 * 2);
        // 2-bit is 16x smaller than the dense f32 blob
        assert_eq!(b2.packed_bytes() * 16, 8 * 64 * 4);
    }

    #[test]
    fn rejects_out_of_range_and_bad_width() {
        assert!(PackedMatrix::pack(&[2], 1, 1, 2, true).is_err());
        assert!(PackedMatrix::pack(&[-1], 1, 1, 2, false).is_err());
        assert!(PackedMatrix::pack(&[0], 1, 1, 3, true).is_err());
        assert!(PackedMatrix::pack(&[0, 0], 1, 1, 2, true).is_err());
    }

    #[test]
    fn extreme_codes_survive_sign_extension() {
        for bits in PACK_BITS {
            let (lo, hi) = code_range(bits, true);
            let codes = vec![lo, -1, 0, 1, hi];
            let p = PackedMatrix::pack(&codes, 1, 5, bits, true).unwrap();
            assert_eq!(p.unpack(), codes, "bits={bits}");
        }
    }

    /// Read code `(r, c)` back out of the panel layout.
    fn panel_code(pm: &PanelMatrix, r: usize, c: usize) -> i32 {
        let (b, m) = pm
            .blocks()
            .iter()
            .enumerate()
            .find_map(|(b, &(r0, mr))| {
                (r >= r0 && r < r0 + mr).then_some((b, r - r0))
            })
            .unwrap();
        pm.panel(b, c / KC)[m * KC + c % KC]
    }

    #[test]
    fn panel_layout_roundtrips_every_remainder_shape() {
        let mut rng = crate::rng::Pcg64::new(41);
        // row counts around MR multiples, row lengths around KC
        // multiples — every padding case of the panel layout
        for rows in [1usize, MR - 1, MR, MR + 1, 3 * MR + 1] {
            for cols in [1usize, 7, KC - 1, KC, KC + 1, 2 * KC + 17] {
                let codes: Vec<i64> = (0..rows * cols)
                    .map(|_| (rng.next_u64() % 15) as i64 - 7)
                    .collect();
                let w = PackedMatrix::pack(&codes, rows, cols, 4, true)
                    .unwrap();
                let pm = PanelMatrix::from_packed(&w);
                assert_eq!(pm.kblocks(), cols.div_ceil(KC).max(1));
                let covered: usize =
                    pm.blocks().iter().map(|&(_, mr)| mr).sum();
                assert_eq!(covered, rows, "rows={rows}");
                for r in 0..rows {
                    for c in 0..cols {
                        assert_eq!(panel_code(&pm, r, c) as i64,
                                   codes[r * cols + c],
                                   "rows={rows} cols={cols} ({r},{c})");
                    }
                }
            }
        }
    }

    #[test]
    fn panel_blocks_never_straddle_groups() {
        // 11 rows in groups [4, 4, 3]: blocks must break at rows 4
        // and 8 even though MR is wider
        let codes = vec![1i64; 11 * 6];
        let w = PackedMatrix::pack(&codes, 11, 6, 2, true).unwrap();
        let group = |r: usize| r / 4;
        let pm = PanelMatrix::from_packed_grouped(&w, group);
        for &(r0, mr) in pm.blocks() {
            assert!(mr >= 1 && mr <= MR);
            assert_eq!(group(r0), group(r0 + mr - 1),
                       "block ({r0},{mr}) straddles a group");
        }
        assert_eq!(pm.blocks().iter().map(|&(_, m)| m).sum::<usize>(),
                   11);
        // padding rows and padding columns read back as zero
        let panel = pm.panel(0, 0);
        for m in 4..MR {
            assert!(panel[m * KC..(m + 1) * KC].iter().all(|v| *v == 0));
        }
        assert!(panel[6..KC].iter().all(|v| *v == 0));
    }
}
