//! Bit-packed integer weight storage for the power-of-two chain.
//!
//! Codes are stored little-endian inside 64-bit words, `64 / bits`
//! codes per word. Every supported width (2/4/8/16/32) divides 64, so
//! a code never straddles a word boundary; rows are padded up to a
//! whole word so one row is always an aligned `&[u64]` slice — the
//! unit the GEMM kernels decode and the unit pruned-channel elision
//! removes. Signed codes are two's complement within their field and
//! sign-extended on decode.

use anyhow::{bail, Result};

/// Widths the packer accepts — `quant::LEVELS`, the paper's chain.
pub const PACK_BITS: [u32; 5] = [2, 4, 8, 16, 32];

/// Inclusive code range for a width: the symmetric signed grid
/// `[-(2^(b-1) - 1), 2^(b-1) - 1]` or the unsigned `[0, 2^b - 1]`
/// (matching `quant::grid::quantize_codes_host`).
pub fn code_range(bits: u32, signed: bool) -> (i64, i64) {
    if signed {
        let hi = (1i64 << (bits - 1)) - 1;
        (-hi, hi)
    } else {
        (0, (1i64 << bits) - 1)
    }
}

/// A dense `rows x cols` matrix of bit-packed integer codes.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub bits: u32,
    pub signed: bool,
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl PackedMatrix {
    /// Pack row-major codes; rejects out-of-range codes and widths
    /// outside the chain.
    pub fn pack(codes: &[i64], rows: usize, cols: usize, bits: u32,
                signed: bool) -> Result<PackedMatrix> {
        if !PACK_BITS.contains(&bits) {
            bail!("unsupported pack width {bits} (chain: {PACK_BITS:?})");
        }
        if codes.len() != rows * cols {
            bail!("code count {} != {rows}x{cols}", codes.len());
        }
        let (lo, hi) = code_range(bits, signed);
        let per = (64 / bits) as usize;
        let words_per_row = cols.div_ceil(per);
        let mask = field_mask(bits);
        let mut data = vec![0u64; words_per_row * rows];
        for r in 0..rows {
            for c in 0..cols {
                let q = codes[r * cols + c];
                if q < lo || q > hi {
                    bail!(
                        "code {q} at ({r},{c}) outside {}-bit {} range \
                         [{lo}, {hi}]",
                        bits,
                        if signed { "signed" } else { "unsigned" }
                    );
                }
                let word = r * words_per_row + c / per;
                let shift = (c % per) as u32 * bits;
                data[word] |= ((q as u64) & mask) << shift;
            }
        }
        Ok(PackedMatrix { bits, signed, rows, cols, words_per_row, data })
    }

    /// Decode row `r` into `out[..cols]` for the GEMM kernels. `i32`
    /// holds every signed chain width; unsigned fields are limited to
    /// 16 bits here (the integer GEMM path never packs wider).
    pub fn unpack_row_into(&self, r: usize, out: &mut [i32]) {
        debug_assert!(self.signed || self.bits <= 16,
                      "unsigned {}-bit codes overflow i32", self.bits);
        assert!(out.len() >= self.cols);
        let per = (64 / self.bits) as usize;
        let mask = field_mask(self.bits);
        let ext = 64 - self.bits;
        let words =
            &self.data[r * self.words_per_row..(r + 1) * self.words_per_row];
        for c in 0..self.cols {
            let raw = (words[c / per] >> ((c % per) as u32 * self.bits))
                & mask;
            out[c] = if self.signed {
                (((raw << ext) as i64) >> ext) as i32
            } else {
                raw as i32
            };
        }
    }

    /// Decode the full matrix back to row-major codes (tests, report).
    pub fn unpack(&self) -> Vec<i64> {
        let per = (64 / self.bits) as usize;
        let mask = field_mask(self.bits);
        let ext = 64 - self.bits;
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            let words = &self.data
                [r * self.words_per_row..(r + 1) * self.words_per_row];
            for c in 0..self.cols {
                let raw = (words[c / per]
                    >> ((c % per) as u32 * self.bits))
                    & mask;
                out.push(if self.signed {
                    ((raw << ext) as i64) >> ext
                } else {
                    raw as i64
                });
            }
        }
        out
    }

    /// Bytes of packed storage (the dense f32 equivalent is
    /// `rows * cols * 4`).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

fn field_mask(bits: u32) -> u64 {
    if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths_signed_and_unsigned() {
        let mut rng = crate::rng::Pcg64::new(5);
        for bits in PACK_BITS {
            for signed in [true, false] {
                let (lo, hi) = code_range(bits, signed);
                let rows = 3;
                let cols = 17; // forces row padding for every width
                let codes: Vec<i64> = (0..rows * cols)
                    .map(|_| {
                        lo + (rng.next_u64()
                            % ((hi - lo + 1) as u64)) as i64
                    })
                    .collect();
                let p = PackedMatrix::pack(&codes, rows, cols, bits,
                                           signed)
                    .unwrap();
                assert_eq!(p.unpack(), codes, "bits={bits}");
            }
        }
    }

    #[test]
    fn storage_shrinks_with_width() {
        let codes = vec![0i64; 8 * 64];
        let b2 = PackedMatrix::pack(&codes, 8, 64, 2, true).unwrap();
        let b16 = PackedMatrix::pack(&codes, 8, 64, 16, true).unwrap();
        assert_eq!(b2.packed_bytes(), 8 * 64 / 4);
        assert_eq!(b16.packed_bytes(), 8 * 64 * 2);
        // 2-bit is 16x smaller than the dense f32 blob
        assert_eq!(b2.packed_bytes() * 16, 8 * 64 * 4);
    }

    #[test]
    fn rejects_out_of_range_and_bad_width() {
        assert!(PackedMatrix::pack(&[2], 1, 1, 2, true).is_err());
        assert!(PackedMatrix::pack(&[-1], 1, 1, 2, false).is_err());
        assert!(PackedMatrix::pack(&[0], 1, 1, 3, true).is_err());
        assert!(PackedMatrix::pack(&[0, 0], 1, 1, 2, true).is_err());
    }

    #[test]
    fn extreme_codes_survive_sign_extension() {
        for bits in PACK_BITS {
            let (lo, hi) = code_range(bits, true);
            let codes = vec![lo, -1, 0, 1, hi];
            let p = PackedMatrix::pack(&codes, 1, 5, bits, true).unwrap();
            assert_eq!(p.unpack(), codes, "bits={bits}");
        }
    }
}
