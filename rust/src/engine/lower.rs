//! Lowering: trained checkpoint + thresholded gates -> executable
//! integer plan.
//!
//! For every layer in the manifest descriptor the lowering
//!
//! 1. thresholds the checkpoint's phi logits through the Eq. 22 gate
//!    chain (`GateManager::test_gates` under the Bayesian-Bits lock
//!    pattern) to obtain the layer's learned weight/activation bit
//!    widths and its per-channel pruning mask;
//! 2. folds the learned clip range beta into a per-tensor grid step
//!    (the closed form of `quant::grid::step_sizes` at the selected
//!    width) with zero-point 0 — the decomposition's grids are
//!    symmetric (signed) or one-sided (unsigned), never affine;
//! 3. physically elides pruned output channels: only surviving rows
//!    are quantized, packed, and stored;
//! 4. keeps conv/dwconv rows in `[cout, cin/groups * k * k]` layout
//!    and attaches a [`SpatialPlan`] (from the manifest's spatial
//!    metadata) plus the inferred inter-layer [`PreOp`] (max pool,
//!    flatten, global average pool), so image-shaped inputs flow
//!    train -> lower -> serve on the real spatial datapath; manifests
//!    from pre-spatial exporters fall back to the legacy flattened
//!    GEMM behind the flat feature adapter;
//! 5. emits bit-packed codes for widths < 32 and the simulated-quant
//!    dense rows that the f32 fallback and parity tests consume.
//!
//! The resulting [`EnginePlan`] is the engine's stable lowering
//! contract; execution compiles it further into the typed graph IR
//! (`engine::graph::Program::compile` runs the `engine::passes`
//! pipeline over the plan — `bbits plan --dump-ir` shows the result).

use anyhow::{bail, Context, Result};

use super::pack::PackedMatrix;
use super::{ActSpec, EnginePlan, PlanLayer, PreOp, SpatialPlan};
use crate::config::Mode;
use crate::coordinator::gate_manager::GateManager;
use crate::models::Padding;
use crate::quant::gates;
use crate::quant::grid::quantize_codes_host;
use crate::rng::Pcg64;
use crate::runtime::Manifest;

/// Lower one dense weight matrix (`out_dim x in_dim`, row-major) into
/// a [`PlanLayer`]. Shared by the manifest path and the synthetic
/// builder; weights are signed (the paper's weight grids always are).
pub fn build_layer(name: &str, dense_w: &[f32], in_dim: usize,
                   out_dim: usize, z2: &[f32], w_bits: u32, w_beta: f32,
                   act: ActSpec, bias: Option<Vec<f32>>, relu: bool)
                   -> Result<PlanLayer> {
    if dense_w.len() != in_dim * out_dim {
        bail!("layer {name}: weight len {} != {out_dim}x{in_dim}",
              dense_w.len());
    }
    if z2.len() != out_dim {
        bail!("layer {name}: {} channel gates for {out_dim} channels",
              z2.len());
    }
    let kept: Vec<u32> = if w_bits == 0 {
        Vec::new()
    } else {
        (0..out_dim as u32).filter(|c| z2[*c as usize] > 0.5).collect()
    };
    let w_bits = if kept.is_empty() { 0 } else { w_bits };
    let mut rows_f32 = Vec::with_capacity(kept.len() * in_dim);
    for c in &kept {
        let r = *c as usize;
        rows_f32.extend_from_slice(&dense_w[r * in_dim..(r + 1) * in_dim]);
    }
    let (packed, w_scale, f32_rows) = if w_bits == 0 {
        (None, 1.0, Vec::new())
    } else if w_bits >= 32 {
        (None, 1.0, rows_f32)
    } else {
        let (step, codes) =
            quantize_codes_host(&rows_f32, w_beta, w_bits, true);
        let packed =
            PackedMatrix::pack(&codes, kept.len(), in_dim, w_bits, true)
                .with_context(|| format!("packing layer {name}"))?;
        let deq: Vec<f32> =
            codes.iter().map(|q| step * *q as f32).collect();
        (Some(packed), step, deq)
    };
    Ok(PlanLayer {
        name: name.to_string(),
        in_dim,
        out_dim,
        w_bits,
        kept,
        packed,
        w_scale,
        f32_rows,
        act,
        bias,
        relu,
        spatial: None,
        pre: PreOp::Direct,
    })
}

/// Lower one conv/dwconv weight tensor already oriented to
/// `[cout, cin/groups * k * k]` rows into a spatial [`PlanLayer`]
/// executing over `sp`, fed through `pre`.
#[allow(clippy::too_many_arguments)]
pub fn build_conv_layer(name: &str, dense_w: &[f32], sp: SpatialPlan,
                        out_dim: usize, z2: &[f32], w_bits: u32,
                        w_beta: f32, act: ActSpec,
                        bias: Option<Vec<f32>>, relu: bool, pre: PreOp)
                        -> Result<PlanLayer> {
    if out_dim % sp.groups != 0 {
        bail!("layer {name}: {out_dim} outputs not divisible into {} \
               groups", sp.groups);
    }
    let mut layer = build_layer(name, dense_w, sp.patch_len(), out_dim,
                                z2, w_bits, w_beta, act, bias, relu)?;
    layer.spatial = Some(sp);
    layer.pre = pre;
    Ok(layer)
}

/// Single-layer plan around [`build_layer`] (tests, micro-benches).
#[allow(clippy::too_many_arguments)]
pub fn build_plan_single(name: &str, dense_w: &[f32], in_dim: usize,
                         out_dim: usize, z2: &[f32], w_bits: u32,
                         w_beta: f32, act: ActSpec,
                         bias: Option<Vec<f32>>, relu: bool)
                         -> Result<EnginePlan> {
    let layer = build_layer(name, dense_w, in_dim, out_dim, z2, w_bits,
                            w_beta, act, bias, relu)?;
    let plan = EnginePlan {
        model: name.to_string(),
        input_dim: in_dim,
        output_dim: out_dim,
        layers: vec![layer],
    };
    plan.validate()?;
    Ok(plan)
}

/// Lower a trained Bayesian-Bits checkpoint into an executable plan,
/// thresholding gates under the full `Mode::BayesianBits` lock
/// pattern. For checkpoints trained in another mode (whose phi slots
/// were locked rather than learned) use [`lower_with_mode`] so the
/// lock values — not the untrained logits — decide the bit widths.
pub fn lower(man: &Manifest, params: &[f32]) -> Result<EnginePlan> {
    lower_with_mode(man, params, &Mode::BayesianBits)
}

/// [`lower`] with an explicit training mode selecting the gate-lock
/// pattern (`bbits serve --mode fixed:w8a8 ...` for an LSQ-style
/// baseline checkpoint, etc.).
pub fn lower_with_mode(man: &Manifest, params: &[f32], mode: &Mode)
                       -> Result<EnginePlan> {
    lower_with_mode_at(man, params, mode, gates::THRESHOLD)
}

/// [`lower_with_mode`] at an explicit Eq. 22 gate threshold in (0, 1):
/// the precision-ladder primitive. One trained posterior lowered at
/// several thresholds yields a family of plans — a smaller threshold
/// opens fewer gates (shorter residual bit chains, more pruned
/// channels => a cheaper rung), a larger one opens more. The default
/// (`gates::THRESHOLD`) keeps [`lower`] / [`lower_with_mode`]
/// bit-exact with the committed golden fixture.
pub fn lower_with_mode_at(man: &Manifest, params: &[f32], mode: &Mode,
                          threshold: f64) -> Result<EnginePlan> {
    if !(threshold > 0.0 && threshold < 1.0) {
        bail!("gate threshold must be in (0, 1), got {}", threshold);
    }
    if man.engine != "bb" {
        bail!("engine lowering needs a Bayesian-Bits manifest, got {:?}",
              man.engine);
    }
    if matches!(mode, Mode::Dq) {
        bail!("DQ checkpoints have no gate chain to lower");
    }
    if params.len() != man.n_params {
        bail!("checkpoint has {} params, manifest {} wants {}",
              params.len(), man.name, man.n_params);
    }
    let gm = GateManager::new(man);
    let (lock_mask, lock_val) = gm.locks(mode);
    let phi: Vec<f64> = man
        .phi_index()
        .iter()
        .map(|i| params[*i] as f64)
        .collect();
    let gates = gm.test_gates_at(&phi, &lock_mask, &lock_val, threshold);

    let n_layers = man.layers.len();
    let mut layers = Vec::with_capacity(n_layers);
    let mut warned_legacy = false;
    // NHWC shape of the feature map entering the next layer, tracked
    // to infer each layer's PreOp; None once the map is flattened (or
    // unknown, on the legacy path).
    let mut shape: Option<(usize, usize, usize)> =
        match man.input_shape[..] {
            [h, w, c] => Some((h, w, c)),
            _ => None,
        };
    for (li, l) in man.layers.iter().enumerate() {
        let wq = man.quantizer(&l.weight_q)?;
        let aq = man.quantizer(&l.act_q)?;
        if !wq.signed {
            bail!("layer {}: unsigned weight quantizer unsupported",
                  l.name);
        }
        if wq.channels != l.cout {
            bail!("layer {}: quantizer has {} channel gates, layer has \
                   {} outputs", l.name, wq.channels, l.cout);
        }
        let wz = &gates[wq.offset..wq.offset + wq.n_slots];
        let az = &gates[aq.offset..aq.offset + aq.n_slots];
        let w_bits = wq.view().effective_bits(wz);
        let a_bits = aq.view().effective_bits(az);
        let wp = man.param(&l.weight_q)?;
        if wp.size % l.cout != 0 {
            bail!("layer {}: weight size {} not divisible by cout {}",
                  l.name, wp.size, l.cout);
        }
        let in_dim = wp.size / l.cout;
        let dense = orient_rows(&params[wp.offset..wp.offset + wp.size],
                                &wp.shape, l.cout)
            .with_context(|| format!("layer {}", l.name))?;
        let w_beta =
            param_scalar(man, params, &format!("{}.beta", l.weight_q))?;
        let a_beta =
            param_scalar(man, params, &format!("{}.beta", l.act_q))?;
        let act = if a_bits >= 32 {
            ActSpec::F32
        } else {
            ActSpec::Int { bits: a_bits, beta: a_beta, signed: aq.signed }
        };
        let bias = man
            .param(&format!("{}.b", l.name))
            .ok()
            .filter(|p| p.size == l.cout)
            .map(|p| params[p.offset..p.offset + p.size].to_vec());
        let z2: Vec<f32> = wz[..wq.channels].to_vec();
        let relu = li + 1 < n_layers;
        let layer = match &l.conv {
            Some(m) if l.kind != "dense" => {
                let sp = SpatialPlan::new(m.in_h, m.in_w, l.cin,
                                          m.ksize, m.stride, m.padding,
                                          m.groups)
                    .with_context(|| format!("layer {}", l.name))?;
                if in_dim != sp.patch_len() {
                    bail!("layer {}: weight fan-in {} != \
                           cin/groups*k*k = {}", l.name, in_dim,
                          sp.patch_len());
                }
                // manifest-recorded interstitial op, else infer it
                // from the previous output map and this input map
                let target = (m.in_h, m.in_w, l.cin);
                let pre = pre_from_ops(&l.pre_ops, shape)
                    .unwrap_or(match shape {
                        Some(s) if s == target => PreOp::Direct,
                        // max_pool2 is VALID 2x2/stride-2: floor, so an
                        // odd map drops its last row/column
                        Some((h, w, c))
                            if c == l.cin && h / 2 == m.in_h
                                && w / 2 == m.in_w && h > m.in_h
                                && w > m.in_w =>
                        {
                            PreOp::MaxPool2 { h, w, c }
                        }
                        Some(s) => {
                            PreOp::AdaptSpatial { from: s, to: target }
                        }
                        None => PreOp::Direct,
                    });
                shape = Some((sp.out_h, sp.out_w, l.cout));
                build_conv_layer(&l.name, &dense, sp, l.cout, &z2,
                                 w_bits, w_beta, act, bias, relu, pre)?
            }
            _ => {
                if l.kind != "dense" && !warned_legacy {
                    crate::util::logging::warn(format!(
                        "layer {}: manifest carries no spatial \
                         metadata (pre-spatial exporter); lowering {} \
                         layers as flattened GEMMs behind the legacy \
                         feature adapter",
                        l.name, l.kind
                    ));
                    warned_legacy = true;
                }
                // manifest-recorded op wins; the shape fallback cannot
                // distinguish maxpool->flatten from global_avg_pool on
                // a 2x2 map (both leave c features), so pre-schema
                // manifests with a 2x2 head resolve to the pool arm
                let pre = pre_from_ops(&l.pre_ops, shape)
                    .unwrap_or(match shape {
                        // NHWC flatten is a memory no-op
                        Some((h, w, c)) if h * w * c == in_dim => {
                            PreOp::Direct
                        }
                        // max_pool2 -> flatten (LeNet/VGG head)
                        Some((h, w, c))
                            if (h / 2) * (w / 2) * c == in_dim =>
                        {
                            PreOp::MaxPool2 { h, w, c }
                        }
                        // global_avg_pool (ResNet/MobileNet head)
                        Some((h, w, c)) if c == in_dim => {
                            PreOp::GlobalAvgPool { h, w, c }
                        }
                        _ => PreOp::Direct,
                    });
                shape = None;
                let mut layer =
                    build_layer(&l.name, &dense, in_dim, l.cout, &z2,
                                w_bits, w_beta, act, bias, relu)?;
                layer.pre = pre;
                layer
            }
        };
        layers.push(layer);
    }
    let plan = EnginePlan {
        model: man.name.clone(),
        input_dim: man.input_shape.iter().product::<usize>().max(1),
        output_dim: layers.last().map(|l| l.output_len()).unwrap_or(0),
        layers,
    };
    plan.validate()?;
    Ok(plan)
}

/// A deterministic random plan for demos, benches, and serve smoke
/// runs when no checkpoint is available. `dims` is the layer width
/// chain (`[in, hidden..., out]`); `prune` is the per-channel pruning
/// probability on hidden layers (the output layer keeps every class).
pub fn synthetic_plan(name: &str, dims: &[usize], w_bits: u32,
                      a_bits: u32, prune: f64, seed: u64)
                      -> Result<EnginePlan> {
    if dims.len() < 2 {
        bail!("synthetic plan needs at least [in, out] dims, got {dims:?}");
    }
    if dims.iter().any(|d| *d == 0) {
        bail!("synthetic plan dims must be positive, got {dims:?}");
    }
    let mut rng = Pcg64::new(seed);
    let n_layers = dims.len() - 1;
    let mut layers = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        let (din, dout) = (dims[i], dims[i + 1]);
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() * 0.5).collect();
        let last = i + 1 == n_layers;
        let mut z2 = vec![1.0f32; dout];
        if !last && prune > 0.0 {
            for z in z2.iter_mut() {
                if rng.next_f64() < prune {
                    *z = 0.0;
                }
            }
            if z2.iter().all(|z| *z == 0.0) {
                z2[0] = 1.0;
            }
        }
        let act = if a_bits >= 32 {
            ActSpec::F32
        } else {
            // raw features are signed; post-ReLU activations are not
            ActSpec::Int {
                bits: a_bits,
                beta: if i == 0 { 3.0 } else { 6.0 },
                signed: i == 0,
            }
        };
        let bias: Vec<f32> =
            (0..dout).map(|_| rng.normal() * 0.1).collect();
        layers.push(build_layer(&format!("fc{}", i + 1), &w, din, dout,
                                &z2, w_bits, 1.5, act, Some(bias),
                                !last)?);
    }
    let plan = EnginePlan {
        model: name.to_string(),
        input_dim: dims[0],
        output_dim: *dims.last().unwrap(),
        layers,
    };
    plan.validate()?;
    Ok(plan)
}

/// A deterministic random single-conv-layer plan (benches, parity
/// tests, serve smoke runs): `hw x hw x cin` NHWC input, `cout`
/// output channels, `k x k` kernel. `groups == cin` builds a
/// depthwise layer; `prune` is the per-channel pruning probability
/// (at least one channel always survives).
#[allow(clippy::too_many_arguments)]
pub fn synthetic_conv_plan(name: &str, hw: usize, cin: usize,
                           cout: usize, k: usize, stride: usize,
                           padding: Padding, groups: usize, w_bits: u32,
                           a_bits: u32, prune: f64, seed: u64)
                           -> Result<EnginePlan> {
    let sp = SpatialPlan::new(hw, hw, cin, k, stride, padding, groups)?;
    let mut rng = Pcg64::new(seed);
    let plen = sp.patch_len();
    let w: Vec<f32> =
        (0..cout * plen).map(|_| rng.normal() * 0.4).collect();
    let mut z2 = vec![1.0f32; cout];
    if prune > 0.0 {
        for z in z2.iter_mut() {
            if rng.next_f64() < prune {
                *z = 0.0;
            }
        }
        if z2.iter().all(|z| *z == 0.0) {
            z2[0] = 1.0;
        }
    }
    let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
    let act = if a_bits >= 32 {
        ActSpec::F32
    } else {
        ActSpec::Int { bits: a_bits, beta: 3.0, signed: true }
    };
    let out_len = sp.out_pixels() * cout;
    let layer = build_conv_layer(name, &w, sp, cout, &z2, w_bits, 1.5,
                                 act, Some(bias), false,
                                 PreOp::Direct)?;
    let plan = EnginePlan {
        model: name.to_string(),
        input_dim: hw * hw * cin,
        output_dim: out_len,
        layers: vec![layer],
    };
    plan.validate()?;
    Ok(plan)
}

/// Map a manifest-recorded interstitial op list (`pre` field) onto a
/// [`PreOp`], given the tracked NHWC shape of the previous layer's
/// output. `None` means nothing usable was recorded — pre-schema
/// manifests, an unknown op sequence, or an untracked shape — and the
/// caller falls back to the shape heuristic.
fn pre_from_ops(ops: &[String], shape: Option<(usize, usize, usize)>)
                -> Option<PreOp> {
    if ops.is_empty() {
        return None;
    }
    if ops.iter().any(|o| o != "maxpool2" && o != "gap" && o != "flatten")
    {
        return None;
    }
    let (h, w, c) = shape?;
    let pools = ops.iter().filter(|o| *o == "maxpool2").count();
    let gaps = ops.iter().filter(|o| *o == "gap").count();
    match (pools, gaps) {
        // flatten alone is a memory no-op on NHWC buffers
        (0, 0) => Some(PreOp::Direct),
        // pooling a 1-pixel axis would leave an empty map; defer such
        // malformed geometry to the shape heuristic / runtime bridge
        (1, 0) if h >= 2 && w >= 2 => {
            Some(PreOp::MaxPool2 { h, w, c })
        }
        (0, 1) => Some(PreOp::GlobalAvgPool { h, w, c }),
        // stacked pools etc. are not modelled as a single PreOp
        _ => None,
    }
}

/// Reorient a flat weight tensor to row-major `[cout, rest]` rows.
///
/// The exporter's convention is channel-*last* (JAX: HWIO conv
/// kernels, `[din, dout]` dense kernels — see python/compile/layers.py),
/// so channel-last wins when both ends match (square dense layers);
/// channel-first (OIHW-style) is accepted as a fallback.
fn orient_rows(w: &[f32], shape: &[usize], cout: usize)
               -> Result<Vec<f32>> {
    if shape.last() == Some(&cout) {
        let rest = w.len() / cout;
        let mut out = vec![0.0f32; w.len()];
        for i in 0..rest {
            for o in 0..cout {
                out[o * rest + i] = w[i * cout + o];
            }
        }
        return Ok(out);
    }
    if shape.first() == Some(&cout) {
        return Ok(w.to_vec());
    }
    bail!("weight shape {shape:?} has no {cout}-channel axis at either \
           end")
}

fn param_scalar(man: &Manifest, params: &[f32], name: &str)
                -> Result<f32> {
    let p = man
        .param(name)
        .with_context(|| format!("engine lowering needs {name}"))?;
    Ok(params[p.offset])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orient_accepts_both_layouts() {
        // channel-first [2, 3]: rows already contiguous
        let w = vec![1., 2., 3., 10., 20., 30.];
        assert_eq!(orient_rows(&w, &[2, 3], 2).unwrap(), w);
        // channel-last [3, 2]: transpose into 2 rows of 3
        let wt = vec![1., 10., 2., 20., 3., 30.];
        assert_eq!(orient_rows(&wt, &[3, 2], 2).unwrap(), w);
        assert!(orient_rows(&w, &[3, 2], 5).is_err());
        // square dense [2, 2] is ambiguous; the exporter convention is
        // channel-last ([din, dout]), so it must transpose
        let sq = vec![1., 10., 2., 20.];
        assert_eq!(orient_rows(&sq, &[2, 2], 2).unwrap(),
                   vec![1., 2., 10., 20.]);
    }

    #[test]
    fn build_layer_elides_pruned_rows() {
        let w = vec![0.5f32; 8]; // 4 out x 2 in
        let l = build_layer("t", &w, 2, 4, &[1., 0., 1., 0.], 4, 1.0,
                            ActSpec::F32, None, false)
            .unwrap();
        assert_eq!(l.kept, vec![0, 2]);
        assert_eq!(l.f32_rows.len(), 4);
        let p = l.packed.as_ref().unwrap();
        assert_eq!((p.rows, p.cols, p.bits), (2, 2, 4));
        // dequantized rows reconstruct code * step exactly
        for (v, q) in l.f32_rows.iter().zip(p.unpack()) {
            assert_eq!(*v, l.w_scale * q as f32);
        }
    }

    #[test]
    fn build_layer_zero_bits_means_empty() {
        let w = vec![1.0f32; 6];
        let l = build_layer("t", &w, 3, 2, &[1., 1.], 0, 1.0,
                            ActSpec::F32, None, false)
            .unwrap();
        assert!(l.kept.is_empty());
        assert!(l.packed.is_none());
        assert!(l.f32_rows.is_empty());
    }

    #[test]
    fn build_layer_32_bits_keeps_raw_weights() {
        let w = vec![0.123f32, -4.5, 0.0, 7.7, 1.0, -1.0];
        let l = build_layer("t", &w, 3, 2, &[1., 1.], 32, 1.0,
                            ActSpec::F32, None, false)
            .unwrap();
        assert!(l.packed.is_none());
        assert_eq!(l.f32_rows, w);
        assert_eq!(l.w_scale, 1.0);
    }

    #[test]
    fn synthetic_conv_plan_builds_spatial_layer() {
        let p = synthetic_conv_plan("c", 6, 3, 5, 3, 2, Padding::Same,
                                    1, 4, 8, 0.3, 7)
            .unwrap();
        let l = &p.layers[0];
        let sp = l.spatial.as_ref().unwrap();
        assert_eq!((sp.out_h, sp.out_w), (3, 3));
        assert_eq!(l.in_dim, 27);
        assert!(!l.kept.is_empty());
        assert_eq!(p.input_dim, 6 * 6 * 3);
        assert_eq!(p.output_dim, 9 * 5);
        assert!(l.packed.is_some());
        // groups must divide the input channels
        assert!(synthetic_conv_plan("c", 6, 3, 5, 3, 1, Padding::Same,
                                    2, 4, 8, 0.0, 1)
            .is_err());
        // depthwise: cout must divide into groups
        assert!(synthetic_conv_plan("c", 6, 4, 6, 3, 1, Padding::Same,
                                    4, 4, 8, 0.0, 1)
            .is_err());
        let dw = synthetic_conv_plan("dw", 6, 4, 4, 3, 1, Padding::Same,
                                     4, 4, 8, 0.0, 1)
            .unwrap();
        assert_eq!(dw.layers[0].in_dim, 9);
    }

    #[test]
    fn pre_from_ops_maps_recorded_sequences() {
        let sh = Some((6, 6, 4));
        let ops = |v: &[&str]| -> Vec<String> {
            v.iter().map(|s| s.to_string()).collect()
        };
        assert_eq!(pre_from_ops(&ops(&[]), sh), None);
        assert_eq!(pre_from_ops(&ops(&["flatten"]), sh),
                   Some(PreOp::Direct));
        assert_eq!(pre_from_ops(&ops(&["maxpool2"]), sh),
                   Some(PreOp::MaxPool2 { h: 6, w: 6, c: 4 }));
        assert_eq!(pre_from_ops(&ops(&["maxpool2", "flatten"]), sh),
                   Some(PreOp::MaxPool2 { h: 6, w: 6, c: 4 }));
        assert_eq!(pre_from_ops(&ops(&["gap"]), sh),
                   Some(PreOp::GlobalAvgPool { h: 6, w: 6, c: 4 }));
        // unknown ops and stacked pools defer to the shape heuristic
        assert_eq!(pre_from_ops(&ops(&["upsample"]), sh), None);
        assert_eq!(pre_from_ops(&ops(&["maxpool2", "maxpool2"]), sh),
                   None);
        // pooling a 1-pixel axis would leave an empty map: rejected
        assert_eq!(pre_from_ops(&ops(&["maxpool2"]), Some((1, 8, 4))),
                   None);
        // recorded ops without a tracked shape cannot be applied
        assert_eq!(pre_from_ops(&ops(&["maxpool2"]), None), None);
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = synthetic_plan("s", &[8, 16, 4], 4, 8, 0.3, 42).unwrap();
        let b = synthetic_plan("s", &[8, 16, 4], 4, 8, 0.3, 42).unwrap();
        assert_eq!(a.layers[0].f32_rows, b.layers[0].f32_rows);
        assert_eq!(a.layers[0].kept, b.layers[0].kept);
        assert!(synthetic_plan("s", &[8], 4, 8, 0.0, 1).is_err());
    }
}
