//! The ordered pass pipeline that compiles an [`EnginePlan`] into an
//! executable [`Program`]:
//!
//! 1. **graph build** — one `Pre` placeholder plus a
//!    quantize/kernel/epilogue node chain per layer, with every buffer
//!    width resolved statically from the plan (the interpreter never
//!    re-derives a shape);
//! 2. **pruned-channel elision** — a fully-pruned layer's quantize +
//!    kernel + accumulator drop out entirely; a `BiasFill` answers its
//!    (ReLU'd) bias, and the pre-op feeding the dead kernel goes with
//!    it;
//! 3. **pre-op materialization** — each `Pre` placeholder expands into
//!    its concrete `MaxPool2`/`GlobalAvgPool`/`AdaptSpatial` node,
//!    with the legacy `AdaptFeatures` bridge appended only where the
//!    statically-tracked width still mismatches (pre-spatial
//!    manifests);
//! 4. **quantize/requant fusion** — a `Requant` whose f32 output is
//!    consumed only by the next integer layer's `Quantize` becomes one
//!    `RequantQuantize`, eliminating the intermediate activation
//!    buffer between adjacent integer layers; the same rewrite fuses
//!    `Epilogue -> Quantize` on mixed f32/int chains into an
//!    `EpilogueQuantize`;
//! 5. **backend assignment** — each integer kernel node gets its
//!    [`Backend`] discriminant: a forced choice (`--backend` /
//!    `BBITS_BACKEND`) when given, otherwise SIMD wherever the
//!    kernel's lane dimension reaches [`kernels::LANES`] and scalar
//!    below it (vector setup would outweigh sub-lane work); the auto
//!    rule never picks [`Backend::Blocked`] — blocking is opt-in, and
//!    layers that got a blocked node have their decoded weight rows
//!    repacked here into L1-sized [`PanelMatrix`] panels;
//! 6. **liveness + arena assignment** (`engine::arena`) — disjoint
//!    live ranges share scratch space (ping-pong reuse).
//!
//! Numerics are untouched by every pass: each rewrite replays exactly
//! the f32/integer operation sequence of the unfused graph (and the
//! scalar/SIMD kernel pairs compute identical exact integer
//! accumulators), which is why `tests/golden_e2e.rs` stays bit-exact
//! across the pipeline on either backend.

use std::sync::Arc;

use super::arena;
use super::graph::{BufId, BufSpec, DType, Node, PreStep, Program};
use super::kernels::{self, Backend};
use super::pack::PanelMatrix;
use super::verify::VerifyError;
use super::{ActSpec, EnginePlan, PlanLayer, PreOp};
use crate::quant::grid::CodeGrid;

/// Mutable program under construction: the pass pipeline's working
/// form of a [`Program`] before arena assignment.
struct Draft {
    plan: Arc<EnginePlan>,
    int_path: bool,
    nodes: Vec<Node>,
    node_layer: Vec<usize>,
    /// Pass-stable id per node (see [`Program::node_ids`]): rewrites
    /// must preserve the id of the node they replace so profiler
    /// attribution survives the pipeline.
    node_ids: Vec<usize>,
    next_id: usize,
    bufs: Vec<BufSpec>,
    input: BufId,
    output: BufId,
}

impl Draft {
    fn buf(&mut self, dtype: DType, len: usize) -> BufId {
        self.bufs.push(BufSpec { dtype, len, offset: None });
        self.bufs.len() - 1
    }

    /// Append a brand-new node under a fresh id.
    fn push(&mut self, node: Node, layer: usize) {
        let id = self.next_id;
        self.next_id += 1;
        self.push_kept(node, layer, id);
    }

    /// Append a node that replaces (or survives from) an earlier one,
    /// keeping that node's id.
    fn push_kept(&mut self, node: Node, layer: usize, id: usize) {
        self.nodes.push(node);
        self.node_layer.push(layer);
        self.node_ids.push(id);
    }
}

pub(crate) fn compile(plan: Arc<EnginePlan>, int_path: bool,
                      forced: Option<Backend>)
                      -> Result<Program, VerifyError> {
    let mut d = build(plan, int_path);
    elide_pruned(&mut d);
    materialize_pre(&mut d);
    fuse_requant_quantize(&mut d);
    fuse_epilogue_quantize(&mut d);
    // the resolved override (CLI/env) is recorded on the program so
    // the verifier knows whether a non-auto backend choice is legal
    let forced = forced.or_else(Backend::from_env);
    assign_backends(&mut d, forced);
    let panels = build_panels(&d);
    let layout =
        arena::assign(&mut d.bufs, &d.nodes, d.input, d.output)?;
    // ids allocated during the pipeline but absent from the final
    // node list (absorbed by fusion, dropped by elision) — stored so
    // post-compile verification can reject any reference to them
    let retired_ids: Vec<usize> = {
        let mut present = vec![false; d.next_id];
        for &id in &d.node_ids {
            if let Some(p) = present.get_mut(id) {
                *p = true;
            }
        }
        (0..d.next_id).filter(|&id| !present[id]).collect()
    };
    let prog = Program {
        plan: d.plan,
        int_path: d.int_path,
        nodes: d.nodes,
        node_layer: d.node_layer,
        node_ids: d.node_ids,
        id_bound: d.next_id,
        retired_ids,
        forced_backend: forced,
        bufs: d.bufs,
        panels,
        input: d.input,
        output: d.output,
        f32_len: layout.f32_len,
        i32_len: layout.i32_len,
        i64_len: layout.i64_len,
        peak_live: layout.peak_live_bytes,
    };
    // debug builds prove every compiled artifact; release builds
    // verify only when asked (`plan --verify`, `verify_plans`) so
    // compile latency stays flat — the hot loop never pays either way
    #[cfg(debug_assertions)]
    super::verify::verify(&prog)?;
    Ok(prog)
}

/// Resolve a layer's [`PreOp`] (plus the legacy width bridge) against
/// the statically-tracked width of the previous output — the
/// compile-time form of the old executor's runtime shape checks: a
/// recorded pre-op whose input shape does not match the live width is
/// skipped, and any residual mismatch falls back to the flat adapter.
fn resolve_pre(layer: &PlanLayer, width: usize) -> Vec<PreStep> {
    let mut steps = Vec::new();
    let mut cur = width;
    match &layer.pre {
        PreOp::Direct => {}
        PreOp::MaxPool2 { h, w, c } => {
            if cur == h * w * c {
                steps.push(PreStep::MaxPool2 { h: *h, w: *w, c: *c });
                cur = (h / 2) * (w / 2) * c;
            }
        }
        PreOp::GlobalAvgPool { h, w, c } => {
            if cur == h * w * c {
                steps.push(PreStep::GlobalAvgPool { h: *h, w: *w, c: *c });
                cur = *c;
            }
        }
        PreOp::AdaptSpatial { from, to } => {
            if cur == from.0 * from.1 * from.2 {
                steps.push(PreStep::AdaptSpatial { from: *from, to: *to });
                cur = to.0 * to.1 * to.2;
            }
        }
    }
    let need = layer.input_len();
    if cur != need {
        steps.push(PreStep::AdaptFeatures { want: need });
    }
    steps
}

/// Pass 1: emit the per-layer node chains with statically resolved
/// buffer widths.
fn build(plan: Arc<EnginePlan>, int_path: bool) -> Draft {
    let mut d = Draft {
        plan: plan.clone(),
        int_path,
        nodes: Vec::new(),
        node_layer: Vec::new(),
        node_ids: Vec::new(),
        next_id: 0,
        bufs: Vec::new(),
        input: 0,
        output: 0,
    };
    d.input = d.buf(DType::F32, plan.input_dim);
    let mut cur = d.input;
    for (li, layer) in plan.layers.iter().enumerate() {
        let steps = resolve_pre(layer, d.bufs[cur].len);
        if !steps.is_empty() {
            // final step always lands on the layer's input width
            let dst = d.buf(DType::F32, layer.input_len());
            d.push(Node::Pre { layer: li, src: cur, dst, steps }, li);
            cur = dst;
        }
        cur = emit_layer(&mut d, li, layer, cur);
    }
    d.output = cur;
    d
}

fn emit_layer(d: &mut Draft, li: usize, l: &PlanLayer, cur: BufId)
              -> BufId {
    let in_len = l.input_len();
    let rows = l.kept.len();
    let opix = l.spatial.as_ref().map(|sp| sp.out_pixels()).unwrap_or(1);
    let out = d.buf(DType::F32, l.output_len());
    let use_int = d.int_path
        && l.packed.is_some()
        && matches!(l.act, ActSpec::Int { .. });
    if use_int {
        let ActSpec::Int { bits, beta, signed } = l.act else {
            unreachable!()
        };
        let grid = CodeGrid::new(beta, bits, signed);
        let q = d.buf(DType::I32, in_len);
        d.push(Node::Quantize { src: cur, dst: q, grid }, li);
        let acc = d.buf(DType::I64, opix * rows);
        // backends are assigned by the dedicated pass after fusion;
        // Scalar here is just the placeholder
        let kernel = match &l.spatial {
            Some(sp) if sp.in_c == sp.groups => {
                Node::DwConv2d { layer: li, src: q, dst: acc,
                                 backend: Backend::Scalar }
            }
            Some(_) => Node::Conv2d { layer: li, src: q, dst: acc,
                                      int: true,
                                      backend: Backend::Scalar },
            None => Node::Gemm { layer: li, src: q, dst: acc,
                                 int: true,
                                 backend: Backend::Scalar },
        };
        d.push(kernel, li);
        let scale = l.w_scale as f64 * grid.step as f64;
        d.push(Node::Requant { layer: li, src: acc, dst: out, scale,
                               relu: l.relu }, li);
    } else {
        // f32 fallback on the simulated-quant rows; the activation
        // grid is still applied (quantize + dequantize) so both paths
        // see identical quantization error.
        let acts = match l.act {
            ActSpec::F32 => cur,
            ActSpec::Int { bits, beta, signed } => {
                let grid = CodeGrid::new(beta, bits, signed);
                let q = d.buf(DType::I32, in_len);
                d.push(Node::Quantize { src: cur, dst: q, grid }, li);
                let deq = d.buf(DType::F32, in_len);
                d.push(Node::Dequantize { src: q, dst: deq,
                                          step: grid.step }, li);
                deq
            }
        };
        let acc = d.buf(DType::F32, opix * rows);
        // the f32 kernels have no SIMD form — backend stays Scalar
        let kernel = match &l.spatial {
            Some(_) => Node::Conv2d { layer: li, src: acts, dst: acc,
                                      int: false,
                                      backend: Backend::Scalar },
            None => Node::Gemm { layer: li, src: acts, dst: acc,
                                 int: false,
                                 backend: Backend::Scalar },
        };
        d.push(kernel, li);
        d.push(Node::Epilogue { layer: li, src: acc, dst: out,
                                relu: l.relu }, li);
    }
    out
}

/// Pass 2: fully-pruned layers keep only a `BiasFill`; their quantize,
/// kernel, accumulator, and feeding pre-op are elided.
fn elide_pruned(d: &mut Draft) {
    let plan = d.plan.clone();
    let old_nodes = std::mem::take(&mut d.nodes);
    let old_layers = std::mem::take(&mut d.node_layer);
    let old_ids = std::mem::take(&mut d.node_ids);
    for ((node, li), id) in
        old_nodes.into_iter().zip(old_layers).zip(old_ids)
    {
        if !plan.layers[li].kept.is_empty() {
            d.push_kept(node, li, id);
            continue;
        }
        match node {
            Node::Requant { layer, dst, relu, .. }
            | Node::Epilogue { layer, dst, relu, .. } => {
                // the BiasFill stands in for the elided epilogue and
                // inherits its id
                d.push_kept(Node::BiasFill { layer, dst, relu }, li, id);
            }
            // quantize / kernel / pre feeding a dead kernel: dropped
            _ => {}
        }
    }
}

/// Pass 3: expand each `Pre` placeholder into its concrete node
/// sequence, allocating the intermediate buffers between steps.
fn materialize_pre(d: &mut Draft) {
    let old_nodes = std::mem::take(&mut d.nodes);
    let old_layers = std::mem::take(&mut d.node_layer);
    let old_ids = std::mem::take(&mut d.node_ids);
    for ((node, li), id) in
        old_nodes.into_iter().zip(old_layers).zip(old_ids)
    {
        match node {
            Node::Pre { src, dst, steps, .. } => {
                let mut cur = src;
                let n_steps = steps.len();
                for (i, step) in steps.into_iter().enumerate() {
                    let out = if i + 1 == n_steps {
                        dst
                    } else {
                        d.buf(DType::F32, step.out_len())
                    };
                    let concrete = match step {
                        PreStep::MaxPool2 { h, w, c } => {
                            Node::MaxPool2 { src: cur, dst: out, h, w, c }
                        }
                        PreStep::GlobalAvgPool { h, w, c } => {
                            Node::GlobalAvgPool { src: cur, dst: out,
                                                  h, w, c }
                        }
                        PreStep::AdaptSpatial { from, to } => {
                            Node::AdaptSpatial { src: cur, dst: out,
                                                 from, to }
                        }
                        PreStep::AdaptFeatures { want } => {
                            Node::AdaptFeatures { src: cur, dst: out,
                                                  want }
                        }
                    };
                    // the first expanded step inherits the Pre
                    // placeholder's id; later steps are new nodes
                    if i == 0 {
                        d.push_kept(concrete, li, id);
                    } else {
                        d.push(concrete, li);
                    }
                    cur = out;
                }
            }
            other => d.push_kept(other, li, id),
        }
    }
}

/// Auto selection rule: SIMD pays off once the kernel's lane
/// dimension fills at least one vector of accumulators.
fn auto_backend(lane_dim: usize) -> Backend {
    if lane_dim >= kernels::LANES {
        Backend::Simd
    } else {
        Backend::Scalar
    }
}

/// Pass 5: assign each integer kernel node its [`Backend`]. `forced`
/// (CLI `--backend` or `BBITS_BACKEND`) overrides the per-node auto
/// rule; f32 kernel nodes always stay scalar. The lane dimension is
/// what the kernel's inner lanes actually run over: the GEMM row
/// width, the conv im2col patch length, and the depthwise kernel's
/// kept-channel count (its lanes run across rows).
fn assign_backends(d: &mut Draft, forced: Option<Backend>) {
    let plan = d.plan.clone();
    for node in d.nodes.iter_mut() {
        match node {
            Node::Gemm { layer, int: true, backend, .. } => {
                *backend = forced.unwrap_or_else(|| {
                    auto_backend(plan.layers[*layer].in_dim)
                });
            }
            Node::Conv2d { layer, int: true, backend, .. } => {
                *backend = forced.unwrap_or_else(|| {
                    auto_backend(plan.layers[*layer].in_dim)
                });
            }
            Node::DwConv2d { layer, backend, .. } => {
                *backend = forced.unwrap_or_else(|| {
                    auto_backend(plan.layers[*layer].kept.len())
                });
            }
            _ => {}
        }
    }
}

/// Pass 4: fuse `Requant -> Quantize` pairs whose intermediate f32
/// buffer has exactly one consumer and is not the program output.
fn fuse_requant_quantize(d: &mut Draft) {
    let old_nodes = std::mem::take(&mut d.nodes);
    let old_layers = std::mem::take(&mut d.node_layer);
    let old_ids = std::mem::take(&mut d.node_ids);
    let mut readers = vec![0usize; d.bufs.len()];
    for node in &old_nodes {
        if let Some(b) = node.reads() {
            readers[b] += 1;
        }
    }
    let mut i = 0;
    while i < old_nodes.len() {
        if i + 1 < old_nodes.len() {
            if let (Node::Requant { layer, src, dst, scale, relu },
                    Node::Quantize { src: qsrc, dst: qdst, grid }) =
                (&old_nodes[i], &old_nodes[i + 1])
            {
                if *dst == *qsrc && readers[*dst] == 1
                    && *dst != d.output
                {
                    // the fused node keeps the requantize's id (the
                    // absorbed quantize's id retires)
                    d.push_kept(Node::RequantQuantize {
                        layer: *layer,
                        src: *src,
                        dst: *qdst,
                        scale: *scale,
                        relu: *relu,
                        grid: *grid,
                    }, old_layers[i], old_ids[i]);
                    i += 2;
                    continue;
                }
            }
        }
        d.push_kept(old_nodes[i].clone(), old_layers[i], old_ids[i]);
        i += 1;
    }
}

/// Pass 4b: fuse `Epilogue -> Quantize` pairs on mixed f32/int chains
/// — an f32 layer whose dense output is consumed only by the next
/// integer layer's quantize goes straight to codes, mirroring
/// [`fuse_requant_quantize`] for the reference-path epilogue.
fn fuse_epilogue_quantize(d: &mut Draft) {
    let old_nodes = std::mem::take(&mut d.nodes);
    let old_layers = std::mem::take(&mut d.node_layer);
    let old_ids = std::mem::take(&mut d.node_ids);
    let mut readers = vec![0usize; d.bufs.len()];
    for node in &old_nodes {
        if let Some(b) = node.reads() {
            readers[b] += 1;
        }
    }
    let mut i = 0;
    while i < old_nodes.len() {
        if i + 1 < old_nodes.len() {
            if let (Node::Epilogue { layer, src, dst, relu },
                    Node::Quantize { src: qsrc, dst: qdst, grid }) =
                (&old_nodes[i], &old_nodes[i + 1])
            {
                if *dst == *qsrc && readers[*dst] == 1
                    && *dst != d.output
                {
                    // the fused node keeps the epilogue's id (the
                    // absorbed quantize's id retires)
                    d.push_kept(Node::EpilogueQuantize {
                        layer: *layer,
                        src: *src,
                        dst: *qdst,
                        relu: *relu,
                        grid: *grid,
                    }, old_layers[i], old_ids[i]);
                    i += 2;
                    continue;
                }
            }
        }
        d.push_kept(old_nodes[i].clone(), old_layers[i], old_ids[i]);
        i += 1;
    }
}

/// Post-assignment panel build: every layer that received a
/// [`Backend::Blocked`] kernel node gets its decoded weight rows
/// repacked into L1-sized `[MR x KC]` panels. Grouped convolutions
/// use the group-aware packing so a row block never straddles a group
/// boundary (one panel is dotted against one group's patch block);
/// GEMMs and depthwise convs block kept rows freely — the depthwise
/// kernel reads rows individually, so its blocks carry no grouping
/// constraint.
fn build_panels(d: &Draft) -> Vec<Option<Arc<PanelMatrix>>> {
    let mut panels: Vec<Option<Arc<PanelMatrix>>> =
        vec![None; d.plan.layers.len()];
    for node in &d.nodes {
        let li = match node {
            Node::Gemm { layer, int: true,
                         backend: Backend::Blocked, .. }
            | Node::DwConv2d { layer,
                               backend: Backend::Blocked, .. }
            | Node::Conv2d { layer, int: true,
                             backend: Backend::Blocked, .. } => *layer,
            _ => continue,
        };
        if panels[li].is_some() {
            continue;
        }
        let l = &d.plan.layers[li];
        let packed = l
            .packed
            .as_ref()
            .expect("blocked kernel on a layer without packed rows");
        let pm = match node {
            Node::Conv2d { .. } => {
                let sp = l
                    .spatial
                    .as_ref()
                    .expect("blocked conv without spatial");
                let cpg = l.out_dim / sp.groups;
                PanelMatrix::from_packed_grouped(packed, |r| {
                    l.kept[r] as usize / cpg
                })
            }
            _ => PanelMatrix::from_packed(packed),
        };
        panels[li] = Some(Arc::new(pm));
    }
    panels
}
