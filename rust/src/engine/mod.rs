//! Integer inference engine: executes a trained, thresholded Bayesian
//! Bits configuration with real fixed-point weight arithmetic.
//!
//! The training stack only ever *simulates* quantization in f32
//! (`quant::grid::bb_quantize_host`, the AOT executables). This
//! subsystem closes the loop to the hardware story the paper argues
//! for: a checkpoint plus its Eq. 22 gate configuration is lowered
//! into an [`EnginePlan`] of per-layer integer GEMMs —
//!
//! * [`lower`] — fold learned clip ranges into grid steps, assign each
//!   tensor its learned bit width from the gate chain, physically
//!   elide pruned output channels from the weight blobs;
//! * [`pack`] — bit-packed weight storage for the 2/4/8/16/32 chain;
//! * [`kernels`] — packed-weight integer GEMM (i32/i64 accumulate,
//!   one requantize multiply) plus the f32 simulated-quant fallback;
//! * [`serve`] — a multi-threaded batched request server over
//!   per-worker [`Engine`] instances.
//!
//! The executor treats every layer as a GEMM over its flattened
//! weight matrix (`[cout, size/cout]`); feature vectors are adapted
//! between mismatched layer widths by deterministic pooling /
//! replication (`adapt_features`). Both the integer and the f32 path
//! share one activation grid and one weight grid, so they agree up to
//! f32 accumulation error — `tests/engine_parity.rs` pins the integer
//! path to the `bb_quantize_host` oracle.

pub mod kernels;
pub mod lower;
pub mod pack;
pub mod serve;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::report::TableBuilder;
use crate::util::bench::{Bench, Summary};
use crate::util::json::{num, s as jstr, Json};
use pack::PackedMatrix;

pub use lower::{lower, lower_with_mode, synthetic_plan};
pub use serve::{ServeConfig, ServeStats, Server};

/// Input-activation quantization of one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActSpec {
    /// 32-bit chain end: activations stay f32.
    F32,
    /// Quantize inputs to `bits` on the learned `[alpha, beta]` grid.
    Int { bits: u32, beta: f32, signed: bool },
}

impl ActSpec {
    pub fn bits(&self) -> u32 {
        match self {
            ActSpec::F32 => 32,
            ActSpec::Int { bits, .. } => *bits,
        }
    }
}

/// One lowered layer: a (possibly packed) GEMM over kept channels.
#[derive(Debug, Clone)]
pub struct PlanLayer {
    pub name: String,
    /// GEMM input width (weight elements per output channel).
    pub in_dim: usize,
    /// Dense output width, including pruned channel positions.
    pub out_dim: usize,
    /// Learned weight width (0 = every channel pruned).
    pub w_bits: u32,
    /// Surviving output channels, ascending; the packed/dense rows
    /// below hold exactly these.
    pub kept: Vec<u32>,
    /// Packed integer codes (`kept.len() x in_dim`) for widths < 32.
    pub packed: Option<PackedMatrix>,
    /// Weight grid step (1.0 on the f32 fallback).
    pub w_scale: f32,
    /// Simulated-quant dense rows (`kept.len() x in_dim`): exactly
    /// `w_scale * code` where packed, raw weights at 32 bits.
    pub f32_rows: Vec<f32>,
    pub act: ActSpec,
    /// Dense per-channel bias (applied to pruned channels too — their
    /// weights are gated off, their bias survives).
    pub bias: Option<Vec<f32>>,
    pub relu: bool,
}

impl PlanLayer {
    pub fn packed_bytes(&self) -> usize {
        self.packed
            .as_ref()
            .map(|p| p.packed_bytes())
            .unwrap_or(self.f32_rows.len() * 4)
    }

    pub fn dense_bytes(&self) -> usize {
        self.in_dim * self.out_dim * 4
    }
}

/// An executable lowered model.
#[derive(Debug, Clone)]
pub struct EnginePlan {
    pub model: String,
    /// Width of raw request vectors (flattened model input).
    pub input_dim: usize,
    /// Width of responses (logits).
    pub output_dim: usize,
    pub layers: Vec<PlanLayer>,
}

impl EnginePlan {
    /// Structural consistency — fail fast on a buggy lowering.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("plan {:?} has no layers", self.model);
        }
        for l in &self.layers {
            if l.f32_rows.len() != l.kept.len() * l.in_dim {
                bail!("layer {}: f32 rows {} != kept {} x in {}",
                      l.name, l.f32_rows.len(), l.kept.len(), l.in_dim);
            }
            if let Some(p) = &l.packed {
                if p.rows != l.kept.len() || p.cols != l.in_dim {
                    bail!("layer {}: packed {}x{} vs kept {} x in {}",
                          l.name, p.rows, p.cols, l.kept.len(), l.in_dim);
                }
                if p.bits != l.w_bits {
                    bail!("layer {}: packed bits {} != w_bits {}",
                          l.name, p.bits, l.w_bits);
                }
            }
            if let Some(b) = &l.bias {
                if b.len() != l.out_dim {
                    bail!("layer {}: bias len {} != out {}", l.name,
                          b.len(), l.out_dim);
                }
            }
            if l.kept.iter().any(|c| *c as usize >= l.out_dim) {
                bail!("layer {}: kept channel out of range", l.name);
            }
        }
        if self.output_dim != self.layers.last().unwrap().out_dim {
            bail!("output_dim {} != last layer out {}", self.output_dim,
                  self.layers.last().unwrap().out_dim);
        }
        Ok(())
    }

    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }

    pub fn dense_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.dense_bytes()).sum()
    }

    /// Human-readable lowering report (the serve CLI prints this).
    pub fn report(&self) -> String {
        let mut t = TableBuilder::new(
            &format!("Engine plan — {} ({} -> {})", self.model,
                     self.input_dim, self.output_dim),
            &["Layer", "W bits", "A bits", "Kept", "In", "Packed KiB",
              "Dense KiB"],
        );
        for l in &self.layers {
            t.row(&[
                l.name.clone(),
                if l.w_bits == 0 {
                    "pruned".into()
                } else if l.packed.is_some() {
                    format!("{}", l.w_bits)
                } else {
                    "f32".into()
                },
                match l.act {
                    ActSpec::F32 => "f32".into(),
                    ActSpec::Int { bits, .. } => format!("{bits}"),
                },
                format!("{}/{}", l.kept.len(), l.out_dim),
                format!("{}", l.in_dim),
                format!("{:.1}", l.packed_bytes() as f64 / 1024.0),
                format!("{:.1}", l.dense_bytes() as f64 / 1024.0),
            ]);
        }
        t.row(&[
            "total".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            format!("{:.1}", self.packed_bytes() as f64 / 1024.0),
            format!("{:.1}", self.dense_bytes() as f64 / 1024.0),
        ]);
        t.render()
    }
}

/// One measurement from [`throughput_sweep`].
pub struct SweepRecord {
    pub summary: Summary,
    pub int_path: bool,
    pub w_bits: u32,
    pub batch: usize,
    pub rows: usize,
    pub cols: usize,
    pub images_per_sec: f64,
}

impl SweepRecord {
    pub fn line(&self) -> String {
        self.summary.line(Some((self.batch as f64, "img")))
    }

    pub fn to_json(&self) -> Json {
        self.summary.to_json(vec![
            ("path", jstr(if self.int_path { "int" } else { "f32" })),
            ("w_bits", num(self.w_bits as f64)),
            ("a_bits", num(8.0)),
            ("batch", num(self.batch as f64)),
            ("rows", num(self.rows as f64)),
            ("cols", num(self.cols as f64)),
            ("images_per_sec", num(self.images_per_sec)),
        ])
    }
}

/// Int-vs-f32 throughput sweep on one synthetic `rows x cols` layer
/// across weight widths and batch sizes — the single implementation
/// behind `bbits engine-bench` and `benches/bench_engine.rs`.
pub fn throughput_sweep(rows: usize, cols: usize, batches: &[usize],
                        wbits: &[u32], b: &Bench)
                        -> Result<Vec<SweepRecord>> {
    let mut rng = crate::rng::Pcg64::new(3);
    let mut out = Vec::new();
    for &batch in batches {
        let xs: Vec<f32> =
            (0..batch * cols).map(|_| rng.normal()).collect();
        for &wb in wbits {
            let plan = Arc::new(synthetic_plan(
                &format!("bench_w{wb}"), &[cols, rows], wb, 8, 0.0,
                11)?);
            for int_path in [true, false] {
                let mut eng = Engine::new(plan.clone());
                eng.set_int_enabled(int_path);
                let label = format!(
                    "{} w{wb}a8 batch={batch} ({rows}x{cols})",
                    if int_path { "int" } else { "f32" }
                );
                let summary = b.run(&label, || {
                    let y = eng.infer_batch(&xs, batch).unwrap();
                    std::hint::black_box(y);
                });
                let images_per_sec =
                    batch as f64 / (summary.median_ns * 1e-9);
                out.push(SweepRecord {
                    summary,
                    int_path,
                    w_bits: wb,
                    batch,
                    rows,
                    cols,
                    images_per_sec,
                });
            }
        }
    }
    Ok(out)
}

/// Deterministic width adapter between mismatched feature widths:
/// bucket-mean when shrinking, index replication when growing. Both
/// execution paths share it, so it never perturbs parity.
pub fn adapt_features(x: &[f32], want: usize, out: &mut Vec<f32>) {
    let m = x.len();
    if m == want {
        out.extend_from_slice(x);
        return;
    }
    if m > want {
        for i in 0..want {
            let lo = i * m / want;
            let hi = ((i + 1) * m / want).max(lo + 1);
            let sum: f32 = x[lo..hi].iter().sum();
            out.push(sum / (hi - lo) as f32);
        }
    } else {
        for i in 0..want {
            out.push(x[i * m / want]);
        }
    }
}

/// One inference executor: a shared read-only plan plus per-instance
/// scratch. Each serving worker owns an `Engine`; they share the plan
/// through the `Arc`.
pub struct Engine {
    plan: Arc<EnginePlan>,
    int_enabled: bool,
    cur: Vec<f32>,
    nxt: Vec<f32>,
    adapted: Vec<f32>,
    qa: Vec<i32>,
    deq: Vec<f32>,
    row: Vec<i32>,
    acc: Vec<i64>,
    accf: Vec<f32>,
}

impl Engine {
    pub fn new(plan: Arc<EnginePlan>) -> Engine {
        Engine {
            plan,
            int_enabled: true,
            cur: Vec::new(),
            nxt: Vec::new(),
            adapted: Vec::new(),
            qa: Vec::new(),
            deq: Vec::new(),
            row: Vec::new(),
            acc: Vec::new(),
            accf: Vec::new(),
        }
    }

    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// Disable the integer path (f32 simulated-quant fallback only) —
    /// the A/B lever behind `bbits serve --no-int` and the benches.
    pub fn set_int_enabled(&mut self, on: bool) {
        self.int_enabled = on;
    }

    /// Run one request; returns the logits.
    pub fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.infer_batch(x, 1)
    }

    /// Run a micro-batch: `xs` is flat `[n, input_dim]`, the result is
    /// flat `[n, output_dim]`. Weight rows are decoded once per layer
    /// and reused across the batch.
    pub fn infer_batch(&mut self, xs: &[f32], n: usize) -> Result<Vec<f32>> {
        let plan = self.plan.clone();
        if xs.len() != n * plan.input_dim {
            bail!("batch of {} inputs must be {} x {} values, got {}",
                  n, n, plan.input_dim, xs.len());
        }
        self.cur.clear();
        self.cur.extend_from_slice(xs);
        let mut cur_dim = plan.input_dim;
        for layer in &plan.layers {
            if cur_dim != layer.in_dim {
                self.adapted.clear();
                for s in 0..n {
                    let x = &self.cur[s * cur_dim..(s + 1) * cur_dim];
                    adapt_features(x, layer.in_dim, &mut self.adapted);
                }
                std::mem::swap(&mut self.cur, &mut self.adapted);
                cur_dim = layer.in_dim;
            }
            let out_dim = layer.out_dim;
            self.nxt.clear();
            match &layer.bias {
                Some(b) => {
                    for _ in 0..n {
                        self.nxt.extend_from_slice(b);
                    }
                }
                None => self.nxt.resize(n * out_dim, 0.0),
            }
            let rows = layer.kept.len();
            if rows > 0 {
                let int_path = self.int_enabled
                    && layer.packed.is_some()
                    && matches!(layer.act, ActSpec::Int { .. });
                if int_path {
                    let ActSpec::Int { bits, beta, signed } = layer.act
                    else {
                        unreachable!()
                    };
                    let s_a = kernels::quantize_acts(
                        &self.cur[..n * cur_dim], beta, bits, signed,
                        &mut self.qa);
                    let packed = layer.packed.as_ref().unwrap();
                    self.row.resize(cur_dim, 0);
                    self.acc.clear();
                    self.acc.resize(n * rows, 0);
                    kernels::matmul_packed(packed, &self.qa, n, bits,
                                           &mut self.row, &mut self.acc);
                    let scale = layer.w_scale as f64 * s_a as f64;
                    for s in 0..n {
                        for (k, ch) in layer.kept.iter().enumerate() {
                            self.nxt[s * out_dim + *ch as usize] +=
                                (self.acc[s * rows + k] as f64 * scale)
                                    as f32;
                        }
                    }
                } else {
                    // f32 fallback on the simulated-quant weights; the
                    // activation grid is still applied so both paths
                    // see identical quantization error.
                    let acts: &[f32] = match layer.act {
                        ActSpec::F32 => &self.cur[..n * cur_dim],
                        ActSpec::Int { bits, beta, signed } => {
                            let s_a = kernels::quantize_acts(
                                &self.cur[..n * cur_dim], beta, bits,
                                signed, &mut self.qa);
                            kernels::dequantize(&self.qa, s_a,
                                                &mut self.deq);
                            &self.deq
                        }
                    };
                    self.accf.clear();
                    self.accf.resize(n * rows, 0.0);
                    kernels::matmul_f32(&layer.f32_rows, rows, cur_dim,
                                        acts, n, &mut self.accf);
                    for s in 0..n {
                        for (k, ch) in layer.kept.iter().enumerate() {
                            self.nxt[s * out_dim + *ch as usize] +=
                                self.accf[s * rows + k];
                        }
                    }
                }
            }
            if layer.relu {
                for v in self.nxt.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            std::mem::swap(&mut self.cur, &mut self.nxt);
            cur_dim = out_dim;
        }
        Ok(self.cur[..n * plan.output_dim].to_vec())
    }

    /// The f32 simulated-quant reference for the same plan (parity
    /// oracle and `--no-int` baseline).
    pub fn infer_reference(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let was = self.int_enabled;
        self.int_enabled = false;
        let out = self.infer(x);
        self.int_enabled = was;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapt_identity_pool_and_replicate() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut out = Vec::new();
        adapt_features(&x, 4, &mut out);
        assert_eq!(out, x);
        out.clear();
        adapt_features(&x, 2, &mut out);
        assert_eq!(out, vec![1.5, 3.5]);
        out.clear();
        adapt_features(&x, 8, &mut out);
        assert_eq!(out.len(), 8);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[7], 4.0);
        // non-divisible pooling still covers every element once
        out.clear();
        adapt_features(&x, 3, &mut out);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn synthetic_plan_validates_and_runs() {
        let plan =
            synthetic_plan("demo", &[16, 32, 10], 4, 8, 0.25, 3).unwrap();
        plan.validate().unwrap();
        let mut eng = Engine::new(Arc::new(plan));
        let x: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 4.0).collect();
        let y = eng.infer(&x).unwrap();
        assert_eq!(y.len(), 10);
        assert!(y.iter().all(|v| v.is_finite()));
        // batch of identical inputs reproduces the single result
        let mut xs = x.clone();
        xs.extend_from_slice(&x);
        let yy = eng.infer_batch(&xs, 2).unwrap();
        assert_eq!(&yy[..10], &y[..]);
        assert_eq!(&yy[10..], &y[..]);
    }

    #[test]
    fn fully_pruned_layer_passes_bias_only() {
        let plan = lower::build_plan_single(
            "p", &[0.5f32; 12], 4, 3, &[0.0, 0.0, 0.0], 4, 1.0,
            ActSpec::Int { bits: 8, beta: 2.0, signed: true },
            Some(vec![0.5, -1.0, 2.0]), false).unwrap();
        let mut eng = Engine::new(Arc::new(plan));
        let y = eng.infer(&[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_eq!(y, vec![0.5, -1.0, 2.0]);
    }

    #[test]
    fn rejects_wrong_input_width() {
        let plan =
            synthetic_plan("demo", &[8, 4], 8, 8, 0.0, 1).unwrap();
        let mut eng = Engine::new(Arc::new(plan));
        assert!(eng.infer(&[0.0; 7]).is_err());
    }
}
