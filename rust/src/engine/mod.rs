//! Integer inference engine: executes a trained, thresholded Bayesian
//! Bits configuration with real fixed-point weight arithmetic.
//!
//! The training stack only ever *simulates* quantization in f32
//! (`quant::grid::bb_quantize_host`, the AOT executables). This
//! subsystem closes the loop to the hardware story the paper argues
//! for: a checkpoint plus its Eq. 22 gate configuration is lowered
//! into an [`EnginePlan`] of per-layer integer GEMMs —
//!
//! * [`lower`] — fold learned clip ranges into grid steps, assign each
//!   tensor its learned bit width from the gate chain, physically
//!   elide pruned output channels from the weight blobs;
//! * [`pack`] — bit-packed weight storage for the 2/4/8/16/32 chain;
//! * [`kernels`] — packed-weight integer GEMM and im2col-over-codes
//!   spatial convolution (i32/i64 accumulate, one requantize
//!   multiply) plus the f32 simulated-quant fallbacks; each integer
//!   kernel exists as the scalar oracle, a bit-identical SIMD form,
//!   and a cache-blocked panel form that can also shard one request
//!   across scoped threads ([`Backend`], `--intra-threads`), selected
//!   per compiled node by the pass pipeline and forceable via
//!   `BBITS_BACKEND` / `--backend`;
//! * [`serve`] — the batched worker-pool core (micro-batching queue,
//!   per-worker [`Engine`] instances over one shared compiled program
//!   pair) plus the single-model [`Server`] wrapper;
//! * [`registry`] — the multi-model front-end: a [`ModelRegistry`] of
//!   named lowered plans with lazy program compilation, a [`Router`]
//!   that fans requests out to per-model pools, and a byte-budget LRU
//!   that evicts cold compiled plans (transparently recompiled on the
//!   next hit);
//! * [`trace`] — the observability substrate: a lock-free span ring
//!   buffer (`enqueue -> queue_wait -> batch_form -> infer ->
//!   respond` plus per-node kernel slices), log-linear latency
//!   histograms, per-(op, backend, bit-width) kernel timers, and
//!   Chrome trace-event export (`--trace-out`, `--profile`).
//!
//! Dense layers execute as GEMMs over `[cout, in]` weight rows.
//! Conv/dwconv layers keep their `[cout, cin/groups * k * k]` row
//! layout and execute as real spatial convolutions over a per-layer
//! [`SpatialPlan`] (kernel size, stride, resolved padding, groups),
//! with the train graph's interstitial ops (2x2 max pool, NHWC
//! flatten, global average pool) replayed as [`PreOp`]s between
//! layers. The flat pool/replicate width adapter (`adapt_features`)
//! survives only as the explicit legacy fallback for manifests that
//! predate the spatial schema.
//!
//! Execution is compiled, not interpreted per layer: an [`EnginePlan`]
//! lowers further into a typed execution-graph IR ([`graph::Program`])
//! through an ordered pass pipeline ([`passes`]: graph build ->
//! pruned-channel elision -> pre-op materialization -> quantize/
//! requant fusion -> buffer liveness + scratch-arena assignment in
//! [`arena`]). [`Engine::infer_batch`] is then a flat interpreter loop
//! over nodes reading/writing pre-assigned arena slices — no
//! per-request allocation and no shape re-derivation. The f32
//! reference path runs the *same* IR compiled with f32 kernels, so
//! int/f32 parity is structural. Both paths share one activation grid
//! and one weight grid and agree up to f32 accumulation error —
//! `tests/engine_parity.rs`, `tests/conv_parity.rs`, and `tests/ir.rs`
//! pin the integer paths and the IR invariants; `tests/golden_e2e.rs`
//! pins the whole pipeline bit-exactly.

mod arena;
pub mod artifact;
pub mod graph;
pub mod kernels;
pub mod lower;
pub mod pack;
mod passes;
pub mod registry;
pub mod serve;
pub mod trace;
pub mod verify;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::models::Padding;
use crate::report::TableBuilder;
use crate::util::bench::{Bench, Summary};
use crate::util::json::{num, s as jstr, Json};
use pack::PackedMatrix;

pub use artifact::{load_plan, load_plan_verified, save_plan};
pub use graph::{ExecState, Program};
pub use kernels::Backend;
pub use lower::{lower, lower_with_mode, lower_with_mode_at,
                synthetic_conv_plan, synthetic_plan};
pub use registry::{pick_rung, CacheStats, ModelRegistry, RungInfo,
                   RungLoad, Router};
pub use serve::{ServeConfig, ServeConfigError, ServeStats, Server};
pub use trace::{Histogram, KernelKey, NodeTimer, SpanKind,
                TraceRecorder};
pub use verify::{verify_all, VerifyError};

/// Spatial execution geometry of one conv/dwconv layer: input feature
/// map, kernel/stride/groups, and the padding resolved to explicit
/// top/left offsets (TF/XLA SAME convention: `total = max((out-1) *
/// stride + k - in, 0)`, low side gets `total / 2`).
#[derive(Debug, Clone, PartialEq)]
pub struct SpatialPlan {
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub k: usize,
    pub stride: usize,
    pub groups: usize,
    pub pad_top: usize,
    pub pad_left: usize,
    pub out_h: usize,
    pub out_w: usize,
}

impl SpatialPlan {
    pub fn new(in_h: usize, in_w: usize, in_c: usize, k: usize,
               stride: usize, padding: Padding, groups: usize)
               -> Result<SpatialPlan> {
        if in_h == 0 || in_w == 0 || in_c == 0 {
            bail!("spatial plan needs a non-empty input map, got \
                   {in_h}x{in_w}x{in_c}");
        }
        if k == 0 || stride == 0 || groups == 0 {
            bail!("spatial plan needs k, stride, groups >= 1, got \
                   k={k} stride={stride} groups={groups}");
        }
        if in_c % groups != 0 {
            bail!("{in_c} input channels not divisible into {groups} \
                   groups");
        }
        let (out_h, out_w, pad_top, pad_left) = match padding {
            Padding::Same => {
                let out_h = in_h.div_ceil(stride);
                let out_w = in_w.div_ceil(stride);
                let ph = ((out_h - 1) * stride + k).saturating_sub(in_h);
                let pw = ((out_w - 1) * stride + k).saturating_sub(in_w);
                (out_h, out_w, ph / 2, pw / 2)
            }
            Padding::Valid => {
                if in_h < k || in_w < k {
                    bail!("VALID conv: {k}x{k} kernel does not fit a \
                           {in_h}x{in_w} map");
                }
                ((in_h - k) / stride + 1, (in_w - k) / stride + 1, 0, 0)
            }
        };
        Ok(SpatialPlan { in_h, in_w, in_c, k, stride, groups, pad_top,
                         pad_left, out_h, out_w })
    }

    /// Flat NHWC input length.
    pub fn in_len(&self) -> usize {
        self.in_h * self.in_w * self.in_c
    }

    pub fn out_pixels(&self) -> usize {
        self.out_h * self.out_w
    }

    /// Weight elements per output channel (the GEMM row width).
    pub fn patch_len(&self) -> usize {
        (self.in_c / self.groups) * self.k * self.k
    }
}

/// Deterministic feature transform replayed before a layer consumes
/// the previous layer's output — the train graph's ops between weight
/// layers, inferred at lowering time from the manifest's spatial
/// metadata.
#[derive(Debug, Clone, PartialEq)]
pub enum PreOp {
    /// Shapes line up (NHWC flatten is a memory no-op). A residual
    /// width mismatch at run time falls back to the legacy flat
    /// pool/replicate adapter (`adapt_features`).
    Direct,
    /// 2x2 max pooling, stride 2, over the previous `h x w x c` map
    /// (the models' `max_pool2`).
    MaxPool2 { h: usize, w: usize, c: usize },
    /// Per-channel mean over all pixels (the models' `global_avg_pool`
    /// ahead of the classifier head).
    GlobalAvgPool { h: usize, w: usize, c: usize },
    /// Shape-aware bucket-mean / replicate bridge for branch layers
    /// (ResNet downsample) whose input is not the previous layer's
    /// output; each NHWC axis pools when shrinking and replicates when
    /// growing, independently.
    AdaptSpatial {
        from: (usize, usize, usize),
        to: (usize, usize, usize),
    },
}

/// Input-activation quantization of one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActSpec {
    /// 32-bit chain end: activations stay f32.
    F32,
    /// Quantize inputs to `bits` on the learned `[alpha, beta]` grid.
    Int { bits: u32, beta: f32, signed: bool },
}

impl ActSpec {
    pub fn bits(&self) -> u32 {
        match self {
            ActSpec::F32 => 32,
            ActSpec::Int { bits, .. } => *bits,
        }
    }
}

/// One lowered layer: a (possibly packed) GEMM or spatial conv over
/// kept channels.
#[derive(Debug, Clone)]
pub struct PlanLayer {
    pub name: String,
    /// Weight elements per output channel — the GEMM row width
    /// (`cin/groups * k * k` for spatial layers).
    pub in_dim: usize,
    /// Dense output channel count, including pruned channel positions.
    pub out_dim: usize,
    /// Learned weight width (0 = every channel pruned).
    pub w_bits: u32,
    /// Surviving output channels, ascending; the packed/dense rows
    /// below hold exactly these.
    pub kept: Vec<u32>,
    /// Packed integer codes (`kept.len() x in_dim`) for widths < 32.
    pub packed: Option<PackedMatrix>,
    /// Weight grid step (1.0 on the f32 fallback).
    pub w_scale: f32,
    /// Simulated-quant dense rows (`kept.len() x in_dim`): exactly
    /// `w_scale * code` where packed, raw weights at 32 bits.
    pub f32_rows: Vec<f32>,
    pub act: ActSpec,
    /// Dense per-channel bias (applied to pruned channels too — their
    /// weights are gated off, their bias survives). Spatial layers
    /// broadcast it over every output pixel.
    pub bias: Option<Vec<f32>>,
    pub relu: bool,
    /// Spatial conv geometry; `None` executes as a flat GEMM.
    pub spatial: Option<SpatialPlan>,
    /// How this layer's input is produced from the previous output.
    pub pre: PreOp,
}

impl PlanLayer {
    pub fn packed_bytes(&self) -> usize {
        self.packed
            .as_ref()
            .map(|p| p.packed_bytes())
            .unwrap_or(self.f32_rows.len() * 4)
    }

    pub fn dense_bytes(&self) -> usize {
        self.in_dim * self.out_dim * 4
    }

    /// Flat feature count this layer consumes (NHWC for spatial).
    pub fn input_len(&self) -> usize {
        self.spatial.as_ref().map(|sp| sp.in_len()).unwrap_or(self.in_dim)
    }

    /// Flat feature count this layer produces (NHWC for spatial).
    pub fn output_len(&self) -> usize {
        self.spatial
            .as_ref()
            .map(|sp| sp.out_pixels() * self.out_dim)
            .unwrap_or(self.out_dim)
    }
}

/// An executable lowered model.
#[derive(Debug, Clone)]
pub struct EnginePlan {
    pub model: String,
    /// Width of raw request vectors (flattened model input).
    pub input_dim: usize,
    /// Width of responses (logits).
    pub output_dim: usize,
    pub layers: Vec<PlanLayer>,
}

impl EnginePlan {
    /// Structural consistency — fail fast on a buggy lowering.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("plan {:?} has no layers", self.model);
        }
        for l in &self.layers {
            if l.f32_rows.len() != l.kept.len() * l.in_dim {
                bail!("layer {}: f32 rows {} != kept {} x in {}",
                      l.name, l.f32_rows.len(), l.kept.len(), l.in_dim);
            }
            if let Some(p) = &l.packed {
                if p.rows != l.kept.len() || p.cols != l.in_dim {
                    bail!("layer {}: packed {}x{} vs kept {} x in {}",
                          l.name, p.rows, p.cols, l.kept.len(), l.in_dim);
                }
                if p.bits != l.w_bits {
                    bail!("layer {}: packed bits {} != w_bits {}",
                          l.name, p.bits, l.w_bits);
                }
            }
            if let Some(b) = &l.bias {
                if b.len() != l.out_dim {
                    bail!("layer {}: bias len {} != out {}", l.name,
                          b.len(), l.out_dim);
                }
            }
            if l.kept.iter().any(|c| *c as usize >= l.out_dim) {
                bail!("layer {}: kept channel out of range", l.name);
            }
            if let Some(sp) = &l.spatial {
                if l.in_dim != sp.patch_len() {
                    bail!("layer {}: row width {} != cin/groups*k*k {}",
                          l.name, l.in_dim, sp.patch_len());
                }
                if l.out_dim % sp.groups != 0 {
                    bail!("layer {}: {} outputs not divisible into {} \
                           groups", l.name, l.out_dim, sp.groups);
                }
            }
        }
        let last = self.layers.last().unwrap();
        if self.output_dim != last.output_len() {
            bail!("output_dim {} != last layer out {}", self.output_dim,
                  last.output_len());
        }
        Ok(())
    }

    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }

    pub fn dense_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.dense_bytes()).sum()
    }

    /// Human-readable lowering report (the serve CLI prints this).
    pub fn report(&self) -> String {
        let mut t = TableBuilder::new(
            &format!("Engine plan — {} ({} -> {})", self.model,
                     self.input_dim, self.output_dim),
            &["Layer", "W bits", "A bits", "Kept", "In", "Spatial",
              "Packed KiB", "Dense KiB"],
        );
        for l in &self.layers {
            t.row(&[
                l.name.clone(),
                if l.w_bits == 0 {
                    "pruned".into()
                } else if l.packed.is_some() {
                    format!("{}", l.w_bits)
                } else {
                    "f32".into()
                },
                match l.act {
                    ActSpec::F32 => "f32".into(),
                    ActSpec::Int { bits, .. } => format!("{bits}"),
                },
                format!("{}/{}", l.kept.len(), l.out_dim),
                format!("{}", l.in_dim),
                match &l.spatial {
                    Some(sp) => format!(
                        "{}x{}->{}x{} k{}s{}{}", sp.in_h, sp.in_w,
                        sp.out_h, sp.out_w, sp.k, sp.stride,
                        if sp.groups > 1 {
                            format!("g{}", sp.groups)
                        } else {
                            String::new()
                        }),
                    None => "-".into(),
                },
                format!("{:.1}", l.packed_bytes() as f64 / 1024.0),
                format!("{:.1}", l.dense_bytes() as f64 / 1024.0),
            ]);
        }
        t.row(&[
            "total".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            format!("{:.1}", self.packed_bytes() as f64 / 1024.0),
            format!("{:.1}", self.dense_bytes() as f64 / 1024.0),
        ]);
        t.render()
    }
}

/// One measurement from [`throughput_sweep`].
pub struct SweepRecord {
    pub summary: Summary,
    pub int_path: bool,
    /// Kernel backend the integer path ran (f32 records are always
    /// scalar — the f32 kernels have no SIMD form).
    pub backend: Backend,
    pub w_bits: u32,
    pub batch: usize,
    pub rows: usize,
    pub cols: usize,
    pub images_per_sec: f64,
    /// Per-sample scratch-arena footprint of the executed program
    /// (all typed arenas, after liveness packing).
    pub arena_bytes: usize,
    /// Max simultaneously-live per-sample bytes (packing lower bound).
    pub peak_scratch_bytes: usize,
    /// Per-(op, backend, bit-width) kernel timers from a short
    /// profiled pass run *after* the timed loop (the timed loop stays
    /// uninstrumented), heaviest first.
    pub nodes: Vec<(trace::KernelKey, trace::NodeTimer)>,
}

impl SweepRecord {
    pub fn line(&self) -> String {
        self.summary.line(Some((self.batch as f64, "img")))
    }

    pub fn to_json(&self) -> Json {
        self.summary.to_json(vec![
            ("path", jstr(if self.int_path { "int" } else { "f32" })),
            ("backend", jstr(self.backend.label())),
            ("w_bits", num(self.w_bits as f64)),
            ("a_bits", num(8.0)),
            ("batch", num(self.batch as f64)),
            ("rows", num(self.rows as f64)),
            ("cols", num(self.cols as f64)),
            ("images_per_sec", num(self.images_per_sec)),
            ("arena_bytes", num(self.arena_bytes as f64)),
            ("peak_scratch_bytes", num(self.peak_scratch_bytes as f64)),
            ("nodes", trace::kernel_rows_json(&self.nodes)),
        ])
    }
}

/// `BENCH_engine.json` artifact title — one constant for its two
/// writers (`bbits engine-bench` and `benches/bench_engine.rs`) so
/// the machine-readable artifact's description cannot drift.
pub const BENCH_ENGINE_TITLE: &str =
    "engine images/sec per bit-width config, scalar vs simd vs \
     blocked integer backends vs f32 fallback";

/// The (int_path, backend) execution configs a sweep measures: the
/// scalar/SIMD/blocked integer trio plus the f32 scalar reference, or
/// just one integer backend (plus the reference) when forced.
fn sweep_configs(forced: Option<Backend>) -> Vec<(bool, Backend)> {
    match forced {
        Some(b) => vec![(true, b), (false, Backend::Scalar)],
        None => vec![(true, Backend::Scalar), (true, Backend::Simd),
                     (true, Backend::Blocked),
                     (false, Backend::Scalar)],
    }
}

/// Int-vs-f32 throughput sweep on one synthetic `rows x cols` layer
/// across weight widths, batch sizes, and kernel backends
/// (scalar-vs-SIMD on the integer path; `forced` restricts to one) —
/// the single implementation behind `bbits engine-bench` and
/// `benches/bench_engine.rs`.
pub fn throughput_sweep(rows: usize, cols: usize, batches: &[usize],
                        wbits: &[u32], forced: Option<Backend>,
                        b: &Bench)
                        -> Result<Vec<SweepRecord>> {
    let mut rng = crate::rng::Pcg64::new(3);
    let mut out = Vec::new();
    for &batch in batches {
        let xs: Vec<f32> =
            (0..batch * cols).map(|_| rng.normal()).collect();
        for &wb in wbits {
            let plan = Arc::new(synthetic_plan(
                &format!("bench_w{wb}"), &[cols, rows], wb, 8, 0.0,
                11)?);
            for (int_path, backend) in sweep_configs(forced) {
                let mut eng =
                    Engine::with_backend(plan.clone(), Some(backend));
                eng.set_int_enabled(int_path);
                let (arena_bytes, peak_scratch_bytes) = {
                    let p = eng.program(int_path);
                    (p.arena_bytes(), p.peak_live_bytes())
                };
                let label = format!(
                    "{} w{wb}a8 batch={batch} ({rows}x{cols})",
                    if int_path {
                        format!("int/{}", backend.label())
                    } else {
                        "f32".to_string()
                    }
                );
                let summary = b.run(&label, || {
                    let y = eng.infer_batch(&xs, batch).unwrap();
                    std::hint::black_box(y);
                });
                let images_per_sec =
                    batch as f64 / (summary.median_ns * 1e-9);
                // per-node breakdown from a short profiled pass after
                // the timed loop, which stays uninstrumented
                eng.enable_profiling();
                for _ in 0..3 {
                    eng.infer_batch(&xs, batch)?;
                }
                let nodes = eng.kernel_profile(int_path);
                out.push(SweepRecord {
                    summary,
                    int_path,
                    backend,
                    w_bits: wb,
                    batch,
                    rows,
                    cols,
                    images_per_sec,
                    arena_bytes,
                    peak_scratch_bytes,
                    nodes,
                });
            }
        }
    }
    Ok(out)
}

/// One measurement from [`conv_throughput_sweep`].
pub struct ConvSweepRecord {
    pub summary: Summary,
    pub int_path: bool,
    /// Kernel backend the integer path ran (f32 records are scalar).
    pub backend: Backend,
    pub w_bits: u32,
    pub batch: usize,
    pub hw: usize,
    pub cin: usize,
    pub cout: usize,
    pub ksize: usize,
    pub images_per_sec: f64,
    /// Per-sample scratch-arena footprint of the executed program.
    pub arena_bytes: usize,
    /// Max simultaneously-live per-sample bytes (packing lower bound).
    pub peak_scratch_bytes: usize,
    /// Per-(op, backend, bit-width) kernel timers from a short
    /// profiled pass run *after* the timed loop, heaviest first.
    pub nodes: Vec<(trace::KernelKey, trace::NodeTimer)>,
}

impl ConvSweepRecord {
    pub fn line(&self) -> String {
        self.summary.line(Some((self.batch as f64, "img")))
    }

    pub fn to_json(&self) -> Json {
        self.summary.to_json(vec![
            ("path", jstr(if self.int_path { "int" } else { "f32" })),
            ("backend", jstr(self.backend.label())),
            ("w_bits", num(self.w_bits as f64)),
            ("a_bits", num(8.0)),
            ("batch", num(self.batch as f64)),
            ("hw", num(self.hw as f64)),
            ("cin", num(self.cin as f64)),
            ("cout", num(self.cout as f64)),
            ("ksize", num(self.ksize as f64)),
            ("images_per_sec", num(self.images_per_sec)),
            ("arena_bytes", num(self.arena_bytes as f64)),
            ("peak_scratch_bytes", num(self.peak_scratch_bytes as f64)),
            ("nodes", trace::kernel_rows_json(&self.nodes)),
        ])
    }
}

/// Int-vs-f32 throughput sweep on one synthetic spatial conv layer
/// (`hw x hw x cin -> cout`, SAME padding, stride 1) across weight
/// widths, batch sizes, and kernel backends — the measurement behind
/// `BENCH_conv.json` (`bbits engine-bench`).
#[allow(clippy::too_many_arguments)]
pub fn conv_throughput_sweep(hw: usize, cin: usize, cout: usize,
                             ksize: usize, batches: &[usize],
                             wbits: &[u32], forced: Option<Backend>,
                             b: &Bench)
                             -> Result<Vec<ConvSweepRecord>> {
    let mut rng = crate::rng::Pcg64::new(5);
    let in_len = hw * hw * cin;
    let mut out = Vec::new();
    for &batch in batches {
        let xs: Vec<f32> =
            (0..batch * in_len).map(|_| rng.normal()).collect();
        for &wb in wbits {
            let plan = Arc::new(synthetic_conv_plan(
                &format!("bench_conv_w{wb}"), hw, cin, cout, ksize, 1,
                Padding::Same, 1, wb, 8, 0.0, 13)?);
            for (int_path, backend) in sweep_configs(forced) {
                let mut eng =
                    Engine::with_backend(plan.clone(), Some(backend));
                eng.set_int_enabled(int_path);
                let (arena_bytes, peak_scratch_bytes) = {
                    let p = eng.program(int_path);
                    (p.arena_bytes(), p.peak_live_bytes())
                };
                let label = format!(
                    "{} conv w{wb}a8 batch={batch} \
                     ({hw}x{hw}x{cin}->{cout} k{ksize})",
                    if int_path {
                        format!("int/{}", backend.label())
                    } else {
                        "f32".to_string()
                    }
                );
                let summary = b.run(&label, || {
                    let y = eng.infer_batch(&xs, batch).unwrap();
                    std::hint::black_box(y);
                });
                let images_per_sec =
                    batch as f64 / (summary.median_ns * 1e-9);
                // per-node breakdown from a short profiled pass after
                // the timed loop, which stays uninstrumented
                eng.enable_profiling();
                for _ in 0..3 {
                    eng.infer_batch(&xs, batch)?;
                }
                let nodes = eng.kernel_profile(int_path);
                out.push(ConvSweepRecord {
                    summary,
                    int_path,
                    backend,
                    w_bits: wb,
                    batch,
                    hw,
                    cin,
                    cout,
                    ksize,
                    images_per_sec,
                    arena_bytes,
                    peak_scratch_bytes,
                    nodes,
                });
            }
        }
    }
    Ok(out)
}

/// Deterministic width adapter between mismatched feature widths:
/// bucket-mean when shrinking, index replication when growing. Both
/// execution paths share it, so it never perturbs parity. The target
/// width is `out.len()` — the IR executor hands in one sample's
/// pre-assigned arena slice.
pub(crate) fn adapt_features_into(x: &[f32], out: &mut [f32]) {
    let m = x.len();
    let want = out.len();
    if m == want {
        out.copy_from_slice(x);
        return;
    }
    if m == 0 {
        // nothing to pool or replicate from — bridge with zeros
        // rather than indexing an empty slice
        out.fill(0.0);
        return;
    }
    if m > want {
        for (i, o) in out.iter_mut().enumerate() {
            let lo = i * m / want;
            let hi = ((i + 1) * m / want).max(lo + 1);
            let sum: f32 = x[lo..hi].iter().sum();
            *o = sum / (hi - lo) as f32;
        }
    } else {
        for (i, o) in out.iter_mut().enumerate() {
            *o = x[i * m / want];
        }
    }
}

/// Appending form of [`adapt_features_into`] (tests, legacy callers).
pub fn adapt_features(x: &[f32], want: usize, out: &mut Vec<f32>) {
    let base = out.len();
    out.resize(base + want, 0.0);
    adapt_features_into(x, &mut out[base..]);
}

/// Source index range feeding target index `i` on one adapted axis:
/// a bucket of >= 1 indices when shrinking (mean), a single replicated
/// index when growing — the per-axis form of [`adapt_features`].
fn axis_bucket(m: usize, want: usize, i: usize) -> (usize, usize) {
    if m >= want {
        let lo = i * m / want;
        (lo, ((i + 1) * m / want).max(lo + 1))
    } else {
        let j = i * m / want;
        (j, j + 1)
    }
}

/// Shape-aware deterministic bridge between NHWC feature maps: each
/// axis pools (bucket mean) when shrinking and replicates when
/// growing, independently — the spatial analogue of [`adapt_features`]
/// used for branch layers (ResNet downsample) whose input is not the
/// previous layer's output. Shared by both execution paths; `out` is
/// one sample's pre-assigned `th * tw * tc` arena slice.
pub(crate) fn adapt_spatial_into(x: &[f32], from: (usize, usize, usize),
                                 to: (usize, usize, usize),
                                 out: &mut [f32]) {
    let (fh, fw, fc) = from;
    let (th, tw, tc) = to;
    debug_assert_eq!(x.len(), fh * fw * fc);
    debug_assert_eq!(out.len(), th * tw * tc);
    let mut idx = 0;
    for i in 0..th {
        let (h0, h1) = axis_bucket(fh, th, i);
        for j in 0..tw {
            let (w0, w1) = axis_bucket(fw, tw, j);
            for ch in 0..tc {
                let (c0, c1) = axis_bucket(fc, tc, ch);
                let mut sum = 0.0f32;
                for a in h0..h1 {
                    for b in w0..w1 {
                        for cc in c0..c1 {
                            sum += x[(a * fw + b) * fc + cc];
                        }
                    }
                }
                let cnt = (h1 - h0) * (w1 - w0) * (c1 - c0);
                out[idx] = sum / cnt as f32;
                idx += 1;
            }
        }
    }
}

/// Appending form of [`adapt_spatial_into`] (tests, legacy callers).
pub fn adapt_spatial(x: &[f32], from: (usize, usize, usize),
                     to: (usize, usize, usize), out: &mut Vec<f32>) {
    let base = out.len();
    out.resize(base + to.0 * to.1 * to.2, 0.0);
    adapt_spatial_into(x, from, to, &mut out[base..]);
}

/// Compile a plan into its two shareable execution graphs (integer
/// path and f32 simulated-quant reference). The registry's serving
/// workers all execute the *same* compiled pair for one model; only
/// the [`ExecState`] arenas are per-worker. Kernel backends resolve
/// from `BBITS_BACKEND`, then the per-node auto rule.
pub fn compile_pair(plan: &Arc<EnginePlan>)
                    -> (Arc<Program>, Arc<Program>) {
    compile_pair_with(plan, None)
}

/// [`compile_pair`] with every integer kernel node forced onto one
/// [`Backend`] (`None` keeps env-then-auto resolution) — the serving
/// and bench plumbing behind `--backend`.
pub fn compile_pair_with(plan: &Arc<EnginePlan>,
                         forced: Option<Backend>)
                         -> (Arc<Program>, Arc<Program>) {
    (Arc::new(Program::compile_with_backend(plan.clone(), true,
                                            forced)),
     Arc::new(Program::compile_with_backend(plan.clone(), false,
                                            forced)))
}

/// Fallible [`compile_pair_with`]: surfaces a [`VerifyError`] from
/// either path's compile instead of panicking — what the registry's
/// lazy checkout and `ServeConfig.verify_plans` register-time proof
/// go through.
pub fn try_compile_pair_with(plan: &Arc<EnginePlan>,
                             forced: Option<Backend>)
                             -> Result<(Arc<Program>, Arc<Program>),
                                       VerifyError> {
    Ok((Arc::new(Program::try_compile_with_backend(plan.clone(), true,
                                                   forced)?),
        Arc::new(Program::try_compile_with_backend(plan.clone(), false,
                                                   forced)?)))
}

/// One inference executor: a shared read-only plan compiled once into
/// its two execution graphs (integer path and f32 simulated-quant
/// reference), plus the per-instance [`ExecState`] arenas. Each
/// serving worker owns an `Engine`; they share the plan *and* the
/// compiled programs through `Arc`s.
pub struct Engine {
    plan: Arc<EnginePlan>,
    int_prog: Arc<Program>,
    f32_prog: Arc<Program>,
    int_enabled: bool,
    st: ExecState,
    /// Per-node timers, one slot per compiled node of each path.
    /// `None` keeps `run_batch` on the uninstrumented hot loop.
    profile: Option<EngineProfile>,
    trace: Option<TraceCtx>,
}

/// Per-node wall-clock timers for both compiled paths (enabled by
/// [`Engine::enable_profiling`]; flushed per batch by the serving
/// workers, read cumulatively by `plan --profile` and the benches).
struct EngineProfile {
    int: Vec<trace::NodeTimer>,
    fp: Vec<trace::NodeTimer>,
}

/// Span-recorder attachment: where this engine's per-node slices go,
/// the node-table base offsets of its two programs, and the trace
/// thread id (worker index + 1) its slices are drawn on.
struct TraceCtx {
    rec: Arc<TraceRecorder>,
    int_base: u64,
    f32_base: u64,
    tid: u64,
}

impl Engine {
    pub fn new(plan: Arc<EnginePlan>) -> Engine {
        let (int_prog, f32_prog) = compile_pair(&plan);
        Engine::from_compiled(plan, int_prog, f32_prog)
    }

    /// [`Engine::new`] with every integer kernel node forced onto one
    /// [`Backend`] (`None` keeps env-then-auto resolution) — what the
    /// differential battery and the bench sweeps construct.
    pub fn with_backend(plan: Arc<EnginePlan>, forced: Option<Backend>)
                        -> Engine {
        let (int_prog, f32_prog) = compile_pair_with(&plan, forced);
        Engine::from_compiled(plan, int_prog, f32_prog)
    }

    /// Build over pre-compiled programs — the zero-compile constructor
    /// the registry's pool workers use so N workers share one program
    /// pair instead of compiling N copies.
    pub fn from_compiled(plan: Arc<EnginePlan>, int_prog: Arc<Program>,
                         f32_prog: Arc<Program>) -> Engine {
        debug_assert!(int_prog.int_path() && !f32_prog.int_path());
        Engine {
            plan,
            int_prog,
            f32_prog,
            int_enabled: true,
            st: ExecState::default(),
            profile: None,
            trace: None,
        }
    }

    /// Turn on per-node wall-clock timing: every subsequent batch runs
    /// through the instrumented interpreter loop, accumulating one
    /// [`NodeTimer`] per compiled node of each path. Off by default —
    /// the uninstrumented hot loop takes no timestamps at all.
    pub fn enable_profiling(&mut self) {
        self.profile = Some(EngineProfile {
            int: vec![trace::NodeTimer::default();
                      self.int_prog.node_ids().len()],
            fp: vec![trace::NodeTimer::default();
                     self.f32_prog.node_ids().len()],
        });
    }

    /// Attach a span recorder: per-node slices of every profiled batch
    /// are recorded into `rec` on trace thread `tid`, attributed via
    /// the node tables registered here. Implies nothing by itself —
    /// slices only flow once [`Self::enable_profiling`] is also on.
    pub fn attach_trace(&mut self, rec: Arc<TraceRecorder>, tid: u64) {
        let int_base = rec.register_nodes(self.int_prog.node_metas());
        let f32_base = rec.register_nodes(self.f32_prog.node_metas());
        self.trace = Some(TraceCtx { rec, int_base, f32_base, tid });
    }

    /// Drain accumulated per-node timers into `sink`, keyed by
    /// (op, backend, bit-width), and reset them — the per-batch flush
    /// the serving workers run under the stats lock. No-op while
    /// profiling is off.
    pub fn flush_profile_into(
        &mut self, sink: &mut BTreeMap<trace::KernelKey,
                                       trace::NodeTimer>) {
        let Some(p) = &mut self.profile else { return };
        for (prog, timers) in [(&self.int_prog, &mut p.int),
                               (&self.f32_prog, &mut p.fp)] {
            for (i, t) in timers.iter_mut().enumerate() {
                if t.calls == 0 {
                    continue;
                }
                sink.entry(prog.kernel_key(i)).or_default().merge(t);
                *t = trace::NodeTimer::default();
            }
        }
    }

    /// Cumulative (op, backend, bit-width) kernel profile of one path,
    /// heaviest first; empty while profiling is off. Does not reset —
    /// the `plan --profile` / bench aggregation read.
    pub fn kernel_profile(&self, int_path: bool)
                          -> Vec<(trace::KernelKey, trace::NodeTimer)> {
        let mut map = BTreeMap::new();
        if let Some(p) = &self.profile {
            let (prog, timers) = if int_path {
                (&self.int_prog, &p.int)
            } else {
                (&self.f32_prog, &p.fp)
            };
            for (i, t) in timers.iter().enumerate() {
                if t.calls > 0 {
                    map.entry(prog.kernel_key(i))
                       .or_insert_with(trace::NodeTimer::default)
                       .merge(t);
                }
            }
        }
        trace::sorted_kernel_rows(&map)
    }

    /// Per-node cumulative profile of one path in execution order:
    /// `(pass-stable node id, kernel key, timer)` for every node that
    /// ran — the `plan --profile` per-node listing.
    pub fn node_profile(&self, int_path: bool)
                        -> Vec<(usize, trace::KernelKey,
                                trace::NodeTimer)> {
        let Some(p) = &self.profile else { return Vec::new() };
        let (prog, timers) = if int_path {
            (&self.int_prog, &p.int)
        } else {
            (&self.f32_prog, &p.fp)
        };
        prog.node_ids()
            .iter()
            .zip(timers)
            .enumerate()
            .filter(|(_, (_, t))| t.calls > 0)
            .map(|(i, (&id, t))| (id, prog.kernel_key(i), *t))
            .collect()
    }

    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The compiled execution graph for one path (IR dump, arena
    /// accounting in the benches).
    pub fn program(&self, int_path: bool) -> &Program {
        if int_path {
            &self.int_prog
        } else {
            &self.f32_prog
        }
    }

    /// Disable the integer path (f32 simulated-quant fallback only) —
    /// the A/B lever behind `bbits serve --no-int` and the benches.
    pub fn set_int_enabled(&mut self, on: bool) {
        self.int_enabled = on;
    }

    /// Number of scoped threads [`Backend::Blocked`] kernel nodes
    /// shard one request across (0 and 1 both mean single-threaded).
    /// Scalar/SIMD nodes ignore it — the lever behind
    /// `--intra-threads`, capped by the serving pool so workers times
    /// intra threads never oversubscribes the machine.
    pub fn set_intra_threads(&mut self, n: usize) {
        self.st.set_intra_threads(n);
    }

    /// Run one request; returns the logits.
    pub fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.infer_batch(x, 1)
    }

    /// Run a micro-batch through the compiled graph and borrow the
    /// flat `[n, output_dim]` logits straight out of the arena — the
    /// zero-copy primitive the serving workers use. Weight rows are
    /// decoded once per layer and reused across the batch.
    pub fn run_batch(&mut self, xs: &[f32], n: usize) -> Result<&[f32]> {
        let int = self.int_enabled;
        let prog = if int { &self.int_prog } else { &self.f32_prog };
        match &mut self.profile {
            None => prog.execute(xs, n, &mut self.st)?,
            Some(p) => {
                let timers = if int { &mut p.int } else { &mut p.fp };
                let tr = self.trace.as_ref().map(|t| {
                    let base =
                        if int { t.int_base } else { t.f32_base };
                    (t.rec.as_ref(), base, t.tid)
                });
                prog.execute_instrumented(xs, n, &mut self.st,
                                          timers, tr)?;
            }
        }
        Ok(prog.output_slice(&self.st, n))
    }

    /// [`Self::run_batch`] into a caller-owned buffer (cleared first);
    /// steady-state callers reuse the buffer's capacity across batches.
    pub fn infer_batch_into(&mut self, xs: &[f32], n: usize,
                            out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        let y = self.run_batch(xs, n)?;
        out.extend_from_slice(y);
        Ok(())
    }

    /// Run a micro-batch: `xs` is flat `[n, input_dim]`, the result is
    /// flat `[n, output_dim]` (allocating convenience form).
    pub fn infer_batch(&mut self, xs: &[f32], n: usize)
                       -> Result<Vec<f32>> {
        Ok(self.run_batch(xs, n)?.to_vec())
    }

    /// The f32 simulated-quant reference for the same plan (parity
    /// oracle and `--no-int` baseline).
    pub fn infer_reference(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let was = self.int_enabled;
        self.int_enabled = false;
        let out = self.infer(x);
        self.int_enabled = was;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapt_identity_pool_and_replicate() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut out = Vec::new();
        adapt_features(&x, 4, &mut out);
        assert_eq!(out, x);
        out.clear();
        adapt_features(&x, 2, &mut out);
        assert_eq!(out, vec![1.5, 3.5]);
        out.clear();
        adapt_features(&x, 8, &mut out);
        assert_eq!(out.len(), 8);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[7], 4.0);
        // non-divisible pooling still covers every element once
        out.clear();
        adapt_features(&x, 3, &mut out);
        assert_eq!(out.len(), 3);
        // an empty source bridges with zeros instead of panicking
        out.clear();
        adapt_features(&[], 4, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn adapt_features_edge_cases_pinned() {
        // want == 0: nothing is produced (and no division by zero)
        let mut out = Vec::new();
        adapt_features(&[1.0, 2.0], 0, &mut out);
        assert!(out.is_empty());
        adapt_features(&[], 0, &mut out);
        assert!(out.is_empty());
        // non-divisible pooling: 5 -> 3 covers every element once
        out.clear();
        adapt_features(&[1.0, 2.0, 3.0, 4.0, 5.0], 3, &mut out);
        assert_eq!(out, vec![1.0, 2.5, 4.5]);
        // non-divisible replication: 3 -> 5
        out.clear();
        adapt_features(&[1.0, 2.0, 3.0], 5, &mut out);
        assert_eq!(out, vec![1.0, 1.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn adapt_spatial_edge_geometries_pinned() {
        // source larger than target on both spatial axes with
        // non-divisible pooling factors: (3,3,1) -> (2,2,1)
        let x: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let mut out = Vec::new();
        adapt_spatial(&x, (3, 3, 1), (2, 2, 1), &mut out);
        assert_eq!(out, vec![0.0, 1.5, 4.5, 6.0]);
        // whole-map collapse: (2,2,2) -> (1,1,1) pools everything
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        out.clear();
        adapt_spatial(&x, (2, 2, 2), (1, 1, 1), &mut out);
        assert_eq!(out, vec![3.5]);
        // a zero-sized target axis produces an empty bridge (and no
        // division by zero on the untouched axes)
        out.clear();
        adapt_spatial(&x, (2, 2, 2), (0, 2, 2), &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn run_batch_and_into_match_infer_batch() {
        let plan = Arc::new(
            synthetic_plan("demo", &[8, 12, 4], 4, 8, 0.2, 7).unwrap());
        let mut eng = Engine::new(plan.clone());
        let xs: Vec<f32> =
            (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let want = eng.infer_batch(&xs, 2).unwrap();
        assert_eq!(want.len(), 2 * plan.output_dim);
        let mut buf = vec![99.0f32; 3]; // stale content is cleared
        eng.infer_batch_into(&xs, 2, &mut buf).unwrap();
        assert_eq!(buf, want);
        assert_eq!(eng.run_batch(&xs, 2).unwrap(), &want[..]);
    }

    #[test]
    fn spatial_plan_resolves_same_and_valid_padding() {
        // SAME, stride 1: output keeps the map size, pad (k-1)/2 low
        let sp = SpatialPlan::new(16, 16, 8, 5, 1, Padding::Same, 1)
            .unwrap();
        assert_eq!((sp.out_h, sp.out_w), (16, 16));
        assert_eq!((sp.pad_top, sp.pad_left), (2, 2));
        assert_eq!(sp.patch_len(), 8 * 25);
        // SAME, stride 2 on an odd map: ceil, asymmetric pad
        let sp = SpatialPlan::new(3, 3, 4, 3, 2, Padding::Same, 4)
            .unwrap();
        assert_eq!((sp.out_h, sp.out_w), (2, 2));
        assert_eq!((sp.pad_top, sp.pad_left), (1, 1));
        assert_eq!(sp.patch_len(), 9);
        // VALID shrinks by k-1
        let sp = SpatialPlan::new(6, 5, 2, 3, 1, Padding::Valid, 1)
            .unwrap();
        assert_eq!((sp.out_h, sp.out_w), (4, 3));
        assert_eq!((sp.pad_top, sp.pad_left), (0, 0));
        // rejections
        assert!(SpatialPlan::new(2, 2, 2, 3, 1, Padding::Valid, 1)
            .is_err());
        assert!(SpatialPlan::new(4, 4, 3, 3, 1, Padding::Same, 2)
            .is_err());
        assert!(SpatialPlan::new(4, 4, 2, 0, 1, Padding::Same, 1)
            .is_err());
    }

    #[test]
    fn adapt_spatial_pools_and_replicates_per_axis() {
        // identity
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut out = Vec::new();
        adapt_spatial(&x, (2, 2, 3), (2, 2, 3), &mut out);
        assert_eq!(out, x);
        // channel pool 4 -> 2 (pairs averaged), spatial identity
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        out.clear();
        adapt_spatial(&x, (1, 2, 4), (1, 2, 2), &mut out);
        assert_eq!(out, vec![1.5, 3.5, 5.5, 7.5]);
        // spatial replicate 1x1 -> 2x2
        let x = vec![9.0f32, -1.0];
        out.clear();
        adapt_spatial(&x, (1, 1, 2), (2, 2, 2), &mut out);
        assert_eq!(out, vec![9.0, -1.0, 9.0, -1.0, 9.0, -1.0, 9.0,
                             -1.0]);
        // resnet-ds shape bridge: replicate h/w, pool c
        let x: Vec<f32> = (0..2 * 2 * 4).map(|i| i as f32).collect();
        out.clear();
        adapt_spatial(&x, (2, 2, 4), (4, 4, 2), &mut out);
        assert_eq!(out.len(), 4 * 4 * 2);
        assert_eq!(out[0], 0.5); // mean of channels 0,1 at pixel (0,0)
    }

    #[test]
    fn conv_plan_runs_and_batches_consistently() {
        let plan = Arc::new(
            lower::synthetic_conv_plan("c", 6, 3, 5, 3, 1,
                                       Padding::Same, 1, 4, 8, 0.3, 11)
                .unwrap(),
        );
        let mut eng = Engine::new(plan.clone());
        let x: Vec<f32> = (0..plan.input_dim)
            .map(|i| ((i as f32) * 0.37).sin())
            .collect();
        let y = eng.infer(&x).unwrap();
        assert_eq!(y.len(), 6 * 6 * 5);
        assert!(y.iter().all(|v| v.is_finite()));
        let mut xs = x.clone();
        xs.extend_from_slice(&x);
        let yy = eng.infer_batch(&xs, 2).unwrap();
        assert_eq!(&yy[..y.len()], &y[..]);
        assert_eq!(&yy[y.len()..], &y[..]);
        // every pixel of a pruned channel carries exactly its bias
        let l = &plan.layers[0];
        let bias = l.bias.as_ref().unwrap();
        for ch in 0..l.out_dim as u32 {
            if !l.kept.contains(&ch) {
                for p in 0..36 {
                    assert_eq!(y[p * 5 + ch as usize],
                               bias[ch as usize]);
                }
            }
        }
    }

    #[test]
    fn synthetic_plan_validates_and_runs() {
        let plan =
            synthetic_plan("demo", &[16, 32, 10], 4, 8, 0.25, 3).unwrap();
        plan.validate().unwrap();
        let mut eng = Engine::new(Arc::new(plan));
        let x: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 4.0).collect();
        let y = eng.infer(&x).unwrap();
        assert_eq!(y.len(), 10);
        assert!(y.iter().all(|v| v.is_finite()));
        // batch of identical inputs reproduces the single result
        let mut xs = x.clone();
        xs.extend_from_slice(&x);
        let yy = eng.infer_batch(&xs, 2).unwrap();
        assert_eq!(&yy[..10], &y[..]);
        assert_eq!(&yy[10..], &y[..]);
    }

    #[test]
    fn fully_pruned_layer_passes_bias_only() {
        let plan = lower::build_plan_single(
            "p", &[0.5f32; 12], 4, 3, &[0.0, 0.0, 0.0], 4, 1.0,
            ActSpec::Int { bits: 8, beta: 2.0, signed: true },
            Some(vec![0.5, -1.0, 2.0]), false).unwrap();
        let mut eng = Engine::new(Arc::new(plan));
        let y = eng.infer(&[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_eq!(y, vec![0.5, -1.0, 2.0]);
    }

    #[test]
    fn rejects_wrong_input_width() {
        let plan =
            synthetic_plan("demo", &[8, 4], 8, 8, 0.0, 1).unwrap();
        let mut eng = Engine::new(Arc::new(plan));
        assert!(eng.infer(&[0.0; 7]).is_err());
    }
}
