//! # Bayesian Bits — Rust coordinator (Layer 3)
//!
//! Reproduction of *Bayesian Bits: Unifying Quantization and Pruning*
//! (van Baalen et al., NeurIPS 2020) as a three-layer Rust + JAX + Pallas
//! stack: the Pallas quantizer kernel and the JAX model are AOT-lowered
//! once to HLO text (`make artifacts`); this crate owns everything that
//! runs afterwards — the PJRT runtime, the training orchestrator, gate
//! management, BOP accounting, the synthetic data pipeline, and the
//! experiment harnesses that regenerate every table and figure of the
//! paper's evaluation.
//!
//! Python never executes on the training path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`util`] — JSON, binary I/O, logging, property-test + bench harnesses
//!   (the offline registry vendors only the `xla` closure, so these are
//!   hand-rolled rather than serde/proptest/criterion).
//! * [`rng`] — PCG64 PRNG and distributions (deterministic datasets).
//! * [`tensor`] — small host-side f32 tensor.
//! * [`data`] — procedural MNIST/CIFAR/ImageNet-like dataset generators,
//!   augmentation, batching.
//! * [`quant`] — host mirror of the quantizer math: hard-concrete gates,
//!   decomposition grids, effective bit widths, thresholding (Eq. 22).
//! * [`bops`] — MAC/BOP accounting (App. B.2) incl. the ResNet rules.
//! * [`models`] — architecture descriptors (small + paper scale).
//! * [`runtime`] — PJRT client wrapper: artifact loading, executable
//!   cache, train state marshalling.
//! * [`coordinator`] — trainer, gate manager, sweeps, post-training
//!   quantization, checkpoints, metrics.
//! * [`engine`] — integer inference engine: lowers a checkpoint + its
//!   Eq. 22 gate configuration into bit-packed fixed-point GEMMs
//!   (pruned channels physically elided) and serves batched requests
//!   (`bbits serve`); parity-tested against the host oracle.
//! * [`baselines`] — fixed-width / LSQ-like / DQ-restricted / sensitivity
//!   baselines.
//! * [`experiments`] — one harness per paper table/figure.
//! * [`report`] — tables, Pareto fronts, ASCII plots, architecture viz.
//! * [`config`] + [`cli`] — run configuration and the `bbits` launcher.

// every unsafe operation must sit in an explicit `unsafe {}` block
// with its own `SAFETY:` argument, even inside `unsafe fn` (the CI
// lint job additionally denies `clippy::undocumented_unsafe_blocks`)
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod bops;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod models;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
