//! Little-endian binary I/O for parameter/checkpoint blobs.
//!
//! Format shared with `python/compile/aot.py` (`init.bin`: raw f32 LE)
//! and with the checkpoint writer (`coordinator::checkpoint`), which
//! adds a small header on top of these primitives.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Read a whole file of raw little-endian f32 values.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let mut f = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    bytes_to_f32(&bytes)
}

/// Write raw little-endian f32 values.
pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let mut f = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    f.write_all(&f32_to_bytes(data))?;
    Ok(())
}

pub fn bytes_to_f32(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("byte length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn f32_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Length-prefixed section writer for simple container formats.
pub struct SectionWriter<W: Write> {
    w: W,
}

impl<W: Write> SectionWriter<W> {
    pub fn new(w: W) -> Self {
        Self { w }
    }

    pub fn write_str(&mut self, s: &str) -> Result<()> {
        self.write_bytes(s.as_bytes())
    }

    pub fn write_f32s(&mut self, data: &[f32]) -> Result<()> {
        self.write_bytes(&f32_to_bytes(data))
    }

    fn write_bytes(&mut self, b: &[u8]) -> Result<()> {
        self.w.write_all(&(b.len() as u64).to_le_bytes())?;
        self.w.write_all(b)?;
        Ok(())
    }
}

/// Length-prefixed section reader.
pub struct SectionReader<R: Read> {
    r: R,
}

impl<R: Read> SectionReader<R> {
    pub fn new(r: R) -> Self {
        Self { r }
    }

    pub fn read_str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.read_bytes()?)?)
    }

    pub fn read_f32s(&mut self) -> Result<Vec<f32>> {
        bytes_to_f32(&self.read_bytes()?)
    }

    fn read_bytes(&mut self) -> Result<Vec<u8>> {
        let mut len = [0u8; 8];
        self.r.read_exact(&mut len)?;
        let n = u64::from_le_bytes(len) as usize;
        if n > (1 << 32) {
            bail!("section too large: {n}");
        }
        let mut buf = vec![0u8; n];
        self.r.read_exact(&mut buf)?;
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let v = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)).unwrap(), v);
    }

    #[test]
    fn rejects_misaligned() {
        assert!(bytes_to_f32(&[0, 1, 2]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("bbits_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let v: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        write_f32_file(&p, &v).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), v);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn sections_roundtrip() {
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            w.write_str("header").unwrap();
            w.write_f32s(&[1.0, 2.0]).unwrap();
        }
        let mut r = SectionReader::new(&buf[..]);
        assert_eq!(r.read_str().unwrap(), "header");
        assert_eq!(r.read_f32s().unwrap(), vec![1.0, 2.0]);
    }
}
