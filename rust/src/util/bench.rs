//! Hand-rolled micro/throughput benchmark harness (criterion is not
//! vendored). Used by every `cargo bench` target (`harness = false`).
//!
//! Reports min/median/mean/p95 wall time per iteration plus an optional
//! user-supplied throughput unit, in a criterion-like one-line format
//! that `EXPERIMENTS.md §Perf` quotes directly.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{num, obj, s, Json};

/// One benchmark's timing summary (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl Summary {
    pub fn line(&self, throughput: Option<(f64, &str)>) -> String {
        let mut s = format!(
            "{:<44} iters={:<4} min={} median={} mean={} p95={}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        );
        if let Some((per_iter, unit)) = throughput {
            let rate = per_iter / (self.median_ns * 1e-9);
            s.push_str(&format!("  [{rate:.1} {unit}/s]"));
        }
        s
    }

    /// JSON record for machine-readable bench artifacts
    /// (`BENCH_*.json`); `extra` carries bench-specific columns such
    /// as batch size or bit width.
    pub fn to_json(&self, extra: Vec<(&str, Json)>) -> Json {
        let mut fields = vec![
            ("name", s(&self.name)),
            ("iters", num(self.iters as f64)),
            ("min_ns", num(self.min_ns)),
            ("median_ns", num(self.median_ns)),
            ("mean_ns", num(self.mean_ns)),
            ("p95_ns", num(self.p95_ns)),
        ];
        fields.extend(extra);
        obj(fields)
    }
}

/// Write a `BENCH_<name>.json` artifact: `{"bench": title,
/// "results": [...]}` — the contract the perf tracking scripts read.
pub fn save_json(path: &Path, title: &str, results: Vec<Json>)
                 -> Result<()> {
    let doc = obj(vec![
        ("bench", s(title)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("write bench artifact {path:?}"))?;
    Ok(())
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Bench runner: warms up, then runs timed iterations until both the
/// minimum iteration count and the time budget are satisfied.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, min_iters: 10, max_iters: 1000,
               budget_secs: 5.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { warmup_iters: 1, min_iters: 5, max_iters: 50,
               budget_secs: 2.0 }
    }

    /// Time `f`, which performs one iteration per call.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < self.min_iters
                || start.elapsed().as_secs_f64() < self.budget_secs)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        summarize(name, &mut samples)
    }
}

fn summarize(name: &str, samples: &mut [f64]) -> Summary {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    Summary {
        name: name.to_string(),
        iters: n,
        min_ns: samples[0],
        median_ns: samples[n / 2],
        mean_ns: mean,
        p95_ns: samples[(n as f64 * 0.95) as usize % n],
    }
}

/// Convenience for bench binaries: print header once.
pub fn header(title: &str) {
    println!("=== bench: {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_summarizes() {
        let b = Bench { warmup_iters: 1, min_iters: 5, max_iters: 10,
                        budget_secs: 0.2 };
        let mut acc = 0u64;
        let s = b.run("noop", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.iters >= 5);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn format_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }

    #[test]
    fn json_artifact_roundtrips() {
        let s = Summary {
            name: "k".into(),
            iters: 10,
            min_ns: 1.0,
            median_ns: 2.0,
            mean_ns: 2.5,
            p95_ns: 3.0,
        };
        let dir = std::env::temp_dir().join("bbits_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_x.json");
        save_json(&p, "x", vec![s.to_json(vec![("batch", num(4.0))])])
            .unwrap();
        let doc =
            Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "x");
        let rows = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("batch").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(rows[0].get("median_ns").unwrap().as_f64().unwrap(),
                   2.0);
        std::fs::remove_file(&p).unwrap();
    }
}
