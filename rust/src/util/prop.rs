//! Hand-rolled property-testing harness (proptest is not vendored).
//!
//! A property is a closure over a [`Gen`] source of randomness; the
//! runner executes it for `cases` iterations with independent seeds and,
//! on failure, retries with the same seed while *shrinking scale*: the
//! generator exposes a `scale` in (0, 1] that generators use to shrink
//! magnitudes/lengths, which makes minimal-ish counterexamples without a
//! full shrink tree. Failures report the seed so a case can be replayed
//! deterministically with [`check_seeded`].

use crate::rng::Pcg64;

/// Randomness source handed to properties.
pub struct Gen {
    pub rng: Pcg64,
    pub scale: f64,
}

impl Gen {
    pub fn new(seed: u64, scale: f64) -> Self {
        Self { rng: Pcg64::new(seed), scale }
    }

    /// Uniform usize in [lo, hi], scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.scale).ceil() as usize;
        lo + self.rng.next_below((span + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi], magnitude-scaled when shrinking.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = (lo + hi) / 2.0;
        let half = (hi - lo) / 2.0 * self.scale;
        mid - half + self.rng.next_f64() * 2.0 * half
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vec of f32 with random length in [min_len, max_len].
    pub fn f32_vec(&mut self, min_len: usize, max_len: usize, lo: f32,
                   hi: f32) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }
}

/// Outcome of a property check.
pub enum PropResult {
    Pass,
    Fail(String),
}

impl PropResult {
    pub fn check(cond: bool, msg: impl FnOnce() -> String) -> PropResult {
        if cond {
            PropResult::Pass
        } else {
            PropResult::Fail(msg())
        }
    }
}

/// Run `prop` for `cases` random cases; panic with diagnostics on failure.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    let base = 0x9e3779b97f4a7c15u64 ^ fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x2545f4914f6cdd1d));
        if let PropResult::Fail(first) = run_one(seed, 1.0, &prop) {
            // try smaller scales with the same seed for a simpler repro
            let mut best = (1.0, first);
            for scale in [0.5, 0.25, 0.1] {
                if let PropResult::Fail(msg) = run_one(seed, scale, &prop) {
                    best = (scale, msg);
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 scale {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Replay a single case deterministically.
pub fn check_seeded(seed: u64, scale: f64,
                    prop: impl Fn(&mut Gen) -> PropResult) -> PropResult {
    run_one(seed, scale, &prop)
}

fn run_one(seed: u64, scale: f64,
           prop: &impl Fn(&mut Gen) -> PropResult) -> PropResult {
    let mut g = Gen::new(seed, scale);
    prop(&mut g)
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", 200, |g| {
            let a = g.f64_in(-1e6, 1e6);
            let b = g.f64_in(-1e6, 1e6);
            PropResult::check((a + b) == (b + a), || "!".into())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        // Fails for roughly half of all draws, so the first failure is
        // found within 50 cases with probability 1 - 2^-50.
        check("half_fail", 50, |g| {
            let v = g.f64_in(-1.0, 1.0);
            PropResult::check(v < 0.0, || format!("v={v}"))
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 500, |g| {
            let n = g.usize_in(3, 17);
            let x = g.f32_in(-2.0, 5.0);
            PropResult::check((3..=17).contains(&n) && (-2.0..=5.0)
                              .contains(&x),
                              || format!("n={n} x={x}"))
        });
    }

    #[test]
    fn seeded_replay_is_deterministic() {
        let f = |g: &mut Gen| {
            let v = g.f64_in(0.0, 1.0);
            PropResult::Fail(format!("{v}"))
        };
        let a = match check_seeded(42, 1.0, f) {
            PropResult::Fail(m) => m,
            _ => unreachable!(),
        };
        let b = match check_seeded(42, 1.0, f) {
            PropResult::Fail(m) => m,
            _ => unreachable!(),
        };
        assert_eq!(a, b);
    }
}
