//! Tiny leveled logger with wall-clock timestamps.
//!
//! One global level, set once from the CLI (`--log-level`). Macro-free
//! call sites (`log::info(...)`) keep the dependency surface at zero.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Option<Level> {
    match s {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

fn emit(level: &str, msg: &str) {
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = t.as_secs() % 86_400;
    eprintln!(
        "[{:02}:{:02}:{:02}.{:03} {level:5}] {msg}",
        secs / 3600,
        (secs % 3600) / 60,
        secs % 60,
        t.subsec_millis()
    );
}

pub fn debug(msg: impl AsRef<str>) {
    if enabled(Level::Debug) {
        emit("DEBUG", msg.as_ref());
    }
}

pub fn info(msg: impl AsRef<str>) {
    if enabled(Level::Info) {
        emit("INFO", msg.as_ref());
    }
}

pub fn warn(msg: impl AsRef<str>) {
    if enabled(Level::Warn) {
        emit("WARN", msg.as_ref());
    }
}

pub fn error(msg: impl AsRef<str>) {
    if enabled(Level::Error) {
        emit("ERROR", msg.as_ref());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(level_from_str("debug"), Some(Level::Debug));
        assert_eq!(level_from_str("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Debug < Level::Error);
    }
}
