//! Infrastructure substrates hand-rolled for the offline environment.
//!
//! The vendored registry only carries the `xla` crate's dependency
//! closure, so the usual ecosystem crates (serde, clap, criterion,
//! proptest, rand, tokio) are unavailable. Each submodule here is a
//! deliberately small, well-tested replacement for the slice of
//! functionality this project needs.

pub mod bench;
pub mod binio;
pub mod json;
pub mod logging;
pub mod prop;

/// Format a float with engineering-style precision for tables.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let mag = v.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{v:.dec$}")
}

/// Mean and (population) standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Standard error of the mean.
pub fn stderr_of_mean(xs: &[f64]) -> f64 {
    if xs.len() <= 1 {
        return 0.0;
    }
    let (_, sd) = mean_std(xs);
    sd / ((xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn fmt_sig_rounds() {
        assert_eq!(fmt_sig(0.5123, 2), "0.51");
        assert_eq!(fmt_sig(93.05123, 4), "93.05");
        assert_eq!(fmt_sig(0.0, 3), "0");
    }

    #[test]
    fn stderr_zero_for_single() {
        assert_eq!(stderr_of_mean(&[5.0]), 0.0);
    }
}
