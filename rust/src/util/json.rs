//! Minimal JSON parser/serializer (serde is not in the offline registry).
//!
//! Supports the full JSON grammar needed by the AOT manifests and golden
//! files: objects, arrays, strings (with escapes), numbers, booleans,
//! null. Numbers are held as `f64`, which is exact for every integer the
//! manifests contain (< 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(anyhow!("expected array, got {:.60?}", other)),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(anyhow!("expected object, got {:.60?}", other)),
        }
    }

    /// Field access on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Convenience: numeric array -> Vec<f32>.
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    /// Convenience: numeric array -> Vec<usize>.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for emitting metrics/report JSON.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(v: f64) -> Json {
    Json::Num(v)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            bail!("truncated utf-8");
                        }
                        out.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xf0 {
        4
    } else if b >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"z":{"q":-3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.f32_vec().unwrap(), vec![1.0, 2.5, 3.0]);
    }
}
