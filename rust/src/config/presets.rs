//! Experiment presets: the mu grids and step budgets used by each paper
//! table/figure, scaled to the CPU testbed.
//!
//! The paper's epoch counts (100 MNIST / 300 CIFAR / 30+10 ImageNet on
//! V100s) map here to step budgets chosen so a full table regenerates in
//! minutes on one CPU. `--steps`/`--mus` CLI flags override everything
//! for longer runs.

use crate::config::RunConfig;

/// mu grid for Table 1 (MNIST/CIFAR10).
pub const TABLE1_MUS: &[f64] = &[0.01, 0.1];
/// mu grid for Figure 2a / Table 4 (ResNet18).
pub const FIGURE2_MUS: &[f64] = &[0.01, 0.03, 0.05, 0.07, 0.2];
/// mu grid for pruning-only ablation (Figure 2a).
pub const PRUNE_ONLY_MUS: &[f64] = &[0.05, 0.2, 0.5, 0.7, 1.0];
/// mu grid for post-training (Table 5 / Figure 3).
pub const PTQ_MUS: &[f64] =
    &[0.0001, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.02, 0.05];

/// Default phase-1/phase-2 step budgets per model (CPU-scaled).
pub fn default_steps(model: &str) -> (usize, usize) {
    match model {
        "lenet5" => (500, 120),
        "vgg7" => (600, 150),
        "resnet18" => (400, 100),
        "mobilenetv2" => (350, 80),
        _ => (400, 100),
    }
}

/// Baseline run config for a model (paper App. B.1 hyper-parameters,
/// learning-rate magnitudes preserved; Adam for all groups).
pub fn base_config(model: &str) -> RunConfig {
    let (steps, ft) = default_steps(model);
    RunConfig {
        model: model.to_string(),
        steps,
        finetune_steps: ft,
        lr_w: 1e-3,
        lr_g: 3e-2,
        lr_s: 1e-3,
        ..RunConfig::default()
    }
}

/// Step budget for post-training mode ("small dataset, minor compute").
/// Must be enough for phi to travel from its +6 init to the Eq. 22
/// threshold (~-0.94) under Adam at `PTQ_LR_G`.
pub fn ptq_steps() -> usize {
    250
}

/// Gate learning rate for post-training mode.
pub const PTQ_LR_G: f64 = 5e-2;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_match_paper() {
        assert_eq!(FIGURE2_MUS.len(), 5);
        assert!(PTQ_MUS.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn base_config_known_models() {
        for m in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
            let c = base_config(m);
            assert!(c.steps > 0 && c.lr_g > c.lr_w);
        }
    }
}
