//! TOML-subset parser for config files.
//!
//! Grammar: `[section]` headers, `key = value` assignments, `#` comments.
//! Values: quoted strings, integers/floats, booleans, and flat arrays of
//! those. That covers the experiment presets; nested tables are out of
//! scope on purpose.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum ConfigValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<ConfigValue>),
}

impl ConfigValue {
    /// Render as the string form `RunConfig::set` accepts.
    pub fn to_flag_string(&self) -> String {
        match self {
            ConfigValue::Str(s) => s.clone(),
            ConfigValue::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            ConfigValue::Bool(b) => b.to_string(),
            ConfigValue::Arr(a) => a
                .iter()
                .map(|v| v.to_flag_string())
                .collect::<Vec<_>>()
                .join(","),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            ConfigValue::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }
}

/// Parsed config document: section -> key -> value. Keys before any
/// section header land in the "" (root) section.
#[derive(Debug, Default, Clone)]
pub struct ConfigDoc {
    sections: BTreeMap<String, BTreeMap<String, ConfigValue>>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc> {
        let mut doc = ConfigDoc::default();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: bad section header",
                                           lineno + 1))?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                anyhow!("line {}: expected key = value", lineno + 1)
            })?;
            let parsed = parse_value(value.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(current.clone())
                .or_default()
                .insert(key.trim().to_string(), parsed);
        }
        Ok(doc)
    }

    pub fn section(&self, name: &str)
                   -> Option<&BTreeMap<String, ConfigValue>> {
        self.sections.get(name)
    }

    pub fn section_names(&self) -> Vec<&str> {
        self.sections.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&ConfigValue> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<ConfigValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(ConfigValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(ConfigValue::Arr(items));
    }
    match s {
        "true" => return Ok(ConfigValue::Bool(true)),
        "false" => return Ok(ConfigValue::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(ConfigValue::Num)
        .map_err(|_| anyhow!("cannot parse value {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = ConfigDoc::parse(
            "top = 1\n[run]\nmodel = \"vgg7\"\nmu = 0.05 # strength\n\
             flag = true\nmus = [0.01, 0.1]\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&ConfigValue::Num(1.0)));
        assert_eq!(doc.get("run", "model"),
                   Some(&ConfigValue::Str("vgg7".into())));
        assert_eq!(doc.get("run", "flag"), Some(&ConfigValue::Bool(true)));
        assert_eq!(
            doc.get("run", "mus"),
            Some(&ConfigValue::Arr(vec![ConfigValue::Num(0.01),
                                        ConfigValue::Num(0.1)]))
        );
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = ConfigDoc::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "k"),
                   Some(&ConfigValue::Str("a#b".into())));
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = ConfigDoc::parse("\nbad line\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn flag_string_roundtrip() {
        assert_eq!(ConfigValue::Num(5.0).to_flag_string(), "5");
        assert_eq!(ConfigValue::Num(0.5).to_flag_string(), "0.5");
        assert_eq!(ConfigValue::Bool(false).to_flag_string(), "false");
    }
}
