//! Run configuration: a TOML-subset parser plus typed experiment configs.
//!
//! The config file format supports `[sections]`, `key = value` with
//! strings, numbers, booleans and flat arrays — exactly what experiment
//! presets need. CLI flags override file values (`cli` module).

mod parse;
pub mod presets;

pub use parse::{ConfigDoc, ConfigValue};

use anyhow::{anyhow, Result};

/// Fully-resolved training run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model key: lenet5 | vgg7 | resnet18 | mobilenetv2 (+ `_dq`).
    pub model: String,
    /// Global regularization strength mu (§4: lambda'_{jk} = mu * base).
    pub mu: f64,
    /// Training mode, selects the gate-lock pattern.
    pub mode: Mode,
    /// Steps of phase 1 (stochastic gates).
    pub steps: usize,
    /// Steps of phase 2 (gates frozen by Eq. 22 thresholding, fine-tune).
    pub finetune_steps: usize,
    /// Learning rates per parameter group.
    pub lr_w: f64,
    pub lr_g: f64,
    pub lr_s: f64,
    /// Evaluate every n steps (0 = only at phase boundaries).
    pub eval_every: usize,
    /// Dataset seed (generator is fully deterministic).
    pub seed: u64,
    /// Deterministic-gate ablation (Table 2).
    pub deterministic_gates: bool,
    /// Directory holding AOT artifacts.
    pub artifacts_dir: String,
    /// Output directory for metrics/checkpoints.
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "lenet5".into(),
            mu: 0.01,
            mode: Mode::BayesianBits,
            steps: 400,
            finetune_steps: 100,
            lr_w: 1e-3,
            lr_g: 3e-2,
            lr_s: 1e-3,
            eval_every: 0,
            seed: 1,
            deterministic_gates: false,
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
        }
    }
}

/// Training mode — maps to a gate-lock pattern (see `coordinator::gate_manager`).
#[derive(Debug, Clone, PartialEq)]
pub enum Mode {
    /// Full method: learn pruning + mixed precision jointly.
    BayesianBits,
    /// Ablation: z2 locked open everywhere (no pruning; §4.2 "QO").
    QuantOnly,
    /// Ablation: fixed wX/aY bits, learn only weight z2 (§4.2 "PO").
    PruneOnly { w_bits: u32, a_bits: u32 },
    /// Fixed-width baseline wX/aY with learned ranges ("LSQ-like").
    Fixed { w_bits: u32, a_bits: u32 },
    /// All gates open at the full chain — the FP32-equivalent reference.
    Fp32,
    /// DQ baseline (separate artifact; locks unused).
    Dq,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        if let Some(rest) = s.strip_prefix("fixed:") {
            let (w, a) = parse_wa(rest)?;
            return Ok(Mode::Fixed { w_bits: w, a_bits: a });
        }
        if let Some(rest) = s.strip_prefix("prune-only:") {
            let (w, a) = parse_wa(rest)?;
            return Ok(Mode::PruneOnly { w_bits: w, a_bits: a });
        }
        match s {
            "bb" | "bayesian-bits" => Ok(Mode::BayesianBits),
            "quant-only" | "qo" => Ok(Mode::QuantOnly),
            "fp32" => Ok(Mode::Fp32),
            "dq" => Ok(Mode::Dq),
            _ => Err(anyhow!(
                "unknown mode {s:?} (bb|quant-only|prune-only:WxA|\
                 fixed:WxA|fp32|dq)"
            )),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Mode::BayesianBits => "bb".into(),
            Mode::QuantOnly => "quant-only".into(),
            Mode::PruneOnly { w_bits, a_bits } => {
                format!("prune-only:w{w_bits}a{a_bits}")
            }
            Mode::Fixed { w_bits, a_bits } => format!("fixed:w{w_bits}a{a_bits}"),
            Mode::Fp32 => "fp32".into(),
            Mode::Dq => "dq".into(),
        }
    }
}

fn parse_wa(s: &str) -> Result<(u32, u32)> {
    // "w4a8" or "4x8"
    let t = s.trim_start_matches('w');
    let (w, a) = t
        .split_once(['a', 'x'])
        .ok_or_else(|| anyhow!("expected WxA spec, got {s:?}"))?;
    Ok((w.parse()?, a.parse()?))
}

impl RunConfig {
    /// Apply `key = value` overrides (from file sections or CLI flags).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.into(),
            "mu" => self.mu = value.parse()?,
            "mode" => self.mode = Mode::parse(value)?,
            "steps" => self.steps = value.parse()?,
            "finetune_steps" | "finetune-steps" => {
                self.finetune_steps = value.parse()?
            }
            "lr_w" | "lr-w" => self.lr_w = value.parse()?,
            "lr_g" | "lr-g" => self.lr_g = value.parse()?,
            "lr_s" | "lr-s" => self.lr_s = value.parse()?,
            "eval_every" | "eval-every" => self.eval_every = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "deterministic_gates" | "det-gates" => {
                self.deterministic_gates = value.parse()?
            }
            "artifacts" | "artifacts_dir" => {
                self.artifacts_dir = value.into()
            }
            "out" | "out_dir" => self.out_dir = value.into(),
            _ => return Err(anyhow!("unknown config key {key:?}")),
        }
        Ok(())
    }

    pub fn from_doc(doc: &ConfigDoc, section: &str) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(map) = doc.section(section) {
            for (k, v) in map {
                cfg.set(k, &v.to_flag_string())?;
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(Mode::parse("bb").unwrap(), Mode::BayesianBits);
        assert_eq!(Mode::parse("fixed:w4a8").unwrap(),
                   Mode::Fixed { w_bits: 4, a_bits: 8 });
        assert_eq!(Mode::parse("prune-only:w4a8").unwrap(),
                   Mode::PruneOnly { w_bits: 4, a_bits: 8 });
        assert!(Mode::parse("nope").is_err());
    }

    #[test]
    fn mode_labels_roundtrip() {
        for m in [Mode::BayesianBits, Mode::QuantOnly,
                  Mode::Fixed { w_bits: 8, a_bits: 8 },
                  Mode::PruneOnly { w_bits: 4, a_bits: 8 }, Mode::Fp32] {
            assert_eq!(Mode::parse(&m.label()).unwrap(), m);
        }
    }

    #[test]
    fn set_overrides() {
        let mut c = RunConfig::default();
        c.set("mu", "0.2").unwrap();
        c.set("mode", "fixed:w4a4").unwrap();
        c.set("steps", "1000").unwrap();
        assert_eq!(c.mu, 0.2);
        assert_eq!(c.steps, 1000);
        assert!(c.set("bogus", "1").is_err());
    }
}
