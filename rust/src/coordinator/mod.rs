//! Layer-3 coordinator: the training orchestrator.
//!
//! * [`gate_manager`] — turns a training [`Mode`](crate::config::Mode)
//!   into per-slot lock vectors, thresholds phi into test-time gates
//!   (Eq. 22), and derives effective bit widths / prune ratios.
//! * [`trainer`] — two-phase training loop (stochastic gates, then
//!   frozen-gate fine-tuning, §4.2) driving the AOT train/eval
//!   executables; cosine learning-rate schedules; periodic evaluation.
//! * [`metrics`] — step/eval history, gate-probability traces
//!   (Figures 10-14), JSON/CSV export.
//! * [`checkpoint`] — binary save/restore of the full train state.
//! * [`sweep`] — thread-parallel mu sweeps producing Pareto fronts.
//! * [`ptq`] — post-training mode (§4.2.1): gates-only / gates+scales
//!   on a frozen pretrained model, plus the sensitivity-ordered
//!   iterative baseline.

pub mod checkpoint;
pub mod gate_manager;
pub mod metrics;
pub mod ptq;
pub mod sweep;
pub mod trainer;

pub use gate_manager::GateManager;
pub use trainer::{RunResult, Trainer};
