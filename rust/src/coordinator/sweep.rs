//! Thread-parallel mu sweeps: the Pareto-front generator behind every
//! accuracy-vs-BOPs figure.
//!
//! The `xla` wrappers hold raw PJRT pointers and are not `Send`, so each
//! worker thread owns its own `Runtime` (client + compilations). Jobs
//! are distributed round-robin; results come back over a channel.

use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::trainer::{RunResult, Trainer};
use crate::config::RunConfig;
use crate::runtime::{Manifest, Runtime};
use crate::util::logging;

/// One sweep job.
#[derive(Debug, Clone)]
pub struct Job {
    pub cfg: RunConfig,
}

/// Run all jobs, `jobs_parallel` at a time, returning results in job
/// order. Each thread builds its own PJRT client.
pub fn run_sweep(jobs: Vec<Job>, jobs_parallel: usize)
                 -> Result<Vec<RunResult>> {
    let n = jobs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = jobs_parallel.clamp(1, n);
    if workers == 1 {
        // fast path: reuse one runtime + executable cache
        let rt = Arc::new(Runtime::cpu()?);
        let mut out = Vec::with_capacity(n);
        for job in jobs {
            out.push(run_job(rt.clone(), job)?);
        }
        return Ok(out);
    }

    let (tx, rx) = mpsc::channel::<(usize, Result<RunResult>)>();
    let mut queue: Vec<(usize, Job)> = jobs.into_iter().enumerate()
        .collect();
    // round-robin static partition
    let mut shards: Vec<Vec<(usize, Job)>> = (0..workers)
        .map(|_| Vec::new())
        .collect();
    for (i, j) in queue.drain(..) {
        shards[i % workers].push((i, j));
    }
    let mut handles = Vec::new();
    for shard in shards {
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let rt = match Runtime::cpu() {
                Ok(rt) => Arc::new(rt),
                Err(e) => {
                    for (i, _) in &shard {
                        let _ = tx.send((*i, Err(anyhow!(
                            "runtime init failed: {e}"))));
                    }
                    return;
                }
            };
            for (i, job) in shard {
                let res = run_job(rt.clone(), job);
                let _ = tx.send((i, res));
            }
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<RunResult>> = (0..n).map(|_| None).collect();
    for (i, res) in rx {
        slots[i] = Some(res?);
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("sweep worker panicked"))?;
    }
    slots
        .into_iter()
        .map(|s| s.ok_or_else(|| anyhow!("missing sweep result")))
        .collect()
}

fn run_job(rt: Arc<Runtime>, job: Job) -> Result<RunResult> {
    let man = Manifest::load(
        std::path::Path::new(&job.cfg.artifacts_dir),
        &job.cfg.model,
    )?;
    logging::info(format!(
        "sweep job: {} mode={} mu={} seed={}",
        job.cfg.model,
        job.cfg.mode.label(),
        job.cfg.mu,
        job.cfg.seed
    ));
    let mut trainer = Trainer::new(rt, man, job.cfg)?;
    trainer.run()
}

/// Aggregate repeated-seed results: mean and standard error per
/// (mode, mu) key, in first-seen order — the "mean±stderr over 3 runs"
/// the paper's tables report.
pub struct Aggregated {
    pub mode: String,
    pub mu: f64,
    pub acc_mean: f64,
    pub acc_stderr: f64,
    pub bops_mean: f64,
    pub bops_stderr: f64,
    pub n: usize,
}

pub fn aggregate(results: &[RunResult]) -> Vec<Aggregated> {
    let mut order: Vec<(String, f64)> = Vec::new();
    for r in results {
        let key = (r.mode.clone(), r.mu);
        if !order.contains(&key) {
            order.push(key);
        }
    }
    order
        .into_iter()
        .map(|(mode, mu)| {
            let accs: Vec<f64> = results
                .iter()
                .filter(|r| r.mode == mode && r.mu == mu)
                .map(|r| r.accuracy)
                .collect();
            let bops: Vec<f64> = results
                .iter()
                .filter(|r| r.mode == mode && r.mu == mu)
                .map(|r| r.rel_bops_pct)
                .collect();
            Aggregated {
                mode,
                mu,
                acc_mean: crate::util::mean_std(&accs).0,
                acc_stderr: crate::util::stderr_of_mean(&accs),
                bops_mean: crate::util::mean_std(&bops).0,
                bops_stderr: crate::util::stderr_of_mean(&bops),
                n: accs.len(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::History;
    use std::collections::BTreeMap;

    fn fake(mode: &str, mu: f64, acc: f64, bops: f64) -> RunResult {
        RunResult {
            model: "m".into(), mode: mode.into(), mu, seed: 0,
            deterministic: false,
            accuracy: acc, pre_ft_accuracy: acc, test_loss: 0.0,
            rel_bops_pct: bops, gates: vec![], states: BTreeMap::new(),
            history: History::default(),
        }
    }

    #[test]
    fn aggregate_groups_and_averages() {
        let rs = vec![
            fake("bb", 0.1, 0.90, 1.0),
            fake("bb", 0.1, 0.92, 1.2),
            fake("bb", 0.2, 0.85, 0.5),
        ];
        let agg = aggregate(&rs);
        assert_eq!(agg.len(), 2);
        assert!((agg[0].acc_mean - 0.91).abs() < 1e-12);
        assert_eq!(agg[0].n, 2);
        assert_eq!(agg[1].n, 1);
        assert_eq!(agg[1].acc_stderr, 0.0);
    }
}
