//! Two-phase training loop (§4.2).
//!
//! Phase 1: stochastic hard-concrete gates, BOP-proportional regularizer
//! `lam = mu * lam_base`, cosine-decayed learning rates. Phase 2: gates
//! thresholded (Eq. 22) and frozen, weights + ranges fine-tuned with a
//! smaller rate (`lr/10`, annealed to zero), matching the paper's 30+10
//! epoch recipe scaled to steps.
//!
//! The trainer owns the data pipeline and all device interaction; one
//! `Trainer` = one run = one (model, mode, mu, seed) configuration.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::gate_manager::GateManager;
use super::metrics::{EvalRecord, History, StepRecord};
use crate::bops::{expected_bops, BopCounter, QuantState};
use crate::config::RunConfig;
use crate::data::{generate, Batcher, Dataset};
use crate::runtime::{Executable, Manifest, Runtime, TrainState};
use crate::util::logging;

/// Final result of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub model: String,
    pub mode: String,
    pub mu: f64,
    pub seed: u64,
    /// Deterministic-gate ablation run (Table 2).
    pub deterministic: bool,
    /// Test accuracy after phase 2 (and after phase 1, for Fig. 7).
    pub accuracy: f64,
    pub pre_ft_accuracy: f64,
    pub test_loss: f64,
    /// Relative BOPs (%) of the final thresholded configuration.
    pub rel_bops_pct: f64,
    /// Final binary gates (n_slots).
    pub gates: Vec<f32>,
    /// Per-quantizer learned state.
    pub states: BTreeMap<String, QuantState>,
    pub history: History,
}

/// One full training run over a loaded artifact.
pub struct Trainer {
    pub rt: Arc<Runtime>,
    pub man: Manifest,
    pub cfg: RunConfig,
    train_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    counter: BopCounter,
    test_set: Dataset,
    batcher: Batcher,
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
}

impl Trainer {
    pub fn new(rt: Arc<Runtime>, man: Manifest, cfg: RunConfig)
               -> Result<Trainer> {
        let train_exe = rt.load(&man.hlo_train)?;
        let eval_exe = rt.load(&man.hlo_eval)?;
        let counter = BopCounter::new(man.layers.clone());
        let train_set = generate(&man.dataset, cfg.seed, false)
            .context("generate train set")?;
        let test_set = generate(&man.dataset, cfg.seed, true)
            .context("generate test set")?;
        let augment = man.dataset.name != "mnist_like";
        let batcher = Batcher::new(train_set, man.batch, augment, cfg.seed);
        let n_in = man.batch * man.input_shape.iter().product::<usize>();
        Ok(Trainer {
            rt,
            train_exe,
            eval_exe,
            counter,
            test_set,
            batcher,
            x_buf: vec![0.0; n_in],
            y_buf: vec![0i32; man.batch],
            man,
            cfg,
        })
    }

    /// Cosine-annealed learning rate over a phase.
    pub fn cosine(lr0: f64, t: usize, total: usize) -> f32 {
        let frac = t as f64 / total.max(1) as f64;
        (lr0 * 0.5 * (1.0 + (std::f64::consts::PI * frac).cos())) as f32
    }

    /// Full evaluation over the test set with fixed binary gates.
    pub fn evaluate(&self, state: &TrainState, gates: &[f32])
                    -> Result<(f64, f64)> {
        let mut total_loss = 0.0f64;
        let mut total_correct = 0.0f64;
        let mut total = 0usize;
        let mut err: Option<anyhow::Error> = None;
        Batcher::for_eval(&self.test_set, self.man.batch, |x, y, count| {
            if err.is_some() {
                return;
            }
            match self.rt.eval_step(&self.eval_exe, &self.man,
                                    &state.params, gates, x, y) {
                Ok(out) => {
                    // partial batches: the padded rows contribute to the
                    // batch mean; rescale by batch/count for the loss and
                    // cap correct by count (labels are 0-padded; a padded
                    // row can count as correct, so subtract its expected
                    // contribution by evaluating only full batches when
                    // possible).
                    total_loss += out.loss as f64 * count as f64;
                    total_correct += out.correct as f64
                        - (self.man.batch - count) as f64
                            * Self::padded_correct_rate(out.correct,
                                                        self.man.batch,
                                                        count);
                    total += count;
                }
                Err(e) => err = Some(e),
            }
        });
        if let Some(e) = err {
            return Err(e);
        }
        Ok((total_loss / total as f64, total_correct / total as f64))
    }

    // For padded eval batches we cannot distinguish which rows were
    // correct; assume padded rows (all-zero image, label 0) are wrong —
    // a conservative, deterministic choice (exact when batch divides the
    // test set, which the default specs ensure).
    fn padded_correct_rate(_correct: f32, _batch: usize,
                           _count: usize) -> f64 {
        0.0
    }

    /// Run both phases from the artifact's initial parameters.
    pub fn run(&mut self) -> Result<RunResult> {
        let init = TrainState::init(&self.man)?;
        Ok(self.run_keeping_state(init)?.1)
    }

    /// Run both phases from a provided state (PTQ starts from a
    /// pretrained checkpoint) and return the final state too.
    pub fn run_keeping_state(&mut self, init: TrainState)
                             -> Result<(TrainState, RunResult)> {
        let gm = GateManager::new(&self.man);
        let (lock_mask, lock_val) = gm.locks(&self.cfg.mode);
        let lam: Vec<f32> = self
            .man
            .lam_base
            .iter()
            .map(|b| (*b as f64 * self.cfg.mu) as f32)
            .collect();
        let det = if self.cfg.deterministic_gates { 1.0 } else { 0.0 };
        let mut state = init;
        let mut history = History::default();
        let fp32 = self.counter.fp32_bops();
        let snapshot_every = (self.cfg.steps / 24).max(1);

        // ---- phase 1: stochastic gates --------------------------------
        let mut probs = vec![1.0f32; self.man.n_slots];
        for t in 0..self.cfg.steps {
            self.batcher.next_into(&mut self.x_buf, &mut self.y_buf);
            let lrs = (
                Self::cosine(self.cfg.lr_w, t, self.cfg.steps),
                Self::cosine(self.cfg.lr_g, t, self.cfg.steps),
                Self::cosine(self.cfg.lr_s, t, self.cfg.steps),
            );
            let seed = (self.cfg.seed as i32)
                .wrapping_mul(2654435761u32 as i32)
                .wrapping_add(t as i32);
            let out = self.rt.train_step(
                &self.train_exe, &self.man, &mut state, &self.x_buf,
                &self.y_buf, seed, lrs, &lock_mask, &lock_val, &lam, det,
            )?;
            probs = out.probs;
            let exp_bits = gm.expected_bits(&probs);
            let exp_pct = if self.man.engine == "dq" {
                dq_expected_pct(&self.counter, &self.man, &probs)
            } else {
                100.0 * expected_bops(&self.counter, &exp_bits) / fp32
            };
            history.record_step(StepRecord {
                step: state.step,
                loss: out.loss,
                batch_acc: out.correct / self.man.batch as f32,
                reg: out.reg,
                exp_bops_pct: exp_pct,
            });
            if t % snapshot_every == 0 {
                history.record_gates(state.step, &probs);
            }
            if self.cfg.eval_every > 0 && (t + 1) % self.cfg.eval_every == 0
            {
                let gates = self.current_gates(&gm, &state, &lock_mask,
                                               &lock_val, &probs);
                let (loss, acc) = self.evaluate(&state, &gates)?;
                let rel = self.rel_bops(&gm, &gates, &probs);
                history.record_eval(EvalRecord {
                    step: state.step, loss, accuracy: acc,
                    rel_bops_pct: rel, phase: 1,
                });
                logging::info(format!(
                    "[{} mu={} {}] step {:>5} loss {:.3} acc {:.3} \
                     relBOPs {:.2}%",
                    self.man.name, self.cfg.mu, self.cfg.mode.label(),
                    state.step, loss, acc, rel
                ));
            }
        }

        // ---- threshold + pre-finetune eval (Fig. 7) --------------------
        let gates =
            self.current_gates(&gm, &state, &lock_mask, &lock_val, &probs);
        let (pre_loss, pre_acc) = self.evaluate(&state, &gates)?;
        let rel = self.rel_bops(&gm, &gates, &probs);
        history.record_eval(EvalRecord {
            step: state.step, loss: pre_loss, accuracy: pre_acc,
            rel_bops_pct: rel, phase: 1,
        });

        // ---- phase 2: frozen gates, fine-tune weights + scales ---------
        if self.cfg.finetune_steps > 0 && self.man.engine != "dq" {
            let (fmask, fval) = gm.freeze(&gates);
            state.reset_optimizer();
            for t in 0..self.cfg.finetune_steps {
                self.batcher.next_into(&mut self.x_buf, &mut self.y_buf);
                let lrs = (
                    Self::cosine(self.cfg.lr_w / 10.0, t,
                                 self.cfg.finetune_steps),
                    0.0,
                    Self::cosine(self.cfg.lr_s / 10.0, t,
                                 self.cfg.finetune_steps),
                );
                let seed = (self.cfg.seed as i32).wrapping_add(t as i32);
                let out = self.rt.train_step(
                    &self.train_exe, &self.man, &mut state, &self.x_buf,
                    &self.y_buf, seed, lrs, &fmask, &fval, &lam, det,
                )?;
                history.record_step(StepRecord {
                    step: state.step,
                    loss: out.loss,
                    batch_acc: out.correct / self.man.batch as f32,
                    reg: out.reg,
                    exp_bops_pct: rel,
                });
            }
        }

        let (loss, acc) = self.evaluate(&state, &gates)?;
        history.record_eval(EvalRecord {
            step: state.step, loss, accuracy: acc, rel_bops_pct: rel,
            phase: 2,
        });
        let states = gm.quant_states(&gates);
        let result = RunResult {
            model: self.man.name.clone(),
            mode: self.cfg.mode.label(),
            mu: self.cfg.mu,
            seed: self.cfg.seed,
            deterministic: self.cfg.deterministic_gates,
            accuracy: acc,
            pre_ft_accuracy: pre_acc,
            test_loss: loss,
            rel_bops_pct: rel,
            gates,
            states,
            history,
        };
        Ok((state, result))
    }

    /// Current test-time gates for evaluation.
    fn current_gates(&self, gm: &GateManager, state: &TrainState,
                     lock_mask: &[f32], lock_val: &[f32],
                     _probs: &[f32]) -> Vec<f32> {
        if self.man.engine == "dq" {
            // DQ has no gates; the eval executable ignores the vector.
            return vec![0.0; self.man.n_slots];
        }
        let phi = state.phi_slots(&self.man);
        gm.test_gates(&phi, lock_mask, lock_val)
    }

    /// Relative BOPs of the configuration implied by `gates` (BB) or by
    /// the inferred-bits vector (DQ).
    fn rel_bops(&self, gm: &GateManager, gates: &[f32],
                probs: &[f32]) -> f64 {
        if self.man.engine == "dq" {
            return dq_expected_pct(&self.counter, &self.man, probs);
        }
        let states = gm.quant_states(gates);
        self.counter.relative_bops_pct(&states)
    }

    /// Expose pieces for the PTQ module.
    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    pub fn counter(&self) -> &BopCounter {
        &self.counter
    }
}

/// DQ: relative BOPs from continuous inferred bits (one slot per
/// quantizer; see python/compile/dq.py).
pub fn dq_expected_pct(counter: &BopCounter, man: &Manifest,
                       bits: &[f32]) -> f64 {
    let mut by_name: BTreeMap<String, f64> = BTreeMap::new();
    for q in &man.quantizers {
        by_name.insert(q.name.clone(), bits[q.offset] as f64);
    }
    100.0 * expected_bops(counter, &by_name) / counter.fp32_bops()
}
