//! Run metrics: step history, eval snapshots, gate-probability traces.
//!
//! Everything serializes to a single `metrics.json` per run, which the
//! figure harnesses (`experiments::figure10` etc.) read back, and a
//! `history.csv` for ad-hoc plotting.

use std::path::Path;

use anyhow::Result;

use crate::util::json::{arr_f64, num, obj, Json};

#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub batch_acc: f32,
    pub reg: f32,
    /// Live relative-BOPs estimate (%), from expected bits.
    pub exp_bops_pct: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub step: u64,
    pub loss: f64,
    pub accuracy: f64,
    /// Relative BOPs (%) of the thresholded configuration.
    pub rel_bops_pct: f64,
    pub phase: u8,
}

/// Snapshot of per-slot gate probabilities (Figure 10 traces).
#[derive(Debug, Clone)]
pub struct GateSnapshot {
    pub step: u64,
    pub probs: Vec<f32>,
}

#[derive(Debug, Default, Clone)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub gate_snapshots: Vec<GateSnapshot>,
}

impl History {
    pub fn record_step(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    pub fn record_eval(&mut self, r: EvalRecord) {
        self.evals.push(r);
    }

    pub fn record_gates(&mut self, step: u64, probs: &[f32]) {
        self.gate_snapshots
            .push(GateSnapshot { step, probs: probs.to_vec() });
    }

    pub fn smoothed_loss(&self, window: usize) -> f64 {
        let n = self.steps.len();
        if n == 0 {
            return f64::NAN;
        }
        let take = window.min(n);
        self.steps[n - take..]
            .iter()
            .map(|r| r.loss as f64)
            .sum::<f64>()
            / take as f64
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "steps",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("step", num(r.step as f64)),
                                ("loss", num(r.loss as f64)),
                                ("batch_acc", num(r.batch_acc as f64)),
                                ("reg", num(r.reg as f64)),
                                ("exp_bops_pct", num(r.exp_bops_pct)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("step", num(r.step as f64)),
                                ("loss", num(r.loss)),
                                ("accuracy", num(r.accuracy)),
                                ("rel_bops_pct", num(r.rel_bops_pct)),
                                ("phase", num(r.phase as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "gate_snapshots",
                Json::Arr(
                    self.gate_snapshots
                        .iter()
                        .map(|g| {
                            obj(vec![
                                ("step", num(g.step as f64)),
                                (
                                    "probs",
                                    arr_f64(
                                        &g.probs
                                            .iter()
                                            .map(|p| *p as f64)
                                            .collect::<Vec<_>>(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<History> {
        let mut h = History::default();
        for r in v.get("steps")?.as_arr()? {
            h.steps.push(StepRecord {
                step: r.get("step")?.as_f64()? as u64,
                loss: r.get("loss")?.as_f64()? as f32,
                batch_acc: r.get("batch_acc")?.as_f64()? as f32,
                reg: r.get("reg")?.as_f64()? as f32,
                exp_bops_pct: r.get("exp_bops_pct")?.as_f64()?,
            });
        }
        for r in v.get("evals")?.as_arr()? {
            h.evals.push(EvalRecord {
                step: r.get("step")?.as_f64()? as u64,
                loss: r.get("loss")?.as_f64()?,
                accuracy: r.get("accuracy")?.as_f64()?,
                rel_bops_pct: r.get("rel_bops_pct")?.as_f64()?,
                phase: r.get("phase")?.as_f64()? as u8,
            });
        }
        for g in v.get("gate_snapshots")?.as_arr()? {
            h.gate_snapshots.push(GateSnapshot {
                step: g.get("step")?.as_f64()? as u64,
                probs: g.get("probs")?.f32_vec()?,
            });
        }
        Ok(h)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<History> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// history.csv with one row per step record.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out =
            String::from("step,loss,batch_acc,reg,exp_bops_pct\n");
        for r in &self.steps {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.step, r.loss, r.batch_acc, r.reg, r.exp_bops_pct
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> History {
        let mut h = History::default();
        h.record_step(StepRecord {
            step: 1, loss: 2.3, batch_acc: 0.1, reg: 0.5,
            exp_bops_pct: 88.0,
        });
        h.record_eval(EvalRecord {
            step: 1, loss: 2.2, accuracy: 0.15, rel_bops_pct: 100.0,
            phase: 1,
        });
        h.record_gates(1, &[0.9, 0.8]);
        h
    }

    #[test]
    fn json_roundtrip() {
        let h = sample();
        let j = h.to_json();
        let h2 = History::from_json(&j).unwrap();
        assert_eq!(h2.steps.len(), 1);
        assert_eq!(h2.evals[0].phase, 1);
        assert_eq!(h2.gate_snapshots[0].probs, vec![0.9, 0.8]);
    }

    #[test]
    fn smoothed_loss_window() {
        let mut h = History::default();
        for (i, l) in [4.0f32, 2.0, 1.0].iter().enumerate() {
            h.record_step(StepRecord {
                step: i as u64, loss: *l, batch_acc: 0.0, reg: 0.0,
                exp_bops_pct: 0.0,
            });
        }
        assert!((h.smoothed_loss(2) - 1.5).abs() < 1e-9);
        assert!((h.smoothed_loss(10) - 7.0 / 3.0).abs() < 1e-9);
    }
}
