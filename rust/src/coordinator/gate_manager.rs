//! Gate management: lock patterns per training mode, Eq. 22
//! thresholding, chain-consistent test-time gates, and the translation
//! from gates to per-quantizer [`QuantState`]s for BOP accounting.

use std::collections::BTreeMap;

use crate::bops::QuantState;
use crate::config::Mode;
use crate::quant::gates::{self, test_time_gate_at};
use crate::runtime::Manifest;

/// Per-slot lock vectors plus helpers bound to one manifest.
pub struct GateManager<'m> {
    man: &'m Manifest,
}

impl<'m> GateManager<'m> {
    pub fn new(man: &'m Manifest) -> Self {
        Self { man }
    }

    /// (lock_mask, lock_val) for a training mode.
    ///
    /// Paper conventions encoded here:
    /// * activations are never *pruned* (§4: group sparsity on weight
    ///   output channels only), so activation z2 slots are always
    ///   locked open except in `Fixed{a_bits: 0}` style configs;
    /// * `QuantOnly` locks every z2 open (§4.2 ablation);
    /// * `PruneOnly{w,a}` locks the residual chains at fixed widths and
    ///   leaves only the weight-channel gates learnable;
    /// * `Fixed`/`Fp32` lock everything.
    pub fn locks(&self, mode: &Mode) -> (Vec<f32>, Vec<f32>) {
        let g = self.man.n_slots;
        let mut mask = vec![0.0f32; g];
        let mut val = vec![0.0f32; g];
        for q in &self.man.quantizers {
            let view = q.view();
            let ch = q.channels;
            let set_fixed = |bits: u32, mask: &mut [f32],
                             val: &mut [f32]| {
                let (m, v) = view.lock_fixed(bits);
                mask[q.offset..q.offset + q.n_slots].copy_from_slice(&m);
                val[q.offset..q.offset + q.n_slots].copy_from_slice(&v);
            };
            match mode {
                Mode::Dq => {}
                Mode::Fp32 => {
                    set_fixed(*q.levels.last().unwrap(), &mut mask,
                              &mut val)
                }
                Mode::Fixed { w_bits, a_bits } => {
                    let bits =
                        if q.kind == 'w' { *w_bits } else { *a_bits };
                    set_fixed(bits, &mut mask, &mut val);
                }
                Mode::BayesianBits => {
                    if q.kind == 'a' {
                        // activation z2 locked open (no act pruning)
                        mask[q.offset] = 1.0;
                        val[q.offset] = 1.0;
                    }
                }
                Mode::QuantOnly => {
                    for c in 0..ch {
                        mask[q.offset + c] = 1.0;
                        val[q.offset + c] = 1.0;
                    }
                }
                Mode::PruneOnly { w_bits, a_bits } => {
                    let bits =
                        if q.kind == 'w' { *w_bits } else { *a_bits };
                    set_fixed(bits, &mut mask, &mut val);
                    if q.kind == 'w' {
                        // channel gates stay learnable
                        for c in 0..ch {
                            mask[q.offset + c] = 0.0;
                            val[q.offset + c] = 0.0;
                        }
                    }
                }
            }
        }
        (mask, val)
    }

    /// Test-time binary gates: locked slots take their lock value,
    /// learnable slots are thresholded from phi (Eq. 22), and residual
    /// chains are made consistent (z_b forced 0 when z_{b/2} is 0 —
    /// matching the autoregressive posterior's support).
    pub fn test_gates(&self, phi: &[f64], lock_mask: &[f32],
                      lock_val: &[f32]) -> Vec<f32> {
        self.test_gates_at(phi, lock_mask, lock_val, gates::THRESHOLD)
    }

    /// [`Self::test_gates`] at an explicit Eq. 22 threshold `t` — one
    /// posterior thresholded at several `t`s yields the precision
    /// ladder's rungs. The `> 0.5` comparisons below are midpoints on
    /// binary {0,1} lock/gate values, not the gate threshold; `t` only
    /// enters through [`test_time_gate_at`].
    pub fn test_gates_at(&self, phi: &[f64], lock_mask: &[f32],
                         lock_val: &[f32], threshold: f64) -> Vec<f32> {
        let mut z = vec![0.0f32; self.man.n_slots];
        for q in &self.man.quantizers {
            for i in 0..q.n_slots {
                let s = q.offset + i;
                z[s] = if lock_mask[s] > 0.5 {
                    lock_val[s]
                } else if test_time_gate_at(phi[s], threshold) {
                    1.0
                } else {
                    0.0
                };
            }
            // enforce the chain on residual slots
            let mut open = true;
            for i in 0..q.levels.len() - 1 {
                let s = q.offset + q.channels + i;
                if !open {
                    z[s] = 0.0;
                }
                open = open && z[s] > 0.5;
            }
        }
        z
    }

    /// Freeze: convert binary gates into an all-locked (mask, val) pair
    /// for phase-2 fine-tuning.
    pub fn freeze(&self, gates: &[f32]) -> (Vec<f32>, Vec<f32>) {
        (vec![1.0; gates.len()], gates.to_vec())
    }

    /// Per-quantizer learned state (bits + keep ratio) from binary gates.
    pub fn quant_states(&self, gates: &[f32])
                        -> BTreeMap<String, QuantState> {
        let mut out = BTreeMap::new();
        for q in &self.man.quantizers {
            let view = q.view();
            let z = &gates[q.offset..q.offset + q.n_slots];
            out.insert(
                q.name.clone(),
                QuantState {
                    bits: view.effective_bits(z),
                    keep_ratio: view.keep_ratio(z),
                },
            );
        }
        out
    }

    /// Expected (soft) bits per quantizer from inclusion probabilities —
    /// the live BOP estimate logged during training.
    pub fn expected_bits(&self, probs: &[f32]) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for q in &self.man.quantizers {
            let view = q.view();
            out.insert(
                q.name.clone(),
                view.expected_bits(&probs[q.offset..q.offset + q.n_slots]),
            );
        }
        out
    }
}
