//! Post-training mixed precision (§4.2.1, Table 5, Figure 3).
//!
//! Starts from a *pretrained* model (trained here at the full-chain
//! FP32-equivalent configuration and checkpointed), then:
//! * `gates`        — learn only the gate logits (lr_w = lr_s = 0);
//! * `gates+scales` — learn gate logits and clip ranges (lr_w = 0);
//! * `sensitivity`  — the iterative baseline: measure each quantizer's
//!   sensitivity (accuracy drop when it alone is set to a low bit width
//!   while the rest stay at 16 bits), then cumulatively lower the least
//!   sensitive quantizers, evaluating after each step;
//! * `fixed8`       — the 8/8 push-button baseline row.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use super::checkpoint;
use super::gate_manager::GateManager;
use super::trainer::Trainer;
use crate::config::{Mode, RunConfig};
use crate::runtime::{Manifest, Runtime, TrainState};
use crate::util::logging;

/// One point on a post-training trade-off curve.
#[derive(Debug, Clone)]
pub struct PtqPoint {
    pub label: String,
    pub mu: f64,
    pub accuracy: f64,
    pub rel_bops_pct: f64,
}

/// Train (or load a cached) full-precision-equivalent base model.
pub fn pretrain_or_load(rt: Arc<Runtime>, man: &Manifest,
                        base_cfg: &RunConfig, cache: &Path)
                        -> Result<TrainState> {
    if cache.exists() {
        let (model, state) = checkpoint::load(cache)?;
        if model == man.name && state.params.len() == man.n_params {
            logging::info(format!("loaded pretrained model from {cache:?}"));
            return Ok(state);
        }
        logging::warn(format!(
            "checkpoint {cache:?} is for {model}, retraining"));
    }
    let mut cfg = base_cfg.clone();
    cfg.mode = Mode::Fp32;
    cfg.mu = 0.0;
    cfg.finetune_steps = 0;
    let mut trainer = Trainer::new(rt, man.clone(), cfg)?;
    let (state, result) = trainer.run_keeping_state(TrainState::init(man)?)?;
    logging::info(format!(
        "pretrained {}: acc {:.4}", man.name, result.accuracy));
    checkpoint::save(cache, &man.name, &state)?;
    Ok(state)
}

/// Learn gates (and optionally scales) post-training.
#[allow(clippy::too_many_arguments)]
pub fn ptq_learn(rt: Arc<Runtime>, man: &Manifest, base: &TrainState,
                 mu: f64, learn_scales: bool, steps: usize, seed: u64,
                 lr_g: f64) -> Result<PtqPoint> {
    let mut cfg = RunConfig {
        model: man.name.clone(),
        mode: Mode::BayesianBits,
        mu,
        steps,
        finetune_steps: 0,
        lr_w: 0.0,
        lr_g,
        lr_s: if learn_scales { 1e-3 } else { 0.0 },
        seed,
        ..RunConfig::default()
    };
    cfg.eval_every = 0;
    let mut trainer = Trainer::new(rt, man.clone(), cfg)?;
    let (_, result) = trainer.run_keeping_state(base.clone())?;
    Ok(PtqPoint {
        label: if learn_scales { "gates+scales" } else { "gates" }.into(),
        mu,
        accuracy: result.accuracy,
        rel_bops_pct: result.rel_bops_pct,
    })
}

/// The iterative sensitivity-ordered baseline (App. D.4.2).
///
/// Returns the cumulative curve: after lowering the k least sensitive
/// quantizers to `low_bits`, (accuracy, rel BOPs).
pub fn sensitivity_baseline(rt: Arc<Runtime>, man: &Manifest,
                            base: &TrainState, low_bits: u32)
                            -> Result<Vec<PtqPoint>> {
    let cfg = RunConfig {
        model: man.name.clone(),
        mode: Mode::Fixed { w_bits: 16, a_bits: 16 },
        ..RunConfig::default()
    };
    let trainer = Trainer::new(rt, man.clone(), cfg)?;
    let gm = GateManager::new(man);
    let (_, base_gates) = gm.locks(&Mode::Fixed { w_bits: 16,
                                                  a_bits: 16 });

    // 1) per-quantizer sensitivity: accuracy with only this quantizer low
    let mut sens: Vec<(usize, f64)> = Vec::new();
    for (qi, q) in man.quantizers.iter().enumerate() {
        let mut gates = base_gates.clone();
        set_quantizer_bits(man, qi, low_bits, &mut gates);
        let (_, acc) = trainer.evaluate(base, &gates)?;
        sens.push((qi, acc));
        logging::debug(format!("sensitivity {}: acc {:.4}", q.name, acc));
    }
    // least sensitive first = highest accuracy first
    sens.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    // 2) cumulative lowering
    let counter = trainer.counter().clone();
    let mut gates = base_gates.clone();
    let mut points = Vec::new();
    let (_, acc0) = trainer.evaluate(base, &gates)?;
    points.push(PtqPoint {
        label: "sensitivity".into(),
        mu: 0.0,
        accuracy: acc0,
        rel_bops_pct: counter
            .relative_bops_pct(&gm.quant_states(&gates)),
    });
    for (qi, _) in &sens {
        set_quantizer_bits(man, *qi, low_bits, &mut gates);
        let (_, acc) = trainer.evaluate(base, &gates)?;
        points.push(PtqPoint {
            label: "sensitivity".into(),
            mu: 0.0,
            accuracy: acc,
            rel_bops_pct: counter
                .relative_bops_pct(&gm.quant_states(&gates)),
        });
    }
    Ok(points)
}

/// Evaluate a fixed wX/aY configuration of the pretrained model.
pub fn fixed_point(rt: Arc<Runtime>, man: &Manifest, base: &TrainState,
                   w_bits: u32, a_bits: u32) -> Result<PtqPoint> {
    let cfg = RunConfig {
        model: man.name.clone(),
        mode: Mode::Fixed { w_bits, a_bits },
        ..RunConfig::default()
    };
    let trainer = Trainer::new(rt, man.clone(), cfg)?;
    let gm = GateManager::new(man);
    let (_, gates) = gm.locks(&Mode::Fixed { w_bits, a_bits });
    let (_, acc) = trainer.evaluate(base, &gates)?;
    Ok(PtqPoint {
        label: format!("fixed w{w_bits}a{a_bits}"),
        mu: 0.0,
        accuracy: acc,
        rel_bops_pct: trainer
            .counter()
            .relative_bops_pct(&gm.quant_states(&gates)),
    })
}

fn set_quantizer_bits(man: &Manifest, qi: usize, bits: u32,
                      gates: &mut [f32]) {
    let q = &man.quantizers[qi];
    let (_, val) = q.view().lock_fixed(bits);
    gates[q.offset..q.offset + q.n_slots].copy_from_slice(&val);
}

/// Pareto front: keep points not dominated (higher BOPs and lower or
/// equal accuracy than another point).
pub fn pareto_front(points: &[PtqPoint]) -> Vec<PtqPoint> {
    let mut sorted: Vec<&PtqPoint> = points.iter().collect();
    sorted.sort_by(|a, b| a.rel_bops_pct.partial_cmp(&b.rel_bops_pct)
                   .unwrap());
    let mut out: Vec<PtqPoint> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in sorted {
        if p.accuracy > best_acc {
            out.push(p.clone());
            best_acc = p.accuracy;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(acc: f64, bops: f64) -> PtqPoint {
        PtqPoint { label: "x".into(), mu: 0.0, accuracy: acc,
                   rel_bops_pct: bops }
    }

    #[test]
    fn pareto_front_filters_dominated() {
        let pts = vec![pt(0.9, 10.0), pt(0.8, 5.0), pt(0.7, 6.0),
                       pt(0.95, 12.0)];
        let front = pareto_front(&pts);
        let accs: Vec<f64> = front.iter().map(|p| p.accuracy).collect();
        assert_eq!(accs, vec![0.8, 0.9, 0.95]); // 0.7@6.0 dominated
    }
}
