//! Checkpoints: full train-state save/restore.
//!
//! Container format (all sections length-prefixed, little-endian):
//!   magic "BBCKPT1", model name, step (as f32 section of len 1 for
//!   format uniformity), params, adam_m, adam_v.

use std::fs::File;
use std::io::{BufReader, BufWriter};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::TrainState;
use crate::util::binio::{SectionReader, SectionWriter};

const MAGIC: &str = "BBCKPT1";

pub fn save(path: &Path, model: &str, state: &TrainState) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    let mut w = SectionWriter::new(f);
    w.write_str(MAGIC)?;
    w.write_str(model)?;
    w.write_f32s(&[state.step as f32])?;
    w.write_f32s(&state.params)?;
    w.write_f32s(&state.m)?;
    w.write_f32s(&state.v)?;
    Ok(())
}

pub fn load(path: &Path) -> Result<(String, TrainState)> {
    let f = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut r = SectionReader::new(f);
    let magic = r.read_str()?;
    if magic != MAGIC {
        bail!("bad checkpoint magic {magic:?}");
    }
    let model = r.read_str()?;
    let step = r.read_f32s()?;
    let params = r.read_f32s()?;
    let m = r.read_f32s()?;
    let v = r.read_f32s()?;
    if m.len() != params.len() || v.len() != params.len() {
        bail!("checkpoint section length mismatch");
    }
    Ok((
        model,
        TrainState { params, m, v, step: step.first().copied()
                     .unwrap_or(0.0) as u64 },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("bbits_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.ckpt");
        let st = TrainState {
            params: vec![1.0, -2.0, 3.5],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.0, 0.5, 1.0],
            step: 42,
        };
        save(&p, "lenet5", &st).unwrap();
        let (model, got) = load(&p).unwrap();
        assert_eq!(model, "lenet5");
        assert_eq!(got.params, st.params);
        assert_eq!(got.m, st.m);
        assert_eq!(got.step, 42);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("bbits_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
