//! Checkpoints: full train-state save/restore.
//!
//! Container format (all sections length-prefixed, little-endian):
//!   magic "BBCKPT<version>", model name, step, params, adam_m, adam_v.
//!
//! Version history:
//! * v1 — step stored as a single-f32 section (loses precision past
//!   2^24 steps); still readable.
//! * v2 (current) — step stored as a decimal string section (exact
//!   u64), and loads validate section lengths against each other.
//!
//! Readers fail with a distinct message for each corruption class:
//! not-a-checkpoint, truncated/corrupt sections, a checkpoint from a
//! newer writer, and moment/param length mismatches.

use std::fs::File;
use std::io::{BufReader, BufWriter};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::TrainState;
use crate::util::binio::{SectionReader, SectionWriter};

const MAGIC_PREFIX: &str = "BBCKPT";
const VERSION: u32 = 2;

pub fn save(path: &Path, model: &str, state: &TrainState) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    let mut w = SectionWriter::new(f);
    w.write_str(&format!("{MAGIC_PREFIX}{VERSION}"))?;
    w.write_str(model)?;
    w.write_str(&state.step.to_string())?;
    w.write_f32s(&state.params)?;
    w.write_f32s(&state.m)?;
    w.write_f32s(&state.v)?;
    Ok(())
}

pub fn load(path: &Path) -> Result<(String, TrainState)> {
    let f = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut r = SectionReader::new(f);
    let magic = r
        .read_str()
        .with_context(|| format!("{path:?} is not a bbits checkpoint"))?;
    let version = match magic.strip_prefix(MAGIC_PREFIX) {
        Some(v) => v.parse::<u32>().with_context(|| {
            format!("{path:?}: malformed checkpoint magic {magic:?}")
        })?,
        None => bail!("{path:?} is not a bbits checkpoint \
                       (magic {magic:?})"),
    };
    if version > VERSION {
        bail!("{path:?} is a v{version} checkpoint; this build reads \
               up to v{VERSION} — upgrade bbits to load it");
    }
    let corrupt = || format!("{path:?}: checkpoint truncated or corrupt");
    let model = r.read_str().with_context(corrupt)?;
    let step = match version {
        1 => {
            // v1 stored the step as one f32 for format uniformity
            let s = r.read_f32s().with_context(corrupt)?;
            if s.len() != 1 {
                bail!("{path:?}: v1 step section has {} values",
                      s.len());
            }
            s[0] as u64
        }
        _ => {
            let s = r.read_str().with_context(corrupt)?;
            s.parse::<u64>().with_context(|| {
                format!("{path:?}: bad step count {s:?}")
            })?
        }
    };
    let params = r.read_f32s().with_context(corrupt)?;
    let m = r.read_f32s().with_context(corrupt)?;
    let v = r.read_f32s().with_context(corrupt)?;
    if params.is_empty() {
        bail!("{path:?}: checkpoint has no parameters");
    }
    if m.len() != params.len() || v.len() != params.len() {
        bail!("{path:?}: Adam moment sections ({}, {}) do not match \
               param section ({})", m.len(), v.len(), params.len());
    }
    Ok((model, TrainState { params, m, v, step }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bbits_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn state() -> TrainState {
        TrainState {
            params: vec![1.0, -2.0, 3.5],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.0, 0.5, 1.0],
            step: (1u64 << 33) + 7, // beyond f32-exact range
        }
    }

    #[test]
    fn roundtrip_is_exact_including_large_steps() {
        let p = tmp("a.ckpt");
        let st = state();
        save(&p, "lenet5", &st).unwrap();
        let (model, got) = load(&p).unwrap();
        assert_eq!(model, "lenet5");
        assert_eq!(got.params, st.params);
        assert_eq!(got.m, st.m);
        assert_eq!(got.v, st.v);
        assert_eq!(got.step, st.step);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn legacy_v1_checkpoints_still_load() {
        let p = tmp("v1.ckpt");
        {
            let f = BufWriter::new(File::create(&p).unwrap());
            let mut w = SectionWriter::new(f);
            w.write_str("BBCKPT1").unwrap();
            w.write_str("vgg7").unwrap();
            w.write_f32s(&[42.0]).unwrap();
            w.write_f32s(&[1.0, 2.0]).unwrap();
            w.write_f32s(&[0.0, 0.0]).unwrap();
            w.write_f32s(&[0.0, 0.0]).unwrap();
        }
        let (model, got) = load(&p).unwrap();
        assert_eq!(model, "vgg7");
        assert_eq!(got.step, 42);
        assert_eq!(got.params, vec![1.0, 2.0]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_bad_magic_with_clear_message() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(err.contains("not a bbits checkpoint"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_future_versions() {
        let p = tmp("future.ckpt");
        {
            let f = BufWriter::new(File::create(&p).unwrap());
            let mut w = SectionWriter::new(f);
            w.write_str("BBCKPT9").unwrap();
        }
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(err.contains("v9"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn truncated_file_reports_corruption() {
        let p = tmp("trunc.ckpt");
        save(&p, "lenet5", &state()).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(err.contains("truncated or corrupt"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn mismatched_moment_lengths_rejected() {
        let p = tmp("moments.ckpt");
        {
            let f = BufWriter::new(File::create(&p).unwrap());
            let mut w = SectionWriter::new(f);
            w.write_str("BBCKPT2").unwrap();
            w.write_str("lenet5").unwrap();
            w.write_str("3").unwrap();
            w.write_f32s(&[1.0, 2.0]).unwrap();
            w.write_f32s(&[0.0]).unwrap(); // short m
            w.write_f32s(&[0.0, 0.0]).unwrap();
        }
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(err.contains("Adam moment"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }
}
