//! Synthetic dataset substrate (DESIGN.md §Substitutions).
//!
//! No real MNIST/CIFAR/ImageNet is available offline, so each generator
//! procedurally builds a *learnable* classification task with the
//! statistics the paper's method cares about: class-conditional
//! structure (so accuracy improves with capacity), within-class
//! variation (so the task does not saturate instantly), and
//! heterogeneous feature scales across spatial frequencies (so layers
//! differ in quantization sensitivity — the property that makes mixed
//! precision beat fixed precision).
//!
//! Everything is deterministic in (dataset name, seed, index): train and
//! test splits draw from disjoint PRNG streams of the same distribution.

pub mod batcher;
pub mod synth;

pub use batcher::Batcher;
pub use synth::{generate, DatasetSpec};

/// An in-memory dataset: NHWC images + integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    /// (H, W, C)
    pub shape: (usize, usize, usize),
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_size(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.image_size();
        &self.images[i * n..(i + 1) * n]
    }

    /// Channel-wise standardization statistics over the whole set.
    pub fn mean_std(&self) -> (f32, f32) {
        let n = self.images.len() as f64;
        let mean = self.images.iter().map(|v| *v as f64).sum::<f64>() / n;
        let var = self
            .images
            .iter()
            .map(|v| (*v as f64 - mean).powi(2))
            .sum::<f64>()
            / n;
        (mean as f32, var.sqrt() as f32)
    }

    /// In-place standardization to zero mean / unit std.
    pub fn normalize(&mut self) {
        let (m, s) = self.mean_std();
        let s = if s < 1e-6 { 1.0 } else { s };
        for v in &mut self.images {
            *v = (*v - m) / s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> DatasetSpec {
        DatasetSpec {
            name: "mnist_like".into(),
            input: (16, 16, 1),
            classes: 10,
            train: 256,
            test: 64,
        }
    }

    #[test]
    fn dataset_indexing() {
        let ds = generate(&spec(), 1, false).unwrap();
        assert_eq!(ds.len(), 256);
        assert_eq!(ds.image(3).len(), 16 * 16);
    }

    #[test]
    fn normalize_standardizes() {
        let mut ds = generate(&spec(), 1, false).unwrap();
        ds.normalize();
        let (m, s) = ds.mean_std();
        assert!(m.abs() < 1e-3, "mean {m}");
        assert!((s - 1.0).abs() < 1e-3, "std {s}");
    }
}
