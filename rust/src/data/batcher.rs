//! Shuffling mini-batch assembler with optional train-time augmentation.
//!
//! Augmentation mirrors the paper's CIFAR recipe (App. B.1): random
//! horizontal flips and random crops of 2-pixel-padded images; applied
//! for multi-channel datasets only (MNIST-like gets neither, matching
//! common practice).

use super::Dataset;
use crate::rng::Pcg64;

/// Epoch-shuffled batcher. Batches are materialized into caller-owned
/// buffers to avoid per-step allocation in the training hot loop.
pub struct Batcher {
    ds: Dataset,
    batch: usize,
    augment: bool,
    rng: Pcg64,
    order: Vec<usize>,
    cursor: usize,
    pub epochs_completed: usize,
}

impl Batcher {
    pub fn new(ds: Dataset, batch: usize, augment: bool, seed: u64) -> Self {
        assert!(batch > 0 && batch <= ds.len());
        let mut rng = Pcg64::with_stream(seed, 0xba7c4);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        let augment = augment && ds.shape.2 > 1;
        Self { ds, batch, augment, rng, order, cursor: 0,
               epochs_completed: 0 }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Fill `x` (batch * H * W * C) and `y` (batch) with the next batch.
    pub fn next_into(&mut self, x: &mut [f32], y: &mut [i32]) {
        let n_px = self.ds.image_size();
        assert_eq!(x.len(), self.batch * n_px);
        assert_eq!(y.len(), self.batch);
        for b in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
                self.epochs_completed += 1;
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            y[b] = self.ds.labels[idx];
            let dst = &mut x[b * n_px..(b + 1) * n_px];
            if self.augment {
                self.augment_into(idx, dst);
            } else {
                dst.copy_from_slice(self.ds.image(idx));
            }
        }
    }

    /// Random flip + random crop from a 2px zero-padded canvas.
    fn augment_into(&mut self, idx: usize, dst: &mut [f32]) {
        const PAD: isize = 2;
        let (h, w, c) = self.ds.shape;
        let src = self.ds.image(idx);
        let flip = self.rng.next_below(2) == 1;
        let dy = self.rng.next_below((2 * PAD + 1) as u64) as isize - PAD;
        let dx = self.rng.next_below((2 * PAD + 1) as u64) as isize - PAD;
        for py in 0..h as isize {
            for px in 0..w as isize {
                let sy = py + dy;
                let sx0 = px + dx;
                let sx = if flip { w as isize - 1 - sx0 } else { sx0 };
                let di = ((py * w as isize + px) * c as isize) as usize;
                if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                    let si = ((sy * w as isize + sx) * c as isize) as usize;
                    dst[di..di + c].copy_from_slice(&src[si..si + c]);
                } else {
                    dst[di..di + c].fill(0.0);
                }
            }
        }
    }

    /// Iterate the *test* set in order, calling `f(x, y, count)` per
    /// full-or-partial batch (partial batches are zero-padded; `count`
    /// is the number of valid rows).
    pub fn for_eval(ds: &Dataset, batch: usize,
                    mut f: impl FnMut(&[f32], &[i32], usize)) {
        let n_px = ds.image_size();
        let mut x = vec![0.0f32; batch * n_px];
        let mut y = vec![0i32; batch];
        let mut i = 0;
        while i < ds.len() {
            let count = batch.min(ds.len() - i);
            x.fill(0.0);
            y.fill(0);
            for b in 0..count {
                x[b * n_px..(b + 1) * n_px]
                    .copy_from_slice(ds.image(i + b));
                y[b] = ds.labels[i + b];
            }
            f(&x, &y, count);
            i += count;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, DatasetSpec};

    fn dataset(c: usize) -> Dataset {
        generate(
            &DatasetSpec {
                name: if c == 1 { "mnist_like" } else { "cifar_like" }
                    .into(),
                input: (8, 8, c),
                classes: 4,
                train: 64,
                test: 20,
            },
            3,
            false,
        )
        .unwrap()
    }

    #[test]
    fn visits_every_sample_each_epoch() {
        let ds = dataset(1);
        let mut b = Batcher::new(ds, 16, false, 1);
        let mut seen = vec![0usize; 4];
        let mut x = vec![0.0; 16 * 64];
        let mut y = vec![0i32; 16];
        for _ in 0..4 {
            b.next_into(&mut x, &mut y);
            for l in &y {
                seen[*l as usize] += 1;
            }
        }
        assert_eq!(seen.iter().sum::<usize>(), 64);
        assert_eq!(b.epochs_completed, 0);
        b.next_into(&mut x, &mut y);
        assert_eq!(b.epochs_completed, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut b = Batcher::new(dataset(3), 8, true, 42);
            let mut x = vec![0.0; 8 * 192];
            let mut y = vec![0i32; 8];
            b.next_into(&mut x, &mut y);
            (x, y)
        };
        let (x1, y1) = mk();
        let (x2, y2) = mk();
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn augmentation_changes_pixels_not_labels() {
        let ds = dataset(3);
        let plain = Batcher::new(ds.clone(), 8, false, 5);
        let mut aug = Batcher::new(ds, 8, true, 5);
        drop(plain);
        let mut x = vec![0.0; 8 * 192];
        let mut y = vec![0i32; 8];
        aug.next_into(&mut x, &mut y);
        // augmented images still normalized-ish and finite
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn eval_covers_all_with_partial_batch() {
        let ds = dataset(1);
        let mut total = 0;
        Batcher::for_eval(&ds, 48, |_x, _y, count| {
            total += count;
        });
        assert_eq!(total, 64);
    }
}
