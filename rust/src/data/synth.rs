//! Procedural class-conditional image generators.
//!
//! * `mnist_like` — grayscale stroke glyphs: each class owns a fixed set
//!   of line segments (a synthetic "digit"); samples jitter the glyph
//!   with small affine transforms plus pixel noise.
//! * `cifar_like` — color textures: each class owns a palette and a set
//!   of oriented sinusoid components; samples re-phase and re-weight the
//!   components, add colored blobs and noise, and may flip.
//! * `imagenet_like` — cifar_like with more within-class variation
//!   (scale jitter, background clutter, occlusion), making the task
//!   harder — mirroring the MNIST < CIFAR < ImageNet difficulty ladder.

use anyhow::{bail, Result};

use super::Dataset;
use crate::rng::Pcg64;
use crate::util::json::Json;

/// Dataset request — mirrors the manifest's `dataset` object.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub input: (usize, usize, usize),
    pub classes: usize,
    pub train: usize,
    pub test: usize,
}

impl DatasetSpec {
    pub fn from_json(v: &Json) -> Result<Self> {
        let input = v.get("input")?.usize_vec()?;
        if input.len() != 3 {
            bail!("dataset input must be rank-3, got {input:?}");
        }
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            input: (input[0], input[1], input[2]),
            classes: v.get("classes")?.as_usize()?,
            train: v.get("train")?.as_usize()?,
            test: v.get("test")?.as_usize()?,
        })
    }
}

/// Generate the train (`test=false`) or test (`test=true`) split.
pub fn generate(spec: &DatasetSpec, seed: u64, test: bool)
                -> Result<Dataset> {
    let n = if test { spec.test } else { spec.train };
    let stream = if test { 0x7e57 } else { 0x7124 };
    let mut rng = Pcg64::with_stream(seed, stream);
    let (h, w, c) = spec.input;
    let mut images = vec![0.0f32; n * h * w * c];
    let mut labels = vec![0i32; n];
    // Class prototypes are derived from the seed only, so train and test
    // share the same class definitions.
    let protos = ClassProtos::new(spec, seed);
    for i in 0..n {
        let label = rng.next_below(spec.classes as u64) as usize;
        labels[i] = label as i32;
        let img = &mut images[i * h * w * c..(i + 1) * h * w * c];
        match spec.name.as_str() {
            "mnist_like" => protos.render_glyph(label, img, &mut rng, h, w),
            "cifar_like" => {
                protos.render_texture(label, img, &mut rng, h, w, c, 0.35)
            }
            "imagenet_like" => {
                protos.render_texture(label, img, &mut rng, h, w, c, 0.7)
            }
            other => bail!("unknown dataset generator {other:?}"),
        }
    }
    let mut ds = Dataset {
        images,
        labels,
        shape: spec.input,
        classes: spec.classes,
    };
    ds.normalize();
    Ok(ds)
}

/// Per-class generative prototypes.
struct ClassProtos {
    /// mnist_like: strokes per class as (x0, y0, x1, y1) in [0,1]^2.
    strokes: Vec<Vec<(f32, f32, f32, f32)>>,
    /// cifar/imagenet_like: sinusoid components per class
    /// (fx, fy, phase, weight) and an RGB palette per class.
    waves: Vec<Vec<(f32, f32, f32, f32)>>,
    palette: Vec<[f32; 3]>,
}

impl ClassProtos {
    fn new(spec: &DatasetSpec, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xc1a55);
        let mut strokes = Vec::new();
        let mut waves = Vec::new();
        let mut palette = Vec::new();
        for _ in 0..spec.classes {
            let n_strokes = 3 + rng.next_below(3) as usize;
            strokes.push(
                (0..n_strokes)
                    .map(|_| {
                        (
                            rng.uniform(0.15, 0.85),
                            rng.uniform(0.15, 0.85),
                            rng.uniform(0.15, 0.85),
                            rng.uniform(0.15, 0.85),
                        )
                    })
                    .collect(),
            );
            let n_waves = 3 + rng.next_below(3) as usize;
            waves.push(
                (0..n_waves)
                    .map(|_| {
                        (
                            rng.uniform(0.5, 4.0),
                            rng.uniform(0.5, 4.0),
                            rng.uniform(0.0, std::f32::consts::TAU),
                            rng.uniform(0.4, 1.0),
                        )
                    })
                    .collect(),
            );
            palette.push([
                rng.uniform(0.2, 1.0),
                rng.uniform(0.2, 1.0),
                rng.uniform(0.2, 1.0),
            ]);
        }
        Self { strokes, waves, palette }
    }

    /// Stroke glyph with affine jitter; grayscale (c == 1 assumed).
    fn render_glyph(&self, class: usize, img: &mut [f32], rng: &mut Pcg64,
                    h: usize, w: usize) {
        let dx = rng.uniform(-0.08, 0.08);
        let dy = rng.uniform(-0.08, 0.08);
        let rot = rng.uniform(-0.22, 0.22);
        let scale = rng.uniform(0.85, 1.15);
        let (sin, cos) = rot.sin_cos();
        let width = rng.uniform(0.045, 0.075);
        for py in 0..h {
            for px in 0..w {
                let mut x = px as f32 / (w - 1) as f32 - 0.5;
                let mut y = py as f32 / (h - 1) as f32 - 0.5;
                // inverse affine into glyph space
                let (rx, ry) = (cos * x + sin * y, -sin * x + cos * y);
                x = rx / scale + 0.5 - dx;
                y = ry / scale + 0.5 - dy;
                let mut v: f32 = 0.0;
                for (x0, y0, x1, y1) in &self.strokes[class] {
                    let d = dist_to_segment(x, y, *x0, *y0, *x1, *y1);
                    v = v.max((-d * d / (2.0 * width * width)).exp());
                }
                img[py * w + px] =
                    v + rng.normal() * 0.08;
            }
        }
    }

    /// Oriented-texture color image; `variation` scales intra-class
    /// randomness (imagenet_like > cifar_like).
    #[allow(clippy::too_many_arguments)]
    fn render_texture(&self, class: usize, img: &mut [f32],
                      rng: &mut Pcg64, h: usize, w: usize, c: usize,
                      variation: f32) {
        let flip = rng.next_below(2) == 1;
        let scale = 1.0 + rng.uniform(-0.3, 0.3) * variation;
        let phase_jit = rng.uniform(-1.0, 1.0) * variation;
        let pal = self.palette[class];
        // occasional occluder rectangle for the hard variant
        let occlude = variation > 0.5 && rng.next_below(3) == 0;
        let (ox, oy, ow, oh) = (
            rng.next_below(w as u64) as usize,
            rng.next_below(h as u64) as usize,
            w / 4 + rng.next_below((w / 4) as u64) as usize,
            h / 4 + rng.next_below((h / 4) as u64) as usize,
        );
        for py in 0..h {
            for px in 0..w {
                let px_eff = if flip { w - 1 - px } else { px };
                let x = px_eff as f32 / w as f32 * scale;
                let y = py as f32 / h as f32 * scale;
                let mut t = 0.0f32;
                for (fx, fy, ph, wt) in &self.waves[class] {
                    t += wt
                        * (std::f32::consts::TAU
                            * (fx * x + fy * y)
                            + ph
                            + phase_jit)
                            .sin();
                }
                t /= self.waves[class].len() as f32;
                let occluded = occlude
                    && px >= ox
                    && px < (ox + ow).min(w)
                    && py >= oy
                    && py < (oy + oh).min(h);
                for ch in 0..c {
                    let base = if occluded {
                        rng.normal() * 0.2
                    } else {
                        t * pal[ch % 3]
                    };
                    img[(py * w + px) * c + ch] =
                        base + rng.normal() * (0.1 + 0.1 * variation);
                }
            }
        }
    }
}

fn dist_to_segment(x: f32, y: f32, x0: f32, y0: f32, x1: f32,
                   y1: f32) -> f32 {
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len2 = dx * dx + dy * dy;
    let t = if len2 < 1e-12 {
        0.0
    } else {
        (((x - x0) * dx + (y - y0) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (x0 + t * dx, y0 + t * dy);
    ((x - cx).powi(2) + (y - cy).powi(2)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, c: usize) -> DatasetSpec {
        DatasetSpec {
            name: name.into(),
            input: (16, 16, c),
            classes: 10,
            train: 128,
            test: 32,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&spec("mnist_like", 1), 7, false).unwrap();
        let b = generate(&spec("mnist_like", 1), 7, false).unwrap();
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = generate(&spec("mnist_like", 1), 8, false).unwrap();
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn train_test_disjoint_streams() {
        let tr = generate(&spec("cifar_like", 3), 7, false).unwrap();
        let te = generate(&spec("cifar_like", 3), 7, true).unwrap();
        assert_ne!(&tr.images[..100], &te.images[..100]);
    }

    #[test]
    fn all_generators_produce_finite_all_classes() {
        for name in ["mnist_like", "cifar_like", "imagenet_like"] {
            let c = if name == "mnist_like" { 1 } else { 3 };
            let ds = generate(&spec(name, c), 3, false).unwrap();
            assert!(ds.images.iter().all(|v| v.is_finite()));
            let mut seen = vec![false; 10];
            for l in &ds.labels {
                seen[*l as usize] = true;
            }
            assert!(seen.iter().all(|s| *s), "{name}: missing classes");
        }
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // Nearest-class-mean classifier on raw pixels must beat chance
        // by a wide margin — guarantees the task is learnable.
        let s = spec("mnist_like", 1);
        let tr = generate(&s, 5, false).unwrap();
        let te = generate(&s, 5, true).unwrap();
        let n_px = tr.image_size();
        let mut means = vec![vec![0.0f32; n_px]; 10];
        let mut counts = [0usize; 10];
        for i in 0..tr.len() {
            let l = tr.labels[i] as usize;
            counts[l] += 1;
            for (m, v) in means[l].iter_mut().zip(tr.image(i)) {
                *m += v;
            }
        }
        for (m, cnt) in means.iter_mut().zip(counts) {
            for v in m.iter_mut() {
                *v /= cnt.max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..te.len() {
            let img = te.image(i);
            let mut best = (f32::INFINITY, 0usize);
            for (cl, m) in means.iter().enumerate() {
                let d: f32 = img
                    .iter()
                    .zip(m)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.0 {
                    best = (d, cl);
                }
            }
            if best.1 == te.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc} too low");
    }

    #[test]
    fn bad_generator_name_errors() {
        assert!(generate(&spec("bogus", 1), 1, false).is_err());
    }
}
