//! MAC / BOP accounting (paper App. B.2).
//!
//! * `BOPs(l) = MACs(l) * b_w * b_a` (Eq. 23), accumulator bits ignored.
//! * Pruning scales MACs by the kept input/output channel ratios
//!   (Eq. 26-27): `BOPs_pruned(l) = p_i p_o MACs(l) b_w b_a`.
//! * ResNet rule (B.2.3): a residual-block input cannot be pruned away
//!   by the previous layer (the skip path still carries it), so `p_i` is
//!   only applied where the layer metadata says the input is prunable.
//!
//! The module consumes the manifest's layer table (`runtime::Manifest`)
//! plus a learned network configuration (bits + keep ratios per
//! quantizer) and produces absolute and relative GBOP counts.

use std::collections::BTreeMap;

use crate::models::LayerDesc;

/// Learned configuration of one quantizer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantState {
    /// Effective bit width (0 = pruned entirely).
    pub bits: u32,
    /// Fraction of output channels kept (weights; 1.0 for activations).
    pub keep_ratio: f64,
}

impl QuantState {
    pub fn full(bits: u32) -> Self {
        Self { bits, keep_ratio: 1.0 }
    }
}

/// Network-level BOP accounting over a layer table.
#[derive(Debug, Clone)]
pub struct BopCounter {
    pub layers: Vec<LayerDesc>,
}

impl BopCounter {
    pub fn new(layers: Vec<LayerDesc>) -> Self {
        Self { layers }
    }

    /// Total MACs of the unpruned network.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Full-precision (32/32) BOP count — the relative-GBOPs denominator.
    pub fn fp32_bops(&self) -> f64 {
        self.total_macs() as f64 * 32.0 * 32.0
    }

    /// BOPs under a learned configuration.
    ///
    /// `states` maps quantizer name -> state. A layer's weight bits come
    /// from its weight quantizer, activation bits from its input
    /// quantizer; `p_o` is the weight quantizer's keep ratio and `p_i`
    /// the *producing* weight quantizer's keep ratio, found by matching
    /// the previous layer. For residual-fed inputs `p_i = 1` (B.2.3
    /// upper bound).
    pub fn bops(&self, states: &BTreeMap<String, QuantState>) -> f64 {
        let mut total = 0.0;
        for (idx, layer) in self.layers.iter().enumerate() {
            let w = states
                .get(&layer.weight_q)
                .copied()
                .unwrap_or(QuantState::full(32));
            let a = states
                .get(&layer.act_q)
                .copied()
                .unwrap_or(QuantState::full(32));
            if w.bits == 0 || a.bits == 0 {
                continue; // layer fully pruned
            }
            let p_o = w.keep_ratio;
            let p_i = if layer.residual_input {
                1.0
            } else {
                self.producer_keep_ratio(idx, states)
            };
            total += p_i
                * p_o
                * layer.macs as f64
                * w.bits as f64
                * a.bits as f64;
        }
        total
    }

    /// Keep ratio of the layer feeding `idx`'s input activation:
    /// pruning output channels of layer l-1 prunes input channels of l
    /// (App. B.2.2). The producer is the nearest earlier layer whose
    /// cout matches this layer's cin (conv/pool chains preserve channel
    /// count); falls back to 1.0 (upper bound) when ambiguous.
    fn producer_keep_ratio(&self, idx: usize,
                           states: &BTreeMap<String, QuantState>) -> f64 {
        let cin = self.layers[idx].cin;
        for prev in self.layers[..idx].iter().rev() {
            if prev.cout == cin && prev.kind != "dense" {
                return states
                    .get(&prev.weight_q)
                    .map(|s| s.keep_ratio)
                    .unwrap_or(1.0);
            }
            if prev.kind == "dense" && prev.cout == cin {
                return states
                    .get(&prev.weight_q)
                    .map(|s| s.keep_ratio)
                    .unwrap_or(1.0);
            }
        }
        1.0
    }

    /// Relative GBOPs in percent vs the FP32 network (paper tables).
    pub fn relative_bops_pct(&self,
                             states: &BTreeMap<String, QuantState>) -> f64 {
        100.0 * self.bops(states) / self.fp32_bops()
    }

    /// Uniform fixed-width configuration (baseline rows: wX/aY).
    pub fn fixed_states(&self, w_bits: u32, a_bits: u32)
                        -> BTreeMap<String, QuantState> {
        let mut m = BTreeMap::new();
        for l in &self.layers {
            m.insert(l.weight_q.clone(), QuantState::full(w_bits));
            m.insert(l.act_q.clone(), QuantState::full(a_bits));
        }
        m
    }
}

/// Expected (soft) BOPs during training, from per-quantizer expected
/// bits — used for live tracking, not for reported tables.
pub fn expected_bops(counter: &BopCounter,
                     exp_bits: &BTreeMap<String, f64>) -> f64 {
    counter
        .layers
        .iter()
        .map(|l| {
            let bw = exp_bits.get(&l.weight_q).copied().unwrap_or(32.0);
            let ba = exp_bits.get(&l.act_q).copied().unwrap_or(32.0);
            l.macs as f64 * bw * ba
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LayerDesc;
    use crate::util::prop::{check, PropResult};

    fn chain() -> BopCounter {
        BopCounter::new(vec![
            LayerDesc {
                name: "conv1".into(), kind: "conv".into(), macs: 1000,
                cin: 3, cout: 8, weight_q: "conv1.w".into(),
                act_q: "conv1.in".into(), residual_input: false,
                conv: None, pre_ops: Vec::new(),
            },
            LayerDesc {
                name: "conv2".into(), kind: "conv".into(), macs: 2000,
                cin: 8, cout: 16, weight_q: "conv2.w".into(),
                act_q: "conv2.in".into(), residual_input: false,
                conv: None, pre_ops: Vec::new(),
            },
        ])
    }

    #[test]
    fn fp32_baseline_is_100pct() {
        let c = chain();
        let states = c.fixed_states(32, 32);
        assert!((c.relative_bops_pct(&states) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn w8a8_is_6_25pct() {
        let c = chain();
        let states = c.fixed_states(8, 8);
        assert!((c.relative_bops_pct(&states) - 6.25).abs() < 1e-9);
    }

    #[test]
    fn pruning_scales_both_consumers() {
        let c = chain();
        let mut states = c.fixed_states(8, 8);
        // prune half of conv1's outputs: conv1 p_o = 0.5, conv2 p_i = 0.5
        states.insert("conv1.w".into(),
                      QuantState { bits: 8, keep_ratio: 0.5 });
        let bops = c.bops(&states);
        let want = 0.5 * 1000.0 * 64.0 + 0.5 * 2000.0 * 64.0;
        assert!((bops - want).abs() < 1e-6, "{bops} vs {want}");
    }

    #[test]
    fn residual_input_not_input_pruned() {
        let mut c = chain();
        c.layers[1].residual_input = true;
        let mut states = c.fixed_states(8, 8);
        states.insert("conv1.w".into(),
                      QuantState { bits: 8, keep_ratio: 0.5 });
        let bops = c.bops(&states);
        // conv2 keeps p_i = 1.0 (B.2.3 upper bound)
        let want = 0.5 * 1000.0 * 64.0 + 1.0 * 2000.0 * 64.0;
        assert!((bops - want).abs() < 1e-6);
    }

    #[test]
    fn zero_bits_prunes_layer() {
        let c = chain();
        let mut states = c.fixed_states(8, 8);
        states.insert("conv2.w".into(),
                      QuantState { bits: 0, keep_ratio: 0.0 });
        let bops = c.bops(&states);
        assert!((bops - 1000.0 * 64.0).abs() < 1e-6);
    }

    #[test]
    fn prop_bops_monotone_in_bits_and_keep() {
        check("bops_monotone", 200, |g| {
            let c = chain();
            let b1 = *g.choose(&[2u32, 4, 8, 16]);
            let b2 = b1 * 2;
            let k1 = g.f64_in(0.0, 1.0);
            let k2 = (k1 + g.f64_in(0.0, 1.0 - k1)).min(1.0);
            let mk = |bits, keep| {
                let mut s = c.fixed_states(8, 8);
                s.insert("conv1.w".into(),
                         QuantState { bits, keep_ratio: keep });
                c.bops(&s)
            };
            let lo = mk(b1, k1);
            let hi = mk(b2, k2);
            PropResult::check(lo <= hi + 1e-9,
                              || format!("{lo} > {hi} (b1={b1} k1={k1})"))
        });
    }
}
