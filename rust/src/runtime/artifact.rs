//! Artifact manifests — the L2 <-> L3 contract (DESIGN.md §6).
//!
//! A manifest freezes the flat parameter layout, the gate-slot vector,
//! the layer MAC table and the executable I/O ordering for one exported
//! model. Everything the coordinator knows about a model comes from
//! here; the Rust model descriptors (`models::descriptor`) are used only
//! to cross-check it in tests and to produce paper-scale analytic
//! tables.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::synth::DatasetSpec;
use crate::models::{ConvMeta, LayerDesc, Padding};
use crate::quant::gates::GateView;
use crate::util::json::Json;

/// One parameter tensor in the flat layout.
#[derive(Debug, Clone)]
pub struct ParamDesc {
    pub name: String,
    pub shape: Vec<usize>,
    /// 'w' weights | 'g' gate logits | 's' range scales.
    pub group: char,
    pub offset: usize,
    pub size: usize,
}

/// One quantizer's slot block in the global gate vector.
#[derive(Debug, Clone)]
pub struct QuantDesc {
    pub name: String,
    /// 'w' weight | 'a' activation.
    pub kind: char,
    pub signed: bool,
    pub channels: usize,
    pub levels: Vec<u32>,
    pub offset: usize,
    pub n_slots: usize,
    pub consumer_macs: u64,
}

impl QuantDesc {
    pub fn view(&self) -> GateView {
        GateView { channels: self.channels, levels: self.levels.clone() }
    }
}

/// Parsed `<model>_manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub engine: String,
    pub preset: String,
    pub batch: usize,
    pub n_params: usize,
    pub n_slots: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub params: Vec<ParamDesc>,
    pub quantizers: Vec<QuantDesc>,
    pub layers: Vec<LayerDesc>,
    pub lam_base: Vec<f32>,
    pub dataset: DatasetSpec,
    pub hlo_train: PathBuf,
    pub hlo_eval: PathBuf,
    pub init_file: PathBuf,
}

impl Manifest {
    /// Load `<dir>/<model>_manifest.json`.
    pub fn load(dir: &Path, model: &str) -> Result<Manifest> {
        let path = dir.join(format!("{model}_manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {path:?}"))?;
        let v = Json::parse(&text)
            .with_context(|| format!("parse manifest {path:?}"))?;
        Self::from_json(&v, dir)
    }

    pub fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| -> Result<ParamDesc> {
                Ok(ParamDesc {
                    name: p.get("name")?.as_str()?.into(),
                    shape: p.get("shape")?.usize_vec()?,
                    group: p.get("group")?.as_str()?.chars().next()
                        .unwrap_or('w'),
                    offset: p.get("offset")?.as_usize()?,
                    size: p.get("size")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let quantizers = v
            .get("quantizers")?
            .as_arr()?
            .iter()
            .map(|q| -> Result<QuantDesc> {
                Ok(QuantDesc {
                    name: q.get("name")?.as_str()?.into(),
                    kind: q.get("kind")?.as_str()?.chars().next()
                        .unwrap_or('a'),
                    signed: q.get("signed")?.as_bool()?,
                    channels: q.get("channels")?.as_usize()?,
                    levels: q
                        .get("levels")?
                        .usize_vec()?
                        .into_iter()
                        .map(|b| b as u32)
                        .collect(),
                    offset: q.get("offset")?.as_usize()?,
                    n_slots: q.get("n_slots")?.as_usize()?,
                    consumer_macs: q.get("consumer_macs")?.as_f64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| -> Result<LayerDesc> {
                // Spatial metadata is a schema addition: layers written
                // by pre-spatial exporters (and dense layers) have no
                // `ksize`, and default to `conv: None` — the engine
                // lowers those onto the legacy flattened-GEMM path.
                let conv = match l.get("ksize") {
                    Ok(k) => Some(ConvMeta {
                        ksize: k.as_usize()?,
                        stride: l.get("stride")?.as_usize()?,
                        padding: Padding::parse(
                            l.get("padding")?.as_str()?)?,
                        groups: l.get("groups")?.as_usize()?,
                        in_h: l.get("in_h")?.as_usize()?,
                        in_w: l.get("in_w")?.as_usize()?,
                    }),
                    Err(_) => None,
                };
                // `pre` is part of the same schema addition: the
                // interstitial ops recorded by the exporter; absent on
                // pre-spatial manifests (the engine then infers from
                // shapes).
                let pre_ops = match l.get("pre") {
                    Ok(v) => v
                        .as_arr()?
                        .iter()
                        .map(|o| Ok(o.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                    Err(_) => Vec::new(),
                };
                Ok(LayerDesc {
                    name: l.get("name")?.as_str()?.into(),
                    kind: l.get("kind")?.as_str()?.into(),
                    macs: l.get("macs")?.as_f64()? as u64,
                    cin: l.get("cin")?.as_usize()?,
                    cout: l.get("cout")?.as_usize()?,
                    weight_q: l.get("weight_q")?.as_str()?.into(),
                    act_q: l.get("act_q")?.as_str()?.into(),
                    residual_input: l.get("residual_input")?.as_bool()?,
                    conv,
                    pre_ops,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let man = Manifest {
            name: v.get("name")?.as_str()?.into(),
            engine: v.get("engine")?.as_str()?.into(),
            preset: v.get("preset")?.as_str()?.into(),
            batch: v.get("batch")?.as_usize()?,
            n_params: v.get("n_params")?.as_usize()?,
            n_slots: v.get("n_slots")?.as_usize()?,
            input_shape: v.get("input_shape")?.usize_vec()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            params,
            quantizers,
            layers,
            lam_base: v.get("lam_base")?.f32_vec()?,
            dataset: DatasetSpec::from_json(v.get("dataset")?)?,
            hlo_train: dir.join(v.get("hlo_train")?.as_str()?),
            hlo_eval: dir.join(v.get("hlo_eval")?.as_str()?),
            init_file: dir.join(v.get("init_file")?.as_str()?),
        };
        man.validate()?;
        Ok(man)
    }

    /// Internal consistency checks — fail fast on a stale manifest.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for p in &self.params {
            if p.offset != off {
                bail!("param {} offset {} != expected {}", p.name,
                      p.offset, off);
            }
            let n: usize = p.shape.iter().product::<usize>().max(1);
            if n != p.size {
                bail!("param {} size mismatch", p.name);
            }
            off += p.size;
        }
        if off != self.n_params {
            bail!("param total {off} != n_params {}", self.n_params);
        }
        let mut soff = 0;
        for q in &self.quantizers {
            if q.offset != soff {
                bail!("quantizer {} slot offset mismatch", q.name);
            }
            soff += q.n_slots;
        }
        if soff != self.n_slots {
            bail!("slot total {soff} != n_slots {}", self.n_slots);
        }
        if self.lam_base.len() != self.n_slots {
            bail!("lam_base length mismatch");
        }
        Ok(())
    }

    pub fn param(&self, name: &str) -> Result<&ParamDesc> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("no param {name:?}"))
    }

    pub fn quantizer(&self, name: &str) -> Result<&QuantDesc> {
        self.quantizers
            .iter()
            .find(|q| q.name == name)
            .with_context(|| format!("no quantizer {name:?}"))
    }

    /// Load the initial flat parameter vector.
    pub fn load_init(&self) -> Result<Vec<f32>> {
        let v = crate::util::binio::read_f32_file(&self.init_file)?;
        if v.len() != self.n_params {
            bail!("init file has {} params, manifest says {}", v.len(),
                  self.n_params);
        }
        Ok(v)
    }

    /// Per-slot phi parameter indices (slot -> flat offset), for
    /// thresholding gates out of a checkpoint. Empty for DQ manifests.
    pub fn phi_index(&self) -> Vec<usize> {
        if self.engine == "dq" {
            return Vec::new();
        }
        let mut idx = vec![0usize; self.n_slots];
        for q in &self.quantizers {
            if let Ok(p) = self.param(&format!("{}.phi", q.name)) {
                for i in 0..q.n_slots {
                    idx[q.offset + i] = p.offset + i;
                }
            }
        }
        idx
    }

    /// Group mask as per-element learning-rate selector ('w'|'g'|'s').
    pub fn group_of(&self, flat_index: usize) -> char {
        // params are offset-sorted; binary search the segment
        let mut lo = 0;
        let mut hi = self.params.len();
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if self.params[mid].offset <= flat_index {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        self.params[lo].group
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest_json() -> String {
        r#"{
        "name":"tiny","engine":"bb","preset":"small","batch":4,
        "n_params":10,"n_slots":6,"input_shape":[2,2,1],"num_classes":2,
        "levels":[2,4,8],
        "dataset":{"name":"mnist_like","input":[2,2,1],"classes":2,
                   "train":8,"test":4},
        "params":[
         {"name":"a.w","shape":[2,2],"group":"w","offset":0,"size":4},
         {"name":"a.w.phi","shape":[4],"group":"g","offset":4,"size":4},
         {"name":"a.w.beta","shape":[1],"group":"s","offset":8,"size":1},
         {"name":"a.b","shape":[1],"group":"w","offset":9,"size":1}],
        "quantizers":[
         {"name":"a.w","kind":"w","signed":true,"channels":2,
          "levels":[2,4,8],"layer":"a","offset":0,"consumer_macs":100,
          "n_slots":4},
         {"name":"a.in","kind":"a","signed":false,"channels":1,
          "levels":[2,4,8],"layer":null,"offset":4,"consumer_macs":100,
          "n_slots":2}],
        "layers":[
         {"name":"a","kind":"conv","macs":100,"cin":1,"cout":2,
          "weight_q":"a.w","act_q":"a.in","residual_input":false}],
        "lam_base":[1,1,4,8,2,4],
        "hlo_train":"t.hlo.txt","hlo_eval":"e.hlo.txt",
        "init_file":"i.bin"}"#
            .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let v = Json::parse(&tiny_manifest_json()).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp")).unwrap();
        assert_eq!(m.n_params, 10);
        assert_eq!(m.quantizers[1].offset, 4);
        assert_eq!(m.param("a.w.beta").unwrap().offset, 8);
        assert_eq!(m.group_of(0), 'w');
        assert_eq!(m.group_of(5), 'g');
        assert_eq!(m.group_of(8), 's');
        assert_eq!(m.group_of(9), 'w');
    }

    #[test]
    fn phi_index_maps_slots() {
        let v = Json::parse(&tiny_manifest_json()).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp")).unwrap();
        let idx = m.phi_index();
        assert_eq!(idx.len(), 6);
        assert_eq!(&idx[..4], &[4, 5, 6, 7]);
        // a.in has no phi param in this tiny manifest -> stays 0
    }

    #[test]
    fn spatial_fields_default_to_none_and_parse_when_present() {
        // the tiny manifest's conv layer predates the spatial schema
        let v = Json::parse(&tiny_manifest_json()).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp")).unwrap();
        assert!(m.layers[0].conv.is_none());
        // the same layer with the spatial schema addition
        let with = tiny_manifest_json().replace(
            "\"weight_q\":\"a.w\"",
            "\"ksize\":3,\"stride\":2,\"padding\":\"SAME\",\"groups\":1,\
             \"in_h\":2,\"in_w\":2,\"pre\":[\"maxpool2\"],\
             \"weight_q\":\"a.w\"");
        let v = Json::parse(&with).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp")).unwrap();
        let c = m.layers[0].conv.as_ref().unwrap();
        assert_eq!((c.ksize, c.stride, c.groups, c.in_h, c.in_w),
                   (3, 2, 1, 2, 2));
        assert_eq!(c.padding, crate::models::Padding::Same);
        assert_eq!(m.layers[0].pre_ops, vec!["maxpool2"]);
        // a bad padding string is rejected, not defaulted
        let bad = with.replace("\"SAME\"", "\"DIAGONAL\"");
        let v = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }

    #[test]
    fn validate_catches_bad_offsets() {
        let bad = tiny_manifest_json().replace(
            "\"offset\":4,\"size\":4", "\"offset\":5,\"size\":4");
        let v = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }
}
