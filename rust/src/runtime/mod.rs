//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO **text** from
//! `artifacts/` is parsed into an `HloModuleProto`, compiled once per
//! process, and executed with `Literal` inputs. Text is the interchange
//! format because jax >= 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1's proto path rejects (see aot.py).

pub mod artifact;
pub mod exec;
pub mod manifest_gen;
pub mod state;

pub use artifact::{Manifest, ParamDesc, QuantDesc};
pub use exec::{Executable, Runtime};
pub use state::TrainState;
