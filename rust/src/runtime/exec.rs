//! Executable loading and typed execution over the PJRT CPU client.
//!
//! One global client per process; compiled executables are cached by
//! path so sweeps across modes reuse compilations. The train/eval entry
//! points marshal flat `Vec<f32>` state into `Literal`s and unpack the
//! tuple outputs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::artifact::Manifest;
use super::state::TrainState;
use crate::util::logging;

/// Outputs of one train step (host copies of scalar/small outputs; the
/// updated state is written back into the passed-in `TrainState`).
#[derive(Debug, Clone)]
pub struct TrainOutputs {
    pub loss: f32,
    pub correct: f32,
    pub reg: f32,
    /// Per-slot gate inclusion probabilities (BB) or inferred bits (DQ).
    pub probs: Vec<f32>,
}

/// Outputs of one eval step.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutputs {
    pub loss: f32,
    pub correct: f32,
}

/// A compiled HLO executable plus its role metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {:?}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        Ok(lit.to_tuple()?)
    }
}

/// Process-wide runtime: PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT client")?;
        logging::debug(format!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        ));
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Load + compile an HLO text file (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        if let Some(hit) = self.cache.lock().unwrap().get(path) {
            return Ok(hit.clone());
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        logging::debug(format!(
            "compiled {path:?} in {:.2}s",
            t0.elapsed().as_secs_f64()
        ));
        let arc = std::sync::Arc::new(Executable {
            exe,
            path: path.to_path_buf(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), arc.clone());
        Ok(arc)
    }

    /// Run one train step. Input ordering matches
    /// `steps.example_args_train`; see the manifest's `train_args`.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        exe: &Executable,
        man: &Manifest,
        state: &mut TrainState,
        x: &[f32],
        y: &[i32],
        seed: i32,
        lrs: (f32, f32, f32),
        lock_mask: &[f32],
        lock_val: &[f32],
        lam: &[f32],
        det_flag: f32,
    ) -> Result<TrainOutputs> {
        if lock_mask.len() != man.n_slots
            || lock_val.len() != man.n_slots
            || lam.len() != man.n_slots
        {
            bail!("gate vector length mismatch vs n_slots {}", man.n_slots);
        }
        state.step += 1;
        let mut dims: Vec<i64> = vec![man.batch as i64];
        dims.extend(man.input_shape.iter().map(|d| *d as i64));
        // DQ artifacts have no gates: the lowering dead-code-eliminates
        // the unused (seed, lock_mask, lock_val, det_flag) parameters,
        // leaving the 10 remaining inputs in their original order.
        let dq = man.engine == "dq";
        let mut inputs = vec![
            xla::Literal::vec1(&state.params),
            xla::Literal::vec1(&state.m),
            xla::Literal::vec1(&state.v),
            xla::Literal::vec1(x).reshape(&dims)?,
            xla::Literal::vec1(y),
        ];
        if !dq {
            inputs.push(xla::Literal::scalar(seed));
        }
        inputs.push(xla::Literal::scalar(state.step as f32));
        inputs.push(xla::Literal::scalar(lrs.0));
        inputs.push(xla::Literal::scalar(lrs.1));
        inputs.push(xla::Literal::scalar(lrs.2));
        if !dq {
            inputs.push(xla::Literal::vec1(lock_mask));
            inputs.push(xla::Literal::vec1(lock_val));
        }
        inputs.push(xla::Literal::vec1(lam));
        if !dq {
            inputs.push(xla::Literal::scalar(det_flag));
        }
        let outs = exe.execute(&inputs)?;
        if outs.len() != 7 {
            bail!("train step returned {} outputs, want 7", outs.len());
        }
        state.params = outs[0].to_vec::<f32>()?;
        state.m = outs[1].to_vec::<f32>()?;
        state.v = outs[2].to_vec::<f32>()?;
        Ok(TrainOutputs {
            loss: outs[3].to_vec::<f32>()?[0],
            correct: outs[4].to_vec::<f32>()?[0],
            reg: outs[5].to_vec::<f32>()?[0],
            probs: outs[6].to_vec::<f32>()?,
        })
    }

    /// Run one eval step with explicit binary gates.
    pub fn eval_step(
        &self,
        exe: &Executable,
        man: &Manifest,
        params: &[f32],
        gates: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<EvalOutputs> {
        let mut dims: Vec<i64> = vec![man.batch as i64];
        dims.extend(man.input_shape.iter().map(|d| *d as i64));
        // DQ eval has no gates parameter (dead-code-eliminated).
        let mut inputs = vec![xla::Literal::vec1(params)];
        if man.engine != "dq" {
            inputs.push(xla::Literal::vec1(gates));
        }
        inputs.push(xla::Literal::vec1(x).reshape(&dims)?);
        inputs.push(xla::Literal::vec1(y));
        let outs = exe.execute(&inputs)?;
        if outs.len() != 2 {
            bail!("eval step returned {} outputs, want 2", outs.len());
        }
        Ok(EvalOutputs {
            loss: outs[0].to_vec::<f32>()?[0],
            correct: outs[1].to_vec::<f32>()?[0],
        })
    }

    /// Run the standalone quantizer-forward artifact (parity checks).
    pub fn quantizer_fwd(
        &self,
        exe: &Executable,
        x: &[f32],
        rows: usize,
        beta: &[f32],
        z2: &[f32],
        zh: &[f32],
    ) -> Result<Vec<f32>> {
        let cols = x.len() / rows;
        let inputs = vec![
            xla::Literal::vec1(x).reshape(&[rows as i64, cols as i64])?,
            xla::Literal::vec1(beta),
            xla::Literal::vec1(z2),
            xla::Literal::vec1(zh),
        ];
        let outs = exe.execute(&inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}
