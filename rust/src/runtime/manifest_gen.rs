//! Deterministic in-process manifest generation: build a full
//! Bayesian-Bits manifest (params + quantizers + layer table, spatial
//! fields included) from a Rust model-preset descriptor — the same
//! shapes the python exporter emits. Grown out of the integration-test
//! support module so the serving CLI can register preset models
//! (`bbits serve --model NAME=preset:MODEL`) without python artifacts;
//! `tests/support/mod.rs` now delegates here.

use std::path::Path;

use anyhow::Result;

use crate::models::{descriptor, Preset};
use crate::rng::Pcg64;
use crate::runtime::Manifest;
use crate::util::json::Json;

struct ManifestBuilder {
    params_json: Vec<String>,
    quant_json: Vec<String>,
    layers_json: Vec<String>,
    params: Vec<f32>,
    slot_offset: usize,
    rng: Pcg64,
}

impl ManifestBuilder {
    fn new(seed: u64) -> Self {
        Self {
            params_json: Vec::new(),
            quant_json: Vec::new(),
            layers_json: Vec::new(),
            params: Vec::new(),
            slot_offset: 0,
            rng: Pcg64::new(seed),
        }
    }

    fn param(&mut self, name: &str, shape: &[usize], group: char,
             values: Vec<f32>) {
        let size: usize = shape.iter().product();
        assert_eq!(values.len(), size, "{name}");
        let shape_s: Vec<String> =
            shape.iter().map(|d| d.to_string()).collect();
        self.params_json.push(format!(
            "{{\"name\":\"{name}\",\"shape\":[{}],\"group\":\"{group}\",\
             \"offset\":{},\"size\":{size}}}",
            shape_s.join(","),
            self.params.len()
        ));
        self.params.extend(values);
    }

    fn quantizer(&mut self, name: &str, kind: char, signed: bool,
                 channels: usize, macs: u64) {
        let n_slots = channels + 4;
        self.quant_json.push(format!(
            "{{\"name\":\"{name}\",\"kind\":\"{kind}\",\
             \"signed\":{signed},\"channels\":{channels},\
             \"levels\":[2,4,8,16,32],\"offset\":{},\
             \"n_slots\":{n_slots},\"consumer_macs\":{macs}}}",
            self.slot_offset
        ));
        self.slot_offset += n_slots;
        // phi: channel slots open, chain -> 8 bit (z4, z8 open)
        let mut phi = vec![6.0f32; channels];
        phi.extend_from_slice(&[6.0, 6.0, -6.0, -6.0]);
        self.param(&format!("{name}.phi"), &[n_slots], 'g', phi);
        let beta = if kind == 'w' { 1.0 } else { 2.0 };
        self.param(&format!("{name}.beta"), &[1], 's', vec![beta]);
    }

    fn normals(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() * scale).collect()
    }
}

/// Build a full manifest + parameter vector for one model preset at
/// the small (test) scale. `legacy` emits the pre-spatial schema (no
/// `ksize`/.../`pre` layer fields), as a pre-schema exporter would
/// have written it. `seed` drives the weight init (the gate
/// configuration is fixed: every channel kept, 8-bit chains).
pub fn preset_manifest(model: &str, legacy: bool, seed: u64)
                       -> Result<(Manifest, Vec<f32>)> {
    preset_manifest_at(model, legacy, seed, Preset::Small)
}

/// [`preset_manifest`] at an explicit descriptor scale —
/// `Preset::Paper` builds the full paper-scale network (e.g.
/// ResNet18 over 224x224x3 with ~11M weights), the manifest the
/// paper-scale end-to-end lowering test pushes through the IR.
pub fn preset_manifest_at(model: &str, legacy: bool, seed: u64,
                          preset: Preset)
                          -> Result<(Manifest, Vec<f32>)> {
    let desc = descriptor(model, preset)?;
    // input map: the first layer's recorded conv geometry (identical
    // to the historical per-model match at the small preset)
    let input = match desc.first().and_then(|l| l.conv.as_ref()) {
        Some(m) => (m.in_h, m.in_w, desc[0].cin),
        None => (1, 1, desc.first().map(|l| l.cin).unwrap_or(1)),
    };
    let classes = desc.last().unwrap().cout;
    let mut b = ManifestBuilder::new(seed);
    for l in &desc {
        if l.act_q == format!("{}.in", l.name) {
            b.quantizer(&l.act_q, 'a', false, 1, l.macs);
        }
        let (wshape, fan) = match &l.conv {
            Some(m) => {
                let cg = l.cin / m.groups;
                (vec![m.ksize, m.ksize, cg, l.cout],
                 m.ksize * m.ksize * cg)
            }
            None => (vec![l.cin, l.cout], l.cin),
        };
        let scale = (2.0 / fan as f32).sqrt();
        let w = b.normals(fan * l.cout, scale);
        b.param(&format!("{}.w", l.name), &wshape, 'w', w);
        b.quantizer(&l.weight_q, 'w', true, l.cout, l.macs);
        let bias = b.normals(l.cout, 0.05);
        b.param(&format!("{}.b", l.name), &[l.cout], 'w', bias);
    }
    for l in &desc {
        let spatial = match &l.conv {
            Some(m) if !legacy => format!(
                ",\"ksize\":{},\"stride\":{},\"padding\":\"{}\",\
                 \"groups\":{},\"in_h\":{},\"in_w\":{}",
                m.ksize, m.stride, m.padding.label(), m.groups, m.in_h,
                m.in_w),
            _ => String::new(),
        };
        let pre = if legacy || l.pre_ops.is_empty() {
            String::new()
        } else {
            let ops: Vec<String> =
                l.pre_ops.iter().map(|o| format!("\"{o}\"")).collect();
            format!(",\"pre\":[{}]", ops.join(","))
        };
        b.layers_json.push(format!(
            "{{\"name\":\"{}\",\"kind\":\"{}\",\"macs\":{},\
             \"cin\":{},\"cout\":{},\"weight_q\":\"{}\",\
             \"act_q\":\"{}\",\"residual_input\":{}{spatial}{pre}}}",
            l.name, l.kind, l.macs, l.cin, l.cout, l.weight_q, l.act_q,
            l.residual_input));
    }
    let lam: Vec<String> =
        (0..b.slot_offset).map(|_| "1".to_string()).collect();
    let preset_label = match preset {
        Preset::Small => "small",
        Preset::Paper => "paper",
    };
    let text = format!(
        "{{\"name\":\"{model}\",\"engine\":\"bb\",\
         \"preset\":\"{preset_label}\",\
         \"batch\":4,\"n_params\":{},\"n_slots\":{},\
         \"input_shape\":[{},{},{}],\"num_classes\":{classes},\
         \"dataset\":{{\"name\":\"mnist_like\",\"input\":[{},{},{}],\
         \"classes\":{classes},\"train\":8,\"test\":4}},\
         \"params\":[{}],\"quantizers\":[{}],\"layers\":[{}],\
         \"lam_base\":[{}],\"hlo_train\":\"t.hlo.txt\",\
         \"hlo_eval\":\"e.hlo.txt\",\"init_file\":\"i.bin\"}}",
        b.params.len(),
        b.slot_offset,
        input.0, input.1, input.2,
        input.0, input.1, input.2,
        b.params_json.join(","),
        b.quant_json.join(","),
        b.layers_json.join(","),
        lam.join(","));
    let man = Manifest::from_json(&Json::parse(&text)?,
                                  Path::new("/tmp"))?;
    Ok((man, b.params))
}

/// Deterministic servable parameter vector for an arbitrary manifest
/// whose init file is unavailable: He-init weights seeded by `seed`,
/// unit weight-grid / 2.0 activation-grid scales, and gate logits set
/// to the preset-builder convention — every channel slot open, chain
/// slots `[6, 6, -6, -6]` (an 8-bit chain) when the quantizer has the
/// standard `channels + 4` phi layout, fully open otherwise.
pub fn default_init(man: &Manifest, seed: u64) -> Vec<f32> {
    let mut v = vec![0.0f32; man.n_params];
    let mut rng = Pcg64::new(seed);
    for p in &man.params {
        let vals: Vec<f32> = match p.group {
            'g' => vec![6.0; p.size],
            's' => vec![1.0; p.size],
            _ => {
                let fan: usize = if p.shape.len() >= 2 {
                    p.shape[..p.shape.len() - 1].iter().product()
                } else {
                    p.size
                };
                let scale = (2.0 / fan.max(1) as f32).sqrt();
                (0..p.size).map(|_| rng.normal() * scale).collect()
            }
        };
        v[p.offset..p.offset + p.size].copy_from_slice(&vals);
    }
    for q in &man.quantizers {
        if let Ok(p) = man.param(&format!("{}.phi", q.name)) {
            if p.size == q.channels + 4 {
                let chain = p.offset + q.channels;
                v[chain..chain + 4]
                    .copy_from_slice(&[6.0, 6.0, -6.0, -6.0]);
            }
        }
        if let Ok(p) = man.param(&format!("{}.beta", q.name)) {
            v[p.offset] = if q.kind == 'w' { 1.0 } else { 2.0 };
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_manifest_validates_and_lowers() {
        let (man, params) = preset_manifest("lenet5", false, 42).unwrap();
        assert_eq!(man.name, "lenet5");
        assert_eq!(params.len(), man.n_params);
        let plan = crate::engine::lower(&man, &params).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.input_dim, 16 * 16);
        // unknown model is an error, not a panic
        assert!(preset_manifest("nope", false, 1).is_err());
    }

    #[test]
    fn paper_preset_manifest_lowers_at_full_scale() {
        let (man, params) =
            preset_manifest_at("lenet5", false, 42,
                               crate::models::Preset::Paper)
                .unwrap();
        assert_eq!(man.preset, "paper");
        assert_eq!(params.len(), man.n_params);
        let plan = crate::engine::lower(&man, &params).unwrap();
        plan.validate().unwrap();
        // paper lenet5 runs on 28x28 MNIST-scale inputs
        assert_eq!(plan.input_dim, 28 * 28);
        assert_eq!(plan.output_dim, 10);
    }

    #[test]
    fn default_init_produces_a_servable_config() {
        let (man, _) = preset_manifest("lenet5", false, 42).unwrap();
        let params = default_init(&man, 7);
        assert_eq!(params.len(), man.n_params);
        let plan = crate::engine::lower(&man, &params).unwrap();
        plan.validate().unwrap();
        // the builder convention pins an 8-bit chain, all channels kept
        for l in &plan.layers {
            assert_eq!(l.w_bits, 8, "{}", l.name);
            assert_eq!(l.kept.len(), l.out_dim, "{}", l.name);
        }
    }
}
