//! Mutable training state: flat parameters + Adam moments + step count.

use anyhow::Result;

use super::artifact::Manifest;

/// The complete optimizer-visible state of one training run.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based Adam step counter (bias correction).
    pub step: u64,
}

impl TrainState {
    /// Fresh state from the artifact's initial parameters.
    pub fn init(man: &Manifest) -> Result<TrainState> {
        let params = man.load_init()?;
        let n = params.len();
        Ok(TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 })
    }

    /// State around externally-provided parameters (checkpoint restore).
    pub fn from_params(params: Vec<f32>) -> TrainState {
        let n = params.len();
        TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Read the phi logits for every gate slot (BB manifests).
    pub fn phi_slots(&self, man: &Manifest) -> Vec<f64> {
        man.phi_index()
            .iter()
            .map(|i| self.params[*i] as f64)
            .collect()
    }

    /// Reset optimizer moments (used between training phases, matching
    /// the paper's separate fine-tuning stage).
    pub fn reset_optimizer(&mut self) {
        self.m.iter_mut().for_each(|v| *v = 0.0);
        self.v.iter_mut().for_each(|v| *v = 0.0);
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_params_zeroes_moments() {
        let st = TrainState::from_params(vec![1.0, 2.0]);
        assert_eq!(st.m, vec![0.0, 0.0]);
        assert_eq!(st.step, 0);
    }

    #[test]
    fn reset_optimizer_clears() {
        let mut st = TrainState::from_params(vec![1.0]);
        st.m[0] = 5.0;
        st.step = 9;
        st.reset_optimizer();
        assert_eq!(st.m[0], 0.0);
        assert_eq!(st.step, 0);
    }
}
