//! Minimal host-side f32 tensor (ndarray is not vendored).
//!
//! Device compute happens in the AOT executables; this type only backs
//! the host paths: dataset batches, parameter blobs, metric reductions.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n,
                  data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {} elements to {:?}", self.data.len(),
                  shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// View row `i` of a 2-D-interpreted tensor (first axis splits).
    pub fn row(&self, i: usize) -> &[f32] {
        let stride: usize = self.shape[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }

    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.shape[0])
            .map(|i| {
                let r = self.row(i);
                let mut best = 0;
                for (j, v) in r.iter().enumerate() {
                    if *v > r[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, b| a.max(b.abs()))
    }

    /// Elementwise maximum absolute difference vs another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |a, (x, y)| a.max((x - y).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn argmax_rows_works() {
        let t = Tensor::new(vec![2, 3], vec![1., 5., 2., 9., 0., 3.])
            .unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let r = t.clone().reshape(vec![4]).unwrap();
        assert_eq!(r.data, t.data);
        assert!(t.reshape(vec![3]).is_err());
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(vec![3], vec![-2.0, 1.0, 0.5]).unwrap();
        assert!((t.mean() + 1.0 / 6.0).abs() < 1e-6);
        assert_eq!(t.max_abs(), 2.0);
    }
}
