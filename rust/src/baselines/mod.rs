//! Baseline methods the paper compares against.
//!
//! * Fixed-width QAT with learned ranges ("LSQ/PACT-like") — expressed
//!   as lock patterns of the Bayesian Bits artifact
//!   (`Mode::Fixed{w,a}`), so they share the data pipeline and training
//!   loop and the comparison is apples-to-apples (§4 / App. C).
//! * DQ / DQ-restricted — the separate `_dq` artifacts learn continuous
//!   bit widths; `dq_restricted_pct` recomputes the BOP count after
//!   rounding every learned width *up* to the next power of two (the
//!   paper's point about hardware-unfriendly methods; accuracy is
//!   unchanged by construction, Table 1).
//! * Sensitivity-ordered iterative PTQ — `coordinator::ptq`.

use std::collections::BTreeMap;

use crate::bops::{BopCounter, QuantState};
use crate::config::Mode;
use crate::runtime::Manifest;

/// The fixed-width baseline grid used in the tables, mirroring the
/// paper's rows: (label, mode).
pub fn fixed_grid() -> Vec<(String, Mode)> {
    [(32, 32), (8, 8), (4, 8), (4, 4), (2, 8), (2, 2)]
        .into_iter()
        .map(|(w, a)| {
            (
                format!("w{w}a{a}"),
                Mode::Fixed { w_bits: w, a_bits: a },
            )
        })
        .collect()
}

/// Round a learned continuous bit width up to the next hardware-friendly
/// (power-of-two, >= 2) width.
pub fn round_up_pow2_bits(bits: f64) -> u32 {
    let mut b = 2u32;
    while (b as f64) < bits && b < 32 {
        b *= 2;
    }
    b
}

/// DQ: BOPs (%) of the learned *continuous* configuration.
pub fn dq_pct(counter: &BopCounter, man: &Manifest, bits: &[f32]) -> f64 {
    crate::coordinator::trainer::dq_expected_pct(counter, man, bits)
}

/// DQ-restricted: BOPs (%) after rounding every width up to a power of
/// two. Accuracy is the DQ accuracy (rounding up only adds precision).
pub fn dq_restricted_pct(counter: &BopCounter, man: &Manifest,
                         bits: &[f32]) -> f64 {
    let mut states: BTreeMap<String, QuantState> = BTreeMap::new();
    for q in &man.quantizers {
        states.insert(
            q.name.clone(),
            QuantState::full(round_up_pow2_bits(bits[q.offset] as f64)),
        );
    }
    counter.relative_bops_pct(&states)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_pow2() {
        assert_eq!(round_up_pow2_bits(1.2), 2);
        assert_eq!(round_up_pow2_bits(2.0), 2);
        assert_eq!(round_up_pow2_bits(2.1), 4);
        assert_eq!(round_up_pow2_bits(5.7), 8);
        assert_eq!(round_up_pow2_bits(9.0), 16);
        assert_eq!(round_up_pow2_bits(31.0), 32);
        assert_eq!(round_up_pow2_bits(40.0), 32);
    }

    #[test]
    fn fixed_grid_has_paper_rows() {
        let g = fixed_grid();
        assert!(g.iter().any(|(l, _)| l == "w8a8"));
        assert!(g.iter().any(|(l, _)| l == "w2a8"));
    }
}
