//! Markdown/ASCII table builder used by every experiment harness.

/// Accumulates rows and renders a padded, pipe-delimited table.
#[derive(Debug, Clone, Default)]
pub struct TableBuilder {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl TableBuilder {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(),
                   "row width mismatch in table {:?}", self.title);
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> =
            cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// `value ± err` cell in paper style.
    pub fn pm(value: f64, err: f64, digits: usize) -> String {
        format!("{value:.digits$}±{err:.digits$}")
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n## {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Comma-separated form for machine consumption.
    pub fn render_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableBuilder::new("T", &["Method", "Acc"]);
        t.row_str(&["FP32", "93.05"]);
        t.row_str(&["BB mu=0.01", "93.2"]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.contains("| FP32       | 93.05 |"));
    }

    #[test]
    fn pm_formats() {
        assert_eq!(TableBuilder::pm(93.234, 0.104, 2), "93.23±0.10");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = TableBuilder::new("T", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn csv_roundtrip_width() {
        let mut t = TableBuilder::new("T", &["a", "b"]);
        t.row_str(&["1", "2"]);
        assert_eq!(t.render_csv(), "a,b\n1,2\n");
    }
}
