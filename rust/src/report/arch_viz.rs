//! Learned-architecture report: per-layer weight/activation bit widths
//! and channel sparsity — the text analogue of Figures 6 and 15-18.

use std::collections::BTreeMap;

use crate::bops::QuantState;
use crate::runtime::Manifest;

/// Render the learned configuration as a bar-annotated table.
pub fn architecture_report(man: &Manifest,
                           states: &BTreeMap<String, QuantState>)
                           -> String {
    let mut out = format!(
        "\nLearned architecture: {} ({} layers)\n\
         {:<16} {:>6} {:>6} {:>8} {:>9}  bits\n",
        man.name,
        man.layers.len(),
        "layer", "w-bit", "a-bit", "keep%", "MACs"
    );
    for l in &man.layers {
        let w = states.get(&l.weight_q).copied()
            .unwrap_or(QuantState::full(32));
        let a = states.get(&l.act_q).copied()
            .unwrap_or(QuantState::full(32));
        let bar_len = if w.bits == 0 { 0 }
                      else { (w.bits as usize).min(32) };
        out.push_str(&format!(
            "{:<16} {:>6} {:>6} {:>7.1}% {:>9}  {}\n",
            truncate(&l.name, 16),
            bits_str(w.bits),
            bits_str(a.bits),
            100.0 * w.keep_ratio,
            l.macs,
            "#".repeat(bar_len)
        ));
    }
    out
}

fn bits_str(b: u32) -> String {
    if b == 0 { "prune".into() } else { b.to_string() }
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("..{}", &s[s.len() - (n - 2)..])
    }
}

/// Aggregate summary line: mean bits weighted by MACs + global sparsity.
pub fn summary_line(man: &Manifest,
                    states: &BTreeMap<String, QuantState>) -> String {
    let total: f64 = man.layers.iter().map(|l| l.macs as f64).sum();
    let mut wbits = 0.0;
    let mut abits = 0.0;
    let mut kept = 0.0;
    for l in &man.layers {
        let w = states.get(&l.weight_q).copied()
            .unwrap_or(QuantState::full(32));
        let a = states.get(&l.act_q).copied()
            .unwrap_or(QuantState::full(32));
        let frac = l.macs as f64 / total;
        wbits += frac * w.bits as f64;
        abits += frac * a.bits as f64;
        kept += frac * w.keep_ratio;
    }
    format!(
        "MAC-weighted mean bits: w={wbits:.2} a={abits:.2}; \
         channel keep ratio {:.1}%",
        kept * 100.0
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_and_truncate_format() {
        assert_eq!(bits_str(0), "prune");
        assert_eq!(bits_str(8), "8");
        assert_eq!(truncate("short", 16), "short");
        assert_eq!(truncate("averyverylongname.conv1", 10).len(), 10);
    }
}
