//! Reporting: markdown tables, ASCII scatter plots, Pareto fronts, and
//! per-layer architecture visualizations (the text analogue of the
//! paper's Figures 6 and 15-18).

pub mod arch_viz;
pub mod plot;
pub mod table;

pub use arch_viz::architecture_report;
pub use plot::scatter;
pub use table::TableBuilder;
