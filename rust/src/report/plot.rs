//! ASCII scatter/line plots for terminal figures.
//!
//! Multiple labeled series share one canvas; the x axis can be log-scaled
//! (relative BOPs span two orders of magnitude in the paper's figures).

/// One plotted series.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
    pub marker: char,
}

/// Render series into an ASCII canvas of the given size.
pub fn scatter(title: &str, xlabel: &str, ylabel: &str, series: &[Series],
               width: usize, height: usize, log_x: bool) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for (x, y) in &s.points {
            let x = if log_x { x.max(1e-12).log10() } else { *x };
            pts.push((x, *y));
        }
    }
    if pts.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &pts {
        x0 = x0.min(*x);
        x1 = x1.max(*x);
        y0 = y0.min(*y);
        y1 = y1.max(*y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    // margin
    let ypad = (y1 - y0) * 0.05;
    let y0 = y0 - ypad;
    let y1 = y1 + ypad;

    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for (px, py) in &s.points {
            let x = if log_x { px.max(1e-12).log10() } else { *px };
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64)
                .round() as usize;
            let cy = (((py - y0) / (y1 - y0)) * (height - 1) as f64)
                .round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = s.marker;
        }
    }
    let mut out = format!("\n{title}\n");
    let yfmt = |v: f64| format!("{v:8.2}");
    for (i, row) in grid.iter().enumerate() {
        let yval = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
        let label = if i % 4 == 0 { yfmt(yval) } else { " ".repeat(8) };
        out.push_str(&format!("{label} |{}\n",
                              row.iter().collect::<String>()));
    }
    out.push_str(&format!("{} +{}\n", " ".repeat(8), "-".repeat(width)));
    let xl = if log_x {
        format!("log10({xlabel}): {:.2} .. {:.2}", x0, x1)
    } else {
        format!("{xlabel}: {x0:.2} .. {x1:.2}")
    };
    out.push_str(&format!("{} {xl}   (y: {ylabel})\n", " ".repeat(8)));
    for s in series {
        out.push_str(&format!("{}   {} = {}\n", " ".repeat(8), s.marker,
                              s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markers_and_legend() {
        let s = scatter(
            "Fig", "bops", "acc",
            &[
                Series { label: "bb".into(),
                         points: vec![(1.0, 0.9), (10.0, 0.95)],
                         marker: 'o' },
                Series { label: "fixed".into(),
                         points: vec![(5.0, 0.85)], marker: 'x' },
            ],
            40, 12, true,
        );
        assert!(s.contains('o') && s.contains('x'));
        assert!(s.contains("o = bb"));
        assert!(s.contains("log10(bops)"));
    }

    #[test]
    fn empty_is_graceful() {
        let s = scatter("F", "x", "y", &[], 10, 5, false);
        assert!(s.contains("no data"));
    }

    #[test]
    fn degenerate_ranges_ok() {
        let s = scatter(
            "F", "x", "y",
            &[Series { label: "a".into(), points: vec![(1.0, 1.0)],
                       marker: '*' }],
            10, 5, false,
        );
        assert!(s.contains('*'));
    }
}
