//! Hand-rolled CLI parser (clap is not vendored).
//!
//! Grammar: `bbits <command> [positional...] [--flag[=| ]value] [--switch]`.
//! Flags collect into a string map; typed access helpers do the parsing
//! and produce uniform error messages. `--help` works on every command.
//!
//! Both switches and value flags come from explicit registries: an
//! unknown `--flag` is an error instead of silently swallowing the
//! next positional as its value (a misspelled `--quikc` used to eat
//! the following argument).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    /// Last occurrence per flag (the historical single-value view).
    pub flags: BTreeMap<String, String>,
    /// Every occurrence per flag, in order — repeatable flags such as
    /// `serve --model NAME=SPEC --model NAME=SPEC` read this.
    pub multi: BTreeMap<String, Vec<String>>,
}

/// Flags that are boolean switches (present => "true").
const SWITCHES: &[&str] = &[
    "help", "det-gates", "show-preft", "curves", "quick", "paper-scale",
    "skip-baselines", "no-finetune", "no-int", "conv-only", "dump-ir",
    "serve-only", "profile", "verify", "verify-plans", "prewarm",
];

/// Flags that take a value (`--flag v` or `--flag=v`). Anything not
/// listed here or in [`SWITCHES`] is rejected at parse time.
const VALUE_FLAGS: &[&str] = &[
    // shared experiment/trainer flags
    "artifacts", "out", "log-level", "model", "mode", "mu", "mus",
    "steps", "finetune-steps", "eval-every", "lr-w", "lr-g", "lr-s",
    "seed", "seeds", "jobs", "threads", "run", "runs", "variant",
    // engine / serving flags
    "checkpoint", "dims", "wbits", "abits", "prune", "max-batch",
    "deadline-ms", "queue-cap", "clients", "requests", "rows", "cols",
    "batch", "hw", "cin", "cout", "ksize", "plan-cache-mb", "backend",
    "trace-out", "ladder", "slo-ms", "intra-threads", "save", "load",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    if !SWITCHES.contains(&k) && !VALUE_FLAGS.contains(&k)
                    {
                        return Err(unknown_flag(k));
                    }
                    args.push_flag(k, v);
                } else if SWITCHES.contains(&name) {
                    args.push_flag(name, "true");
                } else if VALUE_FLAGS.contains(&name) {
                    let v = it.next().ok_or_else(|| {
                        anyhow!("flag --{name} expects a value")
                    })?;
                    args.push_flag(name, v);
                } else {
                    return Err(unknown_flag(name));
                }
            } else if args.command.is_empty() {
                args.command = a.clone();
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    /// Record one flag occurrence: `flags` keeps the last value (the
    /// historical single-value view), `multi` keeps them all.
    fn push_flag(&mut self, name: &str, value: &str) {
        self.flags.insert(name.to_string(), value.to_string());
        self.multi
            .entry(name.to_string())
            .or_default()
            .push(value.to_string());
    }

    /// Every occurrence of a repeatable value flag, in command-line
    /// order (empty if absent).
    pub fn repeated_flag(&self, name: &str) -> &[String] {
        self.multi.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true"))
    }

    /// Comma-separated usize list flag (layer dims etc.).
    pub fn usize_list_flag(&self, name: &str, default: &[usize])
                           -> Result<Vec<usize>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        anyhow!("--{name}: bad integer {p:?}")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated f64 list flag.
    pub fn f64_list_flag(&self, name: &str, default: &[f64])
                         -> Result<Vec<f64>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        anyhow!("--{name}: bad number {p:?}")
                    })
                })
                .collect(),
        }
    }
}

fn unknown_flag(name: &str) -> anyhow::Error {
    anyhow!("unknown flag --{name} (see `bbits --help`); flags are \
             registered explicitly so a typo cannot swallow the next \
             argument")
}

/// Top-level usage text.
pub fn usage() -> String {
    "\
bbits — Bayesian Bits: unified quantization + pruning (NeurIPS 2020)

USAGE: bbits <command> [flags]

Training / evaluation
  train           train one configuration
                  --model M --mode bb|quant-only|prune-only:WxA|fixed:WxA|fp32|dq
                  --mu F --steps N --finetune-steps N --seed N [--det-gates]
  sweep           Pareto sweep over --mus 0.01,0.05,... (threads: --jobs N)
  ptq             post-training mode on a pretrained checkpoint
                  --variant gates|gates+scales|sensitivity|fixed8

Paper experiments (each regenerates one table/figure)
  table1          MNIST + CIFAR10 (LeNet-5 / VGG-7) accuracy vs rel. GBOPs
  table2          deterministic vs stochastic gates ablation
  table4          ResNet18 grid incl. QO/PO ablations (+ --show-preft)
  table5          post-training grid (gates-only vs gates+scales)
  figure2         ResNet18 / MobileNetV2 Pareto fronts (--model)
  figure3         post-training Pareto front vs sensitivity baseline
  figure6         learned per-layer bit widths + sparsity (--run DIR)
  figure10        gate-probability evolution (--run DIR) [--curves]

Integer inference engine (rust/src/engine)
  serve           lower a checkpoint into the integer engine and serve
                  batched requests from a closed-loop load generator
                  --model M --checkpoint PATH  (or, without a
                  checkpoint, a synthetic plan: --dims 128,256,10
                  --wbits N --abits N --prune F)
                  --ladder T1,T2,.. lowers the checkpoint once per
                  gate threshold into a precision ladder (one compiled
                  rung per bit-width tier); --slo-ms D sets the
                  per-request deadline the router picks rungs against —
                  under queue pressure requests degrade to cheaper
                  rungs instead of shedding
                  multi-model: repeat --model NAME=SPEC where SPEC is
                  `preset:MODEL` (in-process preset manifest),
                  `MANIFEST.json` (deterministic init), or
                  `MANIFEST.json:CKPT`; requests round-robin across
                  models, stats are per-model. --plan-cache-mb F caps
                  the compiled-program cache (LRU eviction + lazy
                  recompile; 0 keeps only the hot model resident)
                  --threads N --max-batch B --deadline-ms F
                  --queue-cap N --clients C --requests N [--no-int]
                  --backend scalar|simd|blocked forces the integer
                  kernel backend (default: BBITS_BACKEND env, then
                  per-node auto selection, which never picks blocked;
                  results are bit-identical across all three)
                  --intra-threads N shards each request's blocked
                  kernels across N scoped threads (capped so workers x
                  intra never oversubscribes the machine; scalar/simd
                  nodes ignore it)
                  --trace-out FILE records request spans (enqueue ->
                  queue_wait -> batch_form -> infer -> respond) and
                  per-node kernel slices, written as Chrome
                  trace-event JSON (chrome://tracing / Perfetto)
                  --verify-plans runs the static plan verifier over
                  every rung's compiled programs at register time and
                  refuses to serve a plan that fails (overflow-range,
                  arena-aliasing, IR and backend-invariant proofs)
                  --load FILE serves a saved plan artifact instead of
                  lowering a checkpoint (see plan --save); --prewarm
                  compiles every rung before traffic starts, so the
                  first request of each rung is a cache hit
  plan            lower a checkpoint (or synthetic spec, same flags as
                  serve) and print the plan report; --dump-ir prints
                  the compiled execution graphs (typed node list +
                  scratch-arena map) for the int and f32 paths —
                  integer kernel nodes carry their backend
                  (gemm.simd / conv2d.blocked / dwconv2d.simd);
                  --profile runs a few synthetic batches through the
                  instrumented interpreter and prints per-node timings
                  plus the (op, backend, bit-width) aggregate table
                  --verify compiles both execution paths and runs the
                  static plan verifier (engine/verify.rs): per-node
                  overflow range analysis, arena aliasing, IR
                  well-formedness and backend/panel invariants; exits
                  non-zero on any finding. With --ladder T1,T2,.. and
                  a manifest source (--checkpoint or
                  --model preset:NAME) every rung is verified
                  --save FILE serializes the lowered plan to a
                  versioned binary artifact (checksummed; packed code
                  grids included); --load FILE decodes one instead of
                  lowering — every load re-validates structure and
                  code grids and runs the static verifier, so a
                  corrupt artifact is a typed error, never a served
                  plan
  engine-bench    packed integer GEMM + spatial conv, scalar vs simd
                  vs blocked integer backends vs the f32 fallback;
                  writes BENCH_engine.json (GEMM sweep) and
                  BENCH_conv.json (conv sweep) with a backend column
                  per record, plus a multi-model serve sweep to
                  BENCH_serve.json (per-model p50/p99 + plan-cache
                  eviction counters), an SLO deadline-pressure
                  sweep to BENCH_ladder.json (ladder vs static plan),
                  and a model-lifecycle sweep to BENCH_lifecycle.json
                  (artifact-vs-lowering cold start; a warm model's
                  p99 while another model cold-compiles — per-rung
                  latches keep the two tails identical)
                  --rows N --cols N --batch B (GEMM; skip: --conv-only)
                  --hw N --cin N --cout N --ksize K (conv layer)
                  --backend scalar|simd|blocked restricts the sweep
                  --serve-only runs just the serve sweep
                  --paper-scale instead measures end-to-end forwards
                  through the full 224x224 ResNet18 lowering per
                  backend (incl. blocked + --intra-threads sharding)
                  and writes BENCH_paper.json; every record is a
                  measurement, never a projection

Utilities
  parity          check Rust runtime vs golden quantizer vectors
  bops            print analytic BOP tables (small + paper scale)
  report          summarize a runs directory (--runs DIR)

Common flags
  --artifacts DIR (default: artifacts)   --out DIR (default: runs)
  --quick         shrink step budgets ~10x for smoke runs
  --threads N     worker threads: serve workers / parallel sweep jobs
                  (--jobs is an alias for sweeps)
  --log-level debug|info|warn|error
"
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn parses_command_flags_positional() {
        let a = parse("train pos1 --model vgg7 --mu=0.05 --det-gates pos2");
        assert_eq!(a.command, "train");
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.str_flag("model", "x"), "vgg7");
        assert_eq!(a.f64_flag("mu", 0.0).unwrap(), 0.05);
        assert!(a.bool_flag("det-gates"));
    }

    #[test]
    fn missing_value_is_error() {
        let v: Vec<String> = vec!["train".into(), "--mu".into()];
        assert!(Args::parse(&v).is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse("sweep --mus 0.01,0.05,0.2");
        assert_eq!(a.f64_list_flag("mus", &[]).unwrap(),
                   vec![0.01, 0.05, 0.2]);
        let b = parse("sweep");
        assert_eq!(b.f64_list_flag("mus", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn typed_flag_errors() {
        let a = parse("train --steps abc");
        assert!(a.usize_flag("steps", 1).is_err());
        assert_eq!(a.usize_flag("other", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_flags_are_rejected_not_swallowed() {
        // a misspelled switch used to eat the next positional as its
        // "value"; now it is a parse error
        let v: Vec<String> = "train --quikc pos1"
            .split_whitespace()
            .map(String::from)
            .collect();
        let err = Args::parse(&v).unwrap_err();
        assert!(format!("{err}").contains("--quikc"), "{err}");
        // unknown --flag=value form is rejected too
        let v: Vec<String> =
            vec!["train".into(), "--bogus=3".into()];
        assert!(Args::parse(&v).is_err());
        // known switches and value flags still parse
        let a = parse("serve --no-int --threads 4 --dims 8,16,4");
        assert!(a.bool_flag("no-int"));
        assert_eq!(a.usize_flag("threads", 1).unwrap(), 4);
        assert_eq!(a.usize_list_flag("dims", &[]).unwrap(),
                   vec![8, 16, 4]);
        // conv bench flags are registered
        let c = parse(
            "engine-bench --conv-only --hw 8 --cin 4 --cout 4 --ksize 3");
        assert!(c.bool_flag("conv-only"));
        assert_eq!(c.usize_flag("hw", 1).unwrap(), 8);
        assert_eq!(c.usize_flag("cin", 1).unwrap(), 4);
        assert_eq!(c.usize_flag("cout", 1).unwrap(), 4);
        assert_eq!(c.usize_flag("ksize", 1).unwrap(), 3);
        // the IR dump switch is registered
        let p = parse("plan --dims 8,4 --dump-ir");
        assert_eq!(p.command, "plan");
        assert!(p.bool_flag("dump-ir"));
        // the kernel-backend flag is registered (value form)
        let b = parse("engine-bench --backend simd --rows 64");
        assert_eq!(b.str_flag("backend", "x"), "simd");
        assert_eq!(parse("serve --backend=scalar")
                       .str_flag("backend", "x"),
                   "scalar");
        // observability flags: --profile switch, --trace-out value
        let p = parse("plan --dims 8,4 --profile");
        assert!(p.bool_flag("profile"));
        let t = parse("serve --trace-out trace.json");
        assert_eq!(t.opt_flag("trace-out"), Some("trace.json"));
        // precision-ladder flags: --ladder list, --slo-ms value
        let l = parse("serve --ladder 0.3,0.5,0.9 --slo-ms 2.5");
        assert_eq!(l.f64_list_flag("ladder", &[]).unwrap(),
                   vec![0.3, 0.5, 0.9]);
        assert_eq!(l.f64_flag("slo-ms", 0.0).unwrap(), 2.5);
        // blocked-backend flags: --intra-threads value, --paper-scale
        // switch
        let i = parse("serve --backend blocked --intra-threads 3");
        assert_eq!(i.str_flag("backend", "x"), "blocked");
        assert_eq!(i.usize_flag("intra-threads", 1).unwrap(), 3);
        assert!(parse("engine-bench --paper-scale")
            .bool_flag("paper-scale"));
        assert_eq!(parse("serve --trace-out=t.json")
                       .str_flag("trace-out", "x"),
                   "t.json");
        // static-verifier switches: plan --verify, serve --verify-plans
        let v = parse("plan --model preset:lenet5 --verify \
                       --ladder 0.3,0.9");
        assert!(v.bool_flag("verify"));
        assert_eq!(v.f64_list_flag("ladder", &[]).unwrap(),
                   vec![0.3, 0.9]);
        assert!(parse("serve --verify-plans")
            .bool_flag("verify-plans"));
        // plan-artifact flags: --save/--load values, --prewarm switch
        let s = parse("plan --dims 8,4 --save p.plan");
        assert_eq!(s.opt_flag("save"), Some("p.plan"));
        let l = parse("serve --load p.plan --prewarm");
        assert_eq!(l.opt_flag("load"), Some("p.plan"));
        assert!(l.bool_flag("prewarm"));
    }

    #[test]
    fn repeated_model_flags_collect_in_order() {
        let a = parse(
            "serve --model a=preset:lenet5 --model b=m.json:c.ckpt \
             --plan-cache-mb 4");
        assert_eq!(a.repeated_flag("model"),
                   &["a=preset:lenet5".to_string(),
                     "b=m.json:c.ckpt".to_string()]);
        // the single-value view keeps the last occurrence
        assert_eq!(a.str_flag("model", "x"), "b=m.json:c.ckpt");
        assert_eq!(a.f64_flag("plan-cache-mb", 0.0).unwrap(), 4.0);
        // absent repeatable flag reads as empty, not a panic
        assert!(parse("serve").repeated_flag("model").is_empty());
        // --flag=value occurrences accumulate too
        let b = parse("serve --model=a=x.json --model=b=y.json");
        assert_eq!(b.repeated_flag("model").len(), 2);
        assert_eq!(b.repeated_flag("model")[0], "a=x.json");
        // the serve-only bench switch is registered
        assert!(parse("engine-bench --serve-only")
            .bool_flag("serve-only"));
    }

    #[test]
    fn usize_list_flag_parses_and_defaults() {
        let a = parse("serve --dims 1,2,3");
        assert_eq!(a.usize_list_flag("dims", &[9]).unwrap(),
                   vec![1, 2, 3]);
        assert_eq!(parse("serve").usize_list_flag("dims", &[9]).unwrap(),
                   vec![9]);
        let bad = parse("serve --dims 1,x");
        assert!(bad.usize_list_flag("dims", &[]).is_err());
    }
}
