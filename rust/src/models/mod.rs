//! Architecture descriptors: the layer tables (MACs, channel counts,
//! quantizer wiring) for every model, at both the CPU-scaled `small`
//! preset and the paper-scale preset.
//!
//! The `small` tables must agree exactly with the manifests produced by
//! `python/compile/aot.py` (checked in integration tests); the `paper`
//! tables power the analytic BOP columns for paper-scale comparisons
//! (`bbits bops`) without requiring paper-scale training.

use anyhow::{bail, Result};

/// Conv padding convention (the exporter's JAX string padding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// `ceil(in/stride)` output pixels, zero padding split low/high
    /// (TF/XLA convention: the extra pad goes bottom/right).
    Same,
    /// No padding: `(in - k)/stride + 1` output pixels.
    Valid,
}

impl Padding {
    pub fn parse(s: &str) -> Result<Padding> {
        match s {
            "SAME" => Ok(Padding::Same),
            "VALID" => Ok(Padding::Valid),
            other => bail!("unknown padding {other:?} (SAME | VALID)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Padding::Same => "SAME",
            Padding::Valid => "VALID",
        }
    }
}

/// Spatial metadata of a conv/dwconv layer — what the integer engine
/// needs to run the layer as a real convolution instead of a flattened
/// GEMM. Mirrors the optional `ksize`/`stride`/`padding`/`groups`/
/// `in_h`/`in_w` manifest fields (absent for dense layers and for
/// manifests from pre-spatial exporters).
#[derive(Debug, Clone, PartialEq)]
pub struct ConvMeta {
    pub ksize: usize,
    pub stride: usize,
    pub padding: Padding,
    /// Feature groups (== cin for depthwise).
    pub groups: usize,
    /// Input feature-map height/width (NHWC).
    pub in_h: usize,
    pub in_w: usize,
}

/// One compute layer — mirrors `LayerSpec.to_json()` in python/compile/core.py.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDesc {
    pub name: String,
    /// conv | dwconv | dense
    pub kind: String,
    pub macs: u64,
    pub cin: usize,
    pub cout: usize,
    /// Weight quantizer name (per-output-channel pruning gates).
    pub weight_q: String,
    /// Input-activation quantizer name.
    pub act_q: String,
    /// B.2.3: input feeds from a residual join — not input-prunable.
    pub residual_input: bool,
    /// Spatial metadata for conv/dwconv layers; `None` for dense
    /// layers and for manifests written before the schema gained
    /// spatial fields (those lower onto the legacy flattened path).
    pub conv: Option<ConvMeta>,
    /// Interstitial train-graph ops between the previous layer and
    /// this one (`"maxpool2"` | `"gap"` | `"flatten"`), recorded by
    /// the exporter (manifest `pre` field). Empty for pre-schema
    /// manifests — the engine then infers the op from shapes.
    pub pre_ops: Vec<String>,
}

/// Model preset selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Small,
    Paper,
}

/// Build the descriptor table for a model.
pub fn descriptor(model: &str, preset: Preset) -> Result<Vec<LayerDesc>> {
    match model {
        "lenet5" => Ok(lenet5(preset)),
        "vgg7" => Ok(vgg7(preset)),
        "resnet18" => Ok(resnet18(preset)),
        "mobilenetv2" => Ok(mobilenetv2(preset)),
        _ => bail!("unknown model {model:?}"),
    }
}

/// Builder mirroring `python/compile/layers.py` MAC bookkeeping.
struct Builder {
    h: usize,
    w: usize,
    c: usize,
    layers: Vec<LayerDesc>,
    /// Interstitial ops recorded since the last layer (mirrors
    /// `Context.note_op`); drained into the next layer's `pre_ops`.
    pending: Vec<String>,
}

impl Builder {
    fn new(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, layers: Vec::new(), pending: Vec::new() }
    }

    fn out_hw(&self, stride: usize) -> (usize, usize) {
        // SAME padding: ceil division
        (self.h.div_ceil(stride), self.w.div_ceil(stride))
    }

    fn conv(&mut self, name: &str, cout: usize, k: usize, stride: usize,
            groups: usize, quant_in: bool, in_q: Option<String>,
            residual_input: bool) {
        let (ho, wo) = self.out_hw(stride);
        let macs =
            (ho * wo * cout * (self.c / groups) * k * k) as u64;
        let act_q = if quant_in {
            format!("{name}.in")
        } else {
            in_q.expect("non-quantizing conv needs in_q")
        };
        self.layers.push(LayerDesc {
            name: name.into(),
            kind: if groups == self.c { "dwconv" } else { "conv" }.into(),
            macs,
            cin: self.c,
            cout,
            weight_q: format!("{name}.w"),
            act_q,
            residual_input,
            conv: Some(ConvMeta {
                ksize: k,
                stride,
                padding: Padding::Same,
                groups,
                in_h: self.h,
                in_w: self.w,
            }),
            pre_ops: std::mem::take(&mut self.pending),
        });
        self.h = ho;
        self.w = wo;
        self.c = cout;
    }

    fn pool2(&mut self) {
        self.h /= 2;
        self.w /= 2;
        self.pending.push("maxpool2".into());
    }

    fn gap(&mut self) {
        self.pending.push("gap".into());
    }

    fn flatten(&mut self) {
        self.pending.push("flatten".into());
    }

    fn dense(&mut self, name: &str, din: usize, dout: usize) {
        self.layers.push(LayerDesc {
            name: name.into(),
            kind: "dense".into(),
            macs: (din * dout) as u64,
            cin: din,
            cout: dout,
            weight_q: format!("{name}.w"),
            act_q: format!("{name}.in"),
            residual_input: false,
            conv: None,
            pre_ops: std::mem::take(&mut self.pending),
        });
    }

    fn spatial(&self) -> usize {
        self.h * self.w
    }
}

fn lenet5(preset: Preset) -> Vec<LayerDesc> {
    let (hw, c1, c2, fc, k, classes) = match preset {
        Preset::Small => (16, 8, 16, 64, 5, 10),
        Preset::Paper => (28, 32, 64, 512, 5, 10),
    };
    let mut b = Builder::new(hw, hw, 1);
    b.conv("conv1", c1, k, 1, 1, true, None, false);
    b.pool2();
    b.conv("conv2", c2, k, 1, 1, true, None, false);
    b.pool2();
    b.flatten();
    let din = b.spatial() * b.c;
    b.dense("fc1", din, fc);
    b.dense("fc2", fc, classes);
    b.layers
}

fn vgg7(preset: Preset) -> Vec<LayerDesc> {
    let (hw, widths, fc, classes): (usize, [usize; 3], usize, usize) =
        match preset {
            Preset::Small => (16, [16, 32, 64], 128, 10),
            Preset::Paper => (32, [128, 256, 512], 1024, 10),
        };
    let mut b = Builder::new(hw, hw, 3);
    for (stage, w) in widths.iter().enumerate() {
        for i in 0..2 {
            b.conv(&format!("conv{}_{}", stage + 1, i + 1), *w, 3, 1, 1,
                   true, None, false);
        }
        b.pool2();
    }
    b.flatten();
    let din = b.spatial() * b.c;
    b.dense("fc1", din, fc);
    b.dense("fc2", fc, classes);
    b.layers
}

fn resnet18(preset: Preset) -> Vec<LayerDesc> {
    let (hw, widths, stem_k, stem_s, stem_pool, classes): (
        usize, [usize; 4], usize, usize, bool, usize,
    ) = match preset {
        Preset::Small => (24, [8, 16, 32, 64], 3, 1, false, 10),
        Preset::Paper => (224, [64, 128, 256, 512], 7, 2, true, 1000),
    };
    let mut b = Builder::new(hw, hw, 3);
    b.conv("stem", widths[0], stem_k, stem_s, 1, true, None, false);
    if stem_pool {
        b.pool2();
    }
    for (stage, w) in widths.iter().enumerate() {
        for blk in 0..2usize {
            let name = format!("s{}b{}", stage + 1, blk + 1);
            let stride = if stage > 0 && blk == 0 { 2 } else { 1 };
            let need_ds = stride != 1 || b.c != *w;
            let cin = b.c;
            let (h0, w0) = (b.h, b.w);
            b.conv(&format!("{name}.conv1"), *w, 3, stride, 1, true, None,
                   true);
            b.conv(&format!("{name}.conv2"), *w, 3, 1, 1, true, None,
                   false);
            if need_ds {
                // downsample shares conv1's input quantizer (B.2.4)
                let (ho, wo) =
                    (h0.div_ceil(stride), w0.div_ceil(stride));
                b.layers.push(LayerDesc {
                    name: format!("{name}.ds"),
                    kind: "conv".into(),
                    macs: (ho * wo * *w * cin) as u64,
                    cin,
                    cout: *w,
                    weight_q: format!("{name}.ds.w"),
                    act_q: format!("{name}.conv1.in"),
                    residual_input: true,
                    conv: Some(ConvMeta {
                        ksize: 1,
                        stride,
                        padding: Padding::Same,
                        groups: 1,
                        in_h: h0,
                        in_w: w0,
                    }),
                    // branch input: no interstitial op of its own
                    pre_ops: Vec::new(),
                });
            }
        }
    }
    b.gap();
    b.dense("fc", widths[3], classes);
    b.layers
}

fn mobilenetv2(preset: Preset) -> Vec<LayerDesc> {
    // (cout, stride, expansion, repeats)
    let (hw, stem, stem_stride, blocks, head, classes): (
        usize, usize, usize, Vec<(usize, usize, usize, usize)>, usize,
        usize,
    ) = match preset {
        Preset::Small => (
            24, 8, 1,
            vec![(12, 1, 2, 1), (16, 2, 4, 2), (24, 2, 4, 2),
                 (32, 2, 4, 1)],
            64, 10,
        ),
        Preset::Paper => (
            224, 32, 2, // stock MobileNetV2: stride-2 stem at 224px
            vec![(16, 1, 1, 1), (24, 2, 6, 2), (32, 2, 6, 3),
                 (64, 2, 6, 4), (96, 1, 6, 3), (160, 2, 6, 3),
                 (320, 1, 6, 1)],
            1280, 1000,
        ),
    };
    let mut b = Builder::new(hw, hw, 3);
    b.conv("stem", stem, 3, stem_stride, 1, true, None, false);
    let mut i = 0;
    for (cout, stride, expand, repeats) in blocks {
        for r in 0..repeats {
            i += 1;
            let name = format!("b{i}");
            let s = if r == 0 { stride } else { 1 };
            let mid = b.c * expand;
            if expand != 1 {
                b.conv(&format!("{name}.expand"), mid, 1, 1, 1, true,
                       None, false);
            }
            b.conv(&format!("{name}.dw"), mid, 3, s, mid, true, None,
                   false);
            b.conv(&format!("{name}.project"), cout, 1, 1, 1, true, None,
                   false);
        }
    }
    b.conv("head", head, 1, 1, 1, true, None, false);
    b.gap();
    b.dense("fc", head, classes);
    b.layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_small_macs() {
        let l = lenet5(Preset::Small);
        assert_eq!(l[0].macs, 16 * 16 * 8 * 25);
        assert_eq!(l[1].macs, 8 * 8 * 16 * 8 * 25);
        assert_eq!(l[2].macs, 4 * 4 * 16 * 64);
        assert_eq!(l[3].macs, 64 * 10);
    }

    #[test]
    fn paper_scale_resnet18_macs_plausible() {
        // Stock ResNet18 @224 is ~1.8 GMAC.
        let total: u64 = resnet18(Preset::Paper).iter()
            .map(|l| l.macs).sum();
        assert!(total > 1_500_000_000 && total < 2_200_000_000,
                "total={total}");
    }

    #[test]
    fn paper_scale_mobilenetv2_macs_plausible() {
        // Stock MobileNetV2 @224 is ~0.3 GMAC.
        let total: u64 = mobilenetv2(Preset::Paper).iter()
            .map(|l| l.macs).sum();
        assert!(total > 200_000_000 && total < 450_000_000,
                "total={total}");
    }

    #[test]
    fn resnet_downsample_shares_quantizer() {
        let l = resnet18(Preset::Small);
        let ds: Vec<_> =
            l.iter().filter(|x| x.name.ends_with(".ds")).collect();
        assert_eq!(ds.len(), 3);
        for d in ds {
            assert!(d.act_q.ends_with(".conv1.in"));
            assert!(d.residual_input);
        }
    }

    #[test]
    fn dwconv_marked() {
        let l = mobilenetv2(Preset::Small);
        assert!(l.iter().any(|x| x.kind == "dwconv"));
    }

    #[test]
    fn conv_meta_tracks_shapes_and_groups() {
        let l = lenet5(Preset::Small);
        let c1 = l[0].conv.as_ref().unwrap();
        assert_eq!((c1.in_h, c1.in_w, c1.ksize, c1.stride, c1.groups),
                   (16, 16, 5, 1, 1));
        // conv2 sees the post-pool feature map
        let c2 = l[1].conv.as_ref().unwrap();
        assert_eq!((c2.in_h, c2.in_w), (8, 8));
        assert!(l[2].conv.is_none() && l[3].conv.is_none());
        // depthwise layers carry groups == cin
        for d in mobilenetv2(Preset::Small) {
            if d.kind == "dwconv" {
                let m = d.conv.as_ref().unwrap();
                assert_eq!(m.groups, d.cin, "{}", d.name);
            }
        }
        // resnet downsample is a 1x1 conv over the block input map
        let r = resnet18(Preset::Small);
        let ds = r.iter().find(|x| x.name == "s2b1.ds").unwrap();
        let m = ds.conv.as_ref().unwrap();
        assert_eq!((m.ksize, m.stride, m.in_h, m.in_w), (1, 2, 24, 24));
    }

    #[test]
    fn interstitial_ops_recorded_per_layer() {
        let l = lenet5(Preset::Small);
        assert!(l[0].pre_ops.is_empty());
        assert_eq!(l[1].pre_ops, vec!["maxpool2"]);
        assert_eq!(l[2].pre_ops, vec!["maxpool2", "flatten"]);
        assert!(l[3].pre_ops.is_empty());
        // resnet/mobilenet classifier heads record the global pool
        let r = resnet18(Preset::Small);
        assert_eq!(r.last().unwrap().pre_ops, vec!["gap"]);
        // paper stem pool lands on the first block conv
        let rp = resnet18(Preset::Paper);
        let s1 = rp.iter().find(|x| x.name == "s1b1.conv1").unwrap();
        assert_eq!(s1.pre_ops, vec!["maxpool2"]);
        let m = mobilenetv2(Preset::Small);
        assert_eq!(m.last().unwrap().pre_ops, vec!["gap"]);
        // branch convs carry no interstitial op of their own
        let ds = r.iter().find(|x| x.name == "s2b1.ds").unwrap();
        assert!(ds.pre_ops.is_empty());
    }

    #[test]
    fn padding_parses_and_labels() {
        assert_eq!(Padding::parse("SAME").unwrap(), Padding::Same);
        assert_eq!(Padding::parse("VALID").unwrap(), Padding::Valid);
        assert!(Padding::parse("same").is_err());
        assert_eq!(Padding::Same.label(), "SAME");
        assert_eq!(Padding::Valid.label(), "VALID");
    }

    #[test]
    fn unknown_model_errors() {
        assert!(descriptor("alexnet", Preset::Small).is_err());
    }
}
