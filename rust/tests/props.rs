//! Cross-module property tests (hand-rolled harness, util::prop).
//!
//! Invariants spanning quant/bops/gates/data that unit tests inside the
//! modules don't cover.

use std::collections::BTreeMap;

use bayesian_bits::bops::{BopCounter, QuantState};
use bayesian_bits::data::synth::{generate, DatasetSpec};
use bayesian_bits::engine::kernels::{conv2d_codes, conv2d_codes_simd,
                                     conv2d_panels, dot_codes,
                                     dot_codes_simd, dwconv2d_codes,
                                     dwconv2d_codes_simd,
                                     dwconv2d_panels, extract_patch,
                                     low_bit_pair, matmul_packed,
                                     matmul_packed_simd, matmul_panels,
                                     LANES};
use bayesian_bits::engine::pack::{code_range, PackedMatrix,
                                  PanelMatrix, KC, MR};
use bayesian_bits::engine::SpatialPlan;
use bayesian_bits::models::{descriptor, Padding, Preset};
use bayesian_bits::quant::gates::{
    prob_active, test_time_gate, GateView, HardConcrete,
};
use bayesian_bits::quant::grid::{
    bb_quantize_host, quantize_codes_host, quantize_fixed_host,
    step_sizes, QuantConfig,
};
use bayesian_bits::quant::LEVELS;
use bayesian_bits::util::json::Json;
use bayesian_bits::util::prop::{check, Gen, PropResult};

#[test]
fn prop_step_size_recursion_matches_closed_form() {
    check("step_size_closed_form", 300, |g: &mut Gen| {
        let beta = g.f32_in(0.01, 100.0);
        let signed = g.bool();
        let cfg = QuantConfig::new(signed, &[2, 4, 8, 16, 32]);
        let sizes = step_sizes(beta, &cfg);
        let span = if signed { 2.0 * beta } else { beta };
        for (s, b) in sizes.iter().zip([2u32, 4, 8, 16, 32]) {
            let want = span as f64 / (2f64.powi(b as i32) - 1.0);
            if ((*s as f64) - want).abs() > want * 1e-4 {
                return PropResult::Fail(format!(
                    "beta={beta} b={b}: {s} vs {want}"));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_gated_chain_equals_fixed_quantizer() {
    check("chain_equals_fixed", 150, |g: &mut Gen| {
        let beta = g.f32_in(0.2, 6.0);
        let signed = g.bool();
        let n = g.usize_in(1, 64);
        let x: Vec<f32> = (0..n)
            .map(|_| {
                let v = g.f32_in(-2.0 * beta, 2.0 * beta);
                if signed { v } else { v.abs() }
            })
            .collect();
        let k = g.usize_in(0, 4);
        let mut zh = [0.0f32; 4];
        for z in zh.iter_mut().take(k) {
            *z = 1.0;
        }
        let bits = [2u32, 4, 8, 16, 32][k];
        let cfg = QuantConfig::new(signed, &[2, 4, 8, 16, 32]);
        let got = bb_quantize_host(&x, 1, beta, &[1.0], &zh, &cfg);
        let want = quantize_fixed_host(&x, beta, bits, signed);
        for (a, b) in got.iter().zip(&want) {
            if (a - b).abs() > 2e-4 * beta.max(1.0) {
                return PropResult::Fail(format!(
                    "bits={bits} beta={beta}: {a} vs {b}"));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_effective_bits_consistent_with_expected_bits() {
    // For binary slot vectors, the soft expectation equals the hard
    // effective bit width (pruning -> 0).
    check("hard_vs_soft_bits", 300, |g: &mut Gen| {
        let channels = g.usize_in(1, 8);
        let view = GateView { channels, levels: vec![2, 4, 8, 16, 32] };
        let n = view.n_slots();
        let z: Vec<f32> = (0..n)
            .map(|_| if g.bool() { 1.0 } else { 0.0 })
            .collect();
        // make channel block all-equal so "any channel" == "mean prob"
        let all_on = z[0] > 0.5;
        let mut z = z;
        for c in 0..channels {
            z[c] = if all_on { 1.0 } else { 0.0 };
        }
        let hard = view.effective_bits(&z) as f64;
        // chain-consistent copy for the expectation
        let mut zc = z.clone();
        let mut open = all_on;
        for i in 0..4 {
            if !open {
                zc[channels + i] = 0.0;
            }
            open = open && zc[channels + i] > 0.5;
        }
        let soft = view.expected_bits(&zc);
        PropResult::check((hard - soft).abs() < 1e-9, || {
            format!("hard {hard} vs soft {soft} (z={zc:?})")
        })
    });
}

#[test]
fn prop_threshold_matches_prob_mass() {
    check("threshold_vs_prob", 500, |g: &mut Gen| {
        let phi = g.f64_in(-12.0, 12.0);
        let open = test_time_gate(phi);
        let p_zero = 1.0 - prob_active(phi);
        PropResult::check(open == (p_zero < 0.34),
                          || format!("phi={phi}"))
    });
}

#[test]
fn prop_hard_concrete_sample_bounds_and_monotonicity() {
    check("hc_sample", 300, |g: &mut Gen| {
        let phi = g.f64_in(-8.0, 8.0);
        let u = g.f64_in(1e-6, 1.0 - 1e-6);
        let hc = HardConcrete::new(phi);
        let z = hc.sample(u);
        if !(0.0..=1.0).contains(&z) {
            return PropResult::Fail(format!("z={z}"));
        }
        // monotone in both u and phi
        let z_up = HardConcrete::new(phi + 1.0).sample(u);
        let z_uu = hc.sample((u + 0.1).min(1.0 - 1e-9));
        PropResult::check(z_up >= z && z_uu >= z, || {
            format!("phi={phi} u={u}: {z} {z_up} {z_uu}")
        })
    });
}

#[test]
fn prop_bops_scale_invariance() {
    // Relative BOPs are invariant to uniformly scaling all MACs.
    check("bops_scale_invariant", 100, |g: &mut Gen| {
        for model in ["lenet5", "vgg7", "resnet18"] {
            let layers = descriptor(model, Preset::Small).unwrap();
            let scale = g.usize_in(2, 50) as u64;
            let scaled: Vec<_> = layers
                .iter()
                .cloned()
                .map(|mut l| {
                    l.macs *= scale;
                    l
                })
                .collect();
            let c1 = BopCounter::new(layers);
            let c2 = BopCounter::new(scaled);
            let w = *g.choose(&[2u32, 4, 8, 16]);
            let s1 = c1.fixed_states(w, w);
            let s2 = c2.fixed_states(w, w);
            let (r1, r2) =
                (c1.relative_bops_pct(&s1), c2.relative_bops_pct(&s2));
            if (r1 - r2).abs() > 1e-9 {
                return PropResult::Fail(format!("{model}: {r1} vs {r2}"));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_pruning_reduces_bops() {
    check("pruning_reduces_bops", 150, |g: &mut Gen| {
        let layers = descriptor("vgg7", Preset::Small).unwrap();
        let c = BopCounter::new(layers.clone());
        let mut states: BTreeMap<String, QuantState> =
            c.fixed_states(8, 8);
        let full = c.bops(&states);
        // prune a random layer's outputs by a random ratio
        let li = g.usize_in(0, layers.len() - 1);
        let keep = g.f64_in(0.0, 1.0);
        states.insert(layers[li].weight_q.clone(),
                      QuantState { bits: 8, keep_ratio: keep });
        let pruned = c.bops(&states);
        PropResult::check(pruned <= full + 1e-6, || {
            format!("layer {li} keep {keep}: {pruned} > {full}")
        })
    });
}

#[test]
fn prop_dataset_deterministic_and_finite() {
    check("dataset_determinism", 20, |g: &mut Gen| {
        let name = *g.choose(&["mnist_like", "cifar_like",
                               "imagenet_like"]);
        let c = if name == "mnist_like" { 1 } else { 3 };
        let seed = g.rng.next_u64() % 1000;
        let spec = DatasetSpec {
            name: name.into(),
            input: (8, 8, c),
            classes: 4,
            train: 32,
            test: 8,
        };
        let a = generate(&spec, seed, false).unwrap();
        let b = generate(&spec, seed, false).unwrap();
        if a.images != b.images {
            return PropResult::Fail("non-deterministic".into());
        }
        PropResult::check(a.images.iter().all(|v| v.is_finite()),
                          || "non-finite pixels".into())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_numbers_and_strings() {
    check("json_roundtrip", 300, |g: &mut Gen| {
        let n = g.usize_in(0, 12);
        let mut fields = Vec::new();
        for i in 0..n {
            let v = match g.usize_in(0, 3) {
                0 => Json::Num(g.f64_in(-1e9, 1e9)),
                1 => Json::Bool(g.bool()),
                2 => Json::Str(format!("s{}\n\"{}", i,
                                       g.usize_in(0, 100))),
                _ => Json::Arr(vec![Json::Num(g.f64_in(-5.0, 5.0))]),
            };
            fields.push((format!("k{i}"), v));
        }
        let obj = Json::Obj(fields.into_iter().collect());
        let text = obj.to_string();
        match Json::parse(&text) {
            Ok(back) if back == obj => PropResult::Pass,
            Ok(_) => PropResult::Fail(format!("mismatch: {text}")),
            Err(e) => PropResult::Fail(format!("parse error {e}: {text}")),
        }
    });
}

#[test]
fn prop_quantize_pack_unpack_exact_for_every_level() {
    // The engine's storage contract: quantizing to grid codes, bit-
    // packing, and unpacking is lossless at every width in the chain,
    // and `step * code` reproduces `quantize_fixed_host` bit-exactly.
    check("quantize_pack_unpack", 120, |g: &mut Gen| {
        let bits = *g.choose(&LEVELS);
        let signed = g.bool();
        let beta = g.f32_in(0.1, 8.0);
        let rows = g.usize_in(1, 6);
        let cols = g.usize_in(1, 40);
        let x: Vec<f32> = (0..rows * cols)
            .map(|_| {
                let v = g.f32_in(-2.0 * beta, 2.0 * beta);
                if signed { v } else { v.abs() }
            })
            .collect();
        let (step, codes) = quantize_codes_host(&x, beta, bits, signed);
        let (lo, hi) = code_range(bits, signed);
        if codes.iter().any(|q| *q < lo || *q > hi) {
            return PropResult::Fail(format!(
                "bits={bits} signed={signed}: code outside [{lo},{hi}]"));
        }
        let packed = match PackedMatrix::pack(&codes, rows, cols, bits,
                                              signed) {
            Ok(p) => p,
            Err(e) => return PropResult::Fail(format!("pack: {e}")),
        };
        if packed.unpack() != codes {
            return PropResult::Fail(format!(
                "bits={bits} signed={signed}: pack/unpack not lossless"));
        }
        let fixed = quantize_fixed_host(&x, beta, bits, signed);
        for (q, w) in codes.iter().zip(&fixed) {
            if step * *q as f32 != *w {
                return PropResult::Fail(format!(
                    "bits={bits}: step*{q} != {w}"));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_packed_dot_matches_exact_i64() {
    // Both accumulator paths (blocked i32 and direct i64) agree with
    // exact integer arithmetic on in-range code vectors.
    check("packed_dot_exact", 150, |g: &mut Gen| {
        let w_bits = *g.choose(&[2u32, 4, 8, 16]);
        let a_bits = *g.choose(&[2u32, 4, 8, 16]);
        let n = g.usize_in(1, 300);
        let (wlo, whi) = code_range(w_bits, true);
        let w: Vec<i32> = (0..n)
            .map(|_| g.usize_in(0, (whi - wlo) as usize) as i32
                + wlo as i32)
            .collect();
        let amax = (1u32 << a_bits) - 1;
        let a: Vec<i32> = (0..n)
            .map(|_| g.usize_in(0, amax as usize) as i32)
            .collect();
        let want: i64 =
            w.iter().zip(&a).map(|(x, y)| *x as i64 * *y as i64).sum();
        let got = dot_codes(&w, &a, low_bit_pair(w_bits, a_bits));
        PropResult::check(got == want,
                          || format!("w{w_bits}a{a_bits} n={n}: \
                                      {got} vs {want}"))
    });
}

#[test]
fn prop_im2col_patch_touch_counts_match_window_coverage() {
    // Every input element must be read exactly as many times as the
    // number of (output pixel, tap) windows covering it — the count
    // implied by kernel size, stride, and padding. Padding taps read
    // zero and touch nothing.
    check("im2col_touch_counts", 120, |g: &mut Gen| {
        let in_h = g.usize_in(1, 8);
        let in_w = g.usize_in(1, 8);
        let groups = *g.choose(&[1usize, 2]);
        let cg = g.usize_in(1, 3);
        let in_c = groups * cg;
        let k = g.usize_in(1, 3);
        let stride = g.usize_in(1, 2);
        let padding =
            if g.bool() { Padding::Same } else { Padding::Valid };
        let sp = match SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                        padding, groups) {
            Ok(sp) => sp,
            // VALID kernel larger than the map: nothing to check
            Err(_) => return PropResult::Pass,
        };
        // x[i] = i + 1 so padding zeros are distinguishable
        let x: Vec<i32> =
            (0..sp.in_len() as i32).map(|i| i + 1).collect();
        let mut got = vec![0u32; sp.in_len()];
        let mut patch = vec![0i32; sp.patch_len()];
        for gi in 0..groups {
            for oh in 0..sp.out_h {
                for ow in 0..sp.out_w {
                    extract_patch(&x, &sp, gi, oh, ow, &mut patch);
                    for v in &patch[..sp.patch_len()] {
                        if *v > 0 {
                            got[(*v - 1) as usize] += 1;
                        }
                    }
                }
            }
        }
        // expected coverage from enumerating the windows directly
        let mut want = vec![0u32; sp.in_len()];
        for oh in 0..sp.out_h {
            for ow in 0..sp.out_w {
                for kh in 0..k {
                    for kw in 0..k {
                        let ih = (oh * stride + kh) as isize
                            - sp.pad_top as isize;
                        let iw = (ow * stride + kw) as isize
                            - sp.pad_left as isize;
                        if ih < 0 || iw < 0 || ih as usize >= in_h
                            || iw as usize >= in_w
                        {
                            continue;
                        }
                        for c in 0..in_c {
                            want[(ih as usize * in_w + iw as usize)
                                * in_c + c] += 1;
                        }
                    }
                }
            }
        }
        PropResult::check(got == want, || {
            format!("{sp:?}: got {got:?} want {want:?}")
        })
    });
}

#[test]
fn prop_packed_roundtrip_odd_rows_and_lanes_after_pruning() {
    // The engine's pruned-row storage: packing an arbitrary surviving
    // subset of channels at odd `cout` and non-lane-multiple row
    // lengths is lossless, both wholesale and row by row.
    check("packed_odd_shapes", 150, |g: &mut Gen| {
        let bits = *g.choose(&[2u32, 4, 8, 16, 32]);
        let signed = g.bool();
        let cout = g.usize_in(1, 9);
        // odd, so never a multiple of the 64/bits lane count
        let cols = 2 * g.usize_in(0, 36) + 1;
        let (lo, hi) = code_range(bits, signed);
        let span = (hi - lo) as u64 + 1;
        let dense: Vec<i64> = (0..cout * cols)
            .map(|_| lo + (g.rng.next_u64() % span) as i64)
            .collect();
        // prune a random channel subset (>= 1 survivor)
        let mut kept: Vec<usize> =
            (0..cout).filter(|_| g.bool()).collect();
        if kept.is_empty() {
            kept.push(g.usize_in(0, cout - 1));
        }
        let codes: Vec<i64> = kept
            .iter()
            .flat_map(|r| dense[r * cols..(r + 1) * cols].iter().copied())
            .collect();
        let p = match PackedMatrix::pack(&codes, kept.len(), cols, bits,
                                         signed) {
            Ok(p) => p,
            Err(e) => return PropResult::Fail(format!("pack: {e}")),
        };
        if p.unpack() != codes {
            return PropResult::Fail(format!(
                "bits={bits} signed={signed} rows={} cols={cols}: \
                 unpack not lossless", kept.len()));
        }
        // per-row decode (the GEMM/conv decode unit); i32 decode only
        // covers signed or <= 16-bit unsigned fields
        if signed || bits <= 16 {
            let mut row = vec![0i32; cols];
            for (ri, r) in kept.iter().enumerate() {
                p.unpack_row_into(ri, &mut row);
                for c in 0..cols {
                    if row[c] as i64 != dense[r * cols + c] {
                        return PropResult::Fail(format!(
                            "bits={bits} row {ri} col {c}: {} vs {}",
                            row[c], dense[r * cols + c]));
                    }
                }
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_simd_dot_bit_exact_across_remainder_lane_widths() {
    // Every width in 1..=3*LANES+1 against the exact i64 oracle and
    // the scalar kernel: tail-handling bugs cannot hide behind
    // lane-multiple shapes.
    check("simd_dot_remainder_lanes", 300, |g: &mut Gen| {
        let n = g.usize_in(1, 3 * LANES + 1);
        let w_bits = *g.choose(&[2u32, 4, 8, 16]);
        let a_bits = *g.choose(&[2u32, 4, 8, 16]);
        let (wlo, whi) = code_range(w_bits, true);
        let w: Vec<i32> = (0..n)
            .map(|_| g.usize_in(0, (whi - wlo) as usize) as i32
                + wlo as i32)
            .collect();
        let amax = (1u64 << a_bits) - 1;
        let a: Vec<i32> = (0..n)
            .map(|_| g.usize_in(0, amax as usize) as i32)
            .collect();
        let want: i64 =
            w.iter().zip(&a).map(|(x, y)| *x as i64 * *y as i64).sum();
        let low = low_bit_pair(w_bits, a_bits);
        if dot_codes_simd(&w, &a, low) != want
            || dot_codes(&w, &a, low) != want
        {
            return PropResult::Fail(format!(
                "w{w_bits}a{a_bits} n={n}: simd/scalar vs exact"));
        }
        // the widening path is exact at every width; the blocked-i32
        // path additionally wherever it is eligible
        PropResult::check(
            dot_codes_simd(&w, &a, false) == want
                && (!low || dot_codes_simd(&w, &a, true) == want),
            || format!("n={n}: accumulator paths disagree"))
    });
}

#[test]
fn prop_simd_matmul_bit_exact_at_odd_widths() {
    // GEMM row widths straddling the lane width (never a multiple by
    // construction when odd), pruned row counts, small batches.
    check("simd_matmul_odd_widths", 120, |g: &mut Gen| {
        let bits = *g.choose(&[2u32, 4, 8, 16]);
        let a_bits = *g.choose(&[4u32, 8, 16]);
        let rows = g.usize_in(1, 6);
        let cols = g.usize_in(1, 3 * LANES + 1);
        let n = g.usize_in(1, 3);
        let (lo, hi) = code_range(bits, true);
        let span = (hi - lo) as u64 + 1;
        let codes: Vec<i64> = (0..rows * cols)
            .map(|_| lo + (g.rng.next_u64() % span) as i64)
            .collect();
        let p = match PackedMatrix::pack(&codes, rows, cols, bits,
                                         true) {
            Ok(p) => p,
            Err(e) => return PropResult::Fail(format!("pack: {e}")),
        };
        let amax = (1u64 << a_bits) - 1;
        let acts: Vec<i32> = (0..n * cols)
            .map(|_| (g.rng.next_u64() % (amax + 1)) as i32)
            .collect();
        let mut scratch = vec![0i32; cols];
        let mut ys = vec![0i64; n * rows];
        let mut yv = ys.clone();
        matmul_packed(&p, &acts, n, a_bits, &mut scratch, &mut ys);
        matmul_packed_simd(&p, &acts, n, a_bits, &mut scratch,
                           &mut yv);
        PropResult::check(ys == yv, || format!(
            "w{bits}a{a_bits} {rows}x{cols} n={n}"))
    });
}

#[test]
fn prop_simd_conv_bit_exact_on_odd_patches_and_groups() {
    // Odd im2col row lengths (odd cg x odd k*k) and group counts that
    // do not divide the lane width, with pruned kept subsets.
    check("simd_conv_odd_patches", 100, |g: &mut Gen| {
        let k = *g.choose(&[1usize, 2, 3]);
        let groups = *g.choose(&[1usize, 2, 3, 5]);
        let cg = 2 * g.usize_in(0, 2) + 1; // odd per-group width
        let in_c = groups * cg;
        let in_h = g.usize_in(k, 6);
        let in_w = g.usize_in(k, 6);
        let stride = g.usize_in(1, 2);
        let padding =
            if g.bool() { Padding::Same } else { Padding::Valid };
        let sp = match SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                        padding, groups) {
            Ok(sp) => sp,
            Err(_) => return PropResult::Pass,
        };
        let plen = sp.patch_len();
        let cpg = g.usize_in(1, 3);
        let cout = groups * cpg;
        let mut kept: Vec<u32> =
            (0..cout as u32).filter(|_| g.bool()).collect();
        if kept.is_empty() {
            kept.push(0);
        }
        let w: Vec<i32> = (0..kept.len() * plen)
            .map(|_| g.usize_in(0, 254) as i32 - 127)
            .collect();
        let n = g.usize_in(1, 2);
        let x: Vec<i32> = (0..n * sp.in_len())
            .map(|_| g.usize_in(0, 255) as i32)
            .collect();
        let low = g.bool();
        let mut patch = vec![0i32; plen];
        let mut ys = vec![0i64; n * sp.out_pixels() * kept.len()];
        let mut yv = ys.clone();
        conv2d_codes(&w, &kept, cpg, &sp, &x, n, low, &mut patch,
                     &mut ys);
        conv2d_codes_simd(&w, &kept, cpg, &sp, &x, n, low, &mut patch,
                          &mut yv);
        PropResult::check(ys == yv, || format!(
            "k{k} g{groups} cg{cg} {in_h}x{in_w} s{stride} low={low}"))
    });
}

#[test]
fn prop_simd_dwconv_bit_exact_on_non_lane_channel_counts() {
    // Depthwise group counts (== channels) around and between lane
    // multiples, pruned kept subsets, both accumulator paths.
    check("simd_dwconv_lanes", 100, |g: &mut Gen| {
        let c = g.usize_in(1, 2 * LANES + 3);
        let k = *g.choose(&[1usize, 3]);
        let hw = g.usize_in(k.max(2), 6);
        let stride = g.usize_in(1, 2);
        let sp = match SpatialPlan::new(hw, hw, c, k, stride,
                                        Padding::Same, c) {
            Ok(sp) => sp,
            Err(_) => return PropResult::Pass,
        };
        let mut kept: Vec<u32> =
            (0..c as u32).filter(|_| g.bool()).collect();
        if kept.is_empty() {
            kept.push((c - 1) as u32);
        }
        let plen = k * k;
        let w: Vec<i32> = (0..kept.len() * plen)
            .map(|_| g.usize_in(0, 254) as i32 - 127)
            .collect();
        let n = g.usize_in(1, 2);
        let x: Vec<i32> = (0..n * sp.in_len())
            .map(|_| g.usize_in(0, 255) as i32)
            .collect();
        let low = g.bool();
        let mut ys = vec![0i64; n * sp.out_pixels() * kept.len()];
        let mut yv = ys.clone();
        dwconv2d_codes(&w, &kept, 1, &sp, &x, n, low, &mut ys);
        dwconv2d_codes_simd(&w, &kept, 1, &sp, &x, n, low, &mut yv);
        PropResult::check(ys == yv, || format!(
            "c{c} k{k} hw{hw} s{stride} low={low} kept={}", kept.len()))
    });
}

#[test]
fn prop_blocked_matmul_bit_exact_at_remainder_panel_shapes() {
    // Panel-height remainders (rows 1..=3*MR+1), depths on both sides
    // of the KC boundary that KC never divides (odd offsets), and
    // thread counts exceeding the row-block count (empty shards):
    // the packed scalar kernel is the oracle.
    check("blocked_matmul_remainders", 120, |g: &mut Gen| {
        let bits = *g.choose(&[2u32, 4, 8, 16]);
        let a_bits = *g.choose(&[4u32, 8, 16]);
        let rows = g.usize_in(1, 3 * MR + 1);
        let cols = match g.usize_in(0, 2) {
            0 => g.usize_in(1, 3 * LANES + 1),
            1 => KC - g.usize_in(0, 3),
            _ => KC + 2 * g.usize_in(0, KC / 2) + 1,
        };
        let n = g.usize_in(1, 3);
        let threads = g.usize_in(1, 5);
        let (lo, hi) = code_range(bits, true);
        let span = (hi - lo) as u64 + 1;
        let codes: Vec<i64> = (0..rows * cols)
            .map(|_| lo + (g.rng.next_u64() % span) as i64)
            .collect();
        let p = match PackedMatrix::pack(&codes, rows, cols, bits,
                                         true) {
            Ok(p) => p,
            Err(e) => return PropResult::Fail(format!("pack: {e}")),
        };
        let pm = PanelMatrix::from_packed(&p);
        let amax = (1u64 << a_bits) - 1;
        let acts: Vec<i32> = (0..n * cols)
            .map(|_| (g.rng.next_u64() % (amax + 1)) as i32)
            .collect();
        let mut scratch = vec![0i32; cols];
        let mut ys = vec![0i64; n * rows];
        let mut yb = ys.clone();
        matmul_packed(&p, &acts, n, a_bits, &mut scratch, &mut ys);
        matmul_panels(&pm, &acts, n, a_bits, threads, &mut yb);
        PropResult::check(ys == yb, || format!(
            "w{bits}a{a_bits} {rows}x{cols} n={n} t={threads}"))
    });
}

#[test]
fn prop_blocked_conv_bit_exact_on_groups_and_tile_shards() {
    // Patch lengths KC never divides (odd cg x k*k), group counts, and
    // output-pixel tile sharding at every thread count vs the scalar
    // im2col oracle.
    check("blocked_conv_shards", 80, |g: &mut Gen| {
        let k = *g.choose(&[1usize, 2, 3]);
        let groups = *g.choose(&[1usize, 2, 3]);
        let cg = 2 * g.usize_in(0, 2) + 1; // odd per-group width
        let in_c = groups * cg;
        let in_h = g.usize_in(k, 6);
        let in_w = g.usize_in(k, 6);
        let stride = g.usize_in(1, 2);
        let padding =
            if g.bool() { Padding::Same } else { Padding::Valid };
        let sp = match SpatialPlan::new(in_h, in_w, in_c, k, stride,
                                        padding, groups) {
            Ok(sp) => sp,
            Err(_) => return PropResult::Pass,
        };
        let plen = sp.patch_len();
        let cpg = g.usize_in(1, 3);
        let cout = groups * cpg;
        let mut kept: Vec<u32> =
            (0..cout as u32).filter(|_| g.bool()).collect();
        if kept.is_empty() {
            kept.push(0);
        }
        let codes: Vec<i64> = (0..kept.len() * plen)
            .map(|_| g.usize_in(0, 254) as i64 - 127)
            .collect();
        let w: Vec<i32> = codes.iter().map(|v| *v as i32).collect();
        let p = match PackedMatrix::pack(&codes, kept.len(), plen, 8,
                                         true) {
            Ok(p) => p,
            Err(e) => return PropResult::Fail(format!("pack: {e}")),
        };
        let pm = PanelMatrix::from_packed_grouped(
            &p, |r| kept[r] as usize / cpg);
        let n = g.usize_in(1, 2);
        let x: Vec<i32> = (0..n * sp.in_len())
            .map(|_| g.usize_in(0, 255) as i32)
            .collect();
        let mut patch = vec![0i32; plen];
        let mut ys = vec![0i64; n * sp.out_pixels() * kept.len()];
        conv2d_codes(&w, &kept, cpg, &sp, &x, n, true, &mut patch,
                     &mut ys);
        for threads in 1..=4 {
            let mut yb = vec![0i64; ys.len()];
            conv2d_panels(&pm, &kept, cpg, &sp, &x, n, 8, threads,
                          &mut yb);
            if yb != ys {
                return PropResult::Fail(format!(
                    "k{k} g{groups} cg{cg} {in_h}x{in_w} s{stride} \
                     t={threads}"));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_blocked_dwconv_bit_exact_across_shard_boundaries() {
    // Kept-channel counts straddling the shard split: thread counts
    // from 1 to kept+2 produce empty shards, single-channel shards,
    // and remainder shards — all bit-exact vs the scalar oracle.
    check("blocked_dwconv_shards", 80, |g: &mut Gen| {
        let c = g.usize_in(1, 2 * MR + 3);
        let k = *g.choose(&[1usize, 3]);
        let hw = g.usize_in(k.max(2), 6);
        let stride = g.usize_in(1, 2);
        let sp = match SpatialPlan::new(hw, hw, c, k, stride,
                                        Padding::Same, c) {
            Ok(sp) => sp,
            Err(_) => return PropResult::Pass,
        };
        let mut kept: Vec<u32> =
            (0..c as u32).filter(|_| g.bool()).collect();
        if kept.is_empty() {
            kept.push((c - 1) as u32);
        }
        let plen = k * k;
        let codes: Vec<i64> = (0..kept.len() * plen)
            .map(|_| g.usize_in(0, 254) as i64 - 127)
            .collect();
        let w: Vec<i32> = codes.iter().map(|v| *v as i32).collect();
        let p = match PackedMatrix::pack(&codes, kept.len(), plen, 8,
                                         true) {
            Ok(p) => p,
            Err(e) => return PropResult::Fail(format!("pack: {e}")),
        };
        let pm = PanelMatrix::from_packed(&p);
        let n = g.usize_in(1, 2);
        let x: Vec<i32> = (0..n * sp.in_len())
            .map(|_| g.usize_in(0, 255) as i32)
            .collect();
        let mut ys = vec![0i64; n * sp.out_pixels() * kept.len()];
        dwconv2d_codes(&w, &kept, 1, &sp, &x, n, true, &mut ys);
        for threads in 1..=kept.len() + 2 {
            let mut yb = vec![0i64; ys.len()];
            dwconv2d_panels(&pm, &kept, 1, &sp, &x, n, 8, threads,
                            &mut yb);
            if yb != ys {
                return PropResult::Fail(format!(
                    "c{c} k{k} hw{hw} s{stride} kept={} t={threads}",
                    kept.len()));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn prop_lock_fixed_roundtrips_through_effective_bits() {
    check("lock_fixed_roundtrip", 200, |g: &mut Gen| {
        let channels = g.usize_in(1, 16);
        let view = GateView { channels, levels: vec![2, 4, 8, 16, 32] };
        let bits = *g.choose(&[0u32, 2, 4, 8, 16, 32]);
        let (_, val) = view.lock_fixed(bits);
        let got = view.effective_bits(&val);
        PropResult::check(got == bits,
                          || format!("bits {bits} -> {got}"))
    });
}
