//! Integer-engine parity: the fixed-point datapath vs the f32
//! simulated-quant reference and the `bb_quantize_host` oracle, plus
//! the checkpoint -> lower -> serve end-to-end path.
//!
//! These run without AOT artifacts: the engine is a pure host
//! subsystem, so CI always exercises it.

use std::path::Path;
use std::sync::Arc;

use bayesian_bits::coordinator::checkpoint;
use bayesian_bits::engine::lower::{build_plan_single, lower};
use bayesian_bits::engine::serve::{closed_loop, ServeConfig, Server};
use bayesian_bits::engine::{ActSpec, Engine};
use bayesian_bits::quant::grid::{bb_quantize_host, QuantConfig};
use bayesian_bits::runtime::{Manifest, TrainState};
use bayesian_bits::util::json::Json;
use bayesian_bits::util::prop::{check, Gen, PropResult};

#[test]
fn prop_int_path_matches_simulated_f32() {
    check("engine_int_vs_f32", 60, |g: &mut Gen| {
        let in_dim = g.usize_in(1, 96);
        let out_dim = g.usize_in(1, 32);
        let w_bits = *g.choose(&[2u32, 4, 8, 16]);
        let a_bits = *g.choose(&[4u32, 8, 16]);
        let signed_a = g.bool();
        let beta_w = g.f32_in(0.5, 2.0);
        let beta_a = g.f32_in(0.5, 4.0);
        let w: Vec<f32> = (0..in_dim * out_dim)
            .map(|_| g.f32_in(-1.5 * beta_w, 1.5 * beta_w))
            .collect();
        let z2: Vec<f32> = (0..out_dim)
            .map(|_| if g.bool() { 1.0 } else { 0.0 })
            .collect();
        let bias: Vec<f32> =
            (0..out_dim).map(|_| g.f32_in(-0.5, 0.5)).collect();
        let plan = build_plan_single(
            "l", &w, in_dim, out_dim, &z2, w_bits, beta_w,
            ActSpec::Int { bits: a_bits, beta: beta_a, signed: signed_a },
            Some(bias), g.bool(),
        )
        .unwrap();
        assert!(plan.layers[0].packed.is_some()
                || plan.layers[0].kept.is_empty());
        let mut eng = Engine::new(Arc::new(plan));
        let x: Vec<f32> = (0..in_dim)
            .map(|_| {
                let v = g.f32_in(-beta_a, beta_a);
                if signed_a { v } else { v.abs() }
            })
            .collect();
        let yi = eng.infer(&x).unwrap();
        let yf = eng.infer_reference(&x).unwrap();
        for (a, b) in yi.iter().zip(&yf) {
            let tol = 1e-4 * (1.0 + b.abs());
            if (a - b).abs() > tol {
                return PropResult::Fail(format!(
                    "w{w_bits}a{a_bits} {in_dim}x{out_dim}: int {a} \
                     vs f32 {b}"));
            }
        }
        PropResult::Pass
    });
}

#[test]
fn int8_layer_matches_bb_quantize_host_oracle() {
    // A fully-open 8-bit configuration cross-checked against the host
    // oracle that the runtime parity suite itself is pinned to.
    let in_dim = 24;
    let out_dim = 6;
    let beta_w = 1.0f32;
    let beta_a = 2.0f32;
    let mut rng = bayesian_bits::rng::Pcg64::new(17);
    let w: Vec<f32> =
        (0..in_dim * out_dim).map(|_| rng.normal() * 0.6).collect();
    let x: Vec<f32> =
        (0..in_dim).map(|_| (rng.normal() * 0.8).abs()).collect();
    let z2 = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, 0.0]; // last channel pruned

    let plan = build_plan_single(
        "oracle", &w, in_dim, out_dim, &z2, 8, beta_w,
        ActSpec::Int { bits: 8, beta: beta_a, signed: false }, None,
        false,
    )
    .unwrap();
    let mut eng = Engine::new(Arc::new(plan));
    let y = eng.infer(&x).unwrap();

    // oracle: simulated-quant weights (8 bits = z4, z8 open) and
    // activations, f32 GEMM
    let wcfg = QuantConfig::new(true, &[2, 4, 8, 16, 32]);
    let acfg = QuantConfig::new(false, &[2, 4, 8, 16, 32]);
    let zh8 = [1.0f32, 1.0, 0.0, 0.0];
    let w_sim =
        bb_quantize_host(&w, out_dim, beta_w, &z2, &zh8, &wcfg);
    let a_sim =
        bb_quantize_host(&x, 1, beta_a, &[1.0], &zh8, &acfg);
    for r in 0..out_dim {
        let want: f32 = (0..in_dim)
            .map(|c| w_sim[r * in_dim + c] * a_sim[c])
            .sum();
        let tol = 1e-4 * (1.0 + want.abs());
        assert!((y[r] - want).abs() < tol,
                "row {r}: engine {} vs oracle {want}", y[r]);
    }
    // the pruned channel is exactly zero on both paths
    assert_eq!(y[out_dim - 1], 0.0);
}

/// A hand-built single-dense-layer Bayesian-Bits manifest whose phi
/// logits threshold to: weights 8-bit with channel 3 pruned,
/// activations 8-bit. Weight shape is channel-last `[6, 4]` to
/// exercise the lowering transpose.
fn tiny_manifest() -> Manifest {
    let text = r#"{
    "name":"tiny","engine":"bb","preset":"small","batch":2,
    "n_params":43,"n_slots":13,"input_shape":[6],"num_classes":4,
    "dataset":{"name":"mnist_like","input":[6,1,1],"classes":4,
               "train":8,"test":4},
    "params":[
     {"name":"a.w","shape":[6,4],"group":"w","offset":0,"size":24},
     {"name":"a.w.phi","shape":[8],"group":"g","offset":24,"size":8},
     {"name":"a.w.beta","shape":[1],"group":"s","offset":32,"size":1},
     {"name":"a.in.phi","shape":[5],"group":"g","offset":33,"size":5},
     {"name":"a.in.beta","shape":[1],"group":"s","offset":38,"size":1},
     {"name":"a.b","shape":[4],"group":"w","offset":39,"size":4}],
    "quantizers":[
     {"name":"a.w","kind":"w","signed":true,"channels":4,
      "levels":[2,4,8,16,32],"offset":0,"n_slots":8,
      "consumer_macs":24},
     {"name":"a.in","kind":"a","signed":false,"channels":1,
      "levels":[2,4,8,16,32],"offset":8,"n_slots":5,
      "consumer_macs":24}],
    "layers":[
     {"name":"a","kind":"dense","macs":24,"cin":6,"cout":4,
      "weight_q":"a.w","act_q":"a.in","residual_input":false}],
    "lam_base":[1,1,1,1,1,1,1,1,1,1,1,1,1],
    "hlo_train":"t.hlo.txt","hlo_eval":"e.hlo.txt",
    "init_file":"i.bin"}"#;
    Manifest::from_json(&Json::parse(text).unwrap(), Path::new("/tmp"))
        .unwrap()
}

fn tiny_params() -> Vec<f32> {
    let mut params = vec![0.0f32; 43];
    // a.w, stored [din=6, dout=4] (channel-last): w[i*4 + o]
    let mut rng = bayesian_bits::rng::Pcg64::new(23);
    for v in params[..24].iter_mut() {
        *v = rng.normal() * 0.5;
    }
    // a.w.phi: channels [open, open, open, pruned], chain z4,z8 open,
    // z16,z32 shut -> 8-bit weights, channel 3 elided
    let w_phi = [6.0, 6.0, 6.0, -6.0, 6.0, 6.0, -6.0, -6.0];
    params[24..32].copy_from_slice(&w_phi.map(|v| v as f32));
    params[32] = 1.0; // a.w.beta
    // a.in.phi: channel slot is mode-locked open; chain -> 8 bits
    let a_phi = [-6.0, 6.0, 6.0, -6.0, -6.0];
    params[33..38].copy_from_slice(&a_phi.map(|v| v as f32));
    params[38] = 2.0; // a.in.beta
    params[39..43].copy_from_slice(&[0.1, -0.2, 0.3, 0.5]); // a.b
    params
}

#[test]
fn lowering_reads_gates_weights_and_clip_ranges() {
    let man = tiny_manifest();
    let params = tiny_params();
    let plan = lower(&man, &params).unwrap();
    assert_eq!(plan.model, "tiny");
    assert_eq!(plan.input_dim, 6);
    assert_eq!(plan.output_dim, 4);
    let l = &plan.layers[0];
    assert_eq!(l.w_bits, 8);
    assert_eq!(l.kept, vec![0, 1, 2]); // channel 3 physically elided
    assert_eq!(l.in_dim, 6);
    let p = l.packed.as_ref().unwrap();
    assert_eq!((p.rows, p.cols, p.bits), (3, 6, 8));
    assert_eq!(l.act,
               ActSpec::Int { bits: 8, beta: 2.0, signed: false });
    assert_eq!(l.bias.as_deref(), Some(&[0.1, -0.2, 0.3, 0.5][..]));
    assert!(!l.relu); // single (= last) layer emits raw logits
    // packed codes store 3 of 4 rows at one byte per weight
    assert!(l.packed_bytes() < l.dense_bytes());

    // transpose check: row 0 of the plan is column 0 of the stored
    // [6, 4] tensor, quantized on the learned grid
    let eng_w = &l.f32_rows[..6];
    let (step, codes) =
        bayesian_bits::quant::grid::quantize_codes_host(
            &(0..6).map(|i| params[i * 4]).collect::<Vec<f32>>(),
            1.0, 8, true);
    for (got, q) in eng_w.iter().zip(&codes) {
        assert_eq!(*got, step * *q as f32);
    }

    // a parameter vector that does not match the manifest is rejected
    assert!(lower(&man, &params[..40]).is_err());
}

#[test]
fn checkpoint_to_serve_end_to_end_uses_integer_path() {
    let man = tiny_manifest();
    let params = tiny_params();

    // round-trip the trained state through the v2 checkpoint format
    let dir = std::env::temp_dir().join("bbits_engine_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("tiny.ckpt");
    let state = TrainState::from_params(params.clone());
    checkpoint::save(&ckpt, "tiny", &state).unwrap();
    let (model, restored) = checkpoint::load(&ckpt).unwrap();
    assert_eq!(model, "tiny");
    assert_eq!(restored.params, params);

    let plan = lower(&man, &restored.params).unwrap();
    // gated layer executes on packed integer weights
    assert!(plan.layers[0].packed.is_some());
    let plan = Arc::new(plan);

    let mut eng = Engine::new(plan.clone());
    let server = Server::start(
        plan.clone(),
        ServeConfig {
            workers: 2,
            queue_cap: 16,
            max_batch: 4,
            deadline: std::time::Duration::from_millis(1),
            ..ServeConfig::default()
        },
    )
    .unwrap();

    // batched responses are bit-identical to direct integer inference
    let inputs: Vec<Vec<f32>> = (0..9)
        .map(|i| {
            (0..6).map(|j| ((i * 6 + j) as f32 * 0.37).sin().abs())
                .collect()
        })
        .collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| server.submit(x.clone()).unwrap())
        .collect();
    for (t, x) in tickets.into_iter().zip(&inputs) {
        let got = t.wait().unwrap();
        let want = eng.infer(x).unwrap();
        assert_eq!(got, want);
        // pruned channel 3 carries only its bias on every request
        assert_eq!(got[3], 0.5);
        // integer path agrees with the f32 simulated-quant reference
        let reference = eng.infer_reference(x).unwrap();
        for (a, b) in got.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "int {a} vs ref {b}");
        }
    }

    // a concurrent closed-loop load completes without errors
    let stats = closed_loop(&server, 4, 10, 99).unwrap();
    assert_eq!(stats.errors, 0);
    assert!(stats.requests >= 40 + 9);
    let final_stats = server.shutdown();
    assert_eq!(final_stats.errors, 0);
    std::fs::remove_file(&ckpt).unwrap();
}
